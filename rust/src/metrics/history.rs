//! Time-series core: a bounded ring of periodic [`Registry`] snapshots
//! turned into windowed rates and SLO burn-rates.
//!
//! The coordinator runs a background sampler thread that captures one
//! [`Sample`] per cadence tick ([`DEFAULT_SAMPLE_PERIOD_S`]); the load
//! driver additionally pushes a sample per completed request so short
//! `tpcc load` runs produce a dense series. Samples are cumulative
//! counter snapshots — rates come from the *delta* between the newest
//! sample and the oldest sample inside a lookback window, so a wrapped
//! ring (old samples evicted) degrades gracefully: the window clamps to
//! whatever span is still retained and `window_s` in the output reports
//! the span actually used.
//!
//! Burn-rate follows the SRE convention: over a window, the fraction of
//! requests that missed the TTFT SLO divided by the error budget
//! ([`DEFAULT_SLO_ERROR_BUDGET`]). 1.0 means the service is consuming
//! its budget exactly at the sustainable pace; >1 means the budget
//! exhausts early.
//!
//! [`Registry`]: super::Registry

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Json};

/// Ring capacity: at the 4 Hz default cadence this retains ~34 minutes,
/// enough to cover the longest (30 m) burn-rate window.
pub const DEFAULT_HISTORY_CAP: usize = 8192;

/// Sampler cadence of the coordinator's background thread.
pub const DEFAULT_SAMPLE_PERIOD_S: f64 = 0.25;

/// Lookback windows rates and burn-rates are reported over. The short
/// window makes `tpcc load` smoke runs observable; 60/300/1800 are the
/// conventional 1m/5m/30m SLO windows.
pub const RATE_WINDOWS_S: [f64; 4] = [10.0, 60.0, 300.0, 1800.0];

/// Burn-rate windows (1m/5m/30m).
pub const BURN_WINDOWS_S: [f64; 3] = [60.0, 300.0, 1800.0];

/// Fraction of requests allowed to miss the TTFT SLO (99% goodput
/// target). Burn-rate 1.0 == missing exactly this fraction.
pub const DEFAULT_SLO_ERROR_BUDGET: f64 = 0.01;

/// Rows of the compact `recent` tail `GET /metrics/history` ships for
/// dashboard sparklines (~16 s at the default cadence).
pub const DEFAULT_RECENT_ROWS: usize = 64;

/// One cumulative snapshot of the registry's counters. Fixed fields
/// (no map) keep the ring footprint bounded: ~72 bytes per sample,
/// ~590 KiB at the default capacity.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Sample {
    /// Seconds since the ring's epoch (the registry's construction).
    pub t_s: f64,
    pub requests_received: u64,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub comm_bytes_sent: u64,
    pub comm_bytes_saved: u64,
    /// Cumulative TTFT observations (== first tokens produced).
    pub ttft_count: u64,
    /// Of those, how many met the TTFT SLO (== `ttft_count` when no SLO
    /// is set, so burn deltas read zero misses).
    pub ttft_slo_hits: u64,
    /// Cumulative KV-pool preemptions (alert engine's storm rate).
    pub preemptions: u64,
    /// Cumulative 503-shed connections (alert engine's saturation rate).
    pub sheds: u64,
}

/// Windowed rates derived from a pair of samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rates {
    /// The span actually used (≤ the requested window when the ring
    /// holds less history).
    pub window_s: f64,
    pub qps: f64,
    pub tokens_per_s: f64,
    pub prefill_tokens_per_s: f64,
    pub wire_gb_per_s: f64,
    pub saved_gb_per_s: f64,
    pub preemptions_per_s: f64,
    pub sheds_per_s: f64,
}

/// Bounded ring of [`Sample`]s with windowed delta queries. All pushes
/// and reads go through one mutex — the ring is touched a few times per
/// second, never per token.
pub struct MetricsHistory {
    inner: Mutex<VecDeque<Sample>>,
    cap: usize,
    epoch: Instant,
    evicted: AtomicU64,
}

impl Default for MetricsHistory {
    fn default() -> MetricsHistory {
        MetricsHistory::new(DEFAULT_HISTORY_CAP)
    }
}

impl MetricsHistory {
    pub fn new(cap: usize) -> MetricsHistory {
        MetricsHistory {
            inner: Mutex::new(VecDeque::with_capacity(cap.clamp(2, DEFAULT_HISTORY_CAP))),
            cap: cap.max(2),
            epoch: Instant::now(),
            evicted: AtomicU64::new(0),
        }
    }

    /// Seconds since this ring's epoch — the time base every sampler
    /// (coordinator thread, load driver) shares.
    pub fn elapsed_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Append a sample, evicting the oldest when full. Samples are
    /// expected in nondecreasing `t_s` order (all producers stamp from
    /// [`elapsed_s`](Self::elapsed_s)); an out-of-order push is dropped
    /// rather than corrupting window queries.
    pub fn push(&self, s: Sample) {
        let mut ring = self.inner.lock().unwrap();
        if let Some(last) = ring.back() {
            if s.t_s < last.t_s {
                return;
            }
        }
        if ring.len() == self.cap {
            ring.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(s);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Samples evicted from the front of the ring so far.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Time span currently retained (0 with fewer than two samples).
    pub fn span_s(&self) -> f64 {
        let ring = self.inner.lock().unwrap();
        match (ring.front(), ring.back()) {
            (Some(a), Some(b)) => b.t_s - a.t_s,
            _ => 0.0,
        }
    }

    pub fn latest(&self) -> Option<Sample> {
        self.inner.lock().unwrap().back().copied()
    }

    /// (oldest-within-window, newest) pair for a lookback of `window_s`
    /// seconds. When the ring retains less than the window, the oldest
    /// retained sample anchors the delta (clamped window). None with
    /// fewer than two samples.
    pub fn window_pair(&self, window_s: f64) -> Option<(Sample, Sample)> {
        self.window_pair_at(window_s, f64::NEG_INFINITY).map(|(a, b, _)| (a, b))
    }

    /// Gap-aware variant: the lookback is anchored at
    /// `max(newest.t_s, now_s)` instead of the newest sample, and the
    /// effective span (third tuple element) stretches to that anchor.
    /// When the sampler thread stalls, `now_s` keeps advancing while
    /// `newest.t_s` freezes — anchoring at the newest sample would make
    /// a pre-gap burst look like a *current* rate forever. Returns
    /// `(base, newest, span_s)`.
    pub fn window_pair_at(
        &self,
        window_s: f64,
        now_s: f64,
    ) -> Option<(Sample, Sample, f64)> {
        let ring = self.inner.lock().unwrap();
        let newest = *ring.back()?;
        if ring.len() < 2 {
            return None;
        }
        let now = newest.t_s.max(now_s);
        let cutoff = now - window_s;
        // the oldest sample at-or-after the cutoff, but never the
        // newest itself (a delta needs two distinct points); when the
        // whole ring predates the cutoff (long stall) the front anchors
        // and the widened span deflates the rate toward zero
        let mut base = *ring.front().unwrap();
        for s in ring.iter() {
            if s.t_s >= cutoff {
                base = *s;
                break;
            }
        }
        if base.t_s >= newest.t_s {
            base = ring[ring.len() - 2];
        }
        Some((base, newest, now - base.t_s))
    }

    /// Windowed rates, None with fewer than two samples or zero span.
    pub fn rates(&self, window_s: f64) -> Option<Rates> {
        self.rates_at(window_s, f64::NEG_INFINITY)
    }

    /// Gap-aware windowed rates: deltas divide by the stretched span
    /// from [`window_pair_at`](Self::window_pair_at), so a stalled
    /// sampler widens the window instead of reporting inflated rates.
    pub fn rates_at(&self, window_s: f64, now_s: f64) -> Option<Rates> {
        let (a, b, dt) = self.window_pair_at(window_s, now_s)?;
        if dt <= 0.0 {
            return None;
        }
        let d = |hi: u64, lo: u64| hi.saturating_sub(lo) as f64 / dt;
        Some(Rates {
            window_s: dt,
            qps: d(b.requests_completed, a.requests_completed),
            tokens_per_s: d(b.tokens_generated, a.tokens_generated),
            prefill_tokens_per_s: d(b.prefill_tokens, a.prefill_tokens),
            wire_gb_per_s: d(b.comm_bytes_sent, a.comm_bytes_sent) / 1e9,
            saved_gb_per_s: d(b.comm_bytes_saved, a.comm_bytes_saved) / 1e9,
            preemptions_per_s: d(b.preemptions, a.preemptions),
            sheds_per_s: d(b.sheds, a.sheds),
        })
    }

    /// TTFT-SLO burn-rate over a window: (missed / observed) / budget.
    /// 0.0 when no first tokens landed in the window; None with fewer
    /// than two samples or a non-positive budget.
    pub fn burn_rate(&self, window_s: f64, error_budget: f64) -> Option<f64> {
        self.burn_rate_at(window_s, error_budget, f64::NEG_INFINITY)
    }

    /// Gap-aware burn-rate: the lookback cutoff is anchored at `now_s`
    /// so a stalled sampler's stale misses age out of the window.
    pub fn burn_rate_at(
        &self,
        window_s: f64,
        error_budget: f64,
        now_s: f64,
    ) -> Option<f64> {
        if error_budget <= 0.0 {
            return None;
        }
        let (a, b, _) = self.window_pair_at(window_s, now_s)?;
        let observed = b.ttft_count.saturating_sub(a.ttft_count);
        if observed == 0 {
            return Some(0.0);
        }
        let hits = b.ttft_slo_hits.saturating_sub(a.ttft_slo_hits);
        let missed = observed.saturating_sub(hits);
        Some((missed as f64 / observed as f64) / error_budget)
    }

    /// Compact newest-last tail of the ring for dashboard sparklines:
    /// up to `last` rows of
    /// `[t_s, requests_completed, tokens_generated, comm_bytes_sent]`
    /// (cumulative counters — the consumer differentiates adjacent
    /// rows). Arrays, not objects: ~64 rows must stay cheap to ship on
    /// every `tpcc top` poll.
    pub fn recent(&self, last: usize) -> Vec<Json> {
        let ring = self.inner.lock().unwrap();
        let skip = ring.len().saturating_sub(last);
        ring.iter()
            .skip(skip)
            .map(|s| {
                Json::Arr(vec![
                    json::num(s.t_s),
                    json::num(s.requests_completed as f64),
                    json::num(s.tokens_generated as f64),
                    json::num(s.comm_bytes_sent as f64),
                ])
            })
            .collect()
    }

    /// The `GET /metrics/history` body. `slo_ttft_s` <= 0 suppresses
    /// burn-rates (no SLO to burn against). Rates and burn-rates are
    /// anchored at the current clock ([`elapsed_s`](Self::elapsed_s)),
    /// so a stalled sampler reads as decaying rates, not frozen ones.
    pub fn to_json(&self, slo_ttft_s: f64) -> Json {
        let now_s = self.elapsed_s();
        let rates = RATE_WINDOWS_S
            .iter()
            .map(|&w| match self.rates_at(w, now_s) {
                Some(r) => json::obj(vec![
                    ("requested_window_s", json::num(w)),
                    ("window_s", json::num(r.window_s)),
                    ("qps", json::num(r.qps)),
                    ("tokens_per_s", json::num(r.tokens_per_s)),
                    ("prefill_tokens_per_s", json::num(r.prefill_tokens_per_s)),
                    ("wire_gb_per_s", json::num(r.wire_gb_per_s)),
                    ("saved_gb_per_s", json::num(r.saved_gb_per_s)),
                    ("preemptions_per_s", json::num(r.preemptions_per_s)),
                    ("sheds_per_s", json::num(r.sheds_per_s)),
                ]),
                None => json::obj(vec![
                    ("requested_window_s", json::num(w)),
                    ("window_s", Json::Null),
                ]),
            })
            .collect();
        let burn = BURN_WINDOWS_S
            .iter()
            .map(|&w| {
                let rate = if slo_ttft_s > 0.0 {
                    self.burn_rate_at(w, DEFAULT_SLO_ERROR_BUDGET, now_s)
                } else {
                    None
                };
                json::obj(vec![
                    ("window_s", json::num(w)),
                    ("burn_rate", rate.map(json::num).unwrap_or(Json::Null)),
                ])
            })
            .collect();
        let last = match self.latest() {
            Some(s) => json::obj(vec![
                ("t_s", json::num(s.t_s)),
                ("requests_received", json::num(s.requests_received as f64)),
                ("requests_completed", json::num(s.requests_completed as f64)),
                ("tokens_generated", json::num(s.tokens_generated as f64)),
                ("prefill_tokens", json::num(s.prefill_tokens as f64)),
                ("comm_bytes_sent", json::num(s.comm_bytes_sent as f64)),
                ("comm_bytes_saved", json::num(s.comm_bytes_saved as f64)),
                ("ttft_count", json::num(s.ttft_count as f64)),
                ("ttft_slo_hits", json::num(s.ttft_slo_hits as f64)),
                ("preemptions", json::num(s.preemptions as f64)),
                ("sheds", json::num(s.sheds as f64)),
            ]),
            None => Json::Null,
        };
        json::obj(vec![
            ("samples", json::num(self.len() as f64)),
            ("capacity", json::num(self.cap as f64)),
            ("evicted", json::num(self.evicted() as f64)),
            ("span_s", json::num(self.span_s())),
            ("sample_period_s", json::num(DEFAULT_SAMPLE_PERIOD_S)),
            ("slo_ttft_s", if slo_ttft_s > 0.0 { json::num(slo_ttft_s) } else { Json::Null }),
            ("slo_error_budget", json::num(DEFAULT_SLO_ERROR_BUDGET)),
            ("rates", Json::Arr(rates)),
            ("burn", Json::Arr(burn)),
            ("recent", Json::Arr(self.recent(DEFAULT_RECENT_ROWS))),
            ("last", last),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(t: f64, completed: u64, tokens: u64) -> Sample {
        Sample {
            t_s: t,
            requests_completed: completed,
            requests_received: completed,
            tokens_generated: tokens,
            ..Sample::default()
        }
    }

    #[test]
    fn ring_wraps_and_evicts_oldest() {
        let h = MetricsHistory::new(4);
        for i in 0..10u64 {
            h.push(s(i as f64, i, 0));
        }
        assert_eq!(h.len(), 4);
        assert_eq!(h.evicted(), 6);
        // front is t=6 after eviction
        let (a, b) = h.window_pair(1e9).unwrap();
        assert_eq!(a.t_s, 6.0);
        assert_eq!(b.t_s, 9.0);
        assert_eq!(h.span_s(), 3.0);
    }

    #[test]
    fn rates_across_wrapped_window_clamp_to_retained_span() {
        let h = MetricsHistory::new(4);
        // 10 completed per second, 100 tokens per second
        for i in 0..20u64 {
            h.push(s(i as f64, 10 * i, 100 * i));
        }
        // a 1-hour window only has t=16..19 retained: still 10 qps
        let r = h.rates(3600.0).unwrap();
        assert_eq!(r.window_s, 3.0);
        assert!((r.qps - 10.0).abs() < 1e-9, "qps {}", r.qps);
        assert!((r.tokens_per_s - 100.0).abs() < 1e-9);
        // a 2-second window uses only the tail
        let r2 = h.rates(2.0).unwrap();
        assert_eq!(r2.window_s, 2.0);
        assert!((r2.qps - 10.0).abs() < 1e-9);
    }

    #[test]
    fn out_of_order_pushes_are_dropped() {
        let h = MetricsHistory::new(8);
        h.push(s(5.0, 1, 0));
        h.push(s(3.0, 2, 0)); // dropped
        h.push(s(6.0, 3, 0));
        assert_eq!(h.len(), 2);
        assert_eq!(h.latest().unwrap().t_s, 6.0);
    }

    #[test]
    fn burn_rate_against_known_stream() {
        let h = MetricsHistory::new(64);
        // 100 first-tokens per tick; miss rate ramps from 0 to 2%
        let mut count = 0u64;
        let mut hits = 0u64;
        for i in 0..10u64 {
            count += 100;
            hits += if i < 5 { 100 } else { 98 }; // 2% misses in back half
            h.push(Sample {
                t_s: i as f64,
                ttft_count: count,
                ttft_slo_hits: hits,
                ..Sample::default()
            });
        }
        // whole window: 10 misses / 1000 observed = 1% => burn 1.0 at 1% budget
        let b = h.burn_rate(1e9, 0.01).unwrap();
        assert!((b - 1.0).abs() < 1e-9, "burn {b}");
        // tail window (last 4 ticks): 8 misses / 400 = 2% => burn 2.0
        let b4 = h.burn_rate(4.0, 0.01).unwrap();
        assert!((b4 - 2.0).abs() < 1e-9, "burn {b4}");
        // zero-budget is undefined
        assert!(h.burn_rate(4.0, 0.0).is_none());
    }

    #[test]
    fn burn_rate_zero_when_no_traffic() {
        let h = MetricsHistory::new(8);
        h.push(Sample { t_s: 0.0, ..Sample::default() });
        h.push(Sample { t_s: 1.0, ..Sample::default() });
        assert_eq!(h.burn_rate(60.0, 0.01), Some(0.0));
    }

    #[test]
    fn empty_and_single_sample_report_none() {
        let h = MetricsHistory::default();
        assert!(h.rates(60.0).is_none());
        assert!(h.burn_rate(60.0, 0.01).is_none());
        h.push(s(0.0, 1, 1));
        assert!(h.rates(60.0).is_none());
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn stalled_sampler_widens_window_instead_of_inflating_rates() {
        let h = MetricsHistory::new(64);
        // a 10-second burst at 10 qps, then the sampler stalls
        for i in 0..=10u64 {
            h.push(s(i as f64, 10 * i, 100 * i));
        }
        // anchored at the newest sample the burst reads 10 qps
        let r = h.rates_at(10.0, 10.0).unwrap();
        assert!((r.qps - 10.0).abs() < 1e-9, "qps {}", r.qps);
        // 90 seconds into the stall, a 10 s lookback holds no samples:
        // the window stretches back to the retained ring and the burst
        // is amortized over the full 100 s, not reported as current
        let r = h.rates_at(10.0, 100.0).unwrap();
        assert!((r.window_s - 100.0).abs() < 1e-9, "window {}", r.window_s);
        assert!((r.qps - 1.0).abs() < 1e-9, "stale qps must deflate, got {}", r.qps);
        // a window long enough to reach back into the data still
        // anchors the cutoff at now: 95 s lookback from t=100 keeps
        // base at t=5, span 95
        let r = h.rates_at(95.0, 100.0).unwrap();
        assert!((r.window_s - 95.0).abs() < 1e-9, "window {}", r.window_s);
        assert!(((r.qps) - (50.0 / 95.0)).abs() < 1e-9, "qps {}", r.qps);
        // the non-_at entry points are unchanged (newest-anchored)
        let r = h.rates(10.0).unwrap();
        assert!((r.qps - 10.0).abs() < 1e-9);
    }

    #[test]
    fn preemption_and_shed_rates_from_samples() {
        let h = MetricsHistory::new(16);
        for i in 0..=4u64 {
            h.push(Sample {
                t_s: i as f64,
                preemptions: 3 * i,
                sheds: i,
                ..Sample::default()
            });
        }
        let r = h.rates(10.0).unwrap();
        assert!((r.preemptions_per_s - 3.0).abs() < 1e-9);
        assert!((r.sheds_per_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recent_tail_is_compact_and_newest_last() {
        let h = MetricsHistory::new(128);
        for i in 0..100u64 {
            h.push(s(i as f64, i, 2 * i));
        }
        let rows = h.recent(8);
        assert_eq!(rows.len(), 8);
        let first = rows[0].as_arr().unwrap();
        let last = rows[7].as_arr().unwrap();
        assert_eq!(first[0].as_f64(), Some(92.0));
        assert_eq!(last[0].as_f64(), Some(99.0));
        assert_eq!(last[1].as_f64(), Some(99.0)); // requests_completed
        assert_eq!(last[2].as_f64(), Some(198.0)); // tokens_generated
        // and it rides along in the JSON body
        let j = h.to_json(0.0);
        let recent = j.get("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), DEFAULT_RECENT_ROWS);
    }

    #[test]
    fn history_json_shape() {
        let h = MetricsHistory::new(8);
        h.push(s(0.0, 0, 0));
        h.push(s(2.0, 10, 200));
        let j = h.to_json(0.25);
        let body = j.to_string();
        let parsed = Json::parse(&body).unwrap();
        assert_eq!(parsed.get("samples").unwrap().as_i64(), Some(2));
        let rates = parsed.get("rates").unwrap().as_arr().unwrap();
        assert_eq!(rates.len(), RATE_WINDOWS_S.len());
        assert_eq!(rates[0].get("qps").unwrap().as_f64(), Some(5.0));
        assert_eq!(rates[0].get("tokens_per_s").unwrap().as_f64(), Some(100.0));
        let burn = parsed.get("burn").unwrap().as_arr().unwrap();
        assert_eq!(burn.len(), BURN_WINDOWS_S.len());
        assert_eq!(parsed.get("last").unwrap().get("t_s").unwrap().as_f64(), Some(2.0));
        // no SLO => burn entries null
        let j2 = h.to_json(0.0);
        assert_eq!(j2.get("burn").unwrap().idx(0).unwrap().get("burn_rate"), Some(&Json::Null));
    }
}
