//! Telemetry: counters, gauges and latency histograms with percentile
//! queries. Lock-free-ish (atomics for counters, mutex for histograms —
//! histograms are touched once per request, not per token).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::{self, Json};
use crate::workload::stats::LogHistogram;

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bounded-memory recording histogram, backed by the mergeable
/// log-bucketed [`LogHistogram`]: O(1) record, O(buckets) quantile,
/// fixed footprint no matter how long the server runs. Quantiles carry
/// the bucket layout's bounded relative error
/// ([`crate::workload::stats::GROWTH`], ~4.4%); min/max/mean/stddev are
/// exact. Non-finite samples are rejected — a NaN latency (e.g. from a
/// request that never produced a token) must not poison `/metrics`.
#[derive(Default)]
pub struct Histogram {
    inner: Mutex<LogHistogram>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.inner.lock().unwrap().record(v);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().count() as usize
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot { h: self.inner.lock().unwrap().clone() }
    }

    pub fn clear(&self) {
        *self.inner.lock().unwrap() = LogHistogram::new();
    }
}

pub struct HistogramSnapshot {
    h: LogHistogram,
}

impl HistogramSnapshot {
    pub fn count(&self) -> usize {
        self.h.count() as usize
    }
    pub fn percentile(&self, p: f64) -> f64 {
        self.h.percentile(p)
    }
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn mean(&self) -> f64 {
        self.h.mean()
    }
    pub fn min(&self) -> f64 {
        self.h.min()
    }
    pub fn max(&self) -> f64 {
        self.h.max()
    }
    pub fn stddev(&self) -> f64 {
        self.h.stddev()
    }
    /// Fraction of samples `<= threshold` (NaN when empty) — goodput
    /// when `threshold` is a latency SLO.
    pub fn fraction_below(&self, threshold: f64) -> f64 {
        self.h.fraction_below(threshold)
    }
}

/// The serving stack's metric registry (one per coordinator).
#[derive(Default)]
pub struct Registry {
    pub requests_received: Counter,
    pub requests_completed: Counter,
    pub tokens_generated: Counter,
    pub prefill_tokens: Counter,
    pub batches_executed: Counter,
    pub comm_bytes_sent: Counter,
    pub comm_bytes_saved: Counter,
    pub kv_blocks_in_use: Counter,
    pub ttft: Histogram,
    pub tpot: Histogram,
    pub e2e_latency: Histogram,
    pub queue_wait: Histogram,
    /// TTFT SLO (f64 bits; 0 = unset) the `ttft_goodput` metric is
    /// measured against
    slo_ttft_bits: AtomicU64,
    custom: Mutex<BTreeMap<String, f64>>,
}

impl Registry {
    pub fn set(&self, key: &str, v: f64) {
        self.custom.lock().unwrap().insert(key.to_string(), v);
    }

    /// Set the TTFT SLO that `/metrics` reports goodput against.
    pub fn set_ttft_slo(&self, slo_s: f64) {
        self.slo_ttft_bits.store(slo_s.to_bits(), Ordering::Relaxed);
    }

    pub fn ttft_slo(&self) -> f64 {
        f64::from_bits(self.slo_ttft_bits.load(Ordering::Relaxed))
    }

    /// JSON snapshot served at `/metrics`. Percentiles of empty
    /// histograms serialize as `null` (never `NaN` — invalid JSON).
    pub fn to_json(&self) -> Json {
        let ttft = self.ttft.snapshot();
        let tpot = self.tpot.snapshot();
        let e2e = self.e2e_latency.snapshot();
        let qw = self.queue_wait.snapshot();
        let mut pairs = vec![
            ("requests_received", json::num(self.requests_received.get() as f64)),
            ("requests_completed", json::num(self.requests_completed.get() as f64)),
            ("tokens_generated", json::num(self.tokens_generated.get() as f64)),
            ("prefill_tokens", json::num(self.prefill_tokens.get() as f64)),
            ("batches_executed", json::num(self.batches_executed.get() as f64)),
            ("comm_bytes_sent", json::num(self.comm_bytes_sent.get() as f64)),
            ("comm_bytes_saved", json::num(self.comm_bytes_saved.get() as f64)),
            ("ttft_p50_s", json::num_or_null(ttft.percentile(50.0))),
            ("ttft_p95_s", json::num_or_null(ttft.percentile(95.0))),
            ("ttft_p99_s", json::num_or_null(ttft.percentile(99.0))),
            ("tpot_p50_s", json::num_or_null(tpot.percentile(50.0))),
            ("e2e_p50_s", json::num_or_null(e2e.percentile(50.0))),
            ("e2e_p95_s", json::num_or_null(e2e.percentile(95.0))),
            ("e2e_p99_s", json::num_or_null(e2e.percentile(99.0))),
            ("queue_wait_p50_s", json::num_or_null(qw.percentile(50.0))),
            ("queue_wait_p95_s", json::num_or_null(qw.percentile(95.0))),
            ("queue_wait_p99_s", json::num_or_null(qw.percentile(99.0))),
        ];
        let slo = self.ttft_slo();
        if slo > 0.0 {
            pairs.push(("ttft_slo_s", json::num(slo)));
            // fraction of completed requests meeting the TTFT SLO
            pairs.push(("ttft_goodput", json::num_or_null(ttft.fraction_below(slo))));
        }
        let custom = self.custom.lock().unwrap();
        for (k, v) in custom.iter() {
            pairs.push((k.as_str(), json::num_or_null(*v)));
        }
        let mut obj = BTreeMap::new();
        for (k, v) in pairs {
            obj.insert(k.to_string(), v);
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        // log-bucketed backing: quantiles are exact to within one
        // bucket (GROWTH ≈ 4.4% relative), extremes and mean exact
        let growth = crate::workload::stats::GROWTH;
        for (p, exact) in [(50.0, 50.0), (95.0, 95.0)] {
            let got = s.percentile(p);
            assert!(
                got / exact <= growth + 1e-9 && exact / got <= growth + 1e-9,
                "p{p}: got {got}"
            );
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.stddev() - (83325.0f64 / 99.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = Histogram::default();
        assert!(h.snapshot().percentile(50.0).is_nan());
    }

    #[test]
    fn non_finite_samples_are_rejected_not_recorded() {
        // the old exact-sample histogram panicked in snapshot() when a
        // NaN hit partial_cmp; now NaN/Inf never enter the histogram
        let h = Histogram::default();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(0.5);
        assert_eq!(h.count(), 1);
        let s = h.snapshot();
        assert_eq!(s.percentile(50.0), 0.5);
        assert_eq!(s.max(), 0.5);
        h.clear();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn registry_json() {
        let r = Registry::default();
        r.requests_received.inc();
        r.ttft.record(0.25);
        r.set("custom_metric", 1.5);
        let j = r.to_json();
        assert_eq!(j.get("requests_received").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("ttft_p50_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("ttft_p99_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("custom_metric").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn empty_registry_serializes_valid_json() {
        // empty histograms must serialize percentiles as null, not NaN
        let r = Registry::default();
        let body = r.to_json().to_string();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ttft_p50_s"), Some(&Json::Null));
        assert_eq!(j.get("queue_wait_p99_s"), Some(&Json::Null));
        // no SLO set: goodput absent
        assert!(j.get("ttft_goodput").is_none());
    }

    #[test]
    fn goodput_against_slo() {
        let r = Registry::default();
        r.set_ttft_slo(0.25);
        for v in [0.1, 0.2, 0.3, 0.4] {
            r.ttft.record(v);
        }
        let j = r.to_json();
        assert_eq!(j.get("ttft_slo_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("ttft_goodput").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("queue_wait_p50_s"), Some(&Json::Null));
    }

    #[test]
    fn fraction_below_bounds() {
        let h = Histogram::default();
        assert!(h.snapshot().fraction_below(1.0).is_nan());
        for i in 1..=10 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.fraction_below(0.5), 0.0);
        assert_eq!(s.fraction_below(5.0), 0.5);
        assert_eq!(s.fraction_below(100.0), 1.0);
    }
}
