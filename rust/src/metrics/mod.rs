//! Telemetry: counters, gauges and latency histograms with percentile
//! queries. Lock-free-ish (atomics for counters, mutex for histograms —
//! histograms are touched once per request, not per token).
//!
//! [`history`] holds the time-series layer: a bounded ring of periodic
//! [`Registry`] snapshots ([`Registry::sample_history`]) the coordinator's
//! sampler thread and the load driver both publish into, serving windowed
//! rates and SLO burn-rates at `GET /metrics/history`.

pub mod history;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::json::{self, Json};
use crate::workload::stats::LogHistogram;

pub use history::{MetricsHistory, Rates, Sample, DEFAULT_SAMPLE_PERIOD_S};

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable up/down gauge. Cloneable — the shared atomic lets the
/// registry hand a live handle into the subsystem that owns the
/// underlying resource (e.g. [`crate::tp::kv::BatchKv`] carrying the
/// `kv_blocks_in_use` gauge), so the value can never drift from the
/// allocation/free events it mirrors.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    pub fn dec(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bounded-memory recording histogram, backed by the mergeable
/// log-bucketed [`LogHistogram`]: O(1) record, O(buckets) quantile,
/// fixed footprint no matter how long the server runs. Quantiles carry
/// the bucket layout's bounded relative error
/// ([`crate::workload::stats::GROWTH`], ~4.4%); min/max/mean/stddev are
/// exact. Non-finite samples are rejected — a NaN latency (e.g. from a
/// request that never produced a token) must not poison `/metrics`.
#[derive(Default)]
pub struct Histogram {
    inner: Mutex<LogHistogram>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.inner.lock().unwrap().record(v);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn count(&self) -> usize {
        self.inner.lock().unwrap().count() as usize
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot { h: self.inner.lock().unwrap().clone() }
    }

    pub fn clear(&self) {
        *self.inner.lock().unwrap() = LogHistogram::new();
    }
}

pub struct HistogramSnapshot {
    h: LogHistogram,
}

impl HistogramSnapshot {
    pub fn count(&self) -> usize {
        self.h.count() as usize
    }
    pub fn percentile(&self, p: f64) -> f64 {
        self.h.percentile(p)
    }
    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn mean(&self) -> f64 {
        self.h.mean()
    }
    pub fn min(&self) -> f64 {
        self.h.min()
    }
    pub fn max(&self) -> f64 {
        self.h.max()
    }
    pub fn stddev(&self) -> f64 {
        self.h.stddev()
    }
    /// Fraction of samples `<= threshold` (NaN when empty) — goodput
    /// when `threshold` is a latency SLO.
    pub fn fraction_below(&self, threshold: f64) -> f64 {
        self.h.fraction_below(threshold)
    }
    /// Exact sum of all recorded samples (Prometheus summary `_sum`).
    pub fn sum(&self) -> f64 {
        self.h.sum()
    }
}

/// Keys `to_json` emits from built-in registry state. Custom entries
/// merge into the same output map *after* these, so an unguarded
/// `set("ttft_p50_s", …)` would silently shadow the real percentile —
/// [`Registry::set`] quarantines colliding keys instead.
const BUILTIN_KEYS: &[&str] = &[
    "requests_received",
    "requests_completed",
    "requests_shed",
    "http_requests",
    "uptime_seconds",
    "build_version",
    "build_git",
    "tokens_generated",
    "prefill_tokens",
    "batches_executed",
    "comm_bytes_sent",
    "comm_bytes_saved",
    "preemptions_total",
    "kv_blocks_in_use",
    "kv_blocks_free",
    "ttft_p50_s",
    "ttft_p95_s",
    "ttft_p99_s",
    "tpot_p50_s",
    "tpot_p90_s",
    "tpot_p99_s",
    "e2e_p50_s",
    "e2e_p95_s",
    "e2e_p99_s",
    "queue_wait_p50_s",
    "queue_wait_p95_s",
    "queue_wait_p99_s",
    "ttft_slo_s",
    "ttft_goodput",
];

/// The serving stack's metric registry (one per coordinator).
#[derive(Default)]
pub struct Registry {
    pub requests_received: Counter,
    pub requests_completed: Counter,
    pub tokens_generated: Counter,
    pub prefill_tokens: Counter,
    pub batches_executed: Counter,
    pub comm_bytes_sent: Counter,
    pub comm_bytes_saved: Counter,
    /// Sessions evicted from the paged KV pool (blocks swapped out,
    /// session requeued for restore).
    pub preemptions_total: Counter,
    /// Connections answered `503` because the server's pending queue
    /// was full (the accept loop sheds instead of backlogging).
    pub requests_shed: Counter,
    /// Per-(endpoint, status) request counts — the server records one
    /// entry per answered connection. Keys are normalized route
    /// literals (bounded cardinality), never raw request paths.
    http: Mutex<BTreeMap<(String, u16), u64>>,
    /// KV blocks currently mapped into session block tables. A real
    /// gauge: the coordinator clones it into its decode
    /// [`crate::tp::kv::BatchKv`], which moves it on every block
    /// map/unmap, so the value can never drift from the allocator.
    pub kv_blocks_in_use: Gauge,
    /// KV blocks on the pool's free list (the same allocator carries
    /// this handle; in_use + free == pool size at rest).
    pub kv_blocks_free: Gauge,
    pub ttft: Histogram,
    /// Inter-token gaps, one sample per decode step per session (a real
    /// distribution, not the per-request mean).
    pub tpot: Histogram,
    pub e2e_latency: Histogram,
    pub queue_wait: Histogram,
    /// Bounded ring of periodic snapshots behind `GET /metrics/history`.
    pub history: MetricsHistory,
    /// TTFT SLO (f64 bits; 0 = unset) the `ttft_goodput` metric is
    /// measured against
    slo_ttft_bits: AtomicU64,
    custom: Mutex<BTreeMap<String, f64>>,
}

impl Registry {
    /// Record a custom gauge. A key that would shadow a built-in
    /// `/metrics` field is stored under `custom_<key>` instead of
    /// overwriting the real metric.
    pub fn set(&self, key: &str, v: f64) {
        let key = if BUILTIN_KEYS.contains(&key) {
            format!("custom_{key}")
        } else {
            key.to_string()
        };
        self.custom.lock().unwrap().insert(key, v);
    }

    /// Read back a custom gauge (e.g. the drift sentinel's
    /// `drift_sites_tripped` mirror, consumed by the alert engine).
    pub fn get_custom(&self, key: &str) -> Option<f64> {
        self.custom.lock().unwrap().get(key).copied()
    }

    /// Count one answered HTTP request against a (route, status) pair.
    /// `path` must be a normalized route literal — the server maps
    /// unknown paths to `(other)`, parse failures to `(malformed)` and
    /// queue-full sheds to `(shed)` — so cardinality stays bounded.
    pub fn record_http(&self, path: &str, status: u16) {
        *self.http.lock().unwrap().entry((path.to_string(), status)).or_insert(0) += 1;
    }

    /// Snapshot of the per-(route, status) request counts.
    pub fn http_requests(&self) -> Vec<(String, u16, u64)> {
        self.http
            .lock()
            .unwrap()
            .iter()
            .map(|((p, s), n)| (p.clone(), *s, *n))
            .collect()
    }

    /// Capture one cumulative [`Sample`] of this registry into the
    /// time-series ring, stamped on the ring's own clock. Called by the
    /// coordinator's sampler thread at the
    /// [`DEFAULT_SAMPLE_PERIOD_S`] cadence and by the load driver per
    /// completed request.
    pub fn sample_history(&self) {
        let ttft = self.ttft.snapshot();
        let slo = self.ttft_slo();
        let count = ttft.count() as u64;
        // With no SLO set every first token counts as a hit, so burn
        // deltas read zero misses.
        let hits = if slo > 0.0 && count > 0 {
            (ttft.fraction_below(slo) * count as f64).round() as u64
        } else {
            count
        };
        self.history.push(Sample {
            t_s: self.history.elapsed_s(),
            requests_received: self.requests_received.get(),
            requests_completed: self.requests_completed.get(),
            tokens_generated: self.tokens_generated.get(),
            prefill_tokens: self.prefill_tokens.get(),
            comm_bytes_sent: self.comm_bytes_sent.get(),
            comm_bytes_saved: self.comm_bytes_saved.get(),
            ttft_count: count,
            ttft_slo_hits: hits,
            preemptions: self.preemptions_total.get(),
            sheds: self.requests_shed.get(),
        });
    }

    /// The `GET /metrics/history` body.
    pub fn history_json(&self) -> Json {
        self.history.to_json(self.ttft_slo())
    }

    /// Set the TTFT SLO that `/metrics` reports goodput against.
    pub fn set_ttft_slo(&self, slo_s: f64) {
        self.slo_ttft_bits.store(slo_s.to_bits(), Ordering::Relaxed);
    }

    pub fn ttft_slo(&self) -> f64 {
        f64::from_bits(self.slo_ttft_bits.load(Ordering::Relaxed))
    }

    /// JSON snapshot served at `/metrics`. Percentiles of empty
    /// histograms serialize as `null` (never `NaN` — invalid JSON).
    pub fn to_json(&self) -> Json {
        let ttft = self.ttft.snapshot();
        let tpot = self.tpot.snapshot();
        let e2e = self.e2e_latency.snapshot();
        let qw = self.queue_wait.snapshot();
        let mut pairs = vec![
            ("requests_received", json::num(self.requests_received.get() as f64)),
            ("requests_completed", json::num(self.requests_completed.get() as f64)),
            ("tokens_generated", json::num(self.tokens_generated.get() as f64)),
            ("prefill_tokens", json::num(self.prefill_tokens.get() as f64)),
            ("batches_executed", json::num(self.batches_executed.get() as f64)),
            ("comm_bytes_sent", json::num(self.comm_bytes_sent.get() as f64)),
            ("comm_bytes_saved", json::num(self.comm_bytes_saved.get() as f64)),
            ("preemptions_total", json::num(self.preemptions_total.get() as f64)),
            ("requests_shed", json::num(self.requests_shed.get() as f64)),
            ("uptime_seconds", json::num(self.history.elapsed_s())),
            ("build_version", json::s(build_version())),
            ("build_git", json::s(build_git())),
            ("kv_blocks_in_use", json::num(self.kv_blocks_in_use.get() as f64)),
            ("kv_blocks_free", json::num(self.kv_blocks_free.get() as f64)),
            ("ttft_p50_s", json::num_or_null(ttft.percentile(50.0))),
            ("ttft_p95_s", json::num_or_null(ttft.percentile(95.0))),
            ("ttft_p99_s", json::num_or_null(ttft.percentile(99.0))),
            ("tpot_p50_s", json::num_or_null(tpot.percentile(50.0))),
            ("tpot_p90_s", json::num_or_null(tpot.percentile(90.0))),
            ("tpot_p99_s", json::num_or_null(tpot.percentile(99.0))),
            ("e2e_p50_s", json::num_or_null(e2e.percentile(50.0))),
            ("e2e_p95_s", json::num_or_null(e2e.percentile(95.0))),
            ("e2e_p99_s", json::num_or_null(e2e.percentile(99.0))),
            ("queue_wait_p50_s", json::num_or_null(qw.percentile(50.0))),
            ("queue_wait_p95_s", json::num_or_null(qw.percentile(95.0))),
            ("queue_wait_p99_s", json::num_or_null(qw.percentile(99.0))),
        ];
        let slo = self.ttft_slo();
        if slo > 0.0 {
            pairs.push(("ttft_slo_s", json::num(slo)));
            // fraction of completed requests meeting the TTFT SLO
            pairs.push(("ttft_goodput", json::num_or_null(ttft.fraction_below(slo))));
        }
        // per-(route, status) request counts as a nested object:
        // {"/generate": {"200": 5, "503": 1}, ...}
        let http = self.http_requests();
        let mut by_path: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
        for (path, status, n) in http {
            by_path
                .entry(path)
                .or_default()
                .insert(status.to_string(), json::num(n as f64));
        }
        let http_obj: BTreeMap<String, Json> =
            by_path.into_iter().map(|(p, statuses)| (p, Json::Obj(statuses))).collect();
        pairs.push(("http_requests", Json::Obj(http_obj)));
        let custom = self.custom.lock().unwrap();
        for (k, v) in custom.iter() {
            pairs.push((k.as_str(), json::num_or_null(*v)));
        }
        let mut obj = BTreeMap::new();
        for (k, v) in pairs {
            obj.insert(k.to_string(), v);
        }
        Json::Obj(obj)
    }

    /// Prometheus text exposition (format 0.0.4), served at
    /// `GET /metrics?format=prom`. Built-in counters keep their JSON
    /// names under a `tpcc_` prefix; latency histograms export as
    /// summaries (`quantile` labels + `_sum`/`_count`); custom entries
    /// export as gauges with invalid name characters mapped to `_`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP tpcc_{name} {help}\n# TYPE tpcc_{name} counter\ntpcc_{name} {v}\n"
            ));
        };
        counter(
            "requests_received",
            "Requests accepted by the coordinator.",
            self.requests_received.get(),
        );
        counter("requests_completed", "Requests fully generated.", self.requests_completed.get());
        counter("tokens_generated", "Decode tokens produced.", self.tokens_generated.get());
        counter("prefill_tokens", "Prompt tokens prefilled.", self.prefill_tokens.get());
        counter("batches_executed", "Decode batches executed.", self.batches_executed.get());
        counter(
            "comm_bytes_sent",
            "Collective wire bytes actually sent.",
            self.comm_bytes_sent.get(),
        );
        counter(
            "comm_bytes_saved",
            "Wire bytes saved by compression.",
            self.comm_bytes_saved.get(),
        );
        counter(
            "preemptions_total",
            "Sessions evicted from the KV pool.",
            self.preemptions_total.get(),
        );
        counter(
            "requests_shed",
            "Connections answered 503 because the pending queue was full.",
            self.requests_shed.get(),
        );
        out.push_str(
            "# HELP tpcc_http_requests_total Answered HTTP requests by route and status.\n\
             # TYPE tpcc_http_requests_total counter\n",
        );
        for (path, status, n) in self.http_requests() {
            out.push_str(&format!(
                "tpcc_http_requests_total{{path=\"{path}\",status=\"{status}\"}} {n}\n"
            ));
        }
        out.push_str(&format!(
            "# HELP tpcc_build_info Build identity (constant 1; labels carry the info).\n\
             # TYPE tpcc_build_info gauge\n\
             tpcc_build_info{{version=\"{}\",git=\"{}\"}} 1\n",
            build_version(),
            build_git()
        ));
        out.push_str(&format!(
            "# HELP tpcc_uptime_seconds Seconds since the registry (coordinator) started.\n\
             # TYPE tpcc_uptime_seconds gauge\n\
             tpcc_uptime_seconds {}\n",
            self.history.elapsed_s()
        ));
        out.push_str(&format!(
            "# HELP tpcc_kv_blocks_in_use KV blocks mapped into session block tables.\n\
             # TYPE tpcc_kv_blocks_in_use gauge\n\
             tpcc_kv_blocks_in_use {}\n",
            self.kv_blocks_in_use.get()
        ));
        out.push_str(&format!(
            "# HELP tpcc_kv_blocks_free KV blocks on the pool free list.\n\
             # TYPE tpcc_kv_blocks_free gauge\n\
             tpcc_kv_blocks_free {}\n",
            self.kv_blocks_free.get()
        ));
        let mut summary = |name: &str, help: &str, h: &Histogram| {
            let s = h.snapshot();
            out.push_str(&format!("# HELP tpcc_{name} {help}\n# TYPE tpcc_{name} summary\n"));
            if s.count() > 0 {
                for q in [0.5, 0.9, 0.95, 0.99] {
                    out.push_str(&format!(
                        "tpcc_{name}{{quantile=\"{q}\"}} {}\n",
                        s.percentile(q * 100.0)
                    ));
                }
            }
            out.push_str(&format!("tpcc_{name}_sum {}\n", s.sum()));
            out.push_str(&format!("tpcc_{name}_count {}\n", s.count()));
        };
        summary("ttft_seconds", "Time to first token.", &self.ttft);
        summary("tpot_seconds", "Time per output token.", &self.tpot);
        summary("e2e_seconds", "End-to-end request latency.", &self.e2e_latency);
        summary("queue_wait_seconds", "Admission queue wait.", &self.queue_wait);
        let slo = self.ttft_slo();
        if slo > 0.0 {
            out.push_str(&format!(
                "# HELP tpcc_ttft_slo_seconds Configured TTFT SLO.\n\
                 # TYPE tpcc_ttft_slo_seconds gauge\ntpcc_ttft_slo_seconds {slo}\n"
            ));
            let goodput = self.ttft.snapshot().fraction_below(slo);
            if goodput.is_finite() {
                out.push_str(&format!(
                    "# HELP tpcc_ttft_goodput Fraction of requests meeting the TTFT SLO.\n\
                     # TYPE tpcc_ttft_goodput gauge\ntpcc_ttft_goodput {goodput}\n"
                ));
            }
        }
        let custom = self.custom.lock().unwrap();
        for (k, v) in custom.iter() {
            if !v.is_finite() {
                continue;
            }
            let name = prom_sanitize(k);
            out.push_str(&format!("# TYPE tpcc_{name} gauge\ntpcc_{name} {v}\n"));
        }
        out
    }
}

/// Crate version baked into the binary at compile time.
pub fn build_version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Git short SHA baked in at compile time. `build.rs` stamps
/// `TPCC_GIT_SHA` when the tree is a git checkout; builds from a
/// tarball report `unknown` rather than failing.
pub fn build_git() -> &'static str {
    match option_env!("TPCC_GIT_SHA") {
        Some(sha) if !sha.is_empty() => sha,
        _ => "unknown",
    }
}

/// Map an arbitrary custom-metric key onto the Prometheus metric-name
/// charset `[a-zA-Z0-9_:]` (leading digits get a `_` prefix).
fn prom_sanitize(key: &str) -> String {
    let mut name = String::with_capacity(key.len());
    for c in key.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            name.push(c);
        } else {
            name.push('_');
        }
    }
    if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        name.insert(0, '_');
    }
    if name.is_empty() {
        name.push('_');
    }
    name
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 100);
        // log-bucketed backing: quantiles are exact to within one
        // bucket (GROWTH ≈ 4.4% relative), extremes and mean exact
        let growth = crate::workload::stats::GROWTH;
        for (p, exact) in [(50.0, 50.0), (95.0, 95.0)] {
            let got = s.percentile(p);
            assert!(
                got / exact <= growth + 1e-9 && exact / got <= growth + 1e-9,
                "p{p}: got {got}"
            );
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert!((s.stddev() - (83325.0f64 / 99.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_is_nan() {
        let h = Histogram::default();
        assert!(h.snapshot().percentile(50.0).is_nan());
    }

    #[test]
    fn non_finite_samples_are_rejected_not_recorded() {
        // the old exact-sample histogram panicked in snapshot() when a
        // NaN hit partial_cmp; now NaN/Inf never enter the histogram
        let h = Histogram::default();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(f64::NEG_INFINITY);
        h.record(0.5);
        assert_eq!(h.count(), 1);
        let s = h.snapshot();
        assert_eq!(s.percentile(50.0), 0.5);
        assert_eq!(s.max(), 0.5);
        h.clear();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn registry_json() {
        let r = Registry::default();
        r.requests_received.inc();
        r.ttft.record(0.25);
        r.set("custom_metric", 1.5);
        let j = r.to_json();
        assert_eq!(j.get("requests_received").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("ttft_p50_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("ttft_p99_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("custom_metric").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn empty_registry_serializes_valid_json() {
        // empty histograms must serialize percentiles as null, not NaN
        let r = Registry::default();
        let body = r.to_json().to_string();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("ttft_p50_s"), Some(&Json::Null));
        assert_eq!(j.get("queue_wait_p99_s"), Some(&Json::Null));
        // no SLO set: goodput absent
        assert!(j.get("ttft_goodput").is_none());
    }

    #[test]
    fn goodput_against_slo() {
        let r = Registry::default();
        r.set_ttft_slo(0.25);
        for v in [0.1, 0.2, 0.3, 0.4] {
            r.ttft.record(v);
        }
        let j = r.to_json();
        assert_eq!(j.get("ttft_slo_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("ttft_goodput").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("queue_wait_p50_s"), Some(&Json::Null));
    }

    #[test]
    fn gauge_up_and_down() {
        let g = Gauge::default();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        // clones share the underlying cell — the registry's view tracks
        // the subsystem holding the handle
        let h = g.clone();
        h.add(4);
        assert_eq!(g.get(), 5);
        g.set(0);
        assert_eq!(h.get(), 0);
    }

    #[test]
    fn custom_keys_cannot_shadow_builtins() {
        let r = Registry::default();
        r.ttft.record(0.25);
        r.set("ttft_p50_s", 99.0); // hostile/buggy caller
        r.set("kv_blocks_in_use", 7.0);
        let j = r.to_json();
        // the real metrics survive ...
        assert_eq!(j.get("ttft_p50_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(j.get("kv_blocks_in_use").unwrap().as_f64(), Some(0.0));
        // ... and the custom values land under a quarantined name
        assert_eq!(j.get("custom_ttft_p50_s").unwrap().as_f64(), Some(99.0));
        assert_eq!(j.get("custom_kv_blocks_in_use").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn prometheus_exposition_lints_clean() {
        let r = Registry::default();
        r.requests_completed.add(3);
        r.kv_blocks_in_use.add(2);
        r.set_ttft_slo(0.25);
        for v in [0.1, 0.2, 0.3] {
            r.ttft.record(v);
        }
        r.set("policy_calls_scheme_fp4/e2m1", 5.0); // needs sanitizing
        let text = r.to_prometheus();
        // line lint: every non-comment line is `name[{labels}] value`
        // with a valid metric name and a parseable float
        let mut metric_lines = 0;
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            metric_lines += 1;
            let (name_part, value) =
                line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
            let name = name_part.split('{').next().unwrap();
            assert!(
                name.starts_with("tpcc_")
                    && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in: {line}"
            );
            assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
        }
        assert!(metric_lines > 10, "suspiciously small exposition:\n{text}");
        assert!(text.contains("tpcc_requests_completed 3\n"));
        assert!(text.contains("tpcc_kv_blocks_in_use 2\n"));
        assert!(text.contains("# TYPE tpcc_ttft_seconds summary\n"));
        assert!(text.contains("tpcc_ttft_seconds_count 3\n"));
        assert!(text.contains("tpcc_ttft_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("tpcc_policy_calls_scheme_fp4_e2m1 5\n"));
        // empty histograms still expose _sum/_count, no NaN quantiles
        assert!(text.contains("tpcc_e2e_seconds_count 0\n"));
        assert!(!text.contains("NaN"));
    }

    #[test]
    fn tpot_distribution_and_pool_metrics_are_exposed() {
        let r = Registry::default();
        for i in 1..=100 {
            r.tpot.record(i as f64 / 100.0);
        }
        r.preemptions_total.add(2);
        r.kv_blocks_free.set(5);
        r.kv_blocks_in_use.set(11);
        let j = r.to_json();
        let p50 = j.get("tpot_p50_s").unwrap().as_f64().unwrap();
        let p90 = j.get("tpot_p90_s").unwrap().as_f64().unwrap();
        let p99 = j.get("tpot_p99_s").unwrap().as_f64().unwrap();
        assert!(p50 <= p90 && p90 <= p99, "quantiles must be ordered: {p50} {p90} {p99}");
        assert!(p90 > 0.8 && p90 < 1.0, "p90 of 0.01..=1.00 near 0.9, got {p90}");
        assert_eq!(j.get("preemptions_total").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("kv_blocks_free").unwrap().as_f64(), Some(5.0));
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE tpcc_preemptions_total counter\n"));
        assert!(text.contains("tpcc_preemptions_total 2\n"));
        assert!(text.contains("# TYPE tpcc_kv_blocks_free gauge\n"));
        assert!(text.contains("tpcc_kv_blocks_free 5\n"));
        assert!(text.contains("tpcc_tpot_seconds{quantile=\"0.9\"}"));
    }

    #[test]
    fn http_counters_by_route_and_status() {
        let r = Registry::default();
        r.record_http("/generate", 200);
        r.record_http("/generate", 200);
        r.record_http("/generate", 400);
        r.record_http("(shed)", 503);
        let j = r.to_json();
        let http = j.get("http_requests").unwrap();
        assert_eq!(http.get("/generate").unwrap().get("200").unwrap().as_i64(), Some(2));
        assert_eq!(http.get("/generate").unwrap().get("400").unwrap().as_i64(), Some(1));
        assert_eq!(http.get("(shed)").unwrap().get("503").unwrap().as_i64(), Some(1));
        let text = r.to_prometheus();
        assert!(text.contains("tpcc_http_requests_total{path=\"/generate\",status=\"200\"} 2\n"));
        assert!(text.contains("tpcc_http_requests_total{path=\"(shed)\",status=\"503\"} 1\n"));
    }

    #[test]
    fn build_info_and_uptime_are_exposed() {
        let r = Registry::default();
        let j = r.to_json();
        assert!(j.get("build_version").unwrap().as_str().is_some());
        assert!(j.get("build_git").unwrap().as_str().is_some());
        assert!(j.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
        let text = r.to_prometheus();
        assert!(text.contains("tpcc_build_info{version=\""));
        assert!(text.contains("\"} 1\n"));
        assert!(text.contains("tpcc_uptime_seconds "));
        assert!(!build_version().is_empty());
        assert!(!build_git().is_empty());
    }

    #[test]
    fn shed_counter_feeds_history_samples() {
        let r = Registry::default();
        r.requests_shed.add(3);
        r.preemptions_total.add(5);
        r.sample_history();
        let s = r.history.latest().unwrap();
        assert_eq!(s.sheds, 3);
        assert_eq!(s.preemptions, 5);
        assert_eq!(r.to_json().get("requests_shed").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn get_custom_reads_back_set_values() {
        let r = Registry::default();
        assert_eq!(r.get_custom("drift_sites_tripped"), None);
        r.set("drift_sites_tripped", 2.0);
        assert_eq!(r.get_custom("drift_sites_tripped"), Some(2.0));
    }

    #[test]
    fn sample_history_captures_counters_and_slo_hits() {
        let r = Registry::default();
        r.set_ttft_slo(0.25);
        r.requests_completed.add(4);
        r.tokens_generated.add(40);
        for v in [0.1, 0.2, 0.3, 0.4] {
            r.ttft.record(v);
        }
        r.sample_history();
        let s = r.history.latest().unwrap();
        assert_eq!(s.requests_completed, 4);
        assert_eq!(s.tokens_generated, 40);
        assert_eq!(s.ttft_count, 4);
        assert_eq!(s.ttft_slo_hits, 2); // 0.1, 0.2 meet the 0.25 SLO
        let body = r.history_json().to_string();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("samples").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn fraction_below_bounds() {
        let h = Histogram::default();
        assert!(h.snapshot().fraction_below(1.0).is_nan());
        for i in 1..=10 {
            h.record(i as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.fraction_below(0.5), 0.0);
        assert_eq!(s.fraction_below(5.0), 0.5);
        assert_eq!(s.fraction_below(100.0), 1.0);
    }
}
