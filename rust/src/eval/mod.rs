//! Perplexity evaluation harness (paper §4.2/§5.1).
//!
//! Streams corpus text through the TP engine's prefill path — the same
//! AOT artifacts and compressed collectives the serving path uses — and
//! computes byte-level cross-entropy in rust from the returned logits.
//! Quantization error enters exactly where the paper injects it: at the
//! two row-parallel collectives per layer.

use crate::tokenizer::ByteTokenizer;
use crate::tp::TpEngine;

#[derive(Debug, Clone)]
pub struct PplResult {
    pub nll: f64,
    pub tokens: usize,
    pub batches: usize,
    pub wall_s: f64,
}

impl PplResult {
    pub fn ppl(&self) -> f64 {
        (self.nll / self.tokens as f64).exp()
    }

    /// Relative increase vs a baseline, in percent (paper Tables 1/2/5).
    pub fn increase_pct(&self, baseline: &PplResult) -> f64 {
        (self.ppl() / baseline.ppl() - 1.0) * 100.0
    }
}

/// Evaluation options. `seq`/`batch` must be exported buckets for the
/// engine's model+TP; `max_tokens` bounds the slice of `text` scored.
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    pub seq: usize,
    pub batch: usize,
    pub max_tokens: usize,
    /// stride between window starts (== seq for the wikitext protocol)
    pub stride: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { seq: 128, batch: 8, max_tokens: 2048, stride: 128 }
    }
}

/// Score `text` and return total NLL over predicted tokens.
pub fn perplexity(eng: &mut TpEngine, text: &str, opt: EvalOptions) -> anyhow::Result<PplResult> {
    let tok = ByteTokenizer;
    let ids = tok.encode(text);
    anyhow::ensure!(ids.len() > opt.seq + 1, "text too short");
    let t0 = std::time::Instant::now();

    let v = eng.cfg.vocab;
    let (bb, sb) = (opt.batch, opt.seq);
    let mut nll = 0.0f64;
    let mut scored = 0usize;
    let mut batches = 0usize;

    // windows of seq+1 bytes: score positions 0..seq-1 predicting 1..seq
    let mut windows: Vec<usize> = Vec::new();
    let mut start = 0usize;
    while start + opt.seq + 1 <= ids.len() && windows.len() * (opt.seq - 1) < opt.max_tokens {
        windows.push(start);
        start += opt.stride;
    }

    for chunk in windows.chunks(bb) {
        let mut tokens = vec![0i32; bb * sb];
        for (row, &w) in chunk.iter().enumerate() {
            tokens[row * sb..(row + 1) * sb].copy_from_slice(&ids[w..w + sb]);
        }
        let (logits, _) = eng.prefill(&tokens, bb, sb, &vec![0; bb], None)?;
        for (row, &w) in chunk.iter().enumerate() {
            for s in 0..sb - 1 {
                if scored >= opt.max_tokens {
                    break;
                }
                let target = ids[w + s + 1] as usize;
                let row_logits = &logits[(row * sb + s) * v..(row * sb + s + 1) * v];
                nll += nll_of(row_logits, target);
                scored += 1;
            }
        }
        batches += 1;
        if scored >= opt.max_tokens {
            break;
        }
    }

    Ok(PplResult { nll, tokens: scored, batches, wall_s: t0.elapsed().as_secs_f64() })
}

/// -log p(target | logits) with a numerically-stable log-softmax.
pub fn nll_of(logits: &[f32], target: usize) -> f64 {
    let mut m = f32::NEG_INFINITY;
    for &l in logits {
        m = m.max(l);
    }
    let mut lse = 0.0f64;
    for &l in logits {
        lse += ((l - m) as f64).exp();
    }
    (m as f64 + lse.ln()) - logits[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nll_uniform() {
        let logits = vec![0.0f32; 256];
        let nll = nll_of(&logits, 7);
        assert!((nll - (256f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn nll_confident() {
        let mut logits = vec![0.0f32; 16];
        logits[3] = 20.0;
        assert!(nll_of(&logits, 3) < 1e-6);
        assert!(nll_of(&logits, 4) > 19.0);
    }

    #[test]
    fn ppl_math() {
        let r = PplResult { nll: 100.0 * (2.0f64).ln(), tokens: 100, batches: 1, wall_s: 0.0 };
        assert!((r.ppl() - 2.0).abs() < 1e-9);
        let b = PplResult { nll: 100.0 * (1.6f64).ln(), tokens: 100, batches: 1, wall_s: 0.0 };
        assert!((r.increase_pct(&b) - 25.0).abs() < 1e-9);
    }
}
