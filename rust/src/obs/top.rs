//! `tpcc top` — a terminal operator dashboard over the HTTP surface.
//!
//! Polls `/metrics`, `/metrics/history`, `/alerts`, and `/logs` on a
//! running server and renders one self-contained text frame: throughput
//! sparklines from the history ring's compact `recent` tail, latency
//! percentiles, KV-pool occupancy, every alert rule with its state, and
//! the newest warn-and-above log events. `--once` prints a single frame
//! and exits (no TTY, no ANSI), which is what CI runs; interactive mode
//! redraws in place every `interval_s`.
//!
//! Rendering is a pure function of the fetched JSON (`render`), so the
//! layout is unit-testable against canned snapshots without a server.

use crate::server::http_get;
use crate::util::json::Json;

/// One poll of the four dashboard endpoints.
pub struct Snapshot {
    pub addr: String,
    pub metrics: Json,
    pub history: Json,
    pub alerts: Json,
    pub logs: Json,
}

fn get_json(addr: &str, path: &str) -> anyhow::Result<Json> {
    let (status, body) = http_get(addr, path)?;
    anyhow::ensure!(status == 200, "GET {path} -> {status}");
    Ok(Json::parse(&body)?)
}

/// Fetch a full dashboard snapshot from a running server.
pub fn fetch(addr: &str) -> anyhow::Result<Snapshot> {
    Ok(Snapshot {
        addr: addr.to_string(),
        metrics: get_json(addr, "/metrics")?,
        history: get_json(addr, "/metrics/history")?,
        alerts: get_json(addr, "/alerts")?,
        logs: get_json(addr, "/logs?last=6&level=warn")?,
    })
}

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Scale a series into block-glyph heights. All-zero (or empty) input
/// renders as a flat baseline rather than dividing by zero.
fn sparkline(vals: &[f64]) -> String {
    let max = vals.iter().cloned().fold(0.0f64, f64::max);
    vals.iter()
        .map(|&v| {
            if max <= 0.0 || !v.is_finite() {
                SPARK[0]
            } else {
                let idx = ((v / max) * (SPARK.len() - 1) as f64).round() as usize;
                SPARK[idx.min(SPARK.len() - 1)]
            }
        })
        .collect()
}

/// Difference a cumulative-counter column of the history `recent` rows
/// (`[t_s, requests, tokens, bytes]`, newest-last) into per-second
/// rates, one value per adjacent pair.
fn rate_series(rows: &[Json], col: usize) -> Vec<f64> {
    let mut out = Vec::new();
    let mut prev: Option<(f64, f64)> = None;
    for row in rows {
        let Some(cells) = row.as_arr() else { continue };
        let (Some(t), Some(v)) = (
            cells.first().and_then(|c| c.as_f64()),
            cells.get(col).and_then(|c| c.as_f64()),
        ) else {
            continue;
        };
        if let Some((pt, pv)) = prev {
            let dt = t - pt;
            if dt > 0.0 {
                out.push(((v - pv).max(0.0)) / dt);
            }
        }
        prev = Some((t, v));
    }
    out
}

fn num(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(s) if s.is_finite() => format!("{:.1}ms", s * 1e3),
        _ => "-".to_string(),
    }
}

fn fmt_rate(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

/// Render one dashboard frame from a snapshot. Pure: no I/O, no ANSI
/// control codes — the interactive loop adds clear-screen around it.
pub fn render(snap: &Snapshot) -> String {
    let m = &snap.metrics;
    let mut out = String::with_capacity(2048);

    let uptime = num(m, "uptime_seconds").unwrap_or(0.0);
    let version = m.get("build_version").and_then(|v| v.as_str()).unwrap_or("?");
    let git = m.get("build_git").and_then(|v| v.as_str()).unwrap_or("unknown");
    out.push_str(&format!(
        "tpcc top — {}  (v{} {}  up {:.0}s)\n",
        snap.addr, version, git, uptime
    ));
    out.push_str(&format!(
        "requests: {:.0} done / {:.0} in  tokens: {:.0}  preempt: {:.0}  shed: {:.0}\n",
        num(m, "requests_completed").unwrap_or(0.0),
        num(m, "requests_received").unwrap_or(0.0),
        num(m, "tokens_generated").unwrap_or(0.0),
        num(m, "preemptions_total").unwrap_or(0.0),
        num(m, "requests_shed").unwrap_or(0.0),
    ));

    // throughput sparklines from the compact recent tail
    let empty: Vec<Json> = Vec::new();
    let rows = snap
        .history
        .get("recent")
        .and_then(|r| r.as_arr())
        .unwrap_or(&empty);
    let qps = rate_series(rows, 1);
    let tps = rate_series(rows, 2);
    let wire = rate_series(rows, 3).iter().map(|b| b / 1e9).collect::<Vec<_>>();
    let last = |s: &[f64]| s.last().cloned().unwrap_or(0.0);
    out.push_str(&format!("qps      {:>8} {}\n", fmt_rate(last(&qps)), sparkline(&qps)));
    out.push_str(&format!("tok/s    {:>8} {}\n", fmt_rate(last(&tps)), sparkline(&tps)));
    out.push_str(&format!("wire GB/s{:>8} {}\n", fmt_rate(last(&wire)), sparkline(&wire)));

    // latency percentiles + KV occupancy
    out.push_str(&format!(
        "ttft p50/p95/p99: {} / {} / {}   tpot p50/p99: {} / {}   queue p95: {}\n",
        fmt_ms(num(m, "ttft_p50_s")),
        fmt_ms(num(m, "ttft_p95_s")),
        fmt_ms(num(m, "ttft_p99_s")),
        fmt_ms(num(m, "tpot_p50_s")),
        fmt_ms(num(m, "tpot_p99_s")),
        fmt_ms(num(m, "queue_wait_p95_s")),
    ));
    let kv_used = num(m, "kv_blocks_in_use").unwrap_or(0.0);
    let kv_free = num(m, "kv_blocks_free").unwrap_or(0.0);
    let kv_total = kv_used + kv_free;
    if kv_total > 0.0 {
        let frac = kv_used / kv_total;
        let filled = (frac * 20.0).round() as usize;
        out.push_str(&format!(
            "kv pool  [{}{}] {:.0}% ({:.0}/{:.0} blocks)\n",
            "#".repeat(filled.min(20)),
            ".".repeat(20usize.saturating_sub(filled)),
            frac * 100.0,
            kv_used,
            kv_total,
        ));
    } else {
        out.push_str("kv pool  [no pool]\n");
    }

    // alert rules: firing first, then pending, then a count of quiet ones
    let rules = snap
        .alerts
        .get("rules")
        .and_then(|r| r.as_arr())
        .unwrap_or(&empty);
    let firing = snap
        .alerts
        .get("firing")
        .and_then(|f| f.as_f64())
        .unwrap_or(0.0) as usize;
    out.push_str(&format!("alerts ({firing} firing):\n"));
    let mut quiet = 0usize;
    for rule in rules {
        let state = rule.get("state").and_then(|s| s.as_str()).unwrap_or("?");
        if state == "inactive" {
            quiet += 1;
            continue;
        }
        let name = rule.get("name").and_then(|s| s.as_str()).unwrap_or("?");
        let sev = rule.get("severity").and_then(|s| s.as_str()).unwrap_or("?");
        let value = rule.get("value").and_then(|v| v.as_f64());
        let threshold = num(rule, "threshold").unwrap_or(f64::NAN);
        out.push_str(&format!(
            "  {} {:<20} [{}] value {} vs {:.3}\n",
            if state == "firing" { "●" } else { "◌" },
            name,
            sev,
            value.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".to_string()),
            threshold,
        ));
    }
    if quiet > 0 {
        out.push_str(&format!("  ({quiet} rules quiet)\n"));
    }

    // newest warn+ events, oldest first
    let events = snap
        .logs
        .get("events")
        .and_then(|e| e.as_arr())
        .unwrap_or(&empty);
    if !events.is_empty() {
        out.push_str("recent warnings:\n");
        for ev in events {
            let t = num(ev, "t_s").unwrap_or(0.0);
            let level = ev.get("level").and_then(|l| l.as_str()).unwrap_or("?");
            let target = ev.get("target").and_then(|l| l.as_str()).unwrap_or("?");
            let msg = ev.get("msg").and_then(|l| l.as_str()).unwrap_or("");
            out.push_str(&format!(
                "  t={t:.1} {:<5} {target}: {msg}\n",
                level.to_uppercase()
            ));
        }
    }
    out
}

/// Drive the dashboard: one frame with `--once`, otherwise poll and
/// redraw until killed. Fetch errors in loop mode are shown in place of
/// a frame and retried — a restarting server should not kill the
/// operator's terminal.
pub fn run(addr: &str, once: bool, interval_s: f64) -> anyhow::Result<()> {
    use std::io::Write;
    loop {
        match fetch(addr) {
            Ok(snap) => {
                let frame = render(&snap);
                if once {
                    print!("{frame}");
                    return Ok(());
                }
                // clear + home, then the frame
                print!("\x1b[2J\x1b[H{frame}");
                std::io::stdout().flush().ok();
            }
            Err(e) if once => return Err(e),
            Err(e) => {
                print!("\x1b[2J\x1b[Htpcc top — {addr}: fetch failed: {e:#}\n");
                std::io::stdout().flush().ok();
            }
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval_s.max(0.2)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, Json};

    fn canned() -> Snapshot {
        let metrics = Json::parse(
            r#"{"requests_completed":10,"requests_received":12,"tokens_generated":320,
                "preemptions_total":3,"requests_shed":1,"uptime_seconds":42.5,
                "build_version":"0.1.0","build_git":"abc1234",
                "kv_blocks_in_use":24,"kv_blocks_free":8,
                "ttft_p50_s":0.012,"ttft_p95_s":0.040,"ttft_p99_s":0.055,
                "tpot_p50_s":0.004,"tpot_p99_s":0.009,"queue_wait_p95_s":0.002}"#,
        )
        .unwrap();
        let history = Json::parse(
            r#"{"recent":[[0.0,0,0,0],[1.0,2,64,1000000],[2.0,6,192,3000000],[3.0,10,320,5000000]]}"#,
        )
        .unwrap();
        let alerts = Json::parse(
            r#"{"firing":1,"rules":[
                {"name":"preemption_storm","expr":"x","severity":"warn","state":"firing",
                 "for_s":2.0,"threshold":0.5,"value":1.25,"since_s":10.0,
                 "fired_total":1,"resolved_total":0},
                {"name":"ttft_slo_burn","expr":"y","severity":"error","state":"inactive",
                 "for_s":0.0,"threshold":10.0,"value":null,"since_s":null,
                 "fired_total":0,"resolved_total":0}]}"#,
        )
        .unwrap();
        let logs = json::obj(vec![
            ("total", json::num(5.0)),
            ("dropped", json::num(0.0)),
            (
                "events",
                Json::Arr(vec![json::obj(vec![
                    ("t_s", json::num(9.5)),
                    ("level", json::s("warn")),
                    ("target", json::s("alert")),
                    ("msg", json::s("alert firing")),
                ])]),
            ),
        ]);
        Snapshot { addr: "127.0.0.1:9".to_string(), metrics, history, alerts, logs }
    }

    #[test]
    fn render_shows_alerts_rates_and_logs() {
        let frame = render(&canned());
        assert!(frame.contains("tpcc top"), "header: {frame}");
        assert!(frame.contains("preemption_storm"), "firing rule listed: {frame}");
        assert!(frame.contains("● "), "firing marker: {frame}");
        assert!(frame.contains("(1 rules quiet)"), "quiet rules folded: {frame}");
        assert!(frame.contains("alert firing"), "warn log rendered: {frame}");
        assert!(frame.contains("kv pool"), "kv bar present: {frame}");
        assert!(frame.contains("75%"), "kv occupancy 24/32: {frame}");
        assert!(frame.contains("12.0ms"), "ttft p50 formatted: {frame}");
        // sparkline glyphs present for the qps row
        assert!(frame.chars().any(|c| SPARK.contains(&c)), "sparkline glyphs: {frame}");
    }

    #[test]
    fn rate_series_differences_cumulative_rows() {
        let rows: Vec<Json> = vec![
            Json::parse("[0.0,0,0,0]").unwrap(),
            Json::parse("[1.0,4,0,0]").unwrap(),
            Json::parse("[3.0,10,0,0]").unwrap(),
        ];
        let qps = rate_series(&rows, 1);
        assert_eq!(qps.len(), 2);
        assert!((qps[0] - 4.0).abs() < 1e-9);
        assert!((qps[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn rate_series_clamps_counter_resets_to_zero() {
        let rows: Vec<Json> = vec![
            Json::parse("[0.0,100,0,0]").unwrap(),
            Json::parse("[1.0,2,0,0]").unwrap(),
        ];
        let qps = rate_series(&rows, 1);
        assert_eq!(qps, vec![0.0]);
    }

    #[test]
    fn sparkline_handles_flat_and_scaled_input() {
        assert_eq!(sparkline(&[]), "");
        assert_eq!(sparkline(&[0.0, 0.0]), "▁▁");
        let s = sparkline(&[1.0, 8.0]);
        assert_eq!(s.chars().count(), 2);
        assert_eq!(s.chars().last().unwrap(), '█');
    }

    #[test]
    fn render_survives_empty_json_documents() {
        let snap = Snapshot {
            addr: "x".to_string(),
            metrics: Json::parse("{}").unwrap(),
            history: Json::parse("{}").unwrap(),
            alerts: Json::parse("{}").unwrap(),
            logs: Json::parse("{}").unwrap(),
        };
        let frame = render(&snap);
        assert!(frame.contains("alerts (0 firing)"), "{frame}");
        assert!(frame.contains("[no pool]"), "{frame}");
    }
}
