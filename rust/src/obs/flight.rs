//! Per-request flight recorder: a bounded exemplar store of structured
//! request records behind `GET /debug/requests` and `tpcc explain`.
//!
//! Every completed request leaves one [`RequestRecord`] — queue wait,
//! prefill/decode phase breakdown (folded from the engine's per-step
//! timings), wire bytes per site group, batch occupancy, rank
//! fabric-wait — in two bounded views: the most-recent-K (a ring) and
//! the slowest-K by end-to-end latency (a sorted keep-list). Recent
//! answers "what is the server doing now"; slowest keeps the tail
//! exemplars that a sampling profiler would lose, so p99 regressions
//! stay attributable after the fact.
//!
//! [`attribution`] turns a record set into the p50-vs-tail table
//! `tpcc explain` prints: per phase/site, the mean cost in the p50
//! cohort vs the tail cohort and each component's share of the
//! end-to-end gap — i.e. *which phase grows in the tail*.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::util::json::{self, Json};

/// Most-recent retention (ring).
pub const DEFAULT_RECENT_K: usize = 256;
/// Slowest-by-e2e retention (keep-list).
pub const DEFAULT_SLOWEST_K: usize = 64;

/// Site-group labels matching the engine's `(kind × phase)` rollup
/// order (see `TpEngine::group_wire_bytes`).
pub const SITE_GROUPS: [&str; 4] = ["attn.prefill", "attn.decode", "mlp.prefill", "mlp.decode"];

/// One phase's cost breakdown (prefill or the summed decode steps).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseCost {
    pub compute_s: f64,
    pub codec_s: f64,
    pub link_s: f64,
    pub wire_bytes: u64,
}

/// The flight record of one completed request.
///
/// Decode-phase costs and the engine-level deltas (`site_wire_bytes`,
/// `fabric_wait_s`) are *window* attributions: decode batches are
/// shared, so a step's cost is charged to every request active in it,
/// and the wire/fabric deltas cover the request's residency window
/// including concurrent traffic. That is the honest per-request view a
/// continuous batcher can give without per-row cost splitting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    /// Peak decode-batch occupancy while this request was resident.
    pub batch_peak: usize,
    pub queue_wait_s: f64,
    pub ttft_s: f64,
    pub e2e_s: f64,
    pub tpot_s: f64,
    pub prefill: PhaseCost,
    pub decode: PhaseCost,
    /// Rank fabric-wait accumulated engine-wide over this request's
    /// residency (parallel rank runtime only; 0 under `--rank-threads off`).
    pub fabric_wait_s: f64,
    /// Engine wire bytes per site group ([`SITE_GROUPS`] order) over
    /// this request's residency window.
    pub site_wire_bytes: [u64; 4],
    /// Times this request was evicted from the KV pool (each eviction
    /// adds a swap-out/restore round trip to its tail).
    pub preemptions: u64,
    /// Chunked-prefill slices this request's prompt ran as (0 = single
    /// whole-prompt prefill batch).
    pub prefill_chunks: u64,
}

struct FlightInner {
    recent: VecDeque<Arc<RequestRecord>>,
    /// Sorted slowest-first by `e2e_s`, truncated to `slowest_k`.
    slowest: Vec<Arc<RequestRecord>>,
    total: u64,
}

/// Bounded exemplar store of [`RequestRecord`]s.
pub struct FlightRecorder {
    recent_k: usize,
    slowest_k: usize,
    inner: Mutex<FlightInner>,
    /// Resolved compression scheme summary per site group, set once at
    /// engine bind ([`SITE_GROUPS`] order) — lets `/debug/requests`
    /// say which scheme each group's wire bytes were paid under.
    schemes: Mutex<[String; 4]>,
}

impl Default for FlightRecorder {
    fn default() -> FlightRecorder {
        FlightRecorder::new(DEFAULT_RECENT_K, DEFAULT_SLOWEST_K)
    }
}

impl FlightRecorder {
    pub fn new(recent_k: usize, slowest_k: usize) -> FlightRecorder {
        FlightRecorder {
            recent_k: recent_k.max(1),
            slowest_k: slowest_k.max(1),
            inner: Mutex::new(FlightInner {
                recent: VecDeque::with_capacity(recent_k.max(1)),
                slowest: Vec::with_capacity(slowest_k.max(1) + 1),
                total: 0,
            }),
            schemes: Mutex::new(std::array::from_fn(|_| String::new())),
        }
    }

    pub fn set_group_schemes(&self, schemes: [String; 4]) {
        *self.schemes.lock().unwrap() = schemes;
    }

    pub fn record(&self, rec: RequestRecord) {
        let rec = Arc::new(rec);
        let mut inner = self.inner.lock().unwrap();
        inner.total += 1;
        if inner.recent.len() == self.recent_k {
            inner.recent.pop_front();
        }
        inner.recent.push_back(rec.clone());
        // keep `slowest` sorted descending by e2e; NaN sorts last so it
        // can never displace a real exemplar
        let key = |r: &RequestRecord| if r.e2e_s.is_finite() { r.e2e_s } else { f64::NEG_INFINITY };
        let pos = inner
            .slowest
            .partition_point(|r| key(r) >= key(&rec));
        if pos < self.slowest_k {
            inner.slowest.insert(pos, rec);
            inner.slowest.truncate(self.slowest_k);
        }
    }

    /// Requests recorded over the recorder's lifetime (≥ retained).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Union of the recent and slowest views, deduplicated by id.
    pub fn records(&self) -> Vec<Arc<RequestRecord>> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<Arc<RequestRecord>> = inner.recent.iter().cloned().collect();
        for r in &inner.slowest {
            if !out.iter().any(|o| o.id == r.id) {
                out.push(r.clone());
            }
        }
        out
    }

    /// The `GET /debug/requests` body.
    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let schemes = self.schemes.lock().unwrap();
        let dump = |list: &mut dyn Iterator<Item = &Arc<RequestRecord>>| {
            Json::Arr(list.map(|r| record_json(r)).collect())
        };
        json::obj(vec![
            ("total", json::num(inner.total as f64)),
            ("recent_k", json::num(self.recent_k as f64)),
            ("slowest_k", json::num(self.slowest_k as f64)),
            ("site_groups", Json::Arr(SITE_GROUPS.iter().map(|g| json::s(g)).collect())),
            ("group_schemes", Json::Arr(schemes.iter().map(|g| json::s(g)).collect())),
            ("recent", dump(&mut inner.recent.iter())),
            ("slowest", dump(&mut inner.slowest.iter())),
        ])
    }
}

fn phase_json(p: &PhaseCost) -> Json {
    json::obj(vec![
        ("compute_s", json::num(p.compute_s)),
        ("codec_s", json::num(p.codec_s)),
        ("link_s", json::num(p.link_s)),
        ("wire_bytes", json::num(p.wire_bytes as f64)),
    ])
}

fn record_json(r: &RequestRecord) -> Json {
    json::obj(vec![
        ("id", json::num(r.id as f64)),
        ("prompt_tokens", json::num(r.prompt_tokens as f64)),
        ("new_tokens", json::num(r.new_tokens as f64)),
        ("batch_peak", json::num(r.batch_peak as f64)),
        ("queue_wait_s", json::num_or_null(r.queue_wait_s)),
        ("ttft_s", json::num_or_null(r.ttft_s)),
        ("e2e_s", json::num_or_null(r.e2e_s)),
        ("tpot_s", json::num_or_null(r.tpot_s)),
        ("prefill", phase_json(&r.prefill)),
        ("decode", phase_json(&r.decode)),
        ("fabric_wait_s", json::num(r.fabric_wait_s)),
        (
            "site_wire_bytes",
            Json::Arr(r.site_wire_bytes.iter().map(|&b| json::num(b as f64)).collect()),
        ),
        ("preemptions", json::num(r.preemptions as f64)),
        ("prefill_chunks", json::num(r.prefill_chunks as f64)),
    ])
}

fn phase_from_json(j: &Json) -> PhaseCost {
    let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    PhaseCost {
        compute_s: f("compute_s"),
        codec_s: f("codec_s"),
        link_s: f("link_s"),
        wire_bytes: f("wire_bytes") as u64,
    }
}

fn record_from_json(j: &Json) -> RequestRecord {
    let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
    let u = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let mut site_wire_bytes = [0u64; 4];
    if let Some(arr) = j.get("site_wire_bytes").and_then(Json::as_arr) {
        for (i, v) in arr.iter().take(4).enumerate() {
            site_wire_bytes[i] = v.as_f64().unwrap_or(0.0) as u64;
        }
    }
    RequestRecord {
        id: u("id"),
        prompt_tokens: u("prompt_tokens") as usize,
        new_tokens: u("new_tokens") as usize,
        batch_peak: u("batch_peak") as usize,
        queue_wait_s: f("queue_wait_s"),
        ttft_s: f("ttft_s"),
        e2e_s: f("e2e_s"),
        tpot_s: f("tpot_s"),
        prefill: j.get("prefill").map(phase_from_json).unwrap_or_default(),
        decode: j.get("decode").map(phase_from_json).unwrap_or_default(),
        fabric_wait_s: j.get("fabric_wait_s").and_then(Json::as_f64).unwrap_or(0.0),
        site_wire_bytes,
        preemptions: u("preemptions"),
        prefill_chunks: u("prefill_chunks"),
    }
}

/// Parse a `GET /debug/requests` body back into records (deduplicated
/// by id) — the remote half of `tpcc explain --addr`.
pub fn records_from_json(body: &Json) -> Vec<RequestRecord> {
    let mut out: Vec<RequestRecord> = Vec::new();
    for key in ["recent", "slowest"] {
        if let Some(arr) = body.get(key).and_then(Json::as_arr) {
            for j in arr {
                let r = record_from_json(j);
                if !out.iter().any(|o| o.id == r.id) {
                    out.push(r);
                }
            }
        }
    }
    out
}

/// One row of the p50-vs-tail attribution table.
#[derive(Debug, Clone, PartialEq)]
pub struct AttrRow {
    pub name: &'static str,
    /// Mean over the p50 cohort.
    pub p50: f64,
    /// Mean over the tail cohort.
    pub tail: f64,
    pub delta: f64,
    /// This component's share of the cohorts' e2e gap, in percent
    /// (phases only; NaN when the gap is ~0).
    pub share_pct: f64,
}

/// The `tpcc explain` attribution: which phase/site grows in the tail.
#[derive(Debug, Clone, Default)]
pub struct Attribution {
    pub n: usize,
    pub p50_n: usize,
    pub tail_n: usize,
    pub p50_e2e_s: f64,
    pub tail_e2e_s: f64,
    /// Per-phase rows in seconds.
    pub phases: Vec<AttrRow>,
    /// Per-site-group rows in wire bytes.
    pub sites: Vec<AttrRow>,
    /// Scheduler-event rows in plain counts (preemptions,
    /// chunked-prefill slices): was the tail cohort preempted or
    /// chunked more than the p50 cohort?
    pub counts: Vec<AttrRow>,
}

/// Split records into a p50 cohort (the faster half by e2e) and a tail
/// cohort (the slowest ~5%, at least one) and attribute the e2e gap to
/// phases and site groups. Records without a finite e2e are excluded.
pub fn attribution(records: &[RequestRecord]) -> Option<Attribution> {
    let mut recs: Vec<&RequestRecord> = records.iter().filter(|r| r.e2e_s.is_finite()).collect();
    if recs.len() < 2 {
        return None;
    }
    recs.sort_by(|a, b| a.e2e_s.partial_cmp(&b.e2e_s).unwrap());
    let n = recs.len();
    let p50_n = n.div_ceil(2);
    let tail_n = (n / 20).max(1);
    let p50 = &recs[..p50_n];
    let tail = &recs[n - tail_n..];
    fn mean(cohort: &[&RequestRecord], f: &dyn Fn(&RequestRecord) -> f64) -> f64 {
        cohort.iter().map(|r| f(r)).filter(|v| v.is_finite()).sum::<f64>() / cohort.len() as f64
    }
    let p50_e2e = mean(p50, &|r| r.e2e_s);
    let tail_e2e = mean(tail, &|r| r.e2e_s);
    let gap = tail_e2e - p50_e2e;
    type Field = fn(&RequestRecord) -> f64;
    let phase_fields: [(&'static str, Field); 8] = [
        ("queue_wait", |r| r.queue_wait_s),
        ("prefill.compute", |r| r.prefill.compute_s),
        ("prefill.codec", |r| r.prefill.codec_s),
        ("prefill.link", |r| r.prefill.link_s),
        ("decode.compute", |r| r.decode.compute_s),
        ("decode.codec", |r| r.decode.codec_s),
        ("decode.link", |r| r.decode.link_s),
        ("fabric_wait", |r| r.fabric_wait_s),
    ];
    let phases = phase_fields
        .iter()
        .map(|&(name, f)| {
            let a = mean(p50, &f);
            let b = mean(tail, &f);
            let delta = b - a;
            let share_pct = if gap.abs() > 1e-12 { delta / gap * 100.0 } else { f64::NAN };
            AttrRow { name, p50: a, tail: b, delta, share_pct }
        })
        .collect();
    let sites = SITE_GROUPS
        .iter()
        .enumerate()
        .map(|(gi, &name)| {
            let f = move |r: &RequestRecord| r.site_wire_bytes[gi] as f64;
            let a = mean(p50, &f);
            let b = mean(tail, &f);
            AttrRow { name, p50: a, tail: b, delta: b - a, share_pct: f64::NAN }
        })
        .collect();
    let count_fields: [(&'static str, Field); 2] = [
        ("preemptions", |r| r.preemptions as f64),
        ("prefill_chunks", |r| r.prefill_chunks as f64),
    ];
    let counts = count_fields
        .iter()
        .map(|&(name, f)| {
            let a = mean(p50, &f);
            let b = mean(tail, &f);
            AttrRow { name, p50: a, tail: b, delta: b - a, share_pct: f64::NAN }
        })
        .collect();
    Some(Attribution {
        n,
        p50_n,
        tail_n,
        p50_e2e_s: p50_e2e,
        tail_e2e_s: tail_e2e,
        phases,
        sites,
        counts,
    })
}

/// Render the attribution as the table `tpcc explain` prints.
pub fn render_attribution(a: &Attribution) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "tail attribution over {} requests (p50 cohort n={}, tail cohort n={})\n",
        a.n, a.p50_n, a.tail_n
    ));
    out.push_str(&format!(
        "e2e: p50-cohort mean {:.4}s, tail-cohort mean {:.4}s, gap {:+.4}s\n\n",
        a.p50_e2e_s,
        a.tail_e2e_s,
        a.tail_e2e_s - a.p50_e2e_s
    ));
    out.push_str(&format!(
        "{:<18} {:>12} {:>12} {:>12} {:>10}\n",
        "phase", "p50 (s)", "tail (s)", "delta (s)", "share"
    ));
    for row in &a.phases {
        let share = if row.share_pct.is_finite() {
            format!("{:.1}%", row.share_pct)
        } else {
            "-".to_string()
        };
        out.push_str(&format!(
            "{:<18} {:>12.6} {:>12.6} {:>+12.6} {:>10}\n",
            row.name, row.p50, row.tail, row.delta, share
        ));
    }
    out.push_str(&format!(
        "\n{:<18} {:>12} {:>12} {:>12}\n",
        "site group", "p50 (MB)", "tail (MB)", "delta (MB)"
    ));
    for row in &a.sites {
        out.push_str(&format!(
            "{:<18} {:>12.3} {:>12.3} {:>+12.3}\n",
            row.name,
            row.p50 / 1e6,
            row.tail / 1e6,
            row.delta / 1e6
        ));
    }
    out.push_str(&format!(
        "\n{:<18} {:>12} {:>12} {:>12}\n",
        "scheduler", "p50 (mean)", "tail (mean)", "delta"
    ));
    for row in &a.counts {
        out.push_str(&format!(
            "{:<18} {:>12.2} {:>12.2} {:>+12.2}\n",
            row.name, row.p50, row.tail, row.delta
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, e2e: f64) -> RequestRecord {
        RequestRecord { id, e2e_s: e2e, ttft_s: e2e / 2.0, ..RequestRecord::default() }
    }

    #[test]
    fn recent_ring_keeps_latest_k() {
        let fr = FlightRecorder::new(3, 2);
        for i in 0..10 {
            fr.record(rec(i, 0.1));
        }
        assert_eq!(fr.total(), 10);
        let inner = fr.inner.lock().unwrap();
        let ids: Vec<u64> = inner.recent.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 8, 9]);
    }

    #[test]
    fn slowest_list_keeps_tail_exemplars() {
        let fr = FlightRecorder::new(2, 3);
        // slow outliers arrive early, then a flood of fast requests
        fr.record(rec(0, 9.0));
        fr.record(rec(1, 7.0));
        for i in 2..50 {
            fr.record(rec(i, 0.01 * i as f64));
        }
        fr.record(rec(50, 8.0));
        let inner = fr.inner.lock().unwrap();
        let slowest: Vec<(u64, f64)> = inner.slowest.iter().map(|r| (r.id, r.e2e_s)).collect();
        assert_eq!(slowest, vec![(0, 9.0), (50, 8.0), (1, 7.0)]);
        // the recent ring has long forgotten the outliers
        assert!(inner.recent.iter().all(|r| r.id >= 49));
    }

    #[test]
    fn nan_e2e_never_displaces_real_exemplars() {
        let fr = FlightRecorder::new(4, 2);
        fr.record(rec(0, 1.0));
        fr.record(rec(1, f64::NAN));
        fr.record(rec(2, 2.0));
        let inner = fr.inner.lock().unwrap();
        let ids: Vec<u64> = inner.slowest.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 0]);
    }

    #[test]
    fn records_union_dedups_by_id() {
        let fr = FlightRecorder::new(4, 4);
        for i in 0..3 {
            fr.record(rec(i, i as f64));
        }
        // all three are in both views; union must not double-count
        assert_eq!(fr.records().len(), 3);
    }

    #[test]
    fn json_round_trip() {
        let fr = FlightRecorder::new(4, 4);
        let mut r = rec(7, 1.25);
        r.prompt_tokens = 12;
        r.new_tokens = 5;
        r.batch_peak = 3;
        r.prefill = PhaseCost { compute_s: 0.5, codec_s: 0.1, link_s: 0.2, wire_bytes: 1024 };
        r.site_wire_bytes = [1, 2, 3, 4];
        r.preemptions = 2;
        r.prefill_chunks = 3;
        fr.record(r.clone());
        fr.set_group_schemes(std::array::from_fn(|_| "none".to_string()));
        let body = fr.to_json().to_string();
        let parsed = Json::parse(&body).unwrap();
        let back = records_from_json(&parsed);
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].id, 7);
        assert_eq!(back[0].prefill, r.prefill);
        assert_eq!(back[0].site_wire_bytes, [1, 2, 3, 4]);
        assert_eq!(back[0].e2e_s, 1.25);
        assert_eq!((back[0].preemptions, back[0].prefill_chunks), (2, 3));
        assert_eq!(
            parsed.get("group_schemes").unwrap().idx(0).unwrap().as_str(),
            Some("none")
        );
    }

    #[test]
    fn attribution_blames_the_growing_phase() {
        // fast cohort: decode.compute 10ms; tail: decode.link blows up
        let mut records = Vec::new();
        for i in 0..40u64 {
            let mut r = rec(i, 0.1);
            r.decode.compute_s = 0.01;
            r.decode.link_s = 0.001;
            records.push(r);
        }
        for i in 40..42u64 {
            let mut r = rec(i, 0.5);
            r.decode.compute_s = 0.01;
            r.decode.link_s = 0.4;
            r.site_wire_bytes = [0, 8_000_000, 0, 0];
            r.preemptions = 2;
            r.prefill_chunks = 4;
            records.push(r);
        }
        let a = attribution(&records).unwrap();
        assert_eq!(a.n, 42);
        assert!(a.tail_e2e_s > a.p50_e2e_s);
        let link = a.phases.iter().find(|r| r.name == "decode.link").unwrap();
        let comp = a.phases.iter().find(|r| r.name == "decode.compute").unwrap();
        assert!(link.delta > 0.3, "link delta {}", link.delta);
        assert!(comp.delta.abs() < 1e-9);
        assert!(link.share_pct > 90.0, "share {}", link.share_pct);
        let attn_dec = a.sites.iter().find(|r| r.name == "attn.decode").unwrap();
        assert!(attn_dec.delta > 1e6);
        // scheduler-event counts: the tail cohort was preempted and
        // chunked, the p50 cohort was not
        let pre = a.counts.iter().find(|r| r.name == "preemptions").unwrap();
        assert!((pre.tail - 2.0).abs() < 1e-9 && pre.p50 == 0.0);
        let ch = a.counts.iter().find(|r| r.name == "prefill_chunks").unwrap();
        assert!((ch.delta - 4.0).abs() < 1e-9);
        // render never panics and names the culprit
        let table = render_attribution(&a);
        assert!(table.contains("decode.link"));
        assert!(table.contains("attn.decode"));
        assert!(table.contains("preemptions"));
        assert!(table.contains("prefill_chunks"));
    }

    #[test]
    fn attribution_needs_two_finite_records() {
        assert!(attribution(&[]).is_none());
        assert!(attribution(&[rec(0, 1.0)]).is_none());
        assert!(attribution(&[rec(0, 1.0), rec(1, f64::NAN)]).is_none());
    }
}
