//! Structured tracing: span timelines from request admission down to
//! the codec passes.
//!
//! The engine's older telemetry answers *how much* (byte counters,
//! busy-second gauges) but not *where the wall-clock went*. This module
//! records **spans** — named, categorised intervals with a process id
//! (`pid` = request / forward step) and a thread id (`tid` = TP rank,
//! or [`TID_COORD`] for the coordinator) — into per-thread bounded ring
//! buffers, merged on drain and exported as Chrome-trace/Perfetto JSON
//! (see [`export`]).
//!
//! Design constraints, in order:
//!
//! 1. **Near-zero cost when disabled.** [`span`] checks one relaxed
//!    atomic through a thread-local and returns an inert guard; no
//!    clock is read. Tracing is off unless something (serve loop,
//!    `tpcc trace`, the rankpar bench) turns it on.
//! 2. **No cross-thread contention when enabled.** Each recording
//!    thread owns its own [`SpanRing`]; only that thread pushes to it.
//!    The ring's mutex is uncontended except while a drain/snapshot
//!    briefly clones it out.
//! 3. **Bounded memory.** Rings hold [`DEFAULT_RING_CAP`] spans;
//!    overflow drops the *oldest* span and counts it, so a long-running
//!    server keeps the recent window instead of OOMing or stalling.
//!
//! Spans are sequence-numbered from one shared counter **at close**
//! (children close before parents, so a child's `seq` is smaller than
//! its parent's). Drain merges all rings and sorts by `(t0_ns, seq)`,
//! which is deterministic for any fixed set of spans.
//!
//! Besides the timeline, the tracer folds every closed span into
//! cumulative per-phase counters (`phase_compute_s`, `phase_codec_s`,
//! `phase_fabric_wait_s`, `phase_link_s`) that the coordinator mirrors
//! into `/metrics`. Fabric wait and link time are credited explicitly
//! ([`Tracer::add_phase`]) rather than from guard durations: the
//! exchange span covers the whole rendezvous (deposit + gather + wait)
//! while the phase gauge must count only the time actually blocked,
//! and link time is *modeled* (virtual clock), not walled.

pub mod alert;
pub mod export;
pub mod flight;
pub mod log;
pub mod top;

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// `tid` used for spans recorded on the coordinator / engine thread
/// (rank tids are the small integers `0..tp`).
pub const TID_COORD: u32 = 1000;

/// Default per-thread ring capacity (spans). A forward pass on an
/// 8-rank micro model closes a few hundred spans, so this keeps many
/// recent steps without unbounded growth.
pub const DEFAULT_RING_CAP: usize = 1 << 16;

/// Span category: names the phase a span belongs to and drives both
/// the Chrome-trace `cat` field and the `/metrics` phase gauges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cat {
    /// XLA stage execution (embed / attn / mlp / final)
    Compute,
    /// mx quantization (encode side of the codec)
    Encode,
    /// mx dequant + reduce (decode side of the codec)
    Decode,
    /// blocked in a fabric barrier / rendezvous
    Fabric,
    /// modeled wire time (virtual clock, never walled)
    Link,
    /// request waiting for admission
    Queue,
    /// whole request lifetime (arrival to finish)
    Request,
    /// structural wrapper (forward pass, collective call) — excluded
    /// from phase accounting so it never double-counts its children
    Step,
}

/// Number of phase accumulators (compute, codec, fabric_wait, link).
const N_PHASE: usize = 4;

impl Cat {
    /// Chrome-trace `cat` string.
    pub fn name(self) -> &'static str {
        match self {
            Cat::Compute => "compute",
            Cat::Encode => "codec.encode",
            Cat::Decode => "codec.decode",
            Cat::Fabric => "fabric",
            Cat::Link => "link",
            Cat::Queue => "queue",
            Cat::Request => "request",
            Cat::Step => "step",
        }
    }

    /// Phase accumulator slot, or `None` when the category is excluded
    /// from guard-driven accounting (wrappers; explicitly-credited
    /// fabric/link; request/queue, which the latency histograms own).
    fn phase_slot(self) -> Option<usize> {
        match self {
            Cat::Compute => Some(0),
            Cat::Encode | Cat::Decode => Some(1),
            _ => None,
        }
    }
}

/// Slot indices for [`Tracer::add_phase`]'s explicitly-credited phases.
const PHASE_FABRIC: usize = 2;
const PHASE_LINK: usize = 3;

/// One closed interval. Times are nanoseconds since the tracer's epoch
/// (its construction instant); the exporter converts to microseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: &'static str,
    pub cat: Cat,
    /// request id (coordinator spans) or forward-step id (engine spans)
    pub pid: u64,
    /// TP rank, or [`TID_COORD`]
    pub tid: u32,
    pub t0_ns: u64,
    pub dur_ns: u64,
    /// global close-order sequence number (unique per tracer)
    pub seq: u64,
    /// site / layer index, `-1` when not applicable
    pub arg: i64,
}

struct RingInner {
    buf: VecDeque<Span>,
    cap: usize,
    dropped: u64,
}

/// Bounded per-thread span buffer. Only the owning thread pushes;
/// drains lock briefly from the draining thread.
pub struct SpanRing {
    #[allow(dead_code)] // debugging aid; not exported (tids carry identity)
    label: String,
    inner: Mutex<RingInner>,
}

impl SpanRing {
    fn new(label: &str, cap: usize) -> SpanRing {
        SpanRing {
            label: label.to_string(),
            inner: Mutex::new(RingInner { buf: VecDeque::with_capacity(cap.min(1024)), cap, dropped: 0 }),
        }
    }

    /// Append a span, dropping (and counting) the oldest on overflow.
    pub fn record(&self, s: Span) {
        let mut g = self.inner.lock().unwrap();
        if g.buf.len() == g.cap {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(s);
    }

    fn take(&self) -> (Vec<Span>, u64) {
        let mut g = self.inner.lock().unwrap();
        let spans = g.buf.drain(..).collect();
        let dropped = g.dropped;
        g.dropped = 0;
        (spans, dropped)
    }

    fn peek(&self) -> (Vec<Span>, u64) {
        let g = self.inner.lock().unwrap();
        (g.buf.iter().cloned().collect(), g.dropped)
    }
}

/// A merged, time-ordered view of every ring.
#[derive(Debug, Clone)]
pub struct TraceDump {
    /// sorted by `(t0_ns, seq)`
    pub spans: Vec<Span>,
    /// spans lost to ring overflow since the last drain
    pub dropped: u64,
}

impl TraceDump {
    /// Keep only the last `n` spans (by start time).
    pub fn tail(mut self, n: usize) -> TraceDump {
        let len = self.spans.len();
        if len > n {
            self.spans.drain(..len - n);
        }
        self
    }

    /// Chrome-trace / Perfetto JSON (see [`export::to_chrome_json`]).
    pub fn to_chrome_json(&self) -> crate::util::json::Json {
        export::to_chrome_json(self)
    }
}

/// The recorder shared by every thread of one engine + coordinator.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    seq: AtomicU64,
    ring_cap: usize,
    rings: Mutex<Vec<Arc<SpanRing>>>,
    /// cumulative nanoseconds per phase slot (see [`Cat::phase_slot`])
    phase_ns: [AtomicU64; N_PHASE],
}

impl Tracer {
    /// A disabled tracer with the default ring capacity.
    pub fn new() -> Arc<Tracer> {
        Tracer::with_capacity(DEFAULT_RING_CAP)
    }

    /// A disabled tracer whose rings hold `cap` spans each.
    pub fn with_capacity(cap: usize) -> Arc<Tracer> {
        Arc::new(Tracer {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            ring_cap: cap.max(1),
            rings: Mutex::new(Vec::new()),
            phase_ns: Default::default(),
        })
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Register a new ring (one per recording thread).
    pub fn register(&self, label: &str) -> Arc<SpanRing> {
        let ring = Arc::new(SpanRing::new(label, self.ring_cap));
        self.rings.lock().unwrap().push(ring.clone());
        ring
    }

    /// Nanoseconds since this tracer's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn instant_ns(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    pub fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    fn add_phase_ns(&self, slot: usize, ns: u64) {
        self.phase_ns[slot].fetch_add(ns, Ordering::Relaxed);
    }

    /// Credit measured fabric-wait or modeled link seconds to the
    /// matching phase gauge (only [`Cat::Fabric`] / [`Cat::Link`] are
    /// accepted; other categories accumulate via their span guards).
    pub fn add_phase(&self, cat: Cat, secs: f64) {
        if !self.enabled() || !secs.is_finite() || secs <= 0.0 {
            return;
        }
        let slot = match cat {
            Cat::Fabric => PHASE_FABRIC,
            Cat::Link => PHASE_LINK,
            _ => return,
        };
        self.add_phase_ns(slot, (secs * 1e9) as u64);
    }

    /// Cumulative seconds per phase: `[compute, codec, fabric_wait, link]`.
    pub fn phase_snapshot(&self) -> [f64; N_PHASE] {
        let mut out = [0.0; N_PHASE];
        for (o, p) in out.iter_mut().zip(&self.phase_ns) {
            *o = p.load(Ordering::Relaxed) as f64 * 1e-9;
        }
        out
    }

    /// Spans lost to ring overflow (not reset by reading).
    pub fn dropped_total(&self) -> u64 {
        self.rings.lock().unwrap().iter().map(|r| r.inner.lock().unwrap().dropped).sum()
    }

    /// `/metrics` gauges derived from the phase accumulators.
    pub fn phase_metrics(&self) -> Vec<(String, f64)> {
        let p = self.phase_snapshot();
        vec![
            ("phase_compute_s".to_string(), p[0]),
            ("phase_codec_s".to_string(), p[1]),
            ("phase_fabric_wait_s".to_string(), p[2]),
            ("phase_link_s".to_string(), p[3]),
            ("trace_spans_dropped".to_string(), self.dropped_total() as f64),
        ]
    }

    fn collect(&self, destructive: bool) -> TraceDump {
        let rings = self.rings.lock().unwrap();
        let mut spans = Vec::new();
        let mut dropped = 0;
        for r in rings.iter() {
            let (s, d) = if destructive { r.take() } else { r.peek() };
            spans.extend(s);
            dropped += d;
        }
        drop(rings);
        spans.sort_by_key(|s| (s.t0_ns, s.seq));
        TraceDump { spans, dropped }
    }

    /// Merge + sort every ring, emptying them (CLI export).
    pub fn drain(&self) -> TraceDump {
        self.collect(true)
    }

    /// Merge + sort without consuming (the `/trace` endpoint, so
    /// polling observers don't steal each other's spans).
    pub fn snapshot(&self) -> TraceDump {
        self.collect(false)
    }
}

// ---- thread-local recording context ---------------------------------

struct ThreadCtx {
    tracer: Arc<Tracer>,
    ring: Arc<SpanRing>,
    pid: u64,
    tid: u32,
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = RefCell::new(None);
}

/// Bind this thread to `tracer`: registers a fresh ring and makes
/// [`span`] / [`record_abs`] record into it. Re-installing (e.g. a new
/// engine built on the same thread) replaces the previous binding.
pub fn install(tracer: &Arc<Tracer>, label: &str, tid: u32) {
    let ring = tracer.register(label);
    CTX.with(|c| {
        *c.borrow_mut() = Some(ThreadCtx { tracer: tracer.clone(), ring, pid: 0, tid });
    });
}

/// Set the `pid` stamped on this thread's future spans (request id or
/// forward-step id).
pub fn set_pid(pid: u64) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.pid = pid;
        }
    });
}

/// Set the `tid` stamped on this thread's future spans (the TP rank a
/// multiplexing worker is currently executing).
pub fn set_tid(tid: u32) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            ctx.tid = tid;
        }
    });
}

struct LiveSpan {
    tracer: Arc<Tracer>,
    ring: Arc<SpanRing>,
    name: &'static str,
    cat: Cat,
    pid: u64,
    tid: u32,
    arg: i64,
    t0: Instant,
}

/// Scoped span: records the enclosed interval when dropped. Inert (no
/// clock read, nothing recorded) when the thread has no tracer bound
/// or tracing is disabled.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(l) = self.live.take() {
            let dur_ns = l.t0.elapsed().as_nanos() as u64;
            if let Some(slot) = l.cat.phase_slot() {
                l.tracer.add_phase_ns(slot, dur_ns);
            }
            l.ring.record(Span {
                name: l.name,
                cat: l.cat,
                pid: l.pid,
                tid: l.tid,
                t0_ns: l.tracer.instant_ns(l.t0),
                dur_ns,
                seq: l.tracer.next_seq(),
                arg: l.arg,
            });
        }
    }
}

/// Open a scoped span (see [`SpanGuard`]).
pub fn span(name: &'static str, cat: Cat) -> SpanGuard {
    span_arg(name, cat, -1)
}

/// [`span`] with a site/layer argument.
pub fn span_arg(name: &'static str, cat: Cat, arg: i64) -> SpanGuard {
    CTX.with(|c| {
        let b = c.borrow();
        match b.as_ref() {
            Some(ctx) if ctx.tracer.enabled() => SpanGuard {
                live: Some(LiveSpan {
                    tracer: ctx.tracer.clone(),
                    ring: ctx.ring.clone(),
                    name,
                    cat,
                    pid: ctx.pid,
                    tid: ctx.tid,
                    arg,
                    t0: Instant::now(),
                }),
            },
            _ => SpanGuard { live: None },
        }
    })
}

/// Record an already-measured interval with explicit ids — used for
/// spans whose endpoints live outside any one scope (queue wait,
/// request lifetime reconstructed from session timestamps). No-op when
/// the thread has no tracer bound or tracing is disabled.
pub fn record_abs(name: &'static str, cat: Cat, pid: u64, tid: u32, start: Instant, dur_s: f64) {
    if !dur_s.is_finite() || dur_s < 0.0 {
        return;
    }
    CTX.with(|c| {
        let b = c.borrow();
        if let Some(ctx) = b.as_ref() {
            if !ctx.tracer.enabled() {
                return;
            }
            ctx.ring.record(Span {
                name,
                cat,
                pid,
                tid,
                t0_ns: ctx.tracer.instant_ns(start),
                dur_ns: (dur_s * 1e9) as u64,
                seq: ctx.tracer.next_seq(),
                arg: -1,
            });
        }
    });
}

/// Credit modeled (virtual-clock) seconds to a phase gauge through the
/// thread's bound tracer — no span is recorded.
pub fn add_virtual(cat: Cat, secs: f64) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow().as_ref() {
            ctx.tracer.add_phase(cat, secs);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn raw(name: &'static str, t0_ns: u64, dur_ns: u64, seq: u64) -> Span {
        Span { name, cat: Cat::Compute, pid: 1, tid: 0, t0_ns, dur_ns, seq, arg: -1 }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        install(&t, "test", 0);
        {
            let _g = span("work", Cat::Compute);
        }
        assert!(t.drain().spans.is_empty());
        assert_eq!(t.phase_snapshot(), [0.0; N_PHASE]);
    }

    #[test]
    fn span_without_install_is_inert() {
        // fresh thread: no ctx bound — must not panic or record
        std::thread::spawn(|| {
            let _g = span("orphan", Cat::Compute);
            record_abs("orphan", Cat::Queue, 0, 0, Instant::now(), 0.1);
            add_virtual(Cat::Link, 0.1);
        })
        .join()
        .unwrap();
    }

    #[test]
    fn guard_records_and_accumulates_phase() {
        let t = Tracer::new();
        t.set_enabled(true);
        install(&t, "test", 3);
        set_pid(42);
        {
            let _g = span_arg("attn", Cat::Compute, 5);
            std::thread::sleep(Duration::from_millis(2));
        }
        let d = t.drain();
        assert_eq!(d.spans.len(), 1);
        let s = &d.spans[0];
        assert_eq!((s.name, s.pid, s.tid, s.arg), ("attn", 42, 3, 5));
        assert!(s.dur_ns >= 1_000_000, "dur {} ns", s.dur_ns);
        let p = t.phase_snapshot();
        assert!(p[0] > 0.0, "compute phase not accumulated");
        assert_eq!(p[1], 0.0);
    }

    #[test]
    fn nesting_orders_child_before_parent_and_contains_it() {
        let t = Tracer::new();
        t.set_enabled(true);
        install(&t, "test", 0);
        {
            let _outer = span("outer", Cat::Step);
            std::thread::sleep(Duration::from_millis(1));
            {
                let _inner = span("inner", Cat::Compute);
                std::thread::sleep(Duration::from_millis(1));
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let d = t.drain();
        assert_eq!(d.spans.len(), 2);
        // sorted by start: outer first
        assert_eq!(d.spans[0].name, "outer");
        assert_eq!(d.spans[1].name, "inner");
        let (outer, inner) = (&d.spans[0], &d.spans[1]);
        // child closes first, so its seq is smaller
        assert!(inner.seq < outer.seq);
        // containment
        assert!(inner.t0_ns >= outer.t0_ns);
        assert!(inner.t0_ns + inner.dur_ns <= outer.t0_ns + outer.dur_ns);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let t = Tracer::with_capacity(4);
        let ring = t.register("test");
        for i in 0..10u64 {
            ring.record(raw("s", i, 1, i));
        }
        let d = t.drain();
        assert_eq!(d.dropped, 6);
        let starts: Vec<u64> = d.spans.iter().map(|s| s.t0_ns).collect();
        assert_eq!(starts, vec![6, 7, 8, 9], "newest spans kept");
        // drained: counters reset
        assert_eq!(t.drain().dropped, 0);
    }

    #[test]
    fn cross_thread_merge_is_deterministic() {
        // same spans pushed from different threads in different
        // interleavings must drain in the same order
        let order = |shuffle: bool| {
            let t = Tracer::with_capacity(64);
            let mk = |r: &SpanRing, ids: &[u64]| {
                for &i in ids {
                    r.record(raw("s", i * 10, 5, i));
                }
            };
            let (a, b) = (t.register("a"), t.register("b"));
            if shuffle {
                mk(&b, &[1, 3, 5]);
                mk(&a, &[0, 2, 4]);
            } else {
                mk(&a, &[0, 2, 4]);
                mk(&b, &[1, 3, 5]);
            }
            t.drain().spans.iter().map(|s| s.seq).collect::<Vec<_>>()
        };
        assert_eq!(order(false), order(true));
        assert_eq!(order(false), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn snapshot_is_nondestructive_and_tail_keeps_newest() {
        let t = Tracer::with_capacity(64);
        let ring = t.register("test");
        for i in 0..8u64 {
            ring.record(raw("s", i, 1, i));
        }
        assert_eq!(t.snapshot().spans.len(), 8);
        assert_eq!(t.snapshot().spans.len(), 8, "snapshot consumed spans");
        let tail = t.snapshot().tail(3);
        assert_eq!(tail.spans.iter().map(|s| s.t0_ns).collect::<Vec<_>>(), vec![5, 6, 7]);
        assert_eq!(t.drain().spans.len(), 8);
        assert_eq!(t.drain().spans.len(), 0);
    }

    #[test]
    fn explicit_phase_credit_and_virtual_link() {
        let t = Tracer::new();
        t.set_enabled(true);
        install(&t, "test", 0);
        t.add_phase(Cat::Fabric, 0.5);
        add_virtual(Cat::Link, 0.25);
        t.add_phase(Cat::Link, f64::NAN); // ignored
        t.add_phase(Cat::Compute, 9.0); // wrong slot: ignored
        let p = t.phase_snapshot();
        assert!((p[2] - 0.5).abs() < 1e-9, "fabric {p:?}");
        assert!((p[3] - 0.25).abs() < 1e-9, "link {p:?}");
        assert_eq!(p[0], 0.0);
        let m = t.phase_metrics();
        assert_eq!(m[0].0, "phase_compute_s");
        assert!(m.iter().any(|(k, v)| k == "phase_fabric_wait_s" && (*v - 0.5).abs() < 1e-9));
    }

    #[test]
    fn record_abs_stamps_explicit_ids() {
        let t = Tracer::new();
        t.set_enabled(true);
        install(&t, "test", 7);
        let start = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        record_abs("queue", Cat::Queue, 99, TID_COORD, start, 0.001);
        let d = t.drain();
        assert_eq!(d.spans.len(), 1);
        assert_eq!(d.spans[0].pid, 99);
        assert_eq!(d.spans[0].tid, TID_COORD);
        assert_eq!(d.spans[0].dur_ns, 1_000_000);
    }
}
