//! Declarative alert rules evaluated over [`MetricsHistory`] windows.
//!
//! The rule set is fixed at construction (the serving stack's failure
//! modes are known; we want zero-config alerting, not a DSL): TTFT SLO
//! burn-rate (SRE multi-window — 1 m **and** 5 m must both burn so a
//! brief blip can't page), drift-sentinel trips, preemption storms, KV
//! pool exhaustion, queue-wait growth, and worker-pool saturation
//! (shed rate).
//!
//! Each rule runs a three-state machine with hysteresis:
//!
//! ```text
//!  Inactive --breach--> Pending --breach held for_s--> Firing
//!     ^                    |                             |
//!     +----- !breach ------+<----------- !breach --------+
//! ```
//!
//! `Pending -> Inactive` is silent (the for-duration *is* the flap
//! filter); `-> Firing` and `Firing -> Inactive` each emit exactly one
//! [`Transition`], which the caller logs as a structured event. Missing
//! inputs (empty history, NaN percentile) never breach — a freshly
//! booted server with no traffic must not page anyone.
//!
//! The engine has **no internal clock**: [`AlertEngine::evaluate`]
//! takes `now_s` on the history ring's time base, so the coordinator's
//! sampler drives it in production and tests drive it with synthetic
//! time — hysteresis becomes deterministic instead of sleep-based.
//!
//! [`MetricsHistory`]: crate::metrics::MetricsHistory

use std::sync::Mutex;

use super::log::{Level, Logger};
use crate::metrics::Registry;
use crate::util::json::{self, Json};

/// Burn-rate (both 1 m and 5 m) above which the TTFT SLO alert trips.
/// At a 1% error budget this is >10% of first tokens missing the SLO.
pub const BURN_RATE_LIMIT: f64 = 10.0;

/// Preemptions per second (10 s window) that count as a storm.
pub const PREEMPTION_STORM_PER_S: f64 = 0.5;

/// Free-block fraction below which the KV pool counts as exhausted.
pub const KV_EXHAUSTED_FREE_FRAC: f64 = 0.05;

/// Queue-wait p95 (seconds) above which admission is backing up.
pub const QUEUE_WAIT_P95_LIMIT_S: f64 = 1.0;

/// 503 sheds per second (10 s window) that count as pool saturation.
pub const SHED_RATE_PER_S: f64 = 0.1;

/// Window the storm/shed rates are measured over.
pub const RATE_WINDOW_S: f64 = 10.0;

/// Hysteresis: a rule with `for_s > 0` must breach continuously this
/// long before firing. Two seconds spans ~8 sampler ticks at the
/// default cadence — enough to ignore a single-tick spike.
pub const DEFAULT_FOR_S: f64 = 2.0;

/// How a rule's measured value compares against its threshold.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cmp {
    /// breach when value > threshold
    Above,
    /// breach when value < threshold
    Below,
}

/// One declarative rule (static description; runtime state lives in
/// [`RuleState`]).
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub name: &'static str,
    /// Human-readable condition, served on `GET /alerts`.
    pub expr: &'static str,
    /// Severity the firing transition is logged at.
    pub severity: Level,
    /// Continuous-breach duration required before firing (0 = immediate).
    pub for_s: f64,
    pub threshold: f64,
    cmp: Cmp,
}

/// Everything the rule set reads, pre-extracted so the state machine is
/// a pure function of (inputs, now). `None`/`NaN` means "no data" and
/// never breaches.
#[derive(Debug, Clone, Copy, Default)]
pub struct AlertInputs {
    pub burn_1m: Option<f64>,
    pub burn_5m: Option<f64>,
    /// Sentinel sites currently tripped (from the `drift_sites_tripped`
    /// custom gauge).
    pub drift_sites_tripped: f64,
    pub preemptions_per_s: Option<f64>,
    pub kv_blocks_free: f64,
    /// free + in_use; 0 means "no pool" and the exhaustion rule stays
    /// quiet.
    pub kv_blocks_total: f64,
    /// NaN when no queue waits were recorded.
    pub queue_wait_p95_s: f64,
    pub sheds_per_s: Option<f64>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Inactive,
    /// breaching since `since_s`, not yet held for `for_s`
    Pending { since_s: f64 },
    Firing { since_s: f64 },
}

impl State {
    fn name(self) -> &'static str {
        match self {
            State::Inactive => "inactive",
            State::Pending { .. } => "pending",
            State::Firing { .. } => "firing",
        }
    }
}

struct RuleState {
    rule: Rule,
    state: State,
    /// last measured value (None = no data at the last tick)
    value: Option<f64>,
    fired_total: u64,
    resolved_total: u64,
}

/// One state-machine edge worth telling the operator about.
#[derive(Debug, Clone)]
pub struct Transition {
    pub rule: &'static str,
    pub severity: Level,
    /// true = fired, false = resolved
    pub firing: bool,
    /// measured value at the transition tick (NaN on resolve with no
    /// data, e.g. history went empty)
    pub value: f64,
    pub threshold: f64,
    pub at_s: f64,
}

/// The fixed rule set + per-rule state machines (one per coordinator).
pub struct AlertEngine {
    rules: Mutex<Vec<RuleState>>,
}

impl Default for AlertEngine {
    fn default() -> AlertEngine {
        AlertEngine::new()
    }
}

impl AlertEngine {
    pub fn new() -> AlertEngine {
        let rules = vec![
            Rule {
                name: "ttft_slo_burn",
                expr: "ttft burn_rate(1m) > 10 and burn_rate(5m) > 10",
                severity: Level::Error,
                // the multi-window condition is itself the flap filter
                for_s: 0.0,
                threshold: BURN_RATE_LIMIT,
                cmp: Cmp::Above,
            },
            Rule {
                name: "drift_tripped",
                expr: "drift_sites_tripped > 0",
                severity: Level::Warn,
                for_s: 0.0,
                threshold: 0.0,
                cmp: Cmp::Above,
            },
            Rule {
                name: "preemption_storm",
                expr: "rate(preemptions_total[10s]) > 0.5/s for 2s",
                severity: Level::Warn,
                for_s: DEFAULT_FOR_S,
                threshold: PREEMPTION_STORM_PER_S,
                cmp: Cmp::Above,
            },
            Rule {
                name: "kv_pool_exhausted",
                expr: "kv_blocks_free / kv_blocks_total < 0.05 for 2s",
                severity: Level::Warn,
                for_s: DEFAULT_FOR_S,
                threshold: KV_EXHAUSTED_FREE_FRAC,
                cmp: Cmp::Below,
            },
            Rule {
                name: "queue_wait_growth",
                expr: "queue_wait_p95_s > 1.0 for 2s",
                severity: Level::Warn,
                for_s: DEFAULT_FOR_S,
                threshold: QUEUE_WAIT_P95_LIMIT_S,
                cmp: Cmp::Above,
            },
            Rule {
                name: "pool_saturated",
                expr: "rate(requests_shed[10s]) > 0.1/s",
                severity: Level::Error,
                for_s: 0.0,
                threshold: SHED_RATE_PER_S,
                cmp: Cmp::Above,
            },
        ];
        AlertEngine {
            rules: Mutex::new(
                rules
                    .into_iter()
                    .map(|rule| RuleState {
                        rule,
                        state: State::Inactive,
                        value: None,
                        fired_total: 0,
                        resolved_total: 0,
                    })
                    .collect(),
            ),
        }
    }

    /// The value each rule compares against its threshold. `None` (no
    /// data) never breaches.
    fn measure(rule: &Rule, inp: &AlertInputs) -> Option<f64> {
        let finite = |v: f64| v.is_finite().then_some(v);
        match rule.name {
            // multi-window: the *smaller* burn must clear the limit, so
            // comparing min(burn1m, burn5m) > limit is the AND
            "ttft_slo_burn" => match (inp.burn_1m, inp.burn_5m) {
                (Some(a), Some(b)) => finite(a.min(b)),
                _ => None,
            },
            "drift_tripped" => finite(inp.drift_sites_tripped),
            "preemption_storm" => inp.preemptions_per_s.and_then(finite),
            "kv_pool_exhausted" => {
                if inp.kv_blocks_total <= 0.0 {
                    return None;
                }
                finite(inp.kv_blocks_free / inp.kv_blocks_total)
            }
            "queue_wait_growth" => finite(inp.queue_wait_p95_s),
            "pool_saturated" => inp.sheds_per_s.and_then(finite),
            _ => None,
        }
    }

    /// Advance every rule's state machine one tick. Returns the edges
    /// (fired / resolved) this tick produced — at most one per rule.
    pub fn evaluate(&self, inputs: &AlertInputs, now_s: f64) -> Vec<Transition> {
        let mut out = Vec::new();
        let mut rules = self.rules.lock().unwrap();
        for rs in rules.iter_mut() {
            let value = AlertEngine::measure(&rs.rule, inputs);
            rs.value = value;
            let breach = match (value, rs.rule.cmp) {
                (Some(v), Cmp::Above) => v > rs.rule.threshold,
                (Some(v), Cmp::Below) => v < rs.rule.threshold,
                (None, _) => false,
            };
            let fire = |rs: &mut RuleState, out: &mut Vec<Transition>| {
                rs.state = State::Firing { since_s: now_s };
                rs.fired_total += 1;
                out.push(Transition {
                    rule: rs.rule.name,
                    severity: rs.rule.severity,
                    firing: true,
                    value: value.unwrap_or(f64::NAN),
                    threshold: rs.rule.threshold,
                    at_s: now_s,
                });
            };
            match rs.state {
                State::Inactive if breach => {
                    if rs.rule.for_s <= 0.0 {
                        fire(rs, &mut out);
                    } else {
                        rs.state = State::Pending { since_s: now_s };
                    }
                }
                State::Pending { since_s } if breach => {
                    if now_s - since_s >= rs.rule.for_s {
                        fire(rs, &mut out);
                    }
                }
                State::Pending { .. } => {
                    // flap below the for-duration: silent reset
                    rs.state = State::Inactive;
                }
                State::Firing { .. } if !breach => {
                    rs.state = State::Inactive;
                    rs.resolved_total += 1;
                    out.push(Transition {
                        rule: rs.rule.name,
                        severity: rs.rule.severity,
                        firing: false,
                        value: value.unwrap_or(f64::NAN),
                        threshold: rs.rule.threshold,
                        at_s: now_s,
                    });
                }
                _ => {}
            }
        }
        out
    }

    /// Build [`AlertInputs`] from a live registry and advance the state
    /// machines at `now_s` (the history ring's time base), logging each
    /// transition. Called by the coordinator's sampler every tick; tests
    /// call it with synthetic time.
    pub fn tick_at(&self, metrics: &Registry, log: &Logger, now_s: f64) -> Vec<Transition> {
        let budget = crate::metrics::history::DEFAULT_SLO_ERROR_BUDGET;
        let slo = metrics.ttft_slo();
        let (burn_1m, burn_5m) = if slo > 0.0 {
            (
                metrics.history.burn_rate_at(60.0, budget, now_s),
                metrics.history.burn_rate_at(300.0, budget, now_s),
            )
        } else {
            (None, None)
        };
        let short = metrics.history.rates_at(RATE_WINDOW_S, now_s);
        let free = metrics.kv_blocks_free.get() as f64;
        let in_use = metrics.kv_blocks_in_use.get() as f64;
        let inputs = AlertInputs {
            burn_1m,
            burn_5m,
            drift_sites_tripped: metrics.get_custom("drift_sites_tripped").unwrap_or(0.0),
            preemptions_per_s: short.map(|r| r.preemptions_per_s),
            kv_blocks_free: free,
            kv_blocks_total: free + in_use,
            queue_wait_p95_s: metrics.queue_wait.snapshot().percentile(95.0),
            sheds_per_s: short.map(|r| r.sheds_per_s),
        };
        let transitions = self.evaluate(&inputs, now_s);
        for t in &transitions {
            let (level, msg) = if t.firing {
                (t.severity, "alert firing")
            } else {
                (Level::Info, "alert resolved")
            };
            log.log(
                level,
                "alert",
                msg,
                vec![
                    ("rule", json::s(t.rule)),
                    ("value", json::num_or_null(t.value)),
                    ("threshold", json::num(t.threshold)),
                    ("at_s", json::num(t.at_s)),
                ],
            );
        }
        transitions
    }

    /// Rules currently in the firing state.
    pub fn firing(&self) -> Vec<&'static str> {
        self.rules
            .lock()
            .unwrap()
            .iter()
            .filter(|rs| matches!(rs.state, State::Firing { .. }))
            .map(|rs| rs.rule.name)
            .collect()
    }

    /// The `GET /alerts` body.
    pub fn to_json(&self) -> Json {
        let rules = self.rules.lock().unwrap();
        let mut firing = 0usize;
        let rows: Vec<Json> = rules
            .iter()
            .map(|rs| {
                if matches!(rs.state, State::Firing { .. }) {
                    firing += 1;
                }
                let since = match rs.state {
                    State::Pending { since_s } | State::Firing { since_s } => json::num(since_s),
                    State::Inactive => Json::Null,
                };
                json::obj(vec![
                    ("name", json::s(rs.rule.name)),
                    ("expr", json::s(rs.rule.expr)),
                    ("severity", json::s(rs.rule.severity.name())),
                    ("state", json::s(rs.state.name())),
                    ("for_s", json::num(rs.rule.for_s)),
                    ("threshold", json::num(rs.rule.threshold)),
                    ("value", rs.value.map(json::num_or_null).unwrap_or(Json::Null)),
                    ("since_s", since),
                    ("fired_total", json::num(rs.fired_total as f64)),
                    ("resolved_total", json::num(rs.resolved_total as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("firing", json::num(firing as f64)),
            ("rules", Json::Arr(rows)),
        ])
    }

    /// Per-rule gauges appended to the Prometheus exposition:
    /// `tpcc_alert_firing{rule="…"} 0|1` plus cumulative fired/resolved
    /// counters.
    pub fn to_prometheus(&self) -> String {
        let rules = self.rules.lock().unwrap();
        let mut out = String::with_capacity(512);
        out.push_str(
            "# HELP tpcc_alert_firing Whether the alert rule is currently firing.\n\
             # TYPE tpcc_alert_firing gauge\n",
        );
        for rs in rules.iter() {
            let v = matches!(rs.state, State::Firing { .. }) as u8;
            out.push_str(&format!("tpcc_alert_firing{{rule=\"{}\"}} {v}\n", rs.rule.name));
        }
        out.push_str(
            "# HELP tpcc_alert_fired_total Times the rule transitioned to firing.\n\
             # TYPE tpcc_alert_fired_total counter\n",
        );
        for rs in rules.iter() {
            out.push_str(&format!(
                "tpcc_alert_fired_total{{rule=\"{}\"}} {}\n",
                rs.rule.name, rs.fired_total
            ));
        }
        out.push_str(
            "# HELP tpcc_alert_resolved_total Times the rule resolved.\n\
             # TYPE tpcc_alert_resolved_total counter\n",
        );
        for rs in rules.iter() {
            out.push_str(&format!(
                "tpcc_alert_resolved_total{{rule=\"{}\"}} {}\n",
                rs.rule.name, rs.resolved_total
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drift(n: f64) -> AlertInputs {
        AlertInputs { drift_sites_tripped: n, ..AlertInputs::default() }
    }

    #[test]
    fn empty_inputs_never_fire() {
        let eng = AlertEngine::new();
        // queue_wait_p95_s defaults to 0.0 here; force the no-data shape
        let inputs = AlertInputs { queue_wait_p95_s: f64::NAN, ..AlertInputs::default() };
        for tick in 0..20 {
            let tr = eng.evaluate(&inputs, tick as f64 * 0.25);
            assert!(tr.is_empty(), "tick {tick} produced {tr:?}");
        }
        assert!(eng.firing().is_empty());
    }

    #[test]
    fn immediate_rule_fires_and_resolves_with_one_event_each() {
        let eng = AlertEngine::new();
        let tr = eng.evaluate(&drift(2.0), 1.0);
        assert_eq!(tr.len(), 1);
        assert_eq!(tr[0].rule, "drift_tripped");
        assert!(tr[0].firing);
        assert_eq!(tr[0].value, 2.0);
        // still breaching: no duplicate event
        assert!(eng.evaluate(&drift(2.0), 1.25).is_empty());
        assert_eq!(eng.firing(), vec!["drift_tripped"]);
        // recovers: exactly one resolved event
        let tr = eng.evaluate(&drift(0.0), 2.0);
        assert_eq!(tr.len(), 1);
        assert!(!tr[0].firing);
        assert!(eng.firing().is_empty());
        // and quiet afterwards
        assert!(eng.evaluate(&drift(0.0), 2.25).is_empty());
    }

    #[test]
    fn hysteresis_holds_fire_until_for_duration() {
        let eng = AlertEngine::new();
        let storm = AlertInputs {
            preemptions_per_s: Some(3.0),
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        // breaching from t=0; must stay silent until t >= 2.0
        assert!(eng.evaluate(&storm, 0.0).is_empty());
        assert!(eng.evaluate(&storm, 1.0).is_empty());
        assert!(eng.evaluate(&storm, 1.9).is_empty());
        let tr = eng.evaluate(&storm, 2.0);
        assert_eq!(tr.len(), 1);
        assert_eq!(tr[0].rule, "preemption_storm");
        assert!(tr[0].firing);
    }

    #[test]
    fn flap_below_for_duration_never_fires() {
        let eng = AlertEngine::new();
        let storm = AlertInputs {
            preemptions_per_s: Some(3.0),
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        let calm = AlertInputs {
            preemptions_per_s: Some(0.0),
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        // 1.5 s bursts separated by calm ticks: pending resets each time
        for cycle in 0..5 {
            let t0 = cycle as f64 * 10.0;
            assert!(eng.evaluate(&storm, t0).is_empty());
            assert!(eng.evaluate(&storm, t0 + 1.5).is_empty());
            assert!(eng.evaluate(&calm, t0 + 2.0).is_empty(), "silent pending reset");
        }
        assert!(eng.firing().is_empty());
        let body = eng.to_json().to_string();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("firing").unwrap().as_i64(), Some(0));
    }

    #[test]
    fn multi_window_burn_requires_both_windows() {
        let eng = AlertEngine::new();
        // short-window spike alone (5 m still calm): no page
        let spike = AlertInputs {
            burn_1m: Some(50.0),
            burn_5m: Some(2.0),
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        assert!(eng.evaluate(&spike, 0.0).is_empty());
        // both windows burning: fires immediately (for_s = 0)
        let sustained = AlertInputs {
            burn_1m: Some(50.0),
            burn_5m: Some(20.0),
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        let tr = eng.evaluate(&sustained, 0.25);
        assert_eq!(tr.len(), 1);
        assert_eq!(tr[0].rule, "ttft_slo_burn");
        assert_eq!(tr[0].value, 20.0, "reports the min of the two windows");
    }

    #[test]
    fn kv_exhaustion_needs_a_pool() {
        let eng = AlertEngine::new();
        // no pool (total 0): quiet
        let none = AlertInputs { queue_wait_p95_s: f64::NAN, ..AlertInputs::default() };
        assert!(eng.evaluate(&none, 0.0).is_empty());
        // 2 of 100 blocks free = 2% < 5%: pending, then firing
        let tight = AlertInputs {
            kv_blocks_free: 2.0,
            kv_blocks_total: 100.0,
            queue_wait_p95_s: f64::NAN,
            ..AlertInputs::default()
        };
        assert!(eng.evaluate(&tight, 0.0).is_empty());
        let tr = eng.evaluate(&tight, 2.5);
        assert_eq!(tr.len(), 1);
        assert_eq!(tr[0].rule, "kv_pool_exhausted");
    }

    #[test]
    fn json_and_prometheus_shapes() {
        let eng = AlertEngine::new();
        eng.evaluate(&drift(1.0), 0.5);
        let body = eng.to_json().to_string();
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("firing").unwrap().as_i64(), Some(1));
        let rules = j.get("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), 6);
        let drift_row = rules
            .iter()
            .find(|r| r.get("name").and_then(|n| n.as_str()) == Some("drift_tripped"))
            .unwrap();
        assert_eq!(drift_row.get("state").unwrap().as_str(), Some("firing"));
        assert_eq!(drift_row.get("fired_total").unwrap().as_i64(), Some(1));
        assert_eq!(drift_row.get("since_s").unwrap().as_f64(), Some(0.5));

        let prom = eng.to_prometheus();
        assert!(prom.contains("tpcc_alert_firing{rule=\"drift_tripped\"} 1\n"));
        assert!(prom.contains("tpcc_alert_firing{rule=\"preemption_storm\"} 0\n"));
        assert!(prom.contains("tpcc_alert_fired_total{rule=\"drift_tripped\"} 1\n"));
        // same line lint the registry exposition test enforces
        for line in prom.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (name_part, value) = line.rsplit_once(' ').unwrap();
            let name = name_part.split('{').next().unwrap();
            assert!(name.starts_with("tpcc_alert_"));
            assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
        }
    }
}
