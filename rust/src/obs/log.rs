//! Structured event log: the third observability pillar next to the
//! span recorder ([`super::Tracer`]) and the flight recorder
//! ([`super::flight`]).
//!
//! Spans answer *where the wall-clock went* and flight records answer
//! *what one request cost*; the event log answers *what happened* —
//! admissions, preemptions, chunk-lane slices, drift trips, shed
//! connections, worker panics — as leveled, structured events an
//! operator can tail (`GET /logs?last=N&level=warn`), scrape, or watch
//! in `tpcc top`.
//!
//! Design constraints mirror the span recorder's:
//!
//! 1. **Near-zero cost when filtered.** [`Logger::log`] checks one
//!    relaxed atomic against the event's level and returns before
//!    formatting anything. Lifecycle events are per-request (never
//!    per-token), so the surviving path — a brief mutex push into a
//!    bounded ring — is off the token hot path by construction.
//! 2. **Bounded memory.** The ring holds [`DEFAULT_LOG_CAP`] events;
//!    overflow drops the *oldest* event and counts it, so a long-running
//!    server keeps the recent window.
//! 3. **One sink.** Every diagnostic — coordinator, HTTP server, rank
//!    pool, alert engine, CLI — flows through [`Event`] formatting, so
//!    `--log-json` switches the whole process to JSON lines at once.
//!
//! Events carry a monotonic sequence number and seconds since the
//! logger's epoch; `GET /logs` serves the newest-N tail newest-last.

use std::collections::VecDeque;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{self, Json};

/// Default ring capacity (events). Lifecycle events are per-request,
/// so this retains thousands of requests' worth of history.
pub const DEFAULT_LOG_CAP: usize = 4096;

/// Sentinel level byte meaning "sink disabled".
const LEVEL_OFF: u8 = u8::MAX;

/// Event severity, ordered. The ring gate and the stderr sink each keep
/// events at-or-above their configured level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    /// Parse a level name (`--log-level`, `/logs?level=`).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Debug,
            1 => Level::Info,
            2 => Level::Warn,
            _ => Level::Error,
        }
    }
}

/// One structured log event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Seconds since the logger's epoch (its construction instant).
    pub t_s: f64,
    /// Global emit-order sequence number (unique per logger).
    pub seq: u64,
    pub level: Level,
    /// Emitting subsystem: `coordinator`, `server`, `rank`, `alert`,
    /// `cli`, `bench`.
    pub target: &'static str,
    pub message: String,
    /// Structured payload; keys are static (the event vocabulary is
    /// fixed at the call site), values arbitrary JSON.
    pub fields: Vec<(&'static str, Json)>,
}

impl Event {
    /// The JSON-lines object: fixed envelope keys plus the structured
    /// fields inlined (a field cannot shadow the envelope — envelope
    /// keys win by insertion into the map last).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = self
            .fields
            .iter()
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        pairs.push(("t_s", json::num(self.t_s)));
        pairs.push(("seq", json::num(self.seq as f64)));
        pairs.push(("level", json::s(self.level.name())));
        pairs.push(("target", json::s(self.target)));
        pairs.push(("msg", json::s(&self.message)));
        json::obj(pairs)
    }

    /// Plain-text rendering: `t=12.345 WARN  server msg k=v k=v`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "t={:.3} {:<5} {:<11} {}",
            self.t_s,
            self.level.name().to_ascii_uppercase(),
            self.target,
            self.message
        );
        for (k, v) in &self.fields {
            out.push(' ');
            out.push_str(k);
            out.push('=');
            match v {
                Json::Str(s) => out.push_str(s),
                other => out.push_str(&other.to_string()),
            }
        }
        out
    }
}

struct LogInner {
    buf: VecDeque<Event>,
    cap: usize,
    dropped: u64,
}

/// Bounded, leveled, structured event log (one per engine/coordinator;
/// detached test handles own a fresh one).
pub struct Logger {
    /// minimum level kept in the ring — the relaxed-atomic emit gate
    level: AtomicU8,
    /// minimum level echoed to stderr ([`LEVEL_OFF`] = silent)
    stderr_level: AtomicU8,
    /// stderr format: JSON lines (`--log-json`) vs plain text
    stderr_json: AtomicBool,
    epoch: Instant,
    seq: AtomicU64,
    /// cumulative events that passed the gate (not reset by reads)
    total: AtomicU64,
    inner: Mutex<LogInner>,
}

impl Logger {
    /// A logger keeping everything at-or-above [`Level::Debug`] in the
    /// ring, echoing nothing to stderr until [`Logger::set_stderr`].
    pub fn new() -> Arc<Logger> {
        Logger::with_capacity(DEFAULT_LOG_CAP)
    }

    pub fn with_capacity(cap: usize) -> Arc<Logger> {
        Arc::new(Logger {
            level: AtomicU8::new(Level::Debug as u8),
            stderr_level: AtomicU8::new(LEVEL_OFF),
            stderr_json: AtomicBool::new(false),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            total: AtomicU64::new(0),
            inner: Mutex::new(LogInner {
                buf: VecDeque::with_capacity(cap.clamp(1, DEFAULT_LOG_CAP)),
                cap: cap.max(1),
                dropped: 0,
            }),
        })
    }

    /// Set the minimum level the ring keeps (the emit gate).
    pub fn set_level(&self, level: Level) {
        self.level.store(level as u8, Ordering::Relaxed);
    }

    pub fn level(&self) -> Level {
        Level::from_u8(self.level.load(Ordering::Relaxed))
    }

    /// Configure the stderr sink: echo events at-or-above `level`
    /// (`None` silences it), as JSON lines when `json`.
    pub fn set_stderr(&self, level: Option<Level>, json: bool) {
        self.stderr_level
            .store(level.map_or(LEVEL_OFF, |l| l as u8), Ordering::Relaxed);
        self.stderr_json.store(json, Ordering::Relaxed);
    }

    /// Whether an event at `level` would pass the gate — lets call
    /// sites skip building expensive fields for filtered events.
    pub fn enabled(&self, level: Level) -> bool {
        (level as u8) >= self.level.load(Ordering::Relaxed)
    }

    /// Emit one event. Filtered levels cost one relaxed atomic load;
    /// surviving events take a brief mutex to push into the bounded
    /// ring (never on a per-token path).
    pub fn log(
        &self,
        level: Level,
        target: &'static str,
        message: &str,
        fields: Vec<(&'static str, Json)>,
    ) {
        if !self.enabled(level) {
            return;
        }
        let ev = Event {
            t_s: self.epoch.elapsed().as_secs_f64(),
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            level,
            target,
            message: message.to_string(),
            fields,
        };
        if (level as u8) >= self.stderr_level.load(Ordering::Relaxed) {
            let line = if self.stderr_json.load(Ordering::Relaxed) {
                ev.to_json().to_string()
            } else {
                ev.render()
            };
            let mut err = std::io::stderr().lock();
            let _ = writeln!(err, "{line}");
        }
        self.total.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        if g.buf.len() == g.cap {
            g.buf.pop_front();
            g.dropped += 1;
        }
        g.buf.push_back(ev);
    }

    pub fn debug(&self, target: &'static str, msg: &str, fields: Vec<(&'static str, Json)>) {
        self.log(Level::Debug, target, msg, fields);
    }
    pub fn info(&self, target: &'static str, msg: &str, fields: Vec<(&'static str, Json)>) {
        self.log(Level::Info, target, msg, fields);
    }
    pub fn warn(&self, target: &'static str, msg: &str, fields: Vec<(&'static str, Json)>) {
        self.log(Level::Warn, target, msg, fields);
    }
    pub fn error(&self, target: &'static str, msg: &str, fields: Vec<(&'static str, Json)>) {
        self.log(Level::Error, target, msg, fields);
    }

    /// Events that passed the gate since construction (not reset).
    pub fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Events lost to ring overflow.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Newest-last tail of the ring: up to `last` events at-or-above
    /// `min_level`. Non-destructive (polling observers must not steal
    /// each other's events).
    pub fn snapshot(&self, last: usize, min_level: Level) -> Vec<Event> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<Event> = g
            .buf
            .iter()
            .rev()
            .filter(|e| e.level >= min_level)
            .take(last)
            .cloned()
            .collect();
        out.reverse();
        out
    }

    /// The `GET /logs` body.
    pub fn to_json(&self, last: usize, min_level: Level) -> Json {
        let events: Vec<Json> = self.snapshot(last, min_level).iter().map(Event::to_json).collect();
        json::obj(vec![
            ("total", json::num(self.total() as f64)),
            ("dropped", json::num(self.dropped() as f64)),
            ("level", json::s(self.level().name())),
            ("min_level", json::s(min_level.name())),
            ("events", Json::Arr(events)),
        ])
    }
}

impl Default for Logger {
    fn default() -> Logger {
        Logger {
            level: AtomicU8::new(Level::Debug as u8),
            stderr_level: AtomicU8::new(LEVEL_OFF),
            stderr_json: AtomicBool::new(false),
            epoch: Instant::now(),
            seq: AtomicU64::new(0),
            total: AtomicU64::new(0),
            inner: Mutex::new(LogInner {
                buf: VecDeque::new(),
                cap: DEFAULT_LOG_CAP,
                dropped: 0,
            }),
        }
    }
}

/// One-shot stderr diagnostic for engine-less CLI paths (`main`'s
/// top-level error handler, `golden --emit`): same [`Event`] formatting
/// as the logger's stderr sink, so every line in the process renders
/// identically, without requiring a coordinator to exist.
pub fn cli(level: Level, message: &str, fields: Vec<(&'static str, Json)>) {
    let ev = Event { t_s: 0.0, seq: 0, level, target: "cli", message: message.to_string(), fields };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{}", ev.render());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARNING"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error > Level::Warn && Level::Warn > Level::Info && Level::Info > Level::Debug);
    }

    #[test]
    fn gate_filters_below_level() {
        let log = Logger::new();
        log.set_level(Level::Warn);
        log.info("server", "dropped", vec![]);
        log.warn("server", "kept", vec![]);
        assert_eq!(log.total(), 1);
        let evs = log.snapshot(10, Level::Debug);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].message, "kept");
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let log = Logger::with_capacity(3);
        for i in 0..7u64 {
            log.info("server", &format!("e{i}"), vec![]);
        }
        assert_eq!(log.dropped(), 4);
        assert_eq!(log.total(), 7);
        let evs = log.snapshot(10, Level::Debug);
        let msgs: Vec<&str> = evs.iter().map(|e| e.message.as_str()).collect();
        assert_eq!(msgs, vec!["e4", "e5", "e6"], "newest kept, oldest dropped");
        // seq is monotonic across the ring
        assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn snapshot_tail_and_level_filter() {
        let log = Logger::new();
        log.debug("coordinator", "d", vec![]);
        log.info("coordinator", "i", vec![]);
        log.warn("coordinator", "w1", vec![]);
        log.error("coordinator", "e", vec![]);
        log.warn("coordinator", "w2", vec![]);
        let warns = log.snapshot(2, Level::Warn);
        assert_eq!(
            warns.iter().map(|e| e.message.as_str()).collect::<Vec<_>>(),
            vec!["e", "w2"],
            "newest 2 at warn+, newest-last"
        );
        assert_eq!(log.snapshot(100, Level::Debug).len(), 5);
    }

    #[test]
    fn event_json_roundtrips_and_keeps_envelope() {
        let log = Logger::new();
        log.warn(
            "server",
            "access",
            vec![
                ("path", json::s("/generate")),
                ("status", json::num(200.0)),
                ("latency_s", json::num(0.125)),
                // a hostile field must not shadow the envelope
                ("level", json::s("spoofed")),
            ],
        );
        let body = log.to_json(10, Level::Debug).to_string();
        let doc = Json::parse(&body).expect("valid JSON");
        assert_eq!(doc.get("total").unwrap().as_i64(), Some(1));
        let ev = doc.get("events").unwrap().idx(0).unwrap();
        assert_eq!(ev.get("level").unwrap().as_str(), Some("warn"));
        assert_eq!(ev.get("target").unwrap().as_str(), Some("server"));
        assert_eq!(ev.get("msg").unwrap().as_str(), Some("access"));
        assert_eq!(ev.get("path").unwrap().as_str(), Some("/generate"));
        assert_eq!(ev.get("status").unwrap().as_i64(), Some(200));
    }

    #[test]
    fn render_is_single_line() {
        let log = Logger::new();
        log.error("rank", "worker panicked", vec![("worker", json::num(2.0))]);
        let ev = &log.snapshot(1, Level::Debug)[0];
        let line = ev.render();
        assert!(line.contains("ERROR"), "{line}");
        assert!(line.contains("worker panicked"), "{line}");
        assert!(line.contains("worker=2"), "{line}");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn cross_thread_emit_is_safe() {
        let log = Logger::new();
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let log = log.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        log.info("rank", "tick", vec![("worker", json::num(i as f64))]);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(log.total(), 400);
        let evs = log.snapshot(1000, Level::Debug);
        assert_eq!(evs.len(), 400);
        // seq unique across threads
        let mut seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), 400);
    }
}
