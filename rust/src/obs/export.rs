//! Chrome-trace / Perfetto JSON export of a [`TraceDump`].
//!
//! Emits the JSON-object flavour of the Trace Event Format: complete
//! (`"ph":"X"`) events with microsecond `ts`/`dur`, `pid` = request or
//! forward-step id, `tid` = TP rank (or the coordinator pseudo-thread).
//! Metadata events name each process and thread so Perfetto / `chrome://
//! tracing` render "req 3" lanes with "rank0…rankN" tracks instead of
//! bare integers. Output is deterministic for a fixed dump: spans keep
//! their merge order and metadata is emitted in sorted id order.

use std::collections::BTreeSet;

use crate::util::json::{self, Json};

use super::{TraceDump, TID_COORD};

fn thread_label(tid: u32) -> String {
    if tid == TID_COORD {
        "coordinator".to_string()
    } else {
        format!("rank{tid}")
    }
}

/// Build the `{"traceEvents": [...]}` document for `dump`.
pub fn to_chrome_json(dump: &TraceDump) -> Json {
    let mut events = Vec::with_capacity(dump.spans.len() + 16);
    let mut pids = BTreeSet::new();
    let mut threads = BTreeSet::new();
    for s in &dump.spans {
        pids.insert(s.pid);
        threads.insert((s.pid, s.tid));
        let mut args = vec![("seq", json::num(s.seq as f64))];
        if s.arg >= 0 {
            args.push(("site", json::num(s.arg as f64)));
        }
        events.push(json::obj(vec![
            ("name", json::s(s.name)),
            ("cat", json::s(s.cat.name())),
            ("ph", json::s("X")),
            ("ts", json::num(s.t0_ns as f64 / 1e3)),
            ("dur", json::num(s.dur_ns as f64 / 1e3)),
            ("pid", json::num(s.pid as f64)),
            ("tid", json::num(s.tid as f64)),
            ("args", json::obj(args)),
        ]));
    }
    for pid in &pids {
        events.push(metadata("process_name", *pid, None, &format!("req {pid}")));
    }
    for (pid, tid) in &threads {
        events.push(metadata("thread_name", *pid, Some(*tid), &thread_label(*tid)));
    }
    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("droppedSpans", json::num(dump.dropped as f64)),
        ("traceEvents", Json::Arr(events)),
    ])
}

fn metadata(kind: &str, pid: u64, tid: Option<u32>, label: &str) -> Json {
    let mut pairs = vec![
        ("name", json::s(kind)),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
        ("args", json::obj(vec![("name", json::s(label))])),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", json::num(t as f64)));
    }
    json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Cat, Span};

    fn dump() -> TraceDump {
        let spans = vec![
            Span {
                name: "attn",
                cat: Cat::Compute,
                pid: 1,
                tid: 0,
                t0_ns: 1_000,
                dur_ns: 2_500,
                seq: 0,
                arg: 3,
            },
            Span {
                name: "exchange",
                cat: Cat::Fabric,
                pid: 1,
                tid: TID_COORD,
                t0_ns: 4_000,
                dur_ns: 500,
                seq: 1,
                arg: -1,
            },
        ];
        TraceDump { spans, dropped: 2 }
    }

    #[test]
    fn export_is_valid_and_roundtrips() {
        let j = to_chrome_json(&dump());
        let parsed = Json::parse(&j.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 spans + 1 process + 2 threads of metadata
        assert_eq!(events.len(), 5);
        let first = &events[0];
        assert_eq!(first.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(first.get("cat").unwrap().as_str(), Some("compute"));
        assert_eq!(first.get("ts").unwrap().as_f64(), Some(1.0)); // µs
        assert_eq!(first.get("dur").unwrap().as_f64(), Some(2.5));
        assert_eq!(first.path("args.site").unwrap().as_i64(), Some(3));
        assert_eq!(parsed.get("droppedSpans").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn golden_export_is_stable() {
        // byte-for-byte golden: catches accidental schema drift
        let d = TraceDump {
            spans: vec![Span {
                name: "embed",
                cat: Cat::Compute,
                pid: 7,
                tid: 2,
                t0_ns: 2_000,
                dur_ns: 1_000,
                seq: 4,
                arg: -1,
            }],
            dropped: 0,
        };
        let got = to_chrome_json(&d).to_string();
        let want = concat!(
            r#"{"displayTimeUnit":"ms","droppedSpans":0,"traceEvents":["#,
            r#"{"args":{"seq":4},"cat":"compute","dur":1,"name":"embed","ph":"X","pid":7,"tid":2,"ts":2},"#,
            r#"{"args":{"name":"req 7"},"name":"process_name","ph":"M","pid":7},"#,
            r#"{"args":{"name":"rank2"},"name":"thread_name","ph":"M","pid":7,"tid":2}"#,
            r#"]}"#,
        );
        assert_eq!(got, want);
    }

    #[test]
    fn thread_labels() {
        assert_eq!(thread_label(0), "rank0");
        assert_eq!(thread_label(TID_COORD), "coordinator");
    }
}
