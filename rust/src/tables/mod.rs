//! Generators for every table in the paper's evaluation section.
//! `cargo bench --bench tableN_*` and the examples wrap these; each
//! generator returns structured rows and prints the same layout the
//! paper reports (EXPERIMENTS.md records paper-vs-measured).

pub mod common;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod table7;
