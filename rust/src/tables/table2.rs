//! Table 2 — best-scheme selection + held-out evaluation (paper §5.1):
//! among candidates with < 3% PPL increase on the train slice, pick the
//! one with the fewest effective bits; report its degradation on the
//! *test* split.

use super::common;
use super::table1;
use crate::mxfmt::MxScheme;

#[derive(Debug, Clone)]
pub struct Table2Row {
    pub model: String,
    pub scheme: String,
    pub eff_bits: f64,
    pub fp16_test_ppl: f64,
    pub increase_pct: f64,
}

/// The paper's selection rule (§5.1).
pub const MAX_INCREASE_PCT: f64 = 3.0;

/// Pick per-model winners from Table 1 results. Falls back to the
/// lowest-degradation candidate when nothing clears the 3% bar.
pub fn select(t1: &table1::Table1) -> Vec<(String, String, f64)> {
    let mut out = Vec::new();
    for (mi, model) in t1.models.iter().enumerate() {
        let mut best: Option<(&table1::Table1Row, f64)> = None;
        for row in &t1.rows {
            let inc = row.increase_pct[mi];
            if inc < MAX_INCREASE_PCT {
                let better = match best {
                    None => true,
                    Some((b, binc)) => {
                        row.eff_bits < b.eff_bits
                            || (row.eff_bits == b.eff_bits && inc < binc)
                    }
                };
                if better {
                    best = Some((row, inc));
                }
            }
        }
        let chosen = best.or_else(|| {
            t1.rows
                .iter()
                .map(|r| (r, r.increase_pct[mi]))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        });
        let (row, _) = chosen.expect("nonempty table");
        out.push((
            model.clone(),
            format!("{}_b{}_e8m0", row.dtype, row.block),
            row.eff_bits,
        ));
    }
    out
}

pub fn run(max_tokens: usize) -> anyhow::Result<Vec<Table2Row>> {
    // scheme search on the train slice (Table 1), final eval on test
    let t1 = table1::run(max_tokens)?;
    let winners = select(&t1);
    let test = common::corpus("test")?;
    let mut rows = Vec::new();
    for (model, scheme, eff_bits) in winners {
        let mut eng = common::engine(&model, common::SWEEP_TP, "none")?;
        let base = common::ppl(&mut eng, &test, max_tokens)?;
        eng.set_compress(&scheme)?;
        let q = common::ppl(&mut eng, &test, max_tokens)?;
        rows.push(Table2Row {
            model,
            scheme: scheme.clone(),
            eff_bits: MxScheme::parse(&scheme)?.effective_bits().max(eff_bits),
            fp16_test_ppl: base.ppl(),
            increase_pct: q.increase_pct(&base),
        });
    }
    Ok(rows)
}

pub fn print(rows: &[Table2Row]) {
    println!("\nTable 2 — best schemes on the held-out test set (<{MAX_INCREASE_PCT}% rule)");
    println!(
        "{:<8} {:<22} {:>8} {:>12} {:>10}",
        "model", "scheme", "eff.bits", "fp16 PPL", "increase"
    );
    common::hr(66);
    for r in rows {
        println!(
            "{:<8} {:<22} {:>8.2} {:>12.3} {:>9.2}%",
            r.model, r.scheme, r.eff_bits, r.fp16_test_ppl, r.increase_pct
        );
    }
}
