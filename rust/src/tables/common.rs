//! Shared plumbing for the table generators.

use std::path::PathBuf;

use crate::eval::{perplexity, EvalOptions, PplResult};
use crate::model::weights::Weights;
use crate::runtime::Runtime;
use crate::tp::{EngineOptions, TpEngine};

/// Evaluation token budget: paper-faithful sweeps use the env override
/// `TPCC_EVAL_TOKENS`; tests set a small value for speed.
pub fn eval_tokens(default: usize) -> usize {
    std::env::var("TPCC_EVAL_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn artifacts_root() -> anyhow::Result<PathBuf> {
    let d = crate::artifacts_dir();
    anyhow::ensure!(
        d.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first (looked in {})",
        d.display()
    );
    Ok(d)
}

/// Build an engine for (model, tp, compressor-spec).
pub fn engine(model: &str, tp: usize, compress: &str) -> anyhow::Result<TpEngine> {
    let root = artifacts_root()?;
    let rt = Runtime::load(&root)?;
    let weights = Weights::load(&root.join("weights").join(model))?;
    let opts = EngineOptions::new(model, tp).with_compress(compress);
    TpEngine::new(rt, &weights, opts)
}

/// The corpus split used by the paper's protocol: scheme search on a
/// slice of *train* (paper: 10% of wikitext2 train), final numbers on
/// the held-out *test* set.
pub fn corpus(split: &str) -> anyhow::Result<String> {
    let root = artifacts_root()?;
    let path = root.join("weights").join(format!("corpus_{split}.txt"));
    Ok(std::fs::read_to_string(path)?)
}

/// Perplexity of `model`@tp with `compress` on `text`.
pub fn ppl(
    eng: &mut TpEngine,
    text: &str,
    max_tokens: usize,
) -> anyhow::Result<PplResult> {
    perplexity(
        eng,
        text,
        EvalOptions { max_tokens, ..EvalOptions::default() },
    )
}

pub fn hr(width: usize) {
    println!("{}", "-".repeat(width));
}

/// The models swept by the perplexity tables (stand-ins for the paper's
/// Llama-3.1/Gemma-2/Mistral families — DESIGN.md substitution table).
pub const SWEEP_MODELS: &[&str] = &["nano", "micro", "small"];

/// The TP degree used for perplexity sweeps (the paper's default TP=2
/// ablation baseline; Table 5 sweeps the degree explicitly).
pub const SWEEP_TP: usize = 2;
