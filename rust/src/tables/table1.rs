//! Table 1 — optimal compression scheme search: perplexity degradation
//! (% vs FP16) for {FP3 E1M1, FP4 E2M1, FP5 E2M2} × block {8, 16, 32}
//! on a slice of the *train* split, per model (paper §5.1).

use super::common;
use crate::mxfmt::MxScheme;

#[derive(Debug, Clone)]
pub struct Table1Row {
    pub dtype: &'static str,
    pub block: usize,
    pub eff_bits: f64,
    /// perplexity increase % per model, ordered like SWEEP_MODELS
    pub increase_pct: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Table1 {
    pub models: Vec<String>,
    pub fp16_ppl: Vec<f64>,
    pub rows: Vec<Table1Row>,
    pub eval_tokens: usize,
}

pub const DTYPES: &[&str] = &["fp3_e1m1", "fp4_e2m1", "fp5_e2m2"];
pub const BLOCKS: &[usize] = &[8, 16, 32];

pub fn run(max_tokens: usize) -> anyhow::Result<Table1> {
    let text = common::corpus("train")?;
    // paper evaluates on 10% of the train set; our budget is the token
    // cap (already a small slice of the corpus).
    let mut fp16_ppl = Vec::new();
    let mut per_model: Vec<Vec<f64>> = vec![Vec::new(); DTYPES.len() * BLOCKS.len()];

    for model in common::SWEEP_MODELS {
        let mut eng = common::engine(model, common::SWEEP_TP, "none")?;
        let base = common::ppl(&mut eng, &text, max_tokens)?;
        fp16_ppl.push(base.ppl());
        let mut i = 0usize;
        for dtype in DTYPES {
            for block in BLOCKS {
                let spec = format!("{dtype}_b{block}_e8m0");
                eng.set_compress(&spec)?;
                let r = common::ppl(&mut eng, &text, max_tokens)?;
                per_model[i].push(r.increase_pct(&base));
                i += 1;
            }
        }
    }

    let mut rows = Vec::new();
    let mut i = 0usize;
    for dtype in DTYPES {
        for block in BLOCKS {
            let scheme = MxScheme::parse(&format!("{dtype}_b{block}_e8m0")).unwrap();
            rows.push(Table1Row {
                dtype,
                block: *block,
                eff_bits: scheme.effective_bits(),
                increase_pct: per_model[i].clone(),
            });
            i += 1;
        }
    }
    Ok(Table1 {
        models: common::SWEEP_MODELS.iter().map(|s| s.to_string()).collect(),
        fp16_ppl,
        rows,
        eval_tokens: max_tokens,
    })
}

pub fn print(t: &Table1) {
    println!("\nTable 1 — PPL degradation vs FP16 (train slice, {} tokens, TP={})",
        t.eval_tokens, common::SWEEP_TP);
    print!("{:<10} {:>5} {:>8}", "dtype", "block", "eff.bits");
    for m in &t.models {
        print!(" {:>10}", m);
    }
    println!();
    common::hr(26 + 11 * t.models.len());
    print!("{:<10} {:>5} {:>8}", "fp16", "-", "16");
    for p in &t.fp16_ppl {
        print!(" {:>10.3}", p);
    }
    println!("  (absolute PPL)");
    for r in &t.rows {
        print!("{:<10} {:>5} {:>8.1}", r.dtype, r.block, r.eff_bits);
        for v in &r.increase_pct {
            print!(" {:>9.2}%", v);
        }
        println!();
    }
}
