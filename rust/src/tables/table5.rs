//! Table 5 (appendix A.1) — ablation over quantization hyper-parameters:
//! scale bits, value dtype, block size, and TP (parallelism) degree.

use super::common;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub axis: &'static str,
    pub value: String,
    /// ppl increase % per model (SWEEP_MODELS order)
    pub increase_pct: Vec<f64>,
}

pub const VALUE_DTYPES: &[&str] = &[
    "fp3_e1m1", "fp4_e1m2", "fp4_e2m1", "fp5_e1m3", "fp5_e2m2", "fp5_e3m1",
    "int3", "int4", "int5",
];
// paper sweeps 4..7(8); we add 3 because our byte-level models have a
// narrower activation dynamic range than Llama-class models, so the
// clamping penalty the paper sees at 4 bits appears here at 3.
pub const SCALE_BITS: &[u32] = &[3, 4, 5, 6, 7, 8];
pub const BLOCKS: &[usize] = &[8, 16, 32];
pub const TP_DEGREES: &[usize] = &[1, 2, 4, 8];

pub fn run(max_tokens: usize) -> anyhow::Result<Vec<AblationRow>> {
    let text = common::corpus("train")?;
    let mut rows: Vec<AblationRow> = Vec::new();

    // per-model baselines at the sweep TP
    let mut engines = Vec::new();
    let mut baselines = Vec::new();
    for model in common::SWEEP_MODELS {
        let mut eng = common::engine(model, common::SWEEP_TP, "none")?;
        let base = common::ppl(&mut eng, &text, max_tokens)?;
        baselines.push(base);
        engines.push(eng);
    }

    let mut sweep = |axis: &'static str, value: String, spec: String| -> anyhow::Result<()> {
        let mut incs = Vec::new();
        for (eng, base) in engines.iter_mut().zip(&baselines) {
            eng.set_compress(&spec)?;
            let r = common::ppl(eng, &text, max_tokens)?;
            incs.push(r.increase_pct(base));
        }
        rows.push(AblationRow { axis, value, increase_pct: incs });
        Ok(())
    };

    // scale bits at FP4 E2M1 b32
    for sb in SCALE_BITS {
        sweep("scale_bits", sb.to_string(), format!("fp4_e2m1_b32_e{sb}m0"))?;
    }
    // value dtype at b32 / E8M0
    for dt in VALUE_DTYPES {
        sweep("value_dtype", dt.to_string(), format!("{dt}_b32_e8m0"))?;
    }
    // block size at FP4 E2M1 / E8M0
    for b in BLOCKS {
        sweep("block_size", b.to_string(), format!("fp4_e2m1_b{b}_e8m0"))?;
    }

    // parallelism degree: error enters per-worker; each TP degree is a
    // different engine (different shard artifacts)
    for &tp in TP_DEGREES {
        let mut incs = Vec::new();
        for model in common::SWEEP_MODELS {
            let mut eng = common::engine(model, tp, "none")?;
            let base = common::ppl(&mut eng, &text, max_tokens)?;
            eng.set_compress("fp4_e2m1_b32_e8m0")?;
            let r = common::ppl(&mut eng, &text, max_tokens)?;
            incs.push(r.increase_pct(&base));
        }
        rows.push(AblationRow {
            axis: "parallelism",
            value: tp.to_string(),
            increase_pct: incs,
        });
    }

    Ok(rows)
}

pub fn print(rows: &[AblationRow]) {
    println!("\nTable 5 — ablation over quantization hyper-parameters (PPL increase %)");
    print!("{:<12} {:<12}", "axis", "value");
    for m in common::SWEEP_MODELS {
        print!(" {:>9}", m);
    }
    println!();
    common::hr(26 + 10 * common::SWEEP_MODELS.len());
    let mut last = "";
    for r in rows {
        let axis = if r.axis == last { "" } else { r.axis };
        last = r.axis;
        print!("{:<12} {:<12}", axis, r.value);
        for v in &r.increase_pct {
            print!(" {:>8.2}%", v);
        }
        println!();
    }
}
