//! Table 7 — **serving under load** (beyond the paper's tables): max
//! sustainable QPS at a TTFT SLO per {policy × hardware profile},
//! measured by replaying a heavy-tailed request trace through the
//! virtual-time driver against the modeled engine.
//!
//! The headline: on bandwidth-bound deployments (L4), compressing the
//! prefill collectives shrinks the engine-busy intervals, which
//! compounds under queueing into *capacity* — `paper` and `auto`
//! sustain at least the `uniform:none` rate (asserted in-table, like
//! Table 3b's never-worse guarantee). The NVLink (A100) row shows the
//! crossover: the codec overhead that makes compression a per-request
//! loss (Table 3) makes it a capacity loss too, and only the
//! time-aware `auto` policy stays at the uncompressed baseline.
//!
//! No artifacts needed: service times come from the Table 3 roofline +
//! the collective auto-planner, policies from the synthetic
//! calibration (the same inputs as Table 6).

use super::common;
use super::table3::PAPER_SCHEME;
use crate::interconnect::HwProfile;
use crate::model::perf_model::{PaperModel, LLAMA2_13B, LLAMA2_70B, LLAMA2_7B};
use crate::policy::{
    auto_search, paper_policy, Calibration, PolicyTable, SearchScenario, SiteCosts, CANDIDATES,
    PAPER_ERR_BUDGET_PCT,
};
use crate::workload::{capacity, BatchMode, LoadShape, ModeledEngine, SimOptions, SloSpec};

/// One (deployment, policy) capacity row.
#[derive(Debug, Clone)]
pub struct Table7Row {
    pub model: String,
    pub accelerators: String,
    /// `uniform:none` / `uniform:fp4...` / `paper` / `auto`
    pub policy: String,
    /// max sustainable arrival rate at the SLO (requests/s), bucketed
    /// (batch-at-a-time) serving loop
    pub qps: f64,
    /// max sustainable rate under the continuous (in-flight) batcher —
    /// same engine, same trace seed, [`BatchMode::Continuous`] loop
    pub qps_cont: f64,
    /// TTFT percentiles at that rate (seconds)
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
    /// goodput at that rate (fraction of requests meeting the SLO)
    pub goodput: f64,
    /// decode-token throughput at that rate (tokens/s)
    pub tok_s: f64,
}

/// Search/trace knobs (defaults are test-speed sized; the CLI can
/// raise `requests`/`iters` for tighter brackets).
#[derive(Debug, Clone, Copy)]
pub struct Table7Config {
    pub slo: SloSpec,
    pub shape: LoadShape,
    /// bisection refinement steps after bracketing
    pub iters: usize,
}

impl Default for Table7Config {
    fn default() -> Self {
        Table7Config { slo: SloSpec::default(), shape: LoadShape::default(), iters: 9 }
    }
}

/// The deployments swept: two bandwidth-bound L4 setups (where
/// compression must buy capacity — asserted) and the NVLink crossover.
pub fn deployments() -> Vec<(&'static str, PaperModel, &'static str, usize)> {
    vec![
        // (label, model, profile, tp)
        ("4xL4", LLAMA2_13B, "l4", 4),
        ("2xL4", LLAMA2_7B, "l4", 2),
        ("4xA100", LLAMA2_70B, "a100", 4),
    ]
}

/// The four policies each deployment is searched under.
fn policies(
    model: &PaperModel,
    profile: &'static HwProfile,
    tp: usize,
) -> anyhow::Result<Vec<(String, PolicyTable)>> {
    let uniform_none = PolicyTable::uniform(model.n_layers, "none");
    let uniform_fp4 = PolicyTable::uniform(model.n_layers, PAPER_SCHEME);
    let calib = Calibration::synthetic(model.n_layers, model.d_model, tp, 7);
    let paper = paper_policy(&calib, PAPER_ERR_BUDGET_PCT)?;
    // `auto` gets uniform-fp4's error budget and prices time on the
    // deployment's profile/topology — same construction as Table 6
    // (on NVLink it declines to compress, keeping the uncompressed
    // capacity; on L4 it compresses where time is bought)
    let scen = SearchScenario::new(profile, tp, 8 * 128, 8, model.d_model);
    let costs = SiteCosts::build(&calib, &scen, CANDIDATES)?;
    let u = costs.eval_table(&uniform_fp4)?;
    let auto = auto_search(&costs, model.n_layers, u.mean_err_pct(), Some(&uniform_fp4), "auto")?;
    Ok(vec![
        ("uniform:none".to_string(), uniform_none),
        (format!("uniform:{PAPER_SCHEME}"), uniform_fp4),
        ("paper".to_string(), paper),
        ("auto".to_string(), auto.table),
    ])
}

/// Run the capacity search for `deps` under `cfg`. Asserts the
/// acceptance guarantee in-table: on every L4 (bandwidth-bound)
/// deployment, `paper` and `auto` sustain at least `uniform:none`'s
/// rate — compression buys capacity.
pub fn run_for(
    deps: &[(&'static str, PaperModel, &'static str, usize)],
    cfg: &Table7Config,
) -> anyhow::Result<Vec<Table7Row>> {
    let mut rows = Vec::new();
    for &(label, model, prof, tp) in deps {
        let profile = HwProfile::by_name(prof).unwrap();
        for (policy, table) in policies(&model, profile, tp)? {
            let mut eng = ModeledEngine::new(model, profile, tp, &table)?;
            let cap = capacity(&mut eng, &cfg.shape, &cfg.slo, &SimOptions::default(), cfg.iters);
            // same engine (shared interval memo), same trace seed, the
            // continuous serving loop
            let cont_opts = SimOptions { mode: BatchMode::Continuous, ..SimOptions::default() };
            let cap_cont = capacity(&mut eng, &cfg.shape, &cfg.slo, &cont_opts, cfg.iters);
            let (p50, p99, goodput, tok_s) = match &cap.report {
                Some(r) => (
                    r.ttft.percentile(50.0),
                    r.ttft.percentile(99.0),
                    r.goodput(),
                    r.throughput_tok_s(),
                ),
                None => (f64::NAN, f64::NAN, 0.0, 0.0),
            };
            rows.push(Table7Row {
                model: model.name.to_string(),
                accelerators: label.to_string(),
                policy,
                qps: cap.qps,
                qps_cont: cap_cont.qps,
                ttft_p50_s: p50,
                ttft_p99_s: p99,
                goodput,
                tok_s,
            });
        }
    }
    // in-table acceptance: compression buys capacity on the
    // bandwidth-bound deployments
    for chunk in rows.chunks(4) {
        let base = &chunk[0];
        debug_assert_eq!(base.policy, "uniform:none");
        if !base.accelerators.contains("L4") {
            continue;
        }
        for r in &chunk[1..] {
            if r.policy == "paper" || r.policy == "auto" {
                anyhow::ensure!(
                    r.qps >= base.qps,
                    "{} {}: policy {} sustains {:.2} qps < uncompressed {:.2}",
                    r.model,
                    r.accelerators,
                    r.policy,
                    r.qps,
                    base.qps
                );
            }
        }
        // and the continuous batcher never loses capacity to bucketed
        // on these deployments, compressed or not (0.5% tolerance for
        // bisection-bracket granularity)
        for r in chunk {
            anyhow::ensure!(
                r.qps_cont >= r.qps * 0.995,
                "{} {} {}: continuous sustains {:.2} qps < bucketed {:.2}",
                r.model,
                r.accelerators,
                r.policy,
                r.qps_cont,
                r.qps
            );
        }
    }
    Ok(rows)
}

/// Full sweep with defaults (the `tpcc table7` entry point).
pub fn run(cfg: &Table7Config) -> anyhow::Result<Vec<Table7Row>> {
    run_for(&deployments(), cfg)
}

pub fn print(rows: &[Table7Row], cfg: &Table7Config) {
    println!(
        "\nTable 7 — serving under load: max sustainable QPS at a {:.0} ms TTFT SLO \
         (goodput ≥ {:.0}%, {} heavy-tailed requests per probe)",
        cfg.slo.ttft_s * 1e3,
        cfg.slo.min_goodput * 100.0,
        cfg.shape.requests
    );
    println!(
        "{:<12} {:<8} {:<24} {:>8} {:>9} {:>10} {:>10} {:>9} {:>10}",
        "model", "accel", "policy", "qps", "qps-cont", "ttft-p50", "ttft-p99", "goodput", "tok/s"
    );
    common::hr(110);
    for r in rows {
        println!(
            "{:<12} {:<8} {:<24} {:>8.2} {:>9.2} {:>9.0}ms {:>9.0}ms {:>8.1}% {:>10.1}",
            r.model,
            r.accelerators,
            r.policy,
            r.qps,
            r.qps_cont,
            r.ttft_p50_s * 1e3,
            r.ttft_p99_s * 1e3,
            r.goodput * 100.0,
            r.tok_s
        );
    }
    println!(
        "(qps = bucketed batch-at-a-time loop, qps-cont = continuous in-flight batcher; \
         L4 rows assert compressed ≥ uncompressed and continuous ≥ bucketed capacity)"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    // one deployment, reduced probes: run_for's in-table ensure! is the
    // acceptance check (paper/auto capacity >= uniform:none on L4)
    #[test]
    fn compression_buys_capacity_on_l4() {
        let cfg = Table7Config {
            shape: LoadShape { requests: 120, ..LoadShape::default() },
            iters: 6,
            ..Table7Config::default()
        };
        let deps = vec![("4xL4", LLAMA2_13B, "l4", 4)];
        let rows = run_for(&deps, &cfg).unwrap();
        assert_eq!(rows.len(), 4);
        let base = &rows[0];
        assert_eq!(base.policy, "uniform:none");
        assert!(base.qps > 0.0, "uncompressed deployment must sustain some load");
        for r in &rows {
            assert!(r.qps > 0.0, "{}: zero capacity", r.policy);
            assert!(r.qps_cont > 0.0, "{}: zero continuous capacity", r.policy);
            if r.qps > 0.0 {
                assert!(r.goodput >= cfg.slo.min_goodput - 1e-9, "{}: {}", r.policy, r.goodput);
                assert!(r.ttft_p50_s.is_finite() && r.ttft_p50_s <= cfg.slo.ttft_s);
            }
        }
        // the paper scheme everywhere must also beat uncompressed here
        // (L4 prefill is communication-bound)
        assert!(rows[1].policy.starts_with("uniform:fp4"));
        assert!(rows[1].qps >= base.qps, "fp4 {} < none {}", rows[1].qps, base.qps);
    }
}
