//! Table 6 — **selective compression** ablation (beyond the paper's
//! tables): `uniform` (one scheme everywhere, the paper's §5.2 setup)
//! vs `paper` (the §5.1 selection rule applied per-site) vs `auto`
//! (greedy sensitivity search under the uniform policy's error budget),
//! reporting TTFT, prefill wire bytes, and modeled error.
//!
//! The analytic section prices collectives with the same planner model
//! the engine charges ([`crate::collective::plan::score`]) over a
//! synthetic per-site calibration — no artifacts needed. By
//! construction (`auto_search`'s baseline fallback), `auto` is never
//! slower in virtual time than `uniform` at equal-or-better modeled
//! error; the unit tests assert it row by row.
//!
//! The live section (needs artifacts) runs the trained `micro` model
//! end-to-end under each policy and reports *real* perplexity deltas.

use super::common;
use super::table3::PAPER_SCHEME;
use crate::interconnect::HwProfile;
use crate::model::perf_model::{PaperModel, Scenario, LLAMA2_13B, LLAMA2_70B};
use crate::mxfmt::baselines::Fp16;
use crate::policy::{auto_search, paper_policy, Calibration, PolicyTable, SearchScenario, SiteCosts, CANDIDATES, PAPER_ERR_BUDGET_PCT};

/// One analytic ablation row: a (deployment, policy) pair.
#[derive(Debug, Clone)]
pub struct Table6Row {
    pub model: String,
    pub accelerators: String,
    pub input: String,
    /// `uniform` / `paper` / `auto`
    pub policy: String,
    /// prefill compute + per-site planner-scored collective time
    pub ttft_s: f64,
    /// accounted wire bytes of one prefill pass (MB)
    pub wire_mb: f64,
    /// mean per-site modeled (calibration) error, percent — the
    /// analytic stand-in for the PPL delta
    pub err_pct: f64,
    /// scheme histogram summary, e.g. `fp4_e2m1_b32_e8m0:236,none:84`
    pub schemes: String,
}

/// The deployments swept by the analytic ablation (a slice of the
/// Table 3 scenarios plus a multi-node profile).
pub fn deployments() -> Vec<(&'static str, PaperModel, &'static str, usize, usize, usize)> {
    vec![
        // (label, model, profile, tp, batch, seq)
        ("8xL4", LLAMA2_70B, "l4", 8, 2, 64),
        ("2x4xL4", LLAMA2_70B, "2x4l4", 8, 2, 128),
        ("4xL4", LLAMA2_13B, "l4", 4, 8, 128),
    ]
}

fn histogram_label(table: &PolicyTable) -> String {
    let h = table.histogram();
    let parts: Vec<String> = h.into_iter().map(|(spec, n)| format!("{spec}:{n}")).collect();
    parts.join(",")
}

/// Analytic mode: per deployment, score the three built-in policies
/// with the same calibration + planner cost model.
pub fn run_analytic() -> anyhow::Result<Vec<Table6Row>> {
    let mut rows = Vec::new();
    for (label, model, prof, tp, b, s) in deployments() {
        let profile = HwProfile::by_name(prof).unwrap();
        let calib = Calibration::synthetic(model.n_layers, model.d_model, tp, 6);
        let scen = SearchScenario::new(profile, tp, b * s, 8, model.d_model);
        let costs = SiteCosts::build(&calib, &scen, CANDIDATES)?;

        let uniform = PolicyTable::uniform(model.n_layers, PAPER_SCHEME);
        let u = costs.eval_table(&uniform)?;
        let paper = paper_policy(&calib, PAPER_ERR_BUDGET_PCT)?;
        let p = costs.eval_table(&paper)?;
        // auto gets exactly uniform's error budget and must never be
        // slower than it (auto_search falls back to uniform otherwise)
        let auto = auto_search(&costs, model.n_layers, u.mean_err_pct(), Some(&uniform), "auto")?;

        let sc = Scenario { model, profile, tp, batch: b, seq: s };
        let compute_s = sc.ttft(&Fp16).compute_s;
        for (policy, table, score) in [
            ("uniform", &uniform, u),
            ("paper", &paper, p),
            ("auto", &auto.table, auto.score),
        ] {
            rows.push(Table6Row {
                model: model.name.to_string(),
                accelerators: label.to_string(),
                input: format!("{b}x{s}"),
                policy: policy.to_string(),
                ttft_s: compute_s + score.ttft_comm_s,
                wire_mb: score.prefill_wire_bytes as f64 / 1e6,
                err_pct: score.mean_err_pct(),
                schemes: histogram_label(table),
            });
        }
    }
    Ok(rows)
}

pub fn print(rows: &[Table6Row]) {
    println!(
        "\nTable 6 — selective compression ablation (analytic; uniform = {PAPER_SCHEME} everywhere)"
    );
    println!(
        "{:<12} {:<8} {:>7} {:<8} {:>9} {:>10} {:>8}  {}",
        "model", "accel", "input", "policy", "ttft", "wire", "err", "schemes"
    );
    common::hr(110);
    for r in rows {
        let schemes = if r.schemes.len() > 48 { format!("{}…", &r.schemes[..47]) } else { r.schemes.clone() };
        println!(
            "{:<12} {:<8} {:>7} {:<8} {:>8.3}s {:>8.1}MB {:>7.2}%  {}",
            r.model, r.accelerators, r.input, r.policy, r.ttft_s, r.wire_mb, r.err_pct, schemes
        );
    }
}

/// One live ablation row: the trained `micro` model under a policy.
#[derive(Debug, Clone)]
pub struct Table6Live {
    pub policy: String,
    /// real PPL increase vs the uncompressed engine (test split)
    pub ppl_increase_pct: f64,
    /// wire bytes of one 8x128 prefill under the policy (MB)
    pub wire_mb: f64,
    /// virtual (interconnect-modeled) time of that prefill
    pub virtual_prefill_s: f64,
    pub schemes: String,
}

/// Live mode: `micro` @ TP=2 under each built-in policy; PPL on the
/// test split (real logits through the compressed collectives), plus a
/// probe prefill for wire/virtual-time accounting.
pub fn run_live(max_tokens: usize) -> anyhow::Result<Vec<Table6Live>> {
    let text = common::corpus("test")?;
    let mut eng = common::engine("micro", 2, "none")?;
    let base = common::ppl(&mut eng, &text, max_tokens)?;
    let (bb, sb) = (8usize, 128usize);
    let tokens: Vec<i32> = (0..bb * sb).map(|i| (i * 31 + 7) as i32 % 256).collect();
    let pos = vec![0i32; bb];

    let mut rows = Vec::new();
    for policy in [format!("uniform:{PAPER_SCHEME}"), "paper".to_string(), "auto".to_string()] {
        eng.set_policy(&policy)?;
        let r = common::ppl(&mut eng, &text, max_tokens)?;
        let (_, t) = eng.prefill(&tokens, bb, sb, &pos, None)?;
        rows.push(Table6Live {
            policy,
            ppl_increase_pct: r.increase_pct(&base),
            wire_mb: t.wire_bytes as f64 / 1e6,
            virtual_prefill_s: t.virtual_total(),
            schemes: histogram_label(eng.policy()),
        });
    }
    Ok(rows)
}

pub fn print_live(rows: &[Table6Live]) {
    println!("\nTable 6 (live micro model on CPU PJRT) — real PPL deltas per policy");
    println!(
        "{:<28} {:>10} {:>10} {:>14}  {}",
        "policy", "ppl-inc", "wire", "virt-prefill", "schemes"
    );
    common::hr(100);
    for r in rows {
        println!(
            "{:<28} {:>9.2}% {:>8.2}MB {:>13.4}s  {}",
            r.policy, r.ppl_increase_pct, r.wire_mb, r.virtual_prefill_s, r.schemes
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test (and one `run_analytic` call — the cost model over the
    // 70B site grid is the expensive part in debug builds) asserting
    // the acceptance guarantee plus the row invariants
    #[test]
    fn auto_never_slower_than_uniform_at_equal_or_better_error() {
        let rows = run_analytic().unwrap();
        assert_eq!(rows.len(), deployments().len() * 3);
        for r in rows.iter().filter(|r| r.policy == "uniform") {
            assert!(r.schemes.starts_with(PAPER_SCHEME), "{}", r.schemes);
            assert!(!r.schemes.contains(','), "uniform must be single-scheme: {}", r.schemes);
            assert!(r.wire_mb > 0.0 && r.ttft_s > 0.0);
        }
        for chunk in rows.chunks(3) {
            let uniform = &chunk[0];
            let auto = &chunk[2];
            assert_eq!(uniform.policy, "uniform");
            assert_eq!(auto.policy, "auto");
            assert!(
                auto.ttft_s <= uniform.ttft_s + 1e-9,
                "{} {}: auto ttft {} > uniform {}",
                uniform.model,
                uniform.accelerators,
                auto.ttft_s,
                uniform.ttft_s
            );
            assert!(
                auto.err_pct <= uniform.err_pct + 1e-9,
                "{} {}: auto err {} > uniform {}",
                uniform.model,
                uniform.accelerators,
                auto.err_pct,
                uniform.err_pct
            );
        }
    }
}
