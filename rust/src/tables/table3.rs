//! Table 3 — TTFT profiling (paper §5.2): Llama-2 {70B, 13B, 7B} across
//! {8xL4, 4xA100, 4xL4, 2xL4} and input shapes, uncompressed vs FP4
//! E2M1/b32/E8M0 (4.25 effective bits), plus a *live* section where the
//! trained models run end-to-end on the CPU PJRT testbed under the
//! simulated interconnect.
//!
//! The collective engine adds an algorithm-ablation axis: every row
//! carries an `auto` column (the planner's {algorithm × chunking}
//! choice for the same deployment), and [`run_algo_ablation`] sweeps
//! the planner against the seed's hard-coded flat ring across profiles
//! — `auto` is never slower in virtual time (asserted by tests).

use super::common;
use crate::collective::plan::{self, AlgoChoice};
use crate::collective::Topology;
use crate::interconnect::HwProfile;
use crate::model::perf_model::{Scenario, LLAMA2_13B, LLAMA2_70B, LLAMA2_7B};
use crate::mxfmt::baselines::Fp16;
use crate::mxfmt::{MxCodec, MxScheme};
use crate::tp::BatchKv;

pub const PAPER_SCHEME: &str = "fp4_e2m1_b32_e8m0";

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub model: String,
    pub accelerators: String,
    pub input: String,
    pub uncompressed_s: f64,
    pub compressed_s: f64,
    pub speedup: f64,
    /// compressed TTFT with the planner-chosen collective (same
    /// deployment); never slower than `compressed_s`'s flat ring
    pub auto_s: f64,
    /// algorithm the planner picked (e.g. `two_shot`, `ring x4`)
    pub auto_algo: String,
}

/// The paper's eight analytic scenarios.
pub fn paper_rows() -> Vec<(&'static str, crate::model::perf_model::PaperModel, &'static str, usize, usize, usize)>
{
    vec![
        // (label, model, profile, tp, batch, seq)
        ("8xL4", LLAMA2_70B, "l4", 8, 2, 64),
        ("8xL4", LLAMA2_70B, "l4", 8, 2, 128),
        ("4xA100", LLAMA2_70B, "a100", 4, 2, 128),
        ("4xA100", LLAMA2_70B, "a100", 4, 2, 256),
        ("4xL4", LLAMA2_13B, "l4", 4, 8, 128),
        ("4xL4", LLAMA2_13B, "l4", 4, 8, 256),
        ("2xL4", LLAMA2_7B, "l4", 2, 16, 128),
        ("2xL4", LLAMA2_7B, "l4", 2, 16, 256),
    ]
}

fn plan_label(p: &plan::CollectivePlan) -> String {
    if p.chunks > 1 {
        format!("{} x{}", p.algo.name(), p.chunks)
    } else {
        p.algo.name().to_string()
    }
}

/// Analytic mode: the paper's deployments through the perf model.
pub fn run_analytic() -> Vec<Table3Row> {
    let mx = MxCodec::new(MxScheme::parse(PAPER_SCHEME).unwrap());
    paper_rows()
        .into_iter()
        .map(|(label, model, prof, tp, b, s)| {
            let profile = HwProfile::by_name(prof).unwrap();
            let sc = Scenario { model, profile, tp, batch: b, seq: s };
            let unc = sc.ttft(&Fp16).total();
            let t = sc.ttft(&mx);
            let cmp = t.total();
            // the planner sees the same per-collective message on the
            // profile's topology; its estimate uses the same α/β + codec
            // model, so ring-choice reproduces `cmp` exactly
            let values = b * s * model.d_model;
            let topo = Topology::from_profile(profile, tp);
            let p = plan::choose(
                values, tp, Some(&mx), &topo, profile.quant_values_per_s, AlgoChoice::Auto,
            );
            let collectives = (2 * model.n_layers) as f64;
            let auto_s = t.compute_s + collectives * p.est_total_s;
            Table3Row {
                model: model.name.to_string(),
                accelerators: label.to_string(),
                input: format!("{b}x{s}"),
                uncompressed_s: unc,
                compressed_s: cmp,
                speedup: unc / cmp,
                auto_s,
                auto_algo: plan_label(&p),
            }
        })
        .collect()
}

/// One row of the collective-algorithm ablation: the planner's choice
/// vs the seed's hard-coded flat ring, pure virtual time.
#[derive(Debug, Clone)]
pub struct AlgoAblationRow {
    pub profile: &'static str,
    pub tp: usize,
    pub message: String,
    pub values: usize,
    pub ring_s: f64,
    pub auto_s: f64,
    pub auto_algo: String,
    pub speedup: f64,
}

/// Sweep the auto-planner against the flat-ring baseline over the
/// single- and multi-node profiles at decode- and prefill-sized
/// messages (Llama-2-70B hidden dim). No artifacts needed — this is
/// the α/β + codec model only.
pub fn run_algo_ablation() -> Vec<AlgoAblationRow> {
    let mx = MxCodec::new(MxScheme::parse(PAPER_SCHEME).unwrap());
    let d = LLAMA2_70B.d_model;
    let mut rows = Vec::new();
    for (prof, tp) in [("l4", 8usize), ("a100", 4), ("2x4l4", 8), ("2x4a100", 8)] {
        let profile = HwProfile::by_name(prof).unwrap();
        let topo = Topology::from_profile(profile, tp);
        for (message, values) in [
            ("decode 2x1", 2 * d),
            ("prefill 2x128", 2 * 128 * d),
            ("prefill 8x512", 8 * 512 * d),
        ] {
            let ring_s =
                plan::ring_baseline(values, tp, Some(&mx), &topo, profile.quant_values_per_s);
            let p = plan::choose(
                values, tp, Some(&mx), &topo, profile.quant_values_per_s, AlgoChoice::Auto,
            );
            rows.push(AlgoAblationRow {
                profile: profile.name,
                tp,
                message: message.to_string(),
                values,
                ring_s,
                auto_s: p.est_total_s,
                auto_algo: plan_label(&p),
                speedup: ring_s / p.est_total_s,
            });
        }
    }
    rows
}

/// Live mode: the trained `micro` model executed end-to-end on CPU PJRT
/// with virtual-time interconnect accounting, median of `reps` passes
/// (paper uses median of 32).
///
/// `analytic_overhead` charges the compression overhead at the target
/// profile's quantizer throughput (what the simulated hardware would
/// pay); false charges the measured rust-codec wall time (what *this*
/// CPU pays — its codec/link ratio resembles the paper's fast-
/// interconnect regime).
///
/// Three passes run: uncompressed ring (the seed baseline), compressed
/// ring (the paper's method on the seed collective), and compressed
/// `auto` (the collective engine's planner) — the last fills the
/// `auto` column.
pub fn run_live(
    profile: &str,
    tp: usize,
    batch: usize,
    seq: usize,
    reps: usize,
    analytic_overhead: bool,
) -> anyhow::Result<Table3Row> {
    let prof = HwProfile::by_name(profile).unwrap();
    let tag = if analytic_overhead { "analytic-ovh" } else { "measured-ovh" };
    let mut row = Table3Row {
        model: format!("micro(live,{tag})"),
        accelerators: format!("{tp}x{profile}"),
        input: format!("{batch}x{seq}"),
        uncompressed_s: 0.0,
        compressed_s: 0.0,
        speedup: 0.0,
        auto_s: 0.0,
        auto_algo: String::new(),
    };
    let tokens: Vec<i32> = (0..batch * seq).map(|i| (i * 31 + 7) as i32 % 256).collect();
    let pos = vec![0i32; batch];

    for (spec, algo) in [("none", "ring"), (PAPER_SCHEME, "ring"), (PAPER_SCHEME, "auto")] {
        let mut eng = common::engine("micro", tp, spec)?;
        eng.opts.profile = prof;
        eng.set_algo(algo)?;
        if analytic_overhead {
            eng.opts.overhead = crate::tp::OverheadModel::Analytic {
                values_per_s: prof.quant_values_per_s,
            };
        }
        let mut kv = BatchKv::new(&eng.cfg.clone(), tp, batch);
        // analytic mode rescales the measured CPU compute to the target
        // accelerator (cpu-profile roofline / target roofline): a model
        // this small on L4/A100-class parts is communication-bound,
        // which is the regime the live run is validating.
        let cpu = HwProfile::by_name("cpu").unwrap();
        let compute_scale = if analytic_overhead {
            (cpu.peak_flops * cpu.mfu) / (prof.peak_flops * prof.mfu)
        } else {
            1.0
        };
        let mut samples = Vec::new();
        let mut last_algo = "";
        for _ in 0..reps.max(1) {
            let (_, t) = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
            samples.push(t.compute_s * compute_scale + t.link_s + t.codec_s);
            last_algo = t.algo;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = samples[samples.len() / 2];
        match (spec, algo) {
            ("none", _) => row.uncompressed_s = med,
            (_, "ring") => row.compressed_s = med,
            _ => {
                row.auto_s = med;
                row.auto_algo = last_algo.to_string();
            }
        }
    }
    row.speedup = row.uncompressed_s / row.compressed_s;
    Ok(row)
}

pub fn print(rows: &[Table3Row], title: &str) {
    println!("\nTable 3 ({title}) — TTFT, uncompressed vs {PAPER_SCHEME}");
    println!(
        "{:<14} {:<10} {:>8} {:>14} {:>14} {:>8} {:>12} {:<14}",
        "model", "accel", "input", "uncompressed", "compressed", "speedup", "auto", "auto-algo"
    );
    common::hr(102);
    for r in rows {
        println!(
            "{:<14} {:<10} {:>8} {:>13.3}s {:>13.3}s {:>7.2}x {:>11.3}s {:<14}",
            r.model,
            r.accelerators,
            r.input,
            r.uncompressed_s,
            r.compressed_s,
            r.speedup,
            r.auto_s,
            r.auto_algo
        );
    }
}

pub fn print_algo_ablation(rows: &[AlgoAblationRow]) {
    println!("\nTable 3b — collective algorithm ablation ({PAPER_SCHEME}, virtual time)");
    println!(
        "{:<10} {:>4} {:<16} {:>12} {:>12} {:>12} {:<18} {:>8}",
        "profile", "tp", "message", "values", "ring", "auto", "auto-algo", "speedup"
    );
    common::hr(100);
    for r in rows {
        println!(
            "{:<10} {:>4} {:<16} {:>12} {:>11.3}ms {:>11.3}ms {:<18} {:>7.2}x",
            r.profile,
            r.tp,
            r.message,
            r.values,
            r.ring_s * 1e3,
            r.auto_s * 1e3,
            r.auto_algo,
            r.speedup
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_auto_never_slower_than_ring() {
        for r in run_analytic() {
            assert!(
                r.auto_s <= r.compressed_s + 1e-12,
                "{} {} {}: auto {} > ring {}",
                r.model,
                r.accelerators,
                r.input,
                r.auto_s,
                r.compressed_s
            );
            assert!(!r.auto_algo.is_empty());
        }
    }

    #[test]
    fn ablation_auto_never_slower_and_wins_where_expected() {
        let rows = run_algo_ablation();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(
                r.auto_s <= r.ring_s + 1e-12,
                "{}/tp{}/{}: auto {} > ring {}",
                r.profile,
                r.tp,
                r.message,
                r.auto_s,
                r.ring_s
            );
        }
        // large messages on the multi-node profiles must leave the flat
        // ring (two-shot or hierarchical), with a real win
        for r in rows.iter().filter(|r| r.profile.starts_with("2x4") && r.values >= 2 * 128 * 8192)
        {
            assert!(
                r.auto_algo.contains("two_shot") || r.auto_algo.contains("hierarchical"),
                "{}/{}: expected two_shot/hierarchical, got {}",
                r.profile,
                r.message,
                r.auto_algo
            );
            assert!(r.speedup > 1.2, "{}/{}: speedup {}", r.profile, r.message, r.speedup);
        }
        // small latency-bound messages stay on a gather algorithm
        for r in rows.iter().filter(|r| r.message.starts_with("decode")) {
            assert!(
                r.auto_algo.contains("ring") || r.auto_algo.contains("recursive_doubling"),
                "{}/{}: expected a gather algo, got {}",
                r.profile,
                r.message,
                r.auto_algo
            );
        }
    }
}
