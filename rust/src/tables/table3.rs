//! Table 3 — TTFT profiling (paper §5.2): Llama-2 {70B, 13B, 7B} across
//! {8xL4, 4xA100, 4xL4, 2xL4} and input shapes, uncompressed vs FP4
//! E2M1/b32/E8M0 (4.25 effective bits), plus a *live* section where the
//! trained models run end-to-end on the CPU PJRT testbed under the
//! simulated interconnect.

use super::common;
use crate::interconnect::HwProfile;
use crate::model::perf_model::{Scenario, LLAMA2_13B, LLAMA2_70B, LLAMA2_7B};
use crate::mxfmt::baselines::Fp16;
use crate::mxfmt::{MxCodec, MxScheme};
use crate::tp::BatchKv;

pub const PAPER_SCHEME: &str = "fp4_e2m1_b32_e8m0";

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub model: String,
    pub accelerators: String,
    pub input: String,
    pub uncompressed_s: f64,
    pub compressed_s: f64,
    pub speedup: f64,
}

/// The paper's eight analytic scenarios.
pub fn paper_rows() -> Vec<(&'static str, crate::model::perf_model::PaperModel, &'static str, usize, usize, usize)>
{
    vec![
        // (label, model, profile, tp, batch, seq)
        ("8xL4", LLAMA2_70B, "l4", 8, 2, 64),
        ("8xL4", LLAMA2_70B, "l4", 8, 2, 128),
        ("4xA100", LLAMA2_70B, "a100", 4, 2, 128),
        ("4xA100", LLAMA2_70B, "a100", 4, 2, 256),
        ("4xL4", LLAMA2_13B, "l4", 4, 8, 128),
        ("4xL4", LLAMA2_13B, "l4", 4, 8, 256),
        ("2xL4", LLAMA2_7B, "l4", 2, 16, 128),
        ("2xL4", LLAMA2_7B, "l4", 2, 16, 256),
    ]
}

/// Analytic mode: the paper's deployments through the perf model.
pub fn run_analytic() -> Vec<Table3Row> {
    let mx = MxCodec::new(MxScheme::parse(PAPER_SCHEME).unwrap());
    paper_rows()
        .into_iter()
        .map(|(label, model, prof, tp, b, s)| {
            let sc = Scenario {
                model,
                profile: HwProfile::by_name(prof).unwrap(),
                tp,
                batch: b,
                seq: s,
            };
            let unc = sc.ttft(&Fp16).total();
            let cmp = sc.ttft(&mx).total();
            Table3Row {
                model: model.name.to_string(),
                accelerators: label.to_string(),
                input: format!("{b}x{s}"),
                uncompressed_s: unc,
                compressed_s: cmp,
                speedup: unc / cmp,
            }
        })
        .collect()
}

/// Live mode: the trained `micro` model executed end-to-end on CPU PJRT
/// with virtual-time interconnect accounting, median of `reps` passes
/// (paper uses median of 32).
///
/// `analytic_overhead` charges the compression overhead at the target
/// profile's quantizer throughput (what the simulated hardware would
/// pay); false charges the measured rust-codec wall time (what *this*
/// CPU pays — its codec/link ratio resembles the paper's fast-
/// interconnect regime).
pub fn run_live(
    profile: &str,
    tp: usize,
    batch: usize,
    seq: usize,
    reps: usize,
    analytic_overhead: bool,
) -> anyhow::Result<Table3Row> {
    let prof = HwProfile::by_name(profile).unwrap();
    let tag = if analytic_overhead { "analytic-ovh" } else { "measured-ovh" };
    let mut row = Table3Row {
        model: format!("micro(live,{tag})"),
        accelerators: format!("{tp}x{profile}"),
        input: format!("{batch}x{seq}"),
        uncompressed_s: 0.0,
        compressed_s: 0.0,
        speedup: 0.0,
    };
    let tokens: Vec<i32> = (0..batch * seq).map(|i| (i * 31 + 7) as i32 % 256).collect();
    let pos = vec![0i32; batch];

    for compressed in [false, true] {
        let spec = if compressed { PAPER_SCHEME } else { "none" };
        let mut eng = common::engine("micro", tp, spec)?;
        eng.opts.profile = prof;
        if analytic_overhead {
            eng.opts.overhead = crate::tp::OverheadModel::Analytic {
                values_per_s: prof.quant_values_per_s,
            };
        }
        let mut kv = BatchKv::new(&eng.cfg.clone(), tp, batch);
        // analytic mode rescales the measured CPU compute to the target
        // accelerator (cpu-profile roofline / target roofline): a model
        // this small on L4/A100-class parts is communication-bound,
        // which is the regime the live run is validating.
        let cpu = HwProfile::by_name("cpu").unwrap();
        let compute_scale = if analytic_overhead {
            (cpu.peak_flops * cpu.mfu) / (prof.peak_flops * prof.mfu)
        } else {
            1.0
        };
        let mut samples = Vec::new();
        for _ in 0..reps.max(1) {
            let (_, t) = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
            samples.push(t.compute_s * compute_scale + t.link_s + t.codec_s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = samples[samples.len() / 2];
        if compressed {
            row.compressed_s = med;
        } else {
            row.uncompressed_s = med;
        }
    }
    row.speedup = row.uncompressed_s / row.compressed_s;
    Ok(row)
}

pub fn print(rows: &[Table3Row], title: &str) {
    println!("\nTable 3 ({title}) — TTFT, uncompressed vs {PAPER_SCHEME}");
    println!(
        "{:<14} {:<10} {:>8} {:>14} {:>14} {:>8}",
        "model", "accel", "input", "uncompressed", "compressed", "speedup"
    );
    common::hr(74);
    for r in rows {
        println!(
            "{:<14} {:<10} {:>8} {:>13.3}s {:>13.3}s {:>7.2}x",
            r.model, r.accelerators, r.input, r.uncompressed_s, r.compressed_s, r.speedup
        );
    }
}
