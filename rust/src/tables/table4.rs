//! Table 4 — SoTA comparison vs Bian et al. 2024 (paper §5.3): MX4
//! E2M1/b32 against channel-wise INT4 and TopK-3x, on perplexity (test
//! split) and TTFT speedup (Llama-2 70B analytic scenarios).

use super::common;
use crate::interconnect::HwProfile;
use crate::model::perf_model::{Scenario, LLAMA2_70B};
use crate::mxfmt::baselines::{ChannelInt, Fp16, TopK};
use crate::mxfmt::{Compressor, MxCodec, MxScheme};

pub const METHODS: &[&str] = &["fp4_e2m1_b32_e8m0", "int4_channelwise", "topk3"];

#[derive(Debug, Clone)]
pub struct Table4 {
    pub models: Vec<String>,
    pub fp16_ppl: Vec<f64>,
    /// rows: per method -> (ppl increase % per model, speedup 8xL4, speedup 4xA100)
    pub rows: Vec<Table4Row>,
    pub fp16_ttft: (f64, f64),
}

#[derive(Debug, Clone)]
pub struct Table4Row {
    pub method: String,
    pub increase_pct: Vec<f64>,
    pub speedup_l4: f64,
    pub speedup_a100: f64,
}

fn method_codec(name: &str, channels: usize) -> Box<dyn Compressor> {
    match name {
        "int4_channelwise" => Box::new(ChannelInt::with_channels(4, channels)),
        "topk3" => Box::new(TopK::new(3.0)),
        s => Box::new(MxCodec::new(MxScheme::parse(s).unwrap())),
    }
}

pub fn run(max_tokens: usize) -> anyhow::Result<Table4> {
    let test = common::corpus("test")?;

    // ---- perplexity on the test split ----
    let mut fp16_ppl = Vec::new();
    let mut incs: Vec<Vec<f64>> = vec![Vec::new(); METHODS.len()];
    for model in common::SWEEP_MODELS {
        let mut eng = common::engine(model, common::SWEEP_TP, "none")?;
        let base = common::ppl(&mut eng, &test, max_tokens)?;
        fp16_ppl.push(base.ppl());
        for (mi, method) in METHODS.iter().enumerate() {
            eng.set_compress(method)?;
            let r = common::ppl(&mut eng, &test, max_tokens)?;
            incs[mi].push(r.increase_pct(&base));
        }
    }

    // ---- TTFT speedups (paper: Llama-2 70B, 2x128 on 8xL4 / 2x256 on 4xA100) ----
    let l4 = Scenario {
        model: LLAMA2_70B,
        profile: HwProfile::by_name("l4").unwrap(),
        tp: 8,
        batch: 2,
        seq: 128,
    };
    let a100 = Scenario {
        model: LLAMA2_70B,
        profile: HwProfile::by_name("a100").unwrap(),
        tp: 4,
        batch: 2,
        seq: 256,
    };
    let base_l4 = l4.ttft(&Fp16).total();
    let base_a100 = a100.ttft(&Fp16).total();

    let mut rows = Vec::new();
    for (mi, method) in METHODS.iter().enumerate() {
        let channels = LLAMA2_70B.d_model;
        let codec = method_codec(method, channels);
        rows.push(Table4Row {
            method: method.to_string(),
            increase_pct: incs[mi].clone(),
            speedup_l4: base_l4 / l4.ttft(codec.as_ref()).total(),
            speedup_a100: base_a100 / a100.ttft(codec.as_ref()).total(),
        });
    }
    Ok(Table4 {
        models: common::SWEEP_MODELS.iter().map(|s| s.to_string()).collect(),
        fp16_ppl,
        rows,
        fp16_ttft: (base_l4, base_a100),
    })
}

pub fn print(t: &Table4) {
    println!("\nTable 4 — SoTA comparison (Bian et al. baselines)");
    print!("{:<22}", "method");
    for m in &t.models {
        print!(" {:>9}", m);
    }
    println!(" {:>10} {:>10}", "TTFT 8xL4", "4xA100");
    common::hr(24 + 10 * t.models.len() + 22);
    print!("{:<22}", "fp16 (abs)");
    for p in &t.fp16_ppl {
        print!(" {:>9.3}", p);
    }
    println!(" {:>9.3}s {:>9.3}s", t.fp16_ttft.0, t.fp16_ttft.1);
    for r in &t.rows {
        print!("{:<22}", r.method);
        for v in &r.increase_pct {
            print!(" {:>8.2}%", v);
        }
        println!(" {:>9.2}x {:>9.2}x", r.speedup_l4, r.speedup_a100);
    }
}
