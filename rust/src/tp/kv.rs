//! Batched KV cache owned by the coordinator, sharded per TP rank.
//!
//! The authoritative cache lives here as contiguous `[B, Hn, T, hd]` f32
//! buffers per (rank, layer) — exactly the literal layout the decode
//! attention stage expects, so handing it to PJRT is a single memcpy.
//! Stage programs only *output* the new-token slices; `write_slices`
//! mirrors the HLO-side `dynamic_update_slice` on the rust side.
//!
//! Each rank's buffers sit behind their own `Arc<Mutex<KvShard>>` so the
//! rank-thread runtime can hand rank `r`'s shard to the worker that owns
//! rank `r` ([`BatchKv::shard_handle`]) while the coordinator keeps the
//! whole-cache view for slot management. Access never contends: during a
//! forward only the owning worker touches a shard, and the coordinator's
//! slot operations (`adopt_slot`, `clear_slot`) run between forwards.

use std::sync::{Arc, Mutex};

use crate::metrics::Gauge;
use crate::model::ModelConfig;
use crate::runtime::lit_f32;

/// One rank's KV cache: per-layer contiguous `[B, Hn, T, hd]` buffers.
pub struct KvShard {
    /// [layer] -> contiguous [B, Hn, T, hd]
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    batch: usize,
    heads: usize, // per-rank heads (Hn)
    cap: usize,   // T
    head_dim: usize,
}

/// Cloneable handle to one rank's shard (what a rank worker receives).
pub type KvShardRef = Arc<Mutex<KvShard>>;

impl KvShard {
    fn new(n_layers: usize, batch: usize, heads: usize, cap: usize, head_dim: usize) -> KvShard {
        let size = batch * heads * cap * head_dim;
        KvShard {
            k: (0..n_layers).map(|_| vec![0.0f32; size]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; size]).collect(),
            batch,
            heads,
            cap,
            head_dim,
        }
    }

    fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.len() * 4).sum()
    }

    /// Write the new-token K/V slices returned by an attention stage.
    /// `ks`/`vs` are `[B, Hn, S, hd]` row-major; row `b`'s tokens land at
    /// positions `pos[b] .. pos[b]+s` of its cache slot.
    pub fn write_slices(&mut self, layer: usize, s: usize, pos: &[i32], ks: &[f32], vs: &[f32]) {
        let (bn, hn, t, hd) = (self.batch, self.heads, self.cap, self.head_dim);
        debug_assert_eq!(ks.len(), bn * hn * s * hd);
        for b in 0..bn {
            let p = pos[b] as usize;
            let end = (p + s).min(t);
            let copy_s = end.saturating_sub(p);
            for h in 0..hn {
                let src_base = (b * hn + h) * s * hd;
                let dst_base = ((b * hn + h) * t + p) * hd;
                let kdst = &mut self.k[layer][dst_base..dst_base + copy_s * hd];
                kdst.copy_from_slice(&ks[src_base..src_base + copy_s * hd]);
                let vdst = &mut self.v[layer][dst_base..dst_base + copy_s * hd];
                vdst.copy_from_slice(&vs[src_base..src_base + copy_s * hd]);
            }
        }
    }

    /// Materialize the (k, v) history literals for a decode call.
    pub fn cache_literals(&self, layer: usize) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        let dims = [self.batch, self.heads, self.cap, self.head_dim];
        Ok((lit_f32(&dims, &self.k[layer])?, lit_f32(&dims, &self.v[layer])?))
    }

    fn adopt_slot(&mut self, dst_slot: usize, src: &KvShard, src_slot: usize, len: usize) {
        let (hn, t, hd) = (self.heads, self.cap, self.head_dim);
        assert_eq!(src.heads, hn);
        assert_eq!(src.head_dim, hd);
        let n = len.min(t) * hd;
        for layer in 0..self.k.len() {
            for h in 0..hn {
                let dst_base = ((dst_slot * hn + h) * t) * hd;
                let src_base = ((src_slot * hn + h) * src.cap) * hd;
                self.k[layer][dst_base..dst_base + n]
                    .copy_from_slice(&src.k[layer][src_base..src_base + n]);
                self.v[layer][dst_base..dst_base + n]
                    .copy_from_slice(&src.v[layer][src_base..src_base + n]);
            }
        }
    }

    fn clear_slot(&mut self, slot: usize) {
        let (hn, t, hd) = (self.heads, self.cap, self.head_dim);
        let base = slot * hn * t * hd;
        let n = hn * t * hd;
        for layer in 0..self.k.len() {
            self.k[layer][base..base + n].fill(0.0);
            self.v[layer][base..base + n].fill(0.0);
        }
    }
}

/// The whole-batch KV cache: one [`KvShard`] per TP rank.
pub struct BatchKv {
    /// [rank] -> that rank's shard
    shards: Vec<KvShardRef>,
    /// [slot] -> holds a live sequence's history (tracks the attached
    /// occupancy gauge; adopt/clear are idempotent per slot)
    occupied: Vec<bool>,
    /// occupancy gauge (`kv_blocks_in_use`), when attached
    gauge: Option<Gauge>,
    pub batch: usize,
    pub heads: usize, // per-rank heads (Hn)
    pub cap: usize,   // T
    pub head_dim: usize,
}

impl BatchKv {
    pub fn new(cfg: &ModelConfig, tp: usize, batch: usize) -> BatchKv {
        let hn = cfg.shard_heads(tp);
        BatchKv {
            shards: (0..tp)
                .map(|_| {
                    Arc::new(Mutex::new(KvShard::new(
                        cfg.n_layers,
                        batch,
                        hn,
                        cfg.max_seq,
                        cfg.head_dim,
                    )))
                })
                .collect(),
            occupied: vec![false; batch],
            gauge: None,
            batch,
            heads: hn,
            cap: cfg.max_seq,
            head_dim: cfg.head_dim,
        }
    }

    /// Attach an occupancy gauge: `adopt_slot` / `clear_slot` keep it at
    /// the number of slots holding a live sequence. The gauge is only
    /// meaningful on the cache whose slots track sequence lifetime (the
    /// coordinator's decode cache); per-request prefill caches go
    /// without.
    pub fn with_gauge(mut self, gauge: Gauge) -> BatchKv {
        gauge.add(self.occupied.iter().filter(|&&o| o).count() as i64);
        self.gauge = Some(gauge);
        self
    }

    /// Slots currently holding a live sequence.
    pub fn slots_in_use(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    /// Handle to rank `r`'s shard, for the worker thread that owns it.
    pub fn shard_handle(&self, rank: usize) -> KvShardRef {
        self.shards[rank].clone()
    }

    /// Bytes held by this cache (both K and V, all ranks/layers).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes()).sum()
    }

    /// Write the new-token K/V slices returned by an attention stage
    /// (see [`KvShard::write_slices`]).
    pub fn write_slices(
        &mut self,
        rank: usize,
        layer: usize,
        s: usize,
        pos: &[i32],
        ks: &[f32],
        vs: &[f32],
    ) {
        self.shards[rank].lock().unwrap().write_slices(layer, s, pos, ks, vs);
    }

    /// Materialize the (k, v) history literals for a decode call.
    pub fn cache_literals(
        &self,
        rank: usize,
        layer: usize,
    ) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        self.shards[rank].lock().unwrap().cache_literals(layer)
    }

    /// Copy one sequence slot's cache rows from another BatchKv (used
    /// when a freshly-prefilled sequence joins a decode batch).
    pub fn adopt_slot(&mut self, dst_slot: usize, src: &BatchKv, src_slot: usize, len: usize) {
        for rank in 0..self.shards.len() {
            let mut dst = self.shards[rank].lock().unwrap();
            let s = src.shards[rank].lock().unwrap();
            dst.adopt_slot(dst_slot, &s, src_slot, len);
        }
        if !std::mem::replace(&mut self.occupied[dst_slot], true) {
            if let Some(g) = &self.gauge {
                g.inc();
            }
        }
    }

    /// Zero one slot (sequence retired). Idempotent: the occupancy
    /// gauge only moves when the slot actually held a sequence.
    pub fn clear_slot(&mut self, slot: usize) {
        for shard in &self.shards {
            shard.lock().unwrap().clear_slot(slot);
        }
        if std::mem::replace(&mut self.occupied[slot], false) {
            if let Some(g) = &self.gauge {
                g.dec();
            }
        }
    }

    /// Raw copies for tests.
    pub fn k_at(&self, rank: usize, layer: usize) -> Vec<f32> {
        self.shards[rank].lock().unwrap().k[layer].clone()
    }
    pub fn v_at(&self, rank: usize, layer: usize) -> Vec<f32> {
        self.shards[rank].lock().unwrap().v[layer].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 4,
            head_dim: 2,
            d_ff: 8,
            max_seq: 6,
            params: 0,
        }
    }

    #[test]
    fn write_and_read_back() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 2, 2); // tp=2 -> hn=2
        // write S=3 tokens for row 0 at pos 0, row 1 at pos 2
        let s = 3;
        let n = 2 * 2 * s * 2; // B*Hn*S*hd
        let ks: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let vs: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        kv.write_slices(0, 1, s, &[0, 2], &ks, &vs);
        let k = kv.k_at(0, 1);
        // row 0, head 0, positions 0..3
        assert_eq!(k[0], 0.0);
        assert_eq!(k[1], 1.0);
        assert_eq!(k[2 * 2], 4.0); // pos 2, first elem of third token
        // row 1 (slot base = 1*hn*t*hd = 2*6*2 = 24), head 0, pos 2
        let base = 24 + 2 * 2;
        assert_eq!(k[base], 12.0); // first element of row 1's slice
        // untouched layer stays zero
        assert!(kv.k_at(0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clamps_writes_past_capacity() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 1);
        let s = 4;
        let ks = vec![1.0f32; 4 * s * 2];
        let vs = ks.clone();
        // pos 4 + s 4 > cap 6: only 2 tokens land
        kv.write_slices(0, 0, s, &[4], &ks, &vs);
        let k = kv.k_at(0, 0);
        // head 0: positions 4,5 written
        assert_eq!(k[4 * 2], 1.0);
        assert_eq!(k[5 * 2 + 1], 1.0);
    }

    #[test]
    fn adopt_slot_copies_history() {
        let c = cfg();
        let mut pre = BatchKv::new(&c, 1, 1);
        let s = 2;
        let ks: Vec<f32> = (0..4 * s * 2).map(|i| i as f32 + 1.0).collect();
        pre.write_slices(0, 0, s, &[0], &ks, &ks);
        let mut dec = BatchKv::new(&c, 1, 4);
        dec.adopt_slot(2, &pre, 0, s);
        let k = dec.k_at(0, 0);
        let hn_t_hd = 4 * 6 * 2;
        let slot2 = 2 * hn_t_hd;
        assert_eq!(k[slot2], 1.0);
        assert_eq!(k[slot2 + 1], 2.0);
        // other slots untouched
        assert!(k[..slot2].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 2);
        let ks = vec![5.0f32; 2 * 4 * 1 * 2];
        kv.write_slices(0, 0, 1, &[0, 0], &ks[..], &ks[..]);
        kv.clear_slot(0);
        let hn_t_hd = 4 * 6 * 2;
        assert!(kv.k_at(0, 0)[..hn_t_hd].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bytes_accounting() {
        let c = cfg();
        let kv = BatchKv::new(&c, 2, 3);
        // per rank/layer: 3*2*6*2 floats; 2 ranks * 2 layers * 2 (k+v)
        assert_eq!(kv.bytes(), 3 * 2 * 6 * 2 * 4 * 2 * 2 * 2);
    }

    #[test]
    fn occupancy_gauge_tracks_slot_lifetime_idempotently() {
        let c = cfg();
        let g = Gauge::default();
        let pre = BatchKv::new(&c, 1, 1);
        let mut kv = BatchKv::new(&c, 1, 4).with_gauge(g.clone());
        assert_eq!(g.get(), 0);
        kv.adopt_slot(2, &pre, 0, 1);
        kv.adopt_slot(0, &pre, 0, 1);
        assert_eq!(g.get(), 2);
        assert_eq!(kv.slots_in_use(), 2);
        // re-adopting an occupied slot must not double-count
        kv.adopt_slot(2, &pre, 0, 1);
        assert_eq!(g.get(), 2);
        kv.clear_slot(2);
        assert_eq!(g.get(), 1);
        // clearing an empty slot must not go negative
        kv.clear_slot(2);
        kv.clear_slot(3);
        assert_eq!(g.get(), 1);
        kv.clear_slot(0);
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn shard_handle_aliases_the_coordinator_view() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 2, 1);
        let h = kv.shard_handle(1);
        let s = 1;
        let ks = vec![3.0f32; 2 * s * 2]; // B*Hn*S*hd = 1*2*1*2
        // a worker writing through its handle ...
        h.lock().unwrap().write_slices(0, s, &[0], &ks, &ks);
        // ... is visible through the coordinator's whole-cache view
        assert_eq!(kv.k_at(1, 0)[0], 3.0);
        // and vice versa
        kv.clear_slot(0);
        assert!(h.lock().unwrap().k[0].iter().all(|&x| x == 0.0));
    }
}
