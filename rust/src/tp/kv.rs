//! Batched KV cache owned by the coordinator.
//!
//! The authoritative cache lives here as contiguous `[B, Hn, T, hd]` f32
//! buffers per (rank, layer) — exactly the literal layout the decode
//! attention stage expects, so handing it to PJRT is a single memcpy.
//! Stage programs only *output* the new-token slices; `write_slices`
//! mirrors the HLO-side `dynamic_update_slice` on the rust side.

use crate::model::ModelConfig;
use crate::runtime::lit_f32;

pub struct BatchKv {
    /// [rank][layer] -> contiguous [B, Hn, T, hd]
    k: Vec<Vec<Vec<f32>>>,
    v: Vec<Vec<Vec<f32>>>,
    pub batch: usize,
    pub heads: usize, // per-rank heads (Hn)
    pub cap: usize,   // T
    pub head_dim: usize,
}

impl BatchKv {
    pub fn new(cfg: &ModelConfig, tp: usize, batch: usize) -> BatchKv {
        let hn = cfg.shard_heads(tp);
        let size = batch * hn * cfg.max_seq * cfg.head_dim;
        let mk = || {
            (0..cfg.n_layers)
                .map(|_| vec![0.0f32; size])
                .collect::<Vec<_>>()
        };
        BatchKv {
            k: (0..tp).map(|_| mk()).collect(),
            v: (0..tp).map(|_| mk()).collect(),
            batch,
            heads: hn,
            cap: cfg.max_seq,
            head_dim: cfg.head_dim,
        }
    }

    /// Bytes held by this cache (both K and V, all ranks/layers).
    pub fn bytes(&self) -> usize {
        let per: usize = self.k.iter().flat_map(|l| l.iter()).map(|b| b.len() * 4).sum();
        per * 2
    }

    /// Write the new-token K/V slices returned by an attention stage.
    /// `ks`/`vs` are `[B, Hn, S, hd]` row-major; row `b`'s tokens land at
    /// positions `pos[b] .. pos[b]+s` of its cache slot.
    pub fn write_slices(
        &mut self,
        rank: usize,
        layer: usize,
        s: usize,
        pos: &[i32],
        ks: &[f32],
        vs: &[f32],
    ) {
        let (bn, hn, t, hd) = (self.batch, self.heads, self.cap, self.head_dim);
        debug_assert_eq!(ks.len(), bn * hn * s * hd);
        for b in 0..bn {
            let p = pos[b] as usize;
            let end = (p + s).min(t);
            let copy_s = end.saturating_sub(p);
            for h in 0..hn {
                let src_base = (b * hn + h) * s * hd;
                let dst_base = ((b * hn + h) * t + p) * hd;
                let kdst = &mut self.k[rank][layer][dst_base..dst_base + copy_s * hd];
                kdst.copy_from_slice(&ks[src_base..src_base + copy_s * hd]);
                let vdst = &mut self.v[rank][layer][dst_base..dst_base + copy_s * hd];
                vdst.copy_from_slice(&vs[src_base..src_base + copy_s * hd]);
            }
        }
    }

    /// Materialize the (k, v) history literals for a decode call.
    pub fn cache_literals(
        &self,
        rank: usize,
        layer: usize,
    ) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        let dims = [self.batch, self.heads, self.cap, self.head_dim];
        Ok((
            lit_f32(&dims, &self.k[rank][layer])?,
            lit_f32(&dims, &self.v[rank][layer])?,
        ))
    }

    /// Copy one sequence slot's cache rows from another BatchKv (used
    /// when a freshly-prefilled sequence joins a decode batch).
    pub fn adopt_slot(&mut self, dst_slot: usize, src: &BatchKv, src_slot: usize, len: usize) {
        let (hn, t, hd) = (self.heads, self.cap, self.head_dim);
        assert_eq!(src.heads, hn);
        assert_eq!(src.head_dim, hd);
        let n = len.min(t) * hd;
        for rank in 0..self.k.len() {
            for layer in 0..self.k[rank].len() {
                for h in 0..hn {
                    let dst_base = ((dst_slot * hn + h) * t) * hd;
                    let src_base = ((src_slot * hn + h) * src.cap) * hd;
                    self.k[rank][layer][dst_base..dst_base + n]
                        .copy_from_slice(&src.k[rank][layer][src_base..src_base + n]);
                    self.v[rank][layer][dst_base..dst_base + n]
                        .copy_from_slice(&src.v[rank][layer][src_base..src_base + n]);
                }
            }
        }
    }

    /// Zero one slot (sequence retired).
    pub fn clear_slot(&mut self, slot: usize) {
        let (hn, t, hd) = (self.heads, self.cap, self.head_dim);
        let base = slot * hn * t * hd;
        let n = hn * t * hd;
        for rank in 0..self.k.len() {
            for layer in 0..self.k[rank].len() {
                self.k[rank][layer][base..base + n].fill(0.0);
                self.v[rank][layer][base..base + n].fill(0.0);
            }
        }
    }

    /// Raw access for tests.
    pub fn k_at(&self, rank: usize, layer: usize) -> &[f32] {
        &self.k[rank][layer]
    }
    pub fn v_at(&self, rank: usize, layer: usize) -> &[f32] {
        &self.v[rank][layer]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 4,
            head_dim: 2,
            d_ff: 8,
            max_seq: 6,
            params: 0,
        }
    }

    #[test]
    fn write_and_read_back() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 2, 2); // tp=2 -> hn=2
        // write S=3 tokens for row 0 at pos 0, row 1 at pos 2
        let s = 3;
        let n = 2 * 2 * s * 2; // B*Hn*S*hd
        let ks: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let vs: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        kv.write_slices(0, 1, s, &[0, 2], &ks, &vs);
        let k = kv.k_at(0, 1);
        // row 0, head 0, positions 0..3
        assert_eq!(k[0], 0.0);
        assert_eq!(k[1], 1.0);
        assert_eq!(k[2 * 2], 4.0); // pos 2, first elem of third token
        // row 1 (slot base = 1*hn*t*hd = 2*6*2 = 24), head 0, pos 2
        let base = 24 + 2 * 2;
        assert_eq!(k[base], 12.0); // first element of row 1's slice
        // untouched layer stays zero
        assert!(kv.k_at(0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clamps_writes_past_capacity() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 1);
        let s = 4;
        let ks = vec![1.0f32; 4 * s * 2];
        let vs = ks.clone();
        // pos 4 + s 4 > cap 6: only 2 tokens land
        kv.write_slices(0, 0, s, &[4], &ks, &vs);
        let k = kv.k_at(0, 0);
        // head 0: positions 4,5 written
        assert_eq!(k[4 * 2], 1.0);
        assert_eq!(k[5 * 2 + 1], 1.0);
    }

    #[test]
    fn adopt_slot_copies_history() {
        let c = cfg();
        let mut pre = BatchKv::new(&c, 1, 1);
        let s = 2;
        let ks: Vec<f32> = (0..4 * s * 2).map(|i| i as f32 + 1.0).collect();
        pre.write_slices(0, 0, s, &[0], &ks, &ks);
        let mut dec = BatchKv::new(&c, 1, 4);
        dec.adopt_slot(2, &pre, 0, s);
        let k = dec.k_at(0, 0);
        let hn_t_hd = 4 * 6 * 2;
        let slot2 = 2 * hn_t_hd;
        assert_eq!(k[slot2], 1.0);
        assert_eq!(k[slot2 + 1], 2.0);
        // other slots untouched
        assert!(k[..slot2].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 2);
        let ks = vec![5.0f32; 2 * 4 * 1 * 2];
        kv.write_slices(0, 0, 1, &[0, 0], &ks[..], &ks[..]);
        kv.clear_slot(0);
        let hn_t_hd = 4 * 6 * 2;
        assert!(kv.k_at(0, 0)[..hn_t_hd].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bytes_accounting() {
        let c = cfg();
        let kv = BatchKv::new(&c, 2, 3);
        // per rank/layer: 3*2*6*2 floats; 2 ranks * 2 layers * 2 (k+v)
        assert_eq!(kv.bytes(), 3 * 2 * 6 * 2 * 4 * 2 * 2 * 2);
    }
}
