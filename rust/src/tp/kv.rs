//! Paged, batched KV cache owned by the coordinator, sharded per TP rank.
//!
//! Storage is a fixed pool of fixed-size **blocks** per rank shard: each
//! block holds `block` token positions (all heads, one layer stride per
//! arena) and sequences map tokens to blocks through a per-slot block
//! table, vLLM-style. The decode attention stage still consumes one
//! contiguous `[B, Hn, T, hd]` f32 literal per (rank, layer), so
//! [`KvShard::cache_literals`] gathers the mapped blocks into that layout
//! on demand (we gather on the host instead of running a paged-attention
//! kernel — see DESIGN.md for the deviation rationale). Stage programs
//! only *output* new-token slices; `write_slices` routes them through the
//! block table, mirroring the HLO-side `dynamic_update_slice`.
//!
//! Two allocation modes share one code path:
//! * [`BatchKv::new`] pre-maps every slot to full capacity — the
//!   transient per-prefill cache and the engine tests use this, and it
//!   behaves exactly like the old monolithic cache.
//! * [`BatchKv::paged`] starts with an empty table per slot and a free
//!   list; the coordinator maps blocks on demand ([`BatchKv::ensure_tokens`])
//!   and reclaims them by preempting a session ([`BatchKv::swap_out`] /
//!   [`BatchKv::swap_in`], bit-exact host copies) when the pool runs dry.
//!
//! Each rank's arena sits behind its own `Arc<Mutex<KvShard>>` so the
//! rank-thread runtime can hand rank `r`'s shard to the worker that owns
//! rank `r` ([`BatchKv::shard_handle`]) while the coordinator keeps the
//! whole-cache view for slot management. Access never contends: during a
//! forward only the owning worker touches a shard, and the coordinator's
//! slot operations (map/adopt/clear/swap) run between forwards. Block
//! tables are mirrored into every shard under its mutex, and all shards
//! perform the identical alloc/free sequence, so the tables stay
//! congruent across ranks by construction.

use std::sync::{Arc, Mutex};

use crate::metrics::Gauge;
use crate::model::ModelConfig;
use crate::runtime::lit_f32;

/// Default tokens per KV block (`--kv-block`).
pub const DEFAULT_KV_BLOCK: usize = 16;

/// One rank's KV cache: per-layer block arenas + per-slot block tables.
pub struct KvShard {
    /// [layer] -> arena `[total_blocks, Hn, block, hd]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// [slot] -> block ids mapping token ranges `[i*block, (i+1)*block)`
    tables: Vec<Vec<u32>>,
    batch: usize,
    heads: usize, // per-rank heads (Hn)
    cap: usize,   // T
    head_dim: usize,
    block: usize, // tokens per block
}

/// Cloneable handle to one rank's shard (what a rank worker receives).
pub type KvShardRef = Arc<Mutex<KvShard>>;

impl KvShard {
    fn new(
        n_layers: usize,
        batch: usize,
        heads: usize,
        cap: usize,
        head_dim: usize,
        block: usize,
        total_blocks: usize,
    ) -> KvShard {
        let size = total_blocks * heads * block * head_dim;
        KvShard {
            k: (0..n_layers).map(|_| vec![0.0f32; size]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; size]).collect(),
            tables: vec![Vec::new(); batch],
            batch,
            heads,
            cap,
            head_dim,
            block,
        }
    }

    fn bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|b| b.len() * 4).sum()
    }

    /// Arena offset of (block, head, in-block token offset).
    #[inline]
    fn at(&self, blk: u32, h: usize, off: usize) -> usize {
        ((blk as usize * self.heads + h) * self.block + off) * self.head_dim
    }

    fn map_block(&mut self, slot: usize, blk: u32) {
        self.tables[slot].push(blk);
    }

    fn unmap_slot(&mut self, slot: usize) -> Vec<u32> {
        let blocks = std::mem::take(&mut self.tables[slot]);
        let n = self.heads * self.block * self.head_dim;
        for layer in 0..self.k.len() {
            for &b in &blocks {
                let base = b as usize * n;
                self.k[layer][base..base + n].fill(0.0);
                self.v[layer][base..base + n].fill(0.0);
            }
        }
        blocks
    }

    /// Write the new-token K/V slices returned by an attention stage.
    /// `ks`/`vs` are `[B, Hn, S, hd]` row-major; row `b`'s tokens land at
    /// positions `pos[b] .. pos[b]+s` of its cache slot. Positions past
    /// capacity or past the slot's mapped blocks are dropped (a padded
    /// decode batch writes rows for vacant slots that map nowhere).
    pub fn write_slices(&mut self, layer: usize, s: usize, pos: &[i32], ks: &[f32], vs: &[f32]) {
        let (bn, hn, t, hd) = (self.batch, self.heads, self.cap, self.head_dim);
        debug_assert_eq!(ks.len(), bn * hn * s * hd);
        for b in 0..bn {
            let p = pos[b] as usize;
            let end = (p + s).min(t);
            for tok in p..end {
                let Some(&blk) = self.tables[b].get(tok / self.block) else {
                    continue;
                };
                let off = tok % self.block;
                for h in 0..hn {
                    let src = ((b * hn + h) * s + (tok - p)) * hd;
                    let dst = self.at(blk, h, off);
                    self.k[layer][dst..dst + hd].copy_from_slice(&ks[src..src + hd]);
                    self.v[layer][dst..dst + hd].copy_from_slice(&vs[src..src + hd]);
                }
            }
        }
    }

    /// Gather one layer into the contiguous `[B, Hn, T, hd]` layout the
    /// decode attention stage expects. Unmapped positions read as zeros
    /// (attention masks beyond each row's `pos`, so they are never
    /// observable in logits).
    fn gather_layer(&self, layer: usize) -> (Vec<f32>, Vec<f32>) {
        let (bn, hn, t, hd) = (self.batch, self.heads, self.cap, self.head_dim);
        let mut k = vec![0.0f32; bn * hn * t * hd];
        let mut v = vec![0.0f32; bn * hn * t * hd];
        for b in 0..bn {
            for (j, &blk) in self.tables[b].iter().enumerate() {
                let t0 = j * self.block;
                let run = self.block.min(t - t0);
                for h in 0..hn {
                    let src = self.at(blk, h, 0);
                    let dst = ((b * hn + h) * t + t0) * hd;
                    k[dst..dst + run * hd].copy_from_slice(&self.k[layer][src..src + run * hd]);
                    v[dst..dst + run * hd].copy_from_slice(&self.v[layer][src..src + run * hd]);
                }
            }
        }
        (k, v)
    }

    /// Materialize the (k, v) history literals for a decode call.
    pub fn cache_literals(&self, layer: usize) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        let dims = [self.batch, self.heads, self.cap, self.head_dim];
        let (k, v) = self.gather_layer(layer);
        Ok((lit_f32(&dims, &k)?, lit_f32(&dims, &v)?))
    }

    /// Gather one slot's first `len` tokens of a layer as `[Hn, len, hd]`.
    fn read_slot(&self, layer: usize, slot: usize, len: usize) -> (Vec<f32>, Vec<f32>) {
        let (hn, hd) = (self.heads, self.head_dim);
        let len = len.min(self.cap);
        let mut k = vec![0.0f32; hn * len * hd];
        let mut v = vec![0.0f32; hn * len * hd];
        for (j, &blk) in self.tables[slot].iter().enumerate() {
            let t0 = j * self.block;
            if t0 >= len {
                break;
            }
            let run = self.block.min(len - t0);
            for h in 0..hn {
                let src = self.at(blk, h, 0);
                let dst = (h * len + t0) * hd;
                k[dst..dst + run * hd].copy_from_slice(&self.k[layer][src..src + run * hd]);
                v[dst..dst + run * hd].copy_from_slice(&self.v[layer][src..src + run * hd]);
            }
        }
        (k, v)
    }

    /// Scatter `[Hn, len, hd]` data into a slot's blocks at positions
    /// `0..len`. The caller must have mapped enough blocks.
    fn write_slot(&mut self, layer: usize, slot: usize, len: usize, k: &[f32], v: &[f32]) {
        let (hn, hd) = (self.heads, self.head_dim);
        let len = len.min(self.cap);
        for tok in 0..len {
            let Some(&blk) = self.tables[slot].get(tok / self.block) else {
                continue;
            };
            let off = tok % self.block;
            for h in 0..hn {
                let src = (h * len + tok) * hd;
                let dst = self.at(blk, h, off);
                self.k[layer][dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                self.v[layer][dst..dst + hd].copy_from_slice(&v[src..src + hd]);
            }
        }
    }
}

/// One preempted sequence's KV history, swapped out of the block pool
/// into host buffers. Swapping (rather than recompute-on-restore) keeps
/// the restore **bit-identical**: the cache after `swap_in` is the exact
/// f32 image the session had when evicted.
pub struct SwappedKv {
    pub len: usize,
    /// [rank][layer] -> `[Hn, len, hd]`
    k: Vec<Vec<Vec<f32>>>,
    v: Vec<Vec<Vec<f32>>>,
}

impl SwappedKv {
    /// Host bytes held by this swapped image.
    pub fn bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .flat_map(|layers| layers.iter())
            .map(|b| b.len() * 4)
            .sum()
    }
}

/// The whole-batch KV cache: one [`KvShard`] per TP rank plus the block
/// allocator state (free list, per-slot mapped counts, gauges).
pub struct BatchKv {
    /// [rank] -> that rank's shard
    shards: Vec<KvShardRef>,
    /// [slot] -> holds a live sequence's history (tracks `slots_in_use`;
    /// adopt/clear are idempotent per slot)
    occupied: Vec<bool>,
    /// [slot] -> mapped block count (mirror of the shards' table lens)
    slot_blocks: Vec<usize>,
    /// unmapped block ids, shared across shards (tables are congruent)
    free: Vec<u32>,
    total_blocks: usize,
    n_layers: usize,
    /// mapped-block gauge (`kv_blocks_in_use`), when attached
    in_use_gauge: Option<Gauge>,
    /// free-block gauge (`kv_blocks_free`), when attached
    free_gauge: Option<Gauge>,
    pub batch: usize,
    pub heads: usize, // per-rank heads (Hn)
    pub cap: usize,   // T
    pub head_dim: usize,
    pub block: usize, // tokens per block
}

impl BatchKv {
    /// Fully-mapped cache: every slot pre-mapped to capacity, exactly the
    /// old monolithic behavior. Used for transient prefill caches and
    /// anywhere allocation pressure is not being modeled.
    pub fn new(cfg: &ModelConfig, tp: usize, batch: usize) -> BatchKv {
        let block = DEFAULT_KV_BLOCK.min(cfg.max_seq.max(1));
        let pool = batch * Self::blocks_per_seq(cfg.max_seq, block);
        let mut kv = Self::paged(cfg, tp, batch, block, pool);
        for slot in 0..batch {
            let ok = kv.ensure_tokens(slot, cfg.max_seq);
            debug_assert!(ok, "full pool must map every slot");
        }
        kv
    }

    /// Paged cache: `pool_blocks` blocks per rank shard, nothing mapped.
    /// The coordinator maps blocks per slot on demand and preempts when
    /// `ensure_tokens` fails.
    pub fn paged(
        cfg: &ModelConfig,
        tp: usize,
        batch: usize,
        block: usize,
        pool_blocks: usize,
    ) -> BatchKv {
        let hn = cfg.shard_heads(tp);
        let block = block.clamp(1, cfg.max_seq.max(1));
        BatchKv {
            shards: (0..tp)
                .map(|_| {
                    Arc::new(Mutex::new(KvShard::new(
                        cfg.n_layers,
                        batch,
                        hn,
                        cfg.max_seq,
                        cfg.head_dim,
                        block,
                        pool_blocks,
                    )))
                })
                .collect(),
            occupied: vec![false; batch],
            slot_blocks: vec![0; batch],
            free: (0..pool_blocks as u32).rev().collect(),
            total_blocks: pool_blocks,
            n_layers: cfg.n_layers,
            in_use_gauge: None,
            free_gauge: None,
            batch,
            heads: hn,
            cap: cfg.max_seq,
            head_dim: cfg.head_dim,
            block,
        }
    }

    /// Blocks needed to cover `tokens` positions of a `cap`-long slot.
    pub fn blocks_per_seq(tokens: usize, block: usize) -> usize {
        tokens.div_ceil(block.max(1))
    }

    /// Blocks needed for a sequence of `tokens` tokens in this pool.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        Self::blocks_per_seq(tokens.min(self.cap), self.block)
    }

    /// Attach the mapped-block gauge (`kv_blocks_in_use`). Meaningful on
    /// the cache whose blocks track sequence lifetime (the coordinator's
    /// paged decode pool); per-request prefill caches go without.
    pub fn with_gauge(mut self, gauge: Gauge) -> BatchKv {
        gauge.add(self.mapped_blocks() as i64 - gauge.get());
        self.in_use_gauge = Some(gauge);
        self
    }

    /// Attach the free-block gauge (`kv_blocks_free`).
    pub fn with_free_gauge(mut self, gauge: Gauge) -> BatchKv {
        gauge.add(self.free.len() as i64 - gauge.get());
        self.free_gauge = Some(gauge);
        self
    }

    fn sync_gauges(&self) {
        if let Some(g) = &self.in_use_gauge {
            g.add(self.mapped_blocks() as i64 - g.get());
        }
        if let Some(g) = &self.free_gauge {
            g.add(self.free.len() as i64 - g.get());
        }
    }

    /// Blocks currently mapped to some slot.
    pub fn mapped_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Blocks available for mapping.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Total blocks in the pool.
    pub fn pool_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks mapped to one slot (test/introspection).
    pub fn slot_mapped(&self, slot: usize) -> usize {
        self.slot_blocks[slot]
    }

    /// Slots currently holding a live sequence.
    pub fn slots_in_use(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    /// Map blocks until `slot` covers `tokens` positions. Returns false
    /// (leaving any partial mapping in place for a retry after the
    /// caller frees blocks by preempting) when the free list runs dry.
    pub fn ensure_tokens(&mut self, slot: usize, tokens: usize) -> bool {
        let need = self.blocks_for(tokens);
        while self.slot_blocks[slot] < need {
            let Some(blk) = self.free.pop() else {
                self.sync_gauges();
                return false;
            };
            for shard in &self.shards {
                shard.lock().unwrap().map_block(slot, blk);
            }
            self.slot_blocks[slot] += 1;
        }
        self.sync_gauges();
        true
    }

    /// Handle to rank `r`'s shard, for the worker thread that owns it.
    pub fn shard_handle(&self, rank: usize) -> KvShardRef {
        self.shards[rank].clone()
    }

    /// Bytes held by this cache (both K and V, all ranks/layers).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes()).sum()
    }

    /// Write the new-token K/V slices returned by an attention stage
    /// (see [`KvShard::write_slices`]).
    pub fn write_slices(
        &mut self,
        rank: usize,
        layer: usize,
        s: usize,
        pos: &[i32],
        ks: &[f32],
        vs: &[f32],
    ) {
        self.shards[rank].lock().unwrap().write_slices(layer, s, pos, ks, vs);
    }

    /// Materialize the (k, v) history literals for a decode call.
    pub fn cache_literals(
        &self,
        rank: usize,
        layer: usize,
    ) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        self.shards[rank].lock().unwrap().cache_literals(layer)
    }

    /// Copy one sequence slot's first `len` tokens from another BatchKv
    /// (a freshly-prefilled sequence joining the decode pool). Maps
    /// destination blocks on demand; fails when the pool is exhausted
    /// (the caller preempts and retries).
    pub fn adopt_slot(
        &mut self,
        dst_slot: usize,
        src: &BatchKv,
        src_slot: usize,
        len: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(src.heads == self.heads && src.head_dim == self.head_dim);
        anyhow::ensure!(
            self.ensure_tokens(dst_slot, len),
            "kv pool exhausted adopting {len} tokens into slot {dst_slot}"
        );
        let len = len.min(self.cap).min(src.cap);
        for rank in 0..self.shards.len() {
            let mut dst = self.shards[rank].lock().unwrap();
            let s = src.shards[rank].lock().unwrap();
            for layer in 0..self.n_layers {
                let (k, v) = s.read_slot(layer, src_slot, len);
                dst.write_slot(layer, dst_slot, len, &k, &v);
            }
        }
        self.occupied[dst_slot] = true;
        Ok(())
    }

    /// Unmap and zero one slot (sequence retired or evicted). Idempotent:
    /// clearing an empty slot is a no-op.
    pub fn clear_slot(&mut self, slot: usize) {
        let mut freed: Option<Vec<u32>> = None;
        for shard in &self.shards {
            let blocks = shard.lock().unwrap().unmap_slot(slot);
            freed.get_or_insert(blocks);
        }
        if let Some(blocks) = freed {
            self.free.extend(blocks);
        }
        self.slot_blocks[slot] = 0;
        self.occupied[slot] = false;
        self.sync_gauges();
    }

    /// Preempt one slot: copy its first `len` tokens out to host buffers
    /// and free its blocks. The returned image restores bit-identically
    /// via [`BatchKv::swap_in`].
    pub fn swap_out(&mut self, slot: usize, len: usize) -> SwappedKv {
        let len = len.min(self.cap);
        let mut k = Vec::with_capacity(self.shards.len());
        let mut v = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let sh = shard.lock().unwrap();
            let mut kl = Vec::with_capacity(self.n_layers);
            let mut vl = Vec::with_capacity(self.n_layers);
            for layer in 0..self.n_layers {
                let (klay, vlay) = sh.read_slot(layer, slot, len);
                kl.push(klay);
                vl.push(vlay);
            }
            k.push(kl);
            v.push(vl);
        }
        self.clear_slot(slot);
        SwappedKv { len, k, v }
    }

    /// Restore a preempted sequence into `slot`. Returns false without
    /// side effects on the image when the pool cannot map enough blocks.
    pub fn swap_in(&mut self, slot: usize, sw: &SwappedKv) -> bool {
        if !self.ensure_tokens(slot, sw.len) {
            return false;
        }
        for (rank, shard) in self.shards.iter().enumerate() {
            let mut sh = shard.lock().unwrap();
            for layer in 0..self.n_layers {
                sh.write_slot(layer, slot, sw.len, &sw.k[rank][layer], &sw.v[rank][layer]);
            }
        }
        self.occupied[slot] = true;
        true
    }

    /// Mark a slot live without copying (a chunk-prefilled sequence that
    /// wrote its history in place).
    pub fn mark_occupied(&mut self, slot: usize) {
        self.occupied[slot] = true;
    }

    /// Gathered contiguous `[B, Hn, T, hd]` copies for tests.
    pub fn k_at(&self, rank: usize, layer: usize) -> Vec<f32> {
        self.shards[rank].lock().unwrap().gather_layer(layer).0
    }
    pub fn v_at(&self, rank: usize, layer: usize) -> Vec<f32> {
        self.shards[rank].lock().unwrap().gather_layer(layer).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_heads: 4,
            head_dim: 2,
            d_ff: 8,
            max_seq: 6,
            params: 0,
        }
    }

    #[test]
    fn write_and_read_back() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 2, 2); // tp=2 -> hn=2
        // write S=3 tokens for row 0 at pos 0, row 1 at pos 2
        let s = 3;
        let n = 2 * 2 * s * 2; // B*Hn*S*hd
        let ks: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let vs: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        kv.write_slices(0, 1, s, &[0, 2], &ks, &vs);
        let k = kv.k_at(0, 1);
        // row 0, head 0, positions 0..3
        assert_eq!(k[0], 0.0);
        assert_eq!(k[1], 1.0);
        assert_eq!(k[2 * 2], 4.0); // pos 2, first elem of third token
        // row 1 (slot base = 1*hn*t*hd = 2*6*2 = 24), head 0, pos 2
        let base = 24 + 2 * 2;
        assert_eq!(k[base], 12.0); // first element of row 1's slice
        // untouched layer stays zero
        assert!(kv.k_at(0, 0).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clamps_writes_past_capacity() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 1);
        let s = 4;
        let ks = vec![1.0f32; 4 * s * 2];
        let vs = ks.clone();
        // pos 4 + s 4 > cap 6: only 2 tokens land
        kv.write_slices(0, 0, s, &[4], &ks, &vs);
        let k = kv.k_at(0, 0);
        // head 0: positions 4,5 written
        assert_eq!(k[4 * 2], 1.0);
        assert_eq!(k[5 * 2 + 1], 1.0);
    }

    #[test]
    fn adopt_slot_copies_history() {
        let c = cfg();
        let mut pre = BatchKv::new(&c, 1, 1);
        let s = 2;
        let ks: Vec<f32> = (0..4 * s * 2).map(|i| i as f32 + 1.0).collect();
        pre.write_slices(0, 0, s, &[0], &ks, &ks);
        let mut dec = BatchKv::new(&c, 1, 4);
        dec.adopt_slot(2, &pre, 0, s).unwrap();
        let k = dec.k_at(0, 0);
        let hn_t_hd = 4 * 6 * 2;
        let slot2 = 2 * hn_t_hd;
        assert_eq!(k[slot2], 1.0);
        assert_eq!(k[slot2 + 1], 2.0);
        // other slots untouched
        assert!(k[..slot2].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn clear_slot_zeroes() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 1, 2);
        let ks = vec![5.0f32; 2 * 4 * 1 * 2];
        kv.write_slices(0, 0, 1, &[0, 0], &ks[..], &ks[..]);
        kv.clear_slot(0);
        let hn_t_hd = 4 * 6 * 2;
        assert!(kv.k_at(0, 0)[..hn_t_hd].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn bytes_accounting() {
        let c = cfg();
        let kv = BatchKv::new(&c, 2, 3);
        // block clamps to cap (6), one block per slot: arena bytes equal
        // the dense layout. per rank/layer: 3*2*6*2 floats; 2 ranks *
        // 2 layers * 2 (k+v)
        assert_eq!(kv.bytes(), 3 * 2 * 6 * 2 * 4 * 2 * 2 * 2);
    }

    #[test]
    fn paged_pool_maps_on_demand_and_gauges_follow() {
        let c = cfg(); // cap 6
        let used = Gauge::default();
        let free = Gauge::default();
        // block=2 -> 3 blocks/seq; pool of 4 can't hold two full seqs
        let mut kv = BatchKv::paged(&c, 1, 2, 2, 4)
            .with_gauge(used.clone())
            .with_free_gauge(free.clone());
        assert_eq!((used.get(), free.get()), (0, 4));
        assert!(kv.ensure_tokens(0, 6));
        assert_eq!((used.get(), free.get()), (3, 1));
        // slot 1 can only take one more block
        assert!(kv.ensure_tokens(1, 2));
        assert_eq!((used.get(), free.get()), (4, 0));
        assert!(!kv.ensure_tokens(1, 4), "pool must be exhausted");
        // freeing slot 0 makes the retry succeed
        kv.clear_slot(0);
        assert_eq!((used.get(), free.get()), (1, 3));
        assert!(kv.ensure_tokens(1, 4));
        assert_eq!(used.get() + free.get(), 4);
    }

    #[test]
    fn unmapped_slots_drop_writes_and_read_zero() {
        let c = cfg();
        let mut kv = BatchKv::paged(&c, 1, 2, 2, 6);
        assert!(kv.ensure_tokens(1, 2));
        let ks = vec![7.0f32; 2 * 4 * 1 * 2]; // B=2, Hn=4, S=1, hd=2
        kv.write_slices(0, 0, 1, &[0, 0], &ks, &ks);
        let k = kv.k_at(0, 0);
        let slot = 4 * 6 * 2;
        // slot 0 is unmapped: its write was dropped
        assert!(k[..slot].iter().all(|&x| x == 0.0));
        assert_eq!(k[slot], 7.0);
    }

    #[test]
    fn swap_roundtrip_is_bit_identical() {
        let c = cfg();
        let mut rng = Rng::new(7);
        let mut kv = BatchKv::paged(&c, 2, 2, 2, 8);
        assert!(kv.ensure_tokens(0, 5));
        for layer in 0..2 {
            let ks: Vec<f32> = (0..2 * 2 * 5 * 2).map(|_| rng.f64() as f32).collect();
            let vs: Vec<f32> = (0..2 * 2 * 5 * 2).map(|_| rng.f64() as f32).collect();
            for rank in 0..2 {
                kv.write_slices(rank, layer, 5, &[0, 0], &ks, &vs);
            }
        }
        let before: Vec<Vec<f32>> = (0..2).map(|r| kv.k_at(r, 1)).collect();
        let sw = kv.swap_out(0, 5);
        assert!(sw.bytes() > 0);
        assert_eq!(kv.slot_mapped(0), 0);
        assert!(kv.k_at(0, 1).iter().all(|&x| x == 0.0));
        // interloper takes blocks, then releases them
        assert!(kv.ensure_tokens(1, 6));
        kv.clear_slot(1);
        assert!(kv.swap_in(0, &sw));
        for (r, want) in before.iter().enumerate() {
            assert_eq!(&kv.k_at(r, 1), want, "rank {r} not bit-identical after restore");
        }
    }

    #[test]
    fn swap_in_fails_cleanly_when_pool_full() {
        let c = cfg();
        let mut kv = BatchKv::paged(&c, 1, 2, 2, 3);
        assert!(kv.ensure_tokens(0, 4));
        let sw = kv.swap_out(0, 4);
        assert!(kv.ensure_tokens(1, 6)); // steal the whole pool
        assert!(!kv.swap_in(0, &sw));
        kv.clear_slot(1);
        assert!(kv.swap_in(0, &sw));
    }

    /// Random alloc/free/preempt sequences: the pool never leaks or
    /// double-maps a block, and mapped + free always equals the pool.
    #[test]
    fn prop_paged_allocator_never_leaks_or_double_frees() {
        let c = cfg();
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..50 {
            let pool = 1 + rng.below(10);
            let batch = 1 + rng.below(4);
            let mut kv = BatchKv::paged(&c, 1, batch, 2, pool);
            let mut swapped: Vec<(usize, SwappedKv)> = Vec::new();
            for _ in 0..200 {
                let slot = rng.below(batch);
                match rng.below(4) {
                    0 => {
                        let _ = kv.ensure_tokens(slot, 1 + rng.below(6));
                    }
                    1 => kv.clear_slot(slot),
                    2 => {
                        if kv.slot_mapped(slot) > 0 {
                            let len = kv.slot_mapped(slot) * kv.block;
                            swapped.push((slot, kv.swap_out(slot, len.min(kv.cap))));
                        }
                    }
                    _ => {
                        if let Some((s, sw)) = swapped.pop() {
                            let _ = kv.swap_in(s, &sw);
                        }
                    }
                }
                // conservation: every block is exactly mapped or free
                let mapped: usize = (0..batch).map(|s| kv.slot_mapped(s)).sum();
                assert_eq!(mapped, kv.mapped_blocks());
                assert_eq!(mapped + kv.free_blocks(), pool);
            }
        }
    }

    /// Block-table reads reconstruct exactly what `write_slices` wrote:
    /// gather output matches a dense reference model under random writes.
    #[test]
    fn prop_block_table_reads_match_dense_reference() {
        let c = cfg(); // hn(tp=1)=4, cap=6, hd=2
        let (hn, cap, hd) = (4usize, 6usize, 2usize);
        let mut rng = Rng::new(0xCAFE);
        for _ in 0..30 {
            let batch = 1 + rng.below(3);
            let mut kv = BatchKv::paged(&c, 1, batch, 1 + rng.below(3), batch * 6);
            for slot in 0..batch {
                assert!(kv.ensure_tokens(slot, cap));
            }
            let mut dense = vec![0.0f32; batch * hn * cap * hd];
            for _ in 0..20 {
                let s = 1 + rng.below(3);
                let pos: Vec<i32> = (0..batch).map(|_| rng.below(cap) as i32).collect();
                let ks: Vec<f32> =
                    (0..batch * hn * s * hd).map(|_| (rng.below(1000) as f32) / 10.0).collect();
                kv.write_slices(0, 0, s, &pos, &ks, &ks);
                for b in 0..batch {
                    let p = pos[b] as usize;
                    for tok in p..(p + s).min(cap) {
                        for h in 0..hn {
                            for d in 0..hd {
                                dense[((b * hn + h) * cap + tok) * hd + d] =
                                    ks[((b * hn + h) * s + (tok - p)) * hd + d];
                            }
                        }
                    }
                }
                assert_eq!(kv.k_at(0, 0), dense);
            }
        }
    }

    #[test]
    fn shard_handle_aliases_the_coordinator_view() {
        let c = cfg();
        let mut kv = BatchKv::new(&c, 2, 1);
        let h = kv.shard_handle(1);
        let s = 1;
        let ks = vec![3.0f32; 2 * s * 2]; // B*Hn*S*hd = 1*2*1*2
        // a worker writing through its handle ...
        h.lock().unwrap().write_slices(0, s, &[0], &ks, &ks);
        // ... is visible through the coordinator's whole-cache view
        assert_eq!(kv.k_at(1, 0)[0], 3.0);
        // and vice versa
        kv.clear_slot(0);
        assert!(h.lock().unwrap().gather_layer(0).0.iter().all(|&x| x == 0.0));
    }
}
