//! Per-rank worker runtime: the parallel execution core behind
//! `--rank-threads`.
//!
//! [`RankPool::spawn`] starts one OS thread per worker; each worker
//! *constructs and owns* its own [`Runtime`] (the PJRT client is not
//! `Send`, so it must be built on the thread that uses it), the weight
//! literal shards of the ranks it owns, its per-scheme compressors, and
//! its own plan memo + scratch buffers (no shared `reduce_buf` or
//! [`CommScratch`] — the seed's engine-wide scratch does not survive
//! concurrency).
//!
//! Per forward pass every worker runs the same per-rank stage program
//! the sequential reference path runs, meeting at the shared-memory
//! [`Fabric`] after each row-parallel stage to exchange partials
//! (`Arc`-backed, so the gather is a refcount bump). Each worker then
//! executes the planned collective *locally, concurrently* — encode and
//! decode run on every rank thread, so the measured codec wall times
//! feeding the max-of-ranks virtual clock are real concurrent
//! measurements, not a simulation artifact.
//!
//! Determinism: workers compute the reduction over the same partials in
//! the same rank order with the same plan (the planner is a pure
//! function of (message, topology, scheme)), so every worker's `x` is
//! bit-identical to every other's *and* to the sequential path's —
//! pinned by `tests/rank_parallel.rs`. Rank multiplexing (`tp` ranks on
//! fewer threads) changes only which thread executes a rank's stages,
//! never the numbers.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::collective::{pipeline, plan, AlgoChoice, CollectivePlan, CommScratch, ExecCtx, Topology};
use crate::fabric::Fabric;
use crate::interconnect::HwProfile;
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::mxfmt::{compressor_from_spec_ch, Compressor, MxScheme};
use crate::obs::log::Logger;
use crate::obs::{self, Cat, Tracer};
use crate::policy::{Phase, Site, SiteKind};
use crate::util::json;
use crate::runtime::{lit_f32, lit_i32, lit_u8, to_vec_f32, to_vec_u8, Runtime};

use super::kv::{BatchKv, KvShardRef};
use super::{OverheadModel, RankBusy};

/// Payload a worker publishes to the fabric for one rank after a
/// row-parallel stage: the rank's partial activations plus the measured
/// stage wall time (so every worker learns the lock-step max).
#[derive(Clone)]
pub struct RankPost {
    pub data: Arc<Vec<f32>>,
    pub wall_s: f64,
}

/// One entry of a worker's per-forward execution trace. Workers emit
/// events in an identical order (embed, then per layer: stage, comm,
/// stage, comm; the leader appends the final stage), so the orchestrator
/// merges by position: stage walls max across ranks, collective
/// accounting taken once (deterministic fields are identical across
/// workers; measured codec times are maxed).
pub enum TraceEvent {
    /// a compute stage; one wall per rank that executed it
    Stage { walls: Vec<f64> },
    /// one collective at `site`, already resolved through the worker's
    /// overhead model: `total_s` is the overlapped schedule, `codec_s`
    /// the codec share (sequential path decomposes identically)
    Comm {
        site: Site,
        scheme_idx: usize,
        algo: &'static str,
        wire_bytes: u64,
        raw_bytes: u64,
        codec_s: f64,
        total_s: f64,
        /// observed quantization error (percent) sampled by the leader
        /// worker on drift-sentinel passes; NaN when unsampled
        err_pct: f64,
    },
}

/// What one worker returns for one forward pass.
pub struct RankOutcome {
    pub trace: Vec<TraceEvent>,
    /// logits from the final stage (leader worker only)
    pub logits: Option<Vec<f32>>,
    /// per owned rank: (rank, accumulated compute/codec/fabric-wait)
    pub busy: Vec<(usize, RankBusy)>,
}

/// A policy binding broadcast to the workers: the distinct scheme specs
/// and the site → scheme-index map (mirrors the engine's own binding).
#[derive(Clone)]
pub struct BindSpec {
    pub specs: Vec<String>,
    pub site_spec: Vec<u16>,
}

/// Everything one forward pass needs, snapshotted at dispatch so the
/// sweeps' direct mutations of `EngineOptions` (profile, overhead,
/// fused) reach the workers without a rebind round-trip.
pub struct RankJob {
    pub tokens: Vec<i32>,
    pub pos: Vec<i32>,
    /// forward-step id; workers stamp it as the span `pid` so engine-
    /// and worker-side spans of the same step share a timeline
    pub pid: u64,
    pub bb: usize,
    pub sb: usize,
    pub decode: bool,
    pub model: String,
    pub tp: usize,
    pub profile: &'static HwProfile,
    pub overhead: OverheadModel,
    pub fused: bool,
    pub algo: AlgoChoice,
    /// drift sentinel sampling flag for this pass: the leader worker
    /// measures observed quantization error at every compressed site
    pub sentinel_due: bool,
}

enum RankCmd {
    Bind(BindSpec),
    Forward {
        job: Arc<RankJob>,
        /// KV shard handles for this worker's owned ranks, in owned order
        kv: Option<Vec<KvShardRef>>,
        reply: Sender<(usize, anyhow::Result<RankOutcome>)>,
    },
    Shutdown,
}

/// Contiguous rank assignment: worker `w` of `workers` owns this slice
/// of the `tp` ranks (worker 0 always owns rank 0, the leader).
pub fn owned_ranks(tp: usize, workers: usize, w: usize) -> Vec<usize> {
    let base = tp / workers;
    let rem = tp % workers;
    let start = w * base + w.min(rem);
    let n = base + usize::from(w < rem);
    (start..start + n).collect()
}

/// Handle to the spawned worker threads; owned by the orchestrating
/// [`super::TpEngine`]. Dropping the engine shuts the pool down cleanly
/// (shutdown command + join).
pub struct RankPool {
    txs: Vec<Sender<RankCmd>>,
    joins: Vec<std::thread::JoinHandle<()>>,
    fabric: Arc<Fabric<RankPost>>,
    tp: usize,
    log: Arc<Logger>,
}

impl RankPool {
    /// Spawn `workers` rank threads for a `tp`-way engine. Each worker
    /// loads its own [`Runtime`] from `root` and builds the weight
    /// literals of its owned ranks; startup errors are collected and
    /// the partially-started pool is torn down.
    pub fn spawn(
        weights: &Weights,
        cfg: &ModelConfig,
        root: &std::path::Path,
        tp: usize,
        workers: usize,
        bind: BindSpec,
        tracer: Arc<Tracer>,
        logger: Arc<Logger>,
    ) -> anyhow::Result<RankPool> {
        anyhow::ensure!(
            workers >= 1 && workers <= tp,
            "rank pool wants 1..=tp workers, got {workers} for tp={tp}"
        );
        let fabric = Arc::new(Fabric::new(workers, tp));
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let mut txs = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        for w in 0..workers {
            let ranks = owned_ranks(tp, workers, w);
            let shards: Vec<Weights> = ranks
                .iter()
                .map(|&r| weights.shard(cfg, tp, r))
                .collect::<anyhow::Result<_>>()?;
            let (tx, rx) = channel();
            let boot = WorkerBoot {
                idx: w,
                ranks,
                cfg: cfg.clone(),
                shards,
                root: root.to_path_buf(),
                fabric: fabric.clone(),
                bind: bind.clone(),
                tracer: tracer.clone(),
                logger: logger.clone(),
            };
            let ready = ready_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("tpcc-rank{w}"))
                .spawn(move || match Worker::build(boot) {
                    Ok(mut worker) => {
                        let _ = ready.send(Ok(()));
                        worker.run(rx);
                    }
                    Err(e) => {
                        let _ = ready.send(Err(format!("{e:#}")));
                    }
                })?;
            txs.push(tx);
            joins.push(join);
        }
        drop(ready_tx);
        let mut failure = None;
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(m)) => {
                    failure = Some(m);
                    break;
                }
                Err(_) => {
                    failure = Some("rank worker exited during startup".to_string());
                    break;
                }
            }
        }
        if let Some(m) = failure {
            logger.error("rank", "rank pool startup failed", vec![("err", json::s(&m))]);
            for tx in &txs {
                let _ = tx.send(RankCmd::Shutdown);
            }
            for j in joins {
                let _ = j.join();
            }
            anyhow::bail!("rank pool startup failed: {m}");
        }
        Ok(RankPool { txs, joins, fabric, tp, log: logger })
    }

    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Broadcast a policy rebind (new distinct schemes + site map).
    pub fn bind(&self, b: BindSpec) {
        for tx in &self.txs {
            let _ = tx.send(RankCmd::Bind(b.clone()));
        }
    }

    /// Run one forward across all workers and collect their outcomes
    /// (indexed by worker). A failed round poisons the fabric so no
    /// worker deadlocks, then re-arms it once every worker has replied.
    pub fn forward(&self, job: RankJob, kv: Option<&BatchKv>) -> anyhow::Result<Vec<RankOutcome>> {
        let workers = self.txs.len();
        let job = Arc::new(job);
        let (rtx, rrx) = channel();
        let mut delivered = 0usize;
        let mut send_err = None;
        for (w, tx) in self.txs.iter().enumerate() {
            let shards = kv.map(|k| {
                owned_ranks(self.tp, workers, w)
                    .into_iter()
                    .map(|r| k.shard_handle(r))
                    .collect()
            });
            let cmd = RankCmd::Forward { job: job.clone(), kv: shards, reply: rtx.clone() };
            if tx.send(cmd).is_err() {
                send_err = Some(anyhow::anyhow!("rank worker {w} is gone"));
                break;
            }
            delivered += 1;
        }
        drop(rtx);
        if let Some(e) = send_err {
            // unblock the workers that did get the job, drain their
            // replies, then re-arm the fabric for whoever calls next
            self.log.error("rank", "fabric poisoned", vec![("reason", json::s("a rank worker is gone"))]);
            self.fabric.poison("a rank worker is gone");
            for _ in 0..delivered {
                let _ = rrx.recv();
            }
            self.fabric.reset();
            return Err(e);
        }
        let mut outs: Vec<Option<RankOutcome>> = (0..workers).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..workers {
            match rrx.recv() {
                Ok((idx, Ok(o))) => outs[idx] = Some(o),
                Ok((idx, Err(e))) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("rank worker {idx}")));
                    }
                }
                Err(_) => {
                    // every sender dropped without a reply: worker died
                    self.log.error(
                        "rank",
                        "fabric poisoned",
                        vec![("reason", json::s("rank worker died mid-forward"))],
                    );
                    self.fabric.poison("rank worker died mid-forward");
                    return Err(anyhow::anyhow!("rank worker died mid-forward"));
                }
            }
        }
        // all workers idle again — safe to re-arm after a failed round
        if let Some(e) = first_err {
            self.fabric.reset();
            return Err(e);
        }
        outs.into_iter()
            .map(|o| o.ok_or_else(|| anyhow::anyhow!("missing rank worker outcome")))
            .collect()
    }

    /// Clean shutdown: every worker drains its queue, exits its loop,
    /// and is joined.
    pub fn shutdown(self) {
        for tx in &self.txs {
            let _ = tx.send(RankCmd::Shutdown);
        }
        for j in self.joins {
            let _ = j.join();
        }
    }
}

struct WorkerBoot {
    idx: usize,
    ranks: Vec<usize>,
    cfg: ModelConfig,
    /// weight shards for the owned ranks (plain f32 tensors; literals
    /// are built on the worker thread, which owns the PJRT client)
    shards: Vec<Weights>,
    root: std::path::PathBuf,
    fabric: Arc<Fabric<RankPost>>,
    bind: BindSpec,
    tracer: Arc<Tracer>,
    logger: Arc<Logger>,
}

/// Thread-side state of one rank worker.
struct Worker {
    idx: usize,
    ranks: Vec<usize>,
    cfg: ModelConfig,
    rt: Runtime,
    /// weight literals per owned rank (parallel to `ranks`)
    wlits: Vec<BTreeMap<String, xla::Literal>>,
    fabric: Arc<Fabric<RankPost>>,
    specs: Vec<String>,
    comps: Vec<Option<Box<dyn Compressor>>>,
    site_spec: Vec<u16>,
    /// plan memo keyed like the sequential engine's:
    /// (message len, profile identity, scheme index)
    plan_memo: BTreeMap<(usize, usize, usize), CollectivePlan>,
    /// algo knob of the last job; a change invalidates the memo
    last_algo: Option<AlgoChoice>,
    /// a failed Bind is reported on the next forward
    bind_err: Option<String>,
    log: Arc<Logger>,
    // per-worker scratch (replaces the seed's engine-wide buffers)
    reduce_buf: Vec<f32>,
    comm_scratch: CommScratch,
}

impl Worker {
    fn build(boot: WorkerBoot) -> anyhow::Result<Worker> {
        // runs on the worker thread: bind its span ring to the shared
        // tracer (tid defaults to the lead rank; stages retag per rank)
        obs::install(
            &boot.tracer,
            &format!("rank-worker{}", boot.idx),
            boot.ranks[0] as u32,
        );
        let rt = Runtime::load(&boot.root)?;
        let mut wlits = Vec::with_capacity(boot.shards.len());
        for shard in &boot.shards {
            let mut lits = BTreeMap::new();
            for (name, t) in &shard.tensors {
                lits.insert(name.clone(), lit_f32(&t.shape, &t.data)?);
            }
            wlits.push(lits);
        }
        boot.logger.info(
            "rank",
            "worker started",
            vec![
                ("worker", json::num(boot.idx as f64)),
                (
                    "ranks",
                    json::Json::Arr(
                        boot.ranks.iter().map(|&r| json::num(r as f64)).collect(),
                    ),
                ),
            ],
        );
        let mut w = Worker {
            idx: boot.idx,
            ranks: boot.ranks,
            cfg: boot.cfg,
            rt,
            wlits,
            fabric: boot.fabric,
            specs: Vec::new(),
            comps: Vec::new(),
            site_spec: Vec::new(),
            plan_memo: BTreeMap::new(),
            last_algo: None,
            bind_err: None,
            log: boot.logger,
            reduce_buf: Vec::new(),
            comm_scratch: CommScratch::default(),
        };
        w.apply_bind(boot.bind)?;
        Ok(w)
    }

    fn run(&mut self, rx: Receiver<RankCmd>) {
        while let Ok(cmd) = rx.recv() {
            match cmd {
                RankCmd::Bind(b) => {
                    self.bind_err = self.apply_bind(b).err().map(|e| format!("{e:#}"));
                }
                RankCmd::Forward { job, kv, reply } => {
                    let res = catch_unwind(AssertUnwindSafe(|| self.forward(&job, kv.as_deref())));
                    let res = match res {
                        Ok(r) => r,
                        Err(_) => {
                            self.log.error(
                                "rank",
                                "worker panicked",
                                vec![("worker", json::num(self.idx as f64))],
                            );
                            Err(anyhow::anyhow!("rank worker {} panicked", self.idx))
                        }
                    };
                    if let Err(e) = &res {
                        // wake peers blocked at a fabric barrier before
                        // replying, or the round would deadlock
                        self.log.error(
                            "rank",
                            "fabric poisoned",
                            vec![
                                ("worker", json::num(self.idx as f64)),
                                ("reason", json::s(&format!("{e:#}"))),
                            ],
                        );
                        self.fabric.poison(&format!("worker {}: {e:#}", self.idx));
                    }
                    let _ = reply.send((self.idx, res));
                }
                RankCmd::Shutdown => break,
            }
        }
    }

    fn apply_bind(&mut self, b: BindSpec) -> anyhow::Result<()> {
        let mut comps = Vec::with_capacity(b.specs.len());
        for spec in &b.specs {
            comps.push(if spec == "none" {
                None
            } else {
                Some(compressor_from_spec_ch(spec, self.cfg.d_model)?)
            });
        }
        self.comps = comps;
        self.specs = b.specs;
        self.site_spec = b.site_spec;
        self.plan_memo.clear();
        Ok(())
    }

    fn wl(&self, owned_idx: usize, name: &str) -> &xla::Literal {
        self.wlits[owned_idx].get(name).expect("weight literal")
    }

    /// The per-rank stage program for one forward pass — mirrors the
    /// sequential reference path stage for stage (same artifact names,
    /// same argument order, same reduction order), so outputs are
    /// bit-identical.
    fn forward(
        &mut self,
        job: &RankJob,
        kv: Option<&[KvShardRef]>,
    ) -> anyhow::Result<RankOutcome> {
        if let Some(m) = self.bind_err.take() {
            anyhow::bail!("deferred policy bind failure: {m}");
        }
        if self.last_algo != Some(job.algo) {
            self.plan_memo.clear();
            self.last_algo = Some(job.algo);
        }
        let (bb, sb) = (job.bb, job.sb);
        let d = self.cfg.d_model;
        let tp = job.tp;
        let model = job.model.clone();
        let phase = if job.decode { Phase::Decode } else { Phase::Prefill };
        anyhow::ensure!(job.tokens.len() == bb * sb && job.pos.len() == bb);
        if job.decode {
            anyhow::ensure!(kv.is_some(), "decode requires kv");
        }
        obs::set_pid(job.pid);
        let mut trace: Vec<TraceEvent> = Vec::with_capacity(1 + 4 * self.cfg.n_layers + 1);
        let mut busy: Vec<(usize, RankBusy)> =
            self.ranks.iter().map(|&r| (r, RankBusy::default())).collect();

        // embed — replicated weights: one execution per worker stands in
        // for all of its ranks (identical bits rank to rank)
        let tok_lit = lit_i32(&[bb, sb], &job.tokens)?;
        obs::set_tid(self.ranks[0] as u32);
        let t0 = Instant::now();
        let emb = {
            let _g = obs::span("embed", Cat::Compute);
            self.rt.execute_refs(
                &format!("{model}/embed_b{bb}_s{sb}"),
                &[&tok_lit, self.wl(0, "embed")],
            )?
        };
        let dt = t0.elapsed().as_secs_f64();
        busy[0].1.compute_s += dt;
        trace.push(TraceEvent::Stage { walls: vec![dt] });
        let mut x = to_vec_f32(&emb[0])?;

        let pos_lit = lit_i32(&[bb], &job.pos)?;
        // fused executable names per distinct scheme, resolved lazily
        // once per forward (as in the sequential path)
        let mut fused_memo: BTreeMap<usize, Option<(String, String)>> = BTreeMap::new();
        for l in 0..self.cfg.n_layers {
            // ---- attention ----
            let attn_name = if job.decode {
                format!("{model}/attn_tp{tp}_b{bb}_s{sb}")
            } else {
                format!("{model}/attn_prefill_tp{tp}_b{bb}_s{sb}")
            };
            let x_lit = lit_f32(&[bb, sb, d], &x)?;
            let mut stage_outs = Vec::with_capacity(self.ranks.len());
            for i in 0..self.ranks.len() {
                obs::set_tid(self.ranks[i] as u32);
                let _rank_span = obs::span_arg("attn", Cat::Compute, l as i64);
                let an = format!("l{l}.attn_norm");
                let wq = format!("l{l}.wq");
                let wk = format!("l{l}.wk");
                let wv = format!("l{l}.wv");
                let wo = format!("l{l}.wo");
                let timed = if job.decode {
                    let (kl, vl) = kv.unwrap()[i].lock().unwrap().cache_literals(l)?;
                    let args: Vec<&xla::Literal> = vec![
                        &x_lit,
                        self.wl(i, &an),
                        self.wl(i, &wq),
                        self.wl(i, &wk),
                        self.wl(i, &wv),
                        self.wl(i, &wo),
                        &kl,
                        &vl,
                        &pos_lit,
                    ];
                    let t0 = Instant::now();
                    let out = self.rt.execute_refs(&attn_name, &args)?;
                    (t0.elapsed().as_secs_f64(), out)
                } else {
                    let args: Vec<&xla::Literal> = vec![
                        &x_lit,
                        self.wl(i, &an),
                        self.wl(i, &wq),
                        self.wl(i, &wk),
                        self.wl(i, &wv),
                        self.wl(i, &wo),
                        &pos_lit,
                    ];
                    let t0 = Instant::now();
                    let out = self.rt.execute_refs(&attn_name, &args)?;
                    (t0.elapsed().as_secs_f64(), out)
                };
                stage_outs.push(timed);
            }
            let site = Site { layer: l, kind: SiteKind::AttnOut, phase };
            x = self.stage_collect(
                job, site, x, stage_outs, kv, l, sb, &mut fused_memo, &mut trace, &mut busy,
            )?;

            // ---- MLP ----
            let mlp_name = format!("{model}/mlp_tp{tp}_b{bb}_s{sb}");
            let x_lit = lit_f32(&[bb, sb, d], &x)?;
            let mut stage_outs = Vec::with_capacity(self.ranks.len());
            for i in 0..self.ranks.len() {
                obs::set_tid(self.ranks[i] as u32);
                let _rank_span = obs::span_arg("mlp", Cat::Compute, l as i64);
                let mn = format!("l{l}.mlp_norm");
                let wg = format!("l{l}.w_gate");
                let wu = format!("l{l}.w_up");
                let wd = format!("l{l}.w_down");
                let args: Vec<&xla::Literal> = vec![
                    &x_lit,
                    self.wl(i, &mn),
                    self.wl(i, &wg),
                    self.wl(i, &wu),
                    self.wl(i, &wd),
                ];
                let t0 = Instant::now();
                let out = self.rt.execute_refs(&mlp_name, &args)?;
                stage_outs.push((t0.elapsed().as_secs_f64(), out));
            }
            let site = Site { layer: l, kind: SiteKind::MlpOut, phase };
            x = self.stage_collect(
                job, site, x, stage_outs, None, l, sb, &mut fused_memo, &mut trace, &mut busy,
            )?;
        }

        // final norm + logits — leader (rank 0) only
        let logits = if self.ranks[0] == 0 {
            let x_lit = lit_f32(&[bb, sb, d], &x)?;
            obs::set_tid(0);
            let t0 = Instant::now();
            let out = {
                let _g = obs::span("final", Cat::Compute);
                self.rt.execute_refs(
                    &format!("{model}/final_b{bb}_s{sb}"),
                    &[&x_lit, self.wl(0, "final_norm"), self.wl(0, "lm_head")],
                )?
            };
            let dt = t0.elapsed().as_secs_f64();
            busy[0].1.compute_s += dt;
            trace.push(TraceEvent::Stage { walls: vec![dt] });
            Some(to_vec_f32(&out[0])?)
        } else {
            None
        };
        Ok(RankOutcome { trace, logits, busy })
    }

    /// Post-stage bookkeeping shared by the attention and MLP sites:
    /// write KV slices (attention only), publish the owned partials to
    /// the fabric, gather all ranks', and run the collective.
    #[allow(clippy::too_many_arguments)]
    fn stage_collect(
        &mut self,
        job: &RankJob,
        site: Site,
        x: Vec<f32>,
        stage_outs: Vec<(f64, Vec<xla::Literal>)>,
        kv: Option<&[KvShardRef]>,
        layer: usize,
        s: usize,
        fused_memo: &mut BTreeMap<usize, Option<(String, String)>>,
        trace: &mut Vec<TraceEvent>,
        busy: &mut [(usize, RankBusy)],
    ) -> anyhow::Result<Vec<f32>> {
        let mut posts = Vec::with_capacity(stage_outs.len());
        for (i, (wall, out)) in stage_outs.into_iter().enumerate() {
            busy[i].1.compute_s += wall;
            if let Some(shards) = kv {
                let ks = to_vec_f32(&out[1])?;
                let vs = to_vec_f32(&out[2])?;
                shards[i].lock().unwrap().write_slices(layer, s, &job.pos, &ks, &vs);
            }
            let data = Arc::new(to_vec_f32(&out[0])?);
            posts.push((self.ranks[i], RankPost { data, wall_s: wall }));
        }
        // the exchange span covers the whole rendezvous; only the
        // *blocked* portion (measured inside the fabric) feeds the
        // fabric-wait gauges — a multiplexing worker's wait is credited
        // to each rank it owns, the phase gauge once per worker
        obs::set_tid(self.ranks[0] as u32);
        let (all, wait_s) = {
            let _g = obs::span("exchange", Cat::Fabric);
            self.fabric.exchange_timed(posts)?
        };
        for b in busy.iter_mut() {
            b.1.fabric_wait_s += wait_s;
        }
        obs::add_virtual(Cat::Fabric, wait_s);
        trace.push(TraceEvent::Stage { walls: all.iter().map(|p| p.wall_s).collect() });
        self.communicate(job, site, x, &all, fused_memo, trace, busy)
    }

    /// The collective after a row-parallel stage, executed locally on
    /// this worker (every worker computes the identical reduction, which
    /// is exactly what concurrent ranks do in a real deployment).
    #[allow(clippy::too_many_arguments)]
    fn communicate(
        &mut self,
        job: &RankJob,
        site: Site,
        x: Vec<f32>,
        posts: &[RankPost],
        fused_memo: &mut BTreeMap<usize, Option<(String, String)>>,
        trace: &mut Vec<TraceEvent>,
        busy: &mut [(usize, RankBusy)],
    ) -> anyhow::Result<Vec<f32>> {
        let si = site.index();
        let ci = self.site_spec[si] as usize;
        let _site_span = obs::span_arg("collective", Cat::Step, si as i64);
        let len = x.len();
        let n = posts.len();
        let topo = Topology::from_profile(job.profile, job.tp);

        // fused on-accelerator compression, when exported for this
        // site's scheme + bucket (otherwise the bit-exact host codec)
        if job.fused {
            let names = match fused_memo.get(&ci) {
                Some(v) => v.clone(),
                None => {
                    let v = self.fused_names(job, ci);
                    fused_memo.insert(ci, v.clone());
                    v
                }
            };
            if let Some((qname, dname)) = names {
                return self
                    .communicate_fused(job, site, ci, &x, posts, &qname, &dname, trace, busy);
            }
        }

        let memo_key = (len, job.profile as *const HwProfile as usize, ci);
        let plan = match self.plan_memo.get(&memo_key).copied() {
            Some(p) => p,
            None => {
                let p = plan::choose(
                    len,
                    n,
                    self.comps[ci].as_deref(),
                    &topo,
                    job.profile.quant_values_per_s,
                    job.algo,
                );
                self.plan_memo.insert(memo_key, p);
                p
            }
        };
        let comp = self.comps[ci].as_deref();
        let measure = job.overhead == OverheadModel::Measured;
        let ctx = ExecCtx { comp, topo: &topo, measure };
        let refs: Vec<&[f32]> = posts.iter().map(|p| p.data.as_slice()).collect();
        let mut out = std::mem::take(&mut self.reduce_buf);
        let algo_impl = plan.algo.implementation();
        let rep = pipeline::run_chunked(
            algo_impl, &x, &refs, &ctx, plan.chunks, &mut out, &mut self.comm_scratch,
        );
        // the overhead-model resolution is shared with the sequential
        // path (super::comm_times) so the two cores cannot drift
        let (codec_s, total_s) =
            super::comm_times(job.overhead, &rep, &plan, len, n, comp, &topo);
        for b in busy.iter_mut() {
            b.1.codec_s += codec_s;
        }
        // drift sentinel: the leader worker alone samples observed
        // quantization error on sentinel passes (identical inputs on
        // every worker make duplicate samples pure waste)
        let err_pct = match comp {
            Some(c) if job.sentinel_due && self.ranks[0] == 0 => {
                crate::policy::observed_error(&refs, c, self.cfg.d_model) * 100.0
            }
            _ => f64::NAN,
        };
        trace.push(TraceEvent::Comm {
            site,
            scheme_idx: ci,
            algo: rep.algo,
            wire_bytes: rep.wire_bytes as u64,
            raw_bytes: rep.raw_bytes as u64,
            codec_s,
            total_s,
            err_pct,
        });
        // the consumed x becomes next collective's scratch buffer
        self.reduce_buf = x;
        self.reduce_buf.clear();
        Ok(out)
    }

    /// Names of the fused quantize / dequant-reduce-add executables for
    /// scheme `ci` at this job's bucket, if exported (mirrors the
    /// sequential `fused_names_site`).
    fn fused_names(&self, job: &RankJob, ci: usize) -> Option<(String, String)> {
        let spec = &self.specs[ci];
        if spec == "none" {
            return None;
        }
        let (model, tp, bb, sb) = (&job.model, job.tp, job.bb, job.sb);
        let q = format!("{model}/quant_{spec}_b{bb}_s{sb}");
        let d = format!("{model}/dqra_{spec}_tp{tp}_b{bb}_s{sb}");
        (self.rt.manifest.by_name(&q).is_some() && self.rt.manifest.by_name(&d).is_some())
            .then_some((q, d))
    }

    /// Fused on-accelerator collective on this worker's own runtime —
    /// the same quantize/stack/dequant-reduce-add program the sequential
    /// path runs, so outputs and wire accounting are identical.
    #[allow(clippy::too_many_arguments)]
    fn communicate_fused(
        &mut self,
        job: &RankJob,
        site: Site,
        ci: usize,
        x: &[f32],
        posts: &[RankPost],
        qname: &str,
        dname: &str,
        trace: &mut Vec<TraceEvent>,
        busy: &mut [(usize, RankBusy)],
    ) -> anyhow::Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let tp = job.tp;
        let (bb, sb) = (job.bb, job.sb);
        let values = bb * sb * d;
        let scheme = MxScheme::parse(&self.specs[ci])?;
        let block = scheme.block;
        let nb = d / block;

        let mut codes_all = Vec::with_capacity(tp * values);
        let mut scales_all = Vec::with_capacity(tp * values / block);
        let mut enc_once = 0.0f64;
        for (rank, p) in posts.iter().enumerate() {
            let p_lit = lit_f32(&[bb, sb, d], &p.data)?;
            let t0 = Instant::now();
            let out = {
                let _g = obs::span_arg("quant.fused", Cat::Encode, site.index() as i64);
                self.rt.execute_refs(qname, &[&p_lit])?
            };
            let dt = t0.elapsed().as_secs_f64();
            if rank == 0 {
                enc_once = dt;
            }
            codes_all.extend(to_vec_u8(&out[0])?);
            scales_all.extend(to_vec_u8(&out[1])?);
        }
        let x_lit = lit_f32(&[bb, sb, d], x)?;
        let codes = lit_u8(&[tp, bb, sb, d], &codes_all)?;
        let scales = lit_u8(&[tp, bb, sb, nb], &scales_all)?;
        let t0 = Instant::now();
        let out = {
            let _g = obs::span_arg("dqra.fused", Cat::Decode, site.index() as i64);
            self.rt.execute_refs(dname, &[&x_lit, &codes, &scales])?
        };
        let dqra_s = t0.elapsed().as_secs_f64();
        let reduced = to_vec_f32(&out[0])?;

        let shard_wire = scheme.wire_bytes(values);
        let link_s = job.profile.link.all_gather_time(shard_wire, tp);
        let codec_s = match job.overhead {
            OverheadModel::Measured => enc_once + dqra_s,
            OverheadModel::Analytic { values_per_s } => (values * tp) as f64 / values_per_s,
        };
        for b in busy.iter_mut() {
            b.1.codec_s += codec_s;
        }
        // the fused HLO executables bake in the all-gather layout, so
        // this path always accounts as the flat ring
        trace.push(TraceEvent::Comm {
            site,
            scheme_idx: ci,
            algo: "ring",
            wire_bytes: (shard_wire * (tp - 1)) as u64,
            raw_bytes: (values * 2 * (tp - 1)) as u64,
            codec_s,
            total_s: link_s + codec_s,
            // the fused path round-trips through the accelerator codec;
            // drift sampling stays on the host-codec path
            err_pct: f64::NAN,
        });
        Ok(reduced)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_ranks_cover_contiguously() {
        for tp in 1..=8usize {
            for workers in 1..=tp {
                let mut all = Vec::new();
                for w in 0..workers {
                    let r = owned_ranks(tp, workers, w);
                    assert!(!r.is_empty(), "tp={tp} workers={workers} w={w}");
                    all.extend(r);
                }
                assert_eq!(all, (0..tp).collect::<Vec<_>>(), "tp={tp} workers={workers}");
            }
        }
        // worker 0 always owns the leader rank
        assert_eq!(owned_ranks(8, 3, 0)[0], 0);
    }
}
