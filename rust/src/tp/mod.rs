//! Tensor-parallel engine: executes the AOT stage programs for all TP
//! ranks and performs the (optionally compressed) collectives between
//! them.
//!
//! Two execution cores share one accounting model:
//!
//! * **Rank-thread runtime** (`--rank-threads auto|N`, the default for
//!   `tp > 1`): [`TpEngine`] orchestrates a pool of worker threads
//!   ([`rank::RankPool`]), each owning its own PJRT [`Runtime`], weight
//!   shard literals, and KV shard. Workers exchange partials over the
//!   shared-memory [`crate::fabric`], so stage programs *and* codec
//!   encode/decode run concurrently; the virtual clock's max-of-ranks
//!   stage times and per-collective codec times are real concurrent
//!   measurements.
//! * **Sequential reference path** (`--rank-threads off`): the seed's
//!   single-thread loop, kept bit-identical as the correctness anchor —
//!   `tests/rank_parallel.rs` pins that both paths produce identical
//!   logits, sampled tokens, wire bytes, and policy counters.
//!
//! In both, *virtual* time models the simulated deployment: per
//! lock-step stage the clock advances by the **max** of the per-rank
//! wall times, and communication advances it by the interconnect model
//! + the measured (or analytic) codec overhead. DESIGN.md "Known
//! deviations" discusses fidelity.
//!
//! Compression is resolved **per site** ([`crate::policy`]): each
//! collective's (layer, kind, phase) coordinate maps through the bound
//! [`PolicyTable`] to a compressor, with per-site plan-cache keys and
//! per-site byte/call telemetry. `--compress <spec>` binds the
//! seed-equivalent `uniform:<spec>` table, so the single-compressor
//! path stays bit-identical (pinned by `tests/property_policy.rs`).

pub mod kv;
pub mod rank;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::collective::{self, AlgoChoice, CollectivePlan, Topology};
use crate::interconnect::{HwProfile, LinkModel, VirtualClock};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::mxfmt::{compressor_from_spec_ch, Compressor};
use crate::obs::{self, Cat, Tracer};
use crate::policy::{
    self, Calibration, CompressionPolicy, Phase, PolicyTable, SearchScenario, Site, SiteKind,
};
use crate::runtime::{lit_f32, lit_i32, to_vec_f32, Runtime};
use crate::util::json::Json;

pub use kv::{BatchKv, KvShard, SwappedKv, DEFAULT_KV_BLOCK};
pub use rank::RankPool;

/// How the quantize/dequantize overhead enters virtual time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OverheadModel {
    /// charge the measured rust-codec wall time (live CPU mode)
    Measured,
    /// charge values / rate (paper-scale accelerator mode)
    Analytic { values_per_s: f64 },
}

/// The `--rank-threads` knob: how many worker threads execute the TP
/// ranks. `off` keeps the seed's sequential reference path; `auto`
/// (the default) spawns `min(tp, cores)` workers; a number pins the
/// worker count (ranks are multiplexed when fewer workers than ranks).
///
/// ```
/// use tpcc::tp::RankThreads;
/// assert_eq!(RankThreads::parse("off").unwrap(), RankThreads::Off);
/// assert_eq!(RankThreads::parse("auto").unwrap(), RankThreads::Auto);
/// assert_eq!(RankThreads::parse("3").unwrap(), RankThreads::Fixed(3));
/// assert!(RankThreads::parse("many").is_err());
/// // tp=1 never spawns workers; `off` never does; fixed counts clamp to tp
/// assert_eq!(RankThreads::Off.workers(8), 0);
/// assert_eq!(RankThreads::Fixed(16).workers(4), 4);
/// assert_eq!(RankThreads::Auto.workers(1), 0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankThreads {
    /// sequential reference path (the seed's single-thread loop)
    Off,
    /// one worker per rank, capped at the host's available parallelism
    Auto,
    /// exactly this many workers (clamped to `1..=tp`)
    Fixed(usize),
}

impl RankThreads {
    pub fn parse(s: &str) -> anyhow::Result<RankThreads> {
        match s {
            "off" | "seq" | "sequential" => Ok(RankThreads::Off),
            "" | "auto" => Ok(RankThreads::Auto),
            n => match n.parse::<usize>() {
                Ok(0) => Ok(RankThreads::Off),
                Ok(v) => Ok(RankThreads::Fixed(v)),
                Err(_) => anyhow::bail!("bad rank-threads spec {n:?} (want off|auto|N)"),
            },
        }
    }

    /// Session default from the `RANK_THREADS` env var (`auto` when
    /// unset) — how CI pins its sequential-reference leg.
    ///
    /// A *set but invalid* value panics instead of silently falling
    /// back: a typo'd `RANK_THREADS=off` leg that quietly ran the
    /// parallel engine would let the sequential reference path rot
    /// behind green CI — exactly what the matrix exists to prevent.
    pub fn from_env() -> RankThreads {
        match std::env::var("RANK_THREADS") {
            Err(_) => RankThreads::Auto,
            Ok(v) => RankThreads::parse(&v)
                .unwrap_or_else(|e| panic!("invalid RANK_THREADS env var: {e}")),
        }
    }

    /// Worker-thread count for a `tp`-way engine; 0 selects the
    /// sequential reference path.
    pub fn workers(self, tp: usize) -> usize {
        if tp <= 1 {
            return 0;
        }
        match self {
            RankThreads::Off => 0,
            RankThreads::Auto => {
                let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                tp.min(cores.max(1))
            }
            RankThreads::Fixed(n) => n.clamp(1, tp),
        }
    }
}

/// Resolve one planned collective's `(codec_s, total_s)` under the
/// overhead model. Shared by the sequential reference path and the rank
/// workers so the accounting — the Measured pass-through vs the
/// Analytic re-score through `plan::score` — cannot drift between the
/// two execution cores.
pub(crate) fn comm_times(
    overhead: OverheadModel,
    rep: &collective::CommReport,
    plan: &CollectivePlan,
    len: usize,
    world: usize,
    comp: Option<&dyn Compressor>,
    topo: &Topology,
) -> (f64, f64) {
    match overhead {
        OverheadModel::Measured => (rep.encode_s + rep.decode_s, rep.total_s()),
        OverheadModel::Analytic { values_per_s } => {
            if comp.is_some() {
                // the planner's own scoring at the engine's rate —
                // realized analytic time equals the scored objective
                // (codec values discounted by the codec's cost factor,
                // overlap per the executed chunk count)
                let (total, _link, codec_s) = collective::plan::score(
                    plan.algo, len, world, comp, topo, values_per_s, rep.chunks,
                );
                (codec_s, total)
            } else {
                (0.0, rep.link_s)
            }
        }
    }
}

/// Cumulative per-rank busy time (compute stages + codec work + fabric
/// waits), fed by both execution cores and served as `/metrics`
/// utilization gauges.
#[derive(Debug, Clone, Copy, Default)]
pub struct RankBusy {
    pub compute_s: f64,
    pub codec_s: f64,
    /// time this rank's execution was blocked in a fabric barrier or
    /// rendezvous waiting for its peers (parallel core only; a
    /// multiplexing worker's wait is credited to each rank it owns)
    pub fabric_wait_s: f64,
}

#[derive(Debug, Clone)]
pub struct EngineOptions {
    pub model: String,
    pub tp: usize,
    /// compressor spec (`none`, `fp4_e2m1_b32_e8m0`, `int4_channelwise`,
    /// `topk3`, ...). Without a `policy`, it applies uniformly to every
    /// row-parallel collective (the seed behaviour); with a partial
    /// rule policy it is the default scheme for unmatched sites.
    pub compress: String,
    /// per-site policy spec: empty (= `uniform` of `compress`),
    /// `uniform:<spec>`, `paper`, `auto[:budget_pct]`,
    /// `auto-live[:budget_pct]`, or a rule string
    /// (`mlp=fp4_e2m1_b32_e8m0;attn=none;decode=none`, see
    /// [`crate::policy::spec`])
    pub policy: String,
    /// collective algorithm knob: `auto` (planner decides per message
    /// shape) or a fixed [`crate::collective::AlgoKind`] name
    pub algo: String,
    pub overhead: OverheadModel,
    /// hardware profile used for link simulation
    pub profile: &'static HwProfile,
    /// route quantize/dequant through the fused Pallas HLO executables
    /// (available for FUSED_SCHEMES at the reduced buckets; otherwise
    /// the bit-exact rust codec runs — same math, verified by the
    /// golden-vector tests and `fused_path_matches_rust_codec`)
    pub fused: bool,
    /// rank-thread runtime knob (`off` = sequential reference path);
    /// defaults to the `RANK_THREADS` env var, `auto` when unset
    pub rank_threads: RankThreads,
}

impl EngineOptions {
    pub fn new(model: &str, tp: usize) -> EngineOptions {
        EngineOptions {
            model: model.to_string(),
            tp,
            compress: "none".into(),
            policy: String::new(),
            algo: "auto".into(),
            overhead: OverheadModel::Measured,
            profile: HwProfile::by_name("cpu").unwrap(),
            fused: false,
            rank_threads: RankThreads::from_env(),
        }
    }

    pub fn with_compress(mut self, spec: &str) -> Self {
        self.compress = spec.to_string();
        self
    }

    /// Set the per-site policy spec (see [`EngineOptions::policy`]).
    pub fn with_policy(mut self, policy: &str) -> Self {
        self.policy = policy.to_string();
        self
    }

    pub fn with_algo(mut self, algo: &str) -> Self {
        self.algo = algo.to_string();
        self
    }

    pub fn with_profile(mut self, name: &str) -> Self {
        self.profile = HwProfile::by_name(name).expect("unknown profile");
        self
    }

    pub fn with_fused(mut self, fused: bool) -> Self {
        self.fused = fused;
        self
    }

    /// Set the rank-thread runtime knob (see [`RankThreads`]).
    pub fn with_rank_threads(mut self, rt: RankThreads) -> Self {
        self.rank_threads = rt;
        self
    }
}

/// Per-forward timing breakdown (live + virtual).
///
/// `link_s` is the *exposed* link time: the algorithm's modeled wire
/// schedule minus whatever codec work a pipelined plan hides behind it,
/// so `virtual_total` is the overlapped schedule, not the serial sum.
#[derive(Debug, Clone, Default)]
pub struct StepTiming {
    pub wall_s: f64,
    pub compute_s: f64,
    pub link_s: f64,
    pub codec_s: f64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
    /// collective algorithm used by this step's communicates ("" until
    /// a collective ran)
    pub algo: &'static str,
}

impl StepTiming {
    pub fn virtual_total(&self) -> f64 {
        self.compute_s + self.link_s + self.codec_s
    }

    pub fn merge(&mut self, o: &StepTiming) {
        self.wall_s += o.wall_s;
        self.compute_s += o.compute_s;
        self.link_s += o.link_s;
        self.codec_s += o.codec_s;
        self.wire_bytes += o.wire_bytes;
        self.raw_bytes += o.raw_bytes;
        if !o.algo.is_empty() {
            self.algo = o.algo;
        }
    }
}

/// Per-site collective telemetry (one slot per [`Site::index`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SiteStat {
    pub calls: u64,
    pub wire_bytes: u64,
    pub raw_bytes: u64,
}

pub struct TpEngine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub opts: EngineOptions,
    /// the bound per-site policy (what `opts.policy` resolved to)
    policy: PolicyTable,
    /// distinct specs the policy uses; compressors parallel to it
    policy_specs: Vec<String>,
    policy_comps: Vec<Option<Box<dyn Compressor>>>,
    /// site index -> index into `policy_specs`/`policy_comps`
    site_spec: Vec<u16>,
    /// per-site byte/call counters (feeds `/metrics` rollups)
    site_stats: Vec<SiteStat>,
    /// incremental (kind × phase) rollups, indexed [kind.ord][phase.ord]
    /// — kept in step with `site_stats` so `policy_metrics` never scans
    /// the site grid on the serving path
    group_stats: [[SiteStat; 2]; 2],
    /// collective calls per bound scheme (parallel to `policy_specs`)
    scheme_calls: Vec<u64>,
    /// when set, `communicate` records each site's first pre-quantization
    /// partials here (the calibration forward pass)
    calib_capture: Option<Vec<Vec<Vec<f32>>>>,
    /// parsed `opts.algo` (planner constraint)
    algo_choice: AlgoChoice,
    /// per-engine plan memo keyed on (message len, profile identity,
    /// site scheme) — keeps the hot path free of the planner's global
    /// cache lock and key allocations; cleared when the policy or algo
    /// knob changes
    plan_cache: BTreeMap<(usize, usize, usize), CollectivePlan>,
    /// collective invocations per algorithm name (feeds `/metrics`)
    pub algo_calls: BTreeMap<&'static str, u64>,
    /// per-rank weight literals, keyed like the python param dict
    wlits: Vec<BTreeMap<String, xla::Literal>>,
    pub clock: VirtualClock,
    /// rank-thread worker pool; `None` runs the sequential reference
    /// path (`--rank-threads off`, or `tp <= 1`)
    pool: Option<rank::RankPool>,
    /// cumulative per-rank busy time (compute + codec), both paths
    rank_busy: Vec<RankBusy>,
    /// structured span recorder shared with the rank workers (and the
    /// coordinator); disabled until serving / `tpcc trace` / the
    /// rankpar bench turns it on
    tracer: Arc<Tracer>,
    /// structured event log shared the same way (rank workers emit
    /// start/panic/poison events; the coordinator adopts it as the
    /// process-wide sink)
    logger: Arc<obs::log::Logger>,
    /// online compression-error sentinel: streams observed quantization
    /// error on sampled forwards against the calibrated budget. Rebuilt
    /// (drift history reset) whenever a new policy binds —
    /// `apply_drift_fallback` carries the history across its own rebind.
    sentinel: policy::Sentinel,
    /// monotonically increasing forward-step id, stamped as the span
    /// `pid` of engine-level timelines
    next_step: u64,
    // reusable scratch (sequential path; workers own their own)
    reduce_buf: Vec<f32>,
    comm_scratch: collective::CommScratch,
}

impl TpEngine {
    pub fn new(rt: Runtime, weights: &Weights, opts: EngineOptions) -> anyhow::Result<TpEngine> {
        let cfg = ModelConfig::from_manifest(&opts.model, &rt.manifest.raw)?;
        let algo_choice = AlgoChoice::parse(&opts.algo)?;
        // engine-side weight literals feed the sequential path only;
        // with an active rank pool every forward runs on the workers
        // (which build their own shard literals), so holding a second
        // full copy here would double weight memory for nothing
        let workers = opts.rank_threads.workers(opts.tp);
        let mut wlits = Vec::with_capacity(opts.tp);
        if workers == 0 {
            for rank in 0..opts.tp {
                let shard = weights.shard(&cfg, opts.tp, rank)?;
                let mut lits = BTreeMap::new();
                for (name, t) in &shard.tensors {
                    lits.insert(name.clone(), lit_f32(&t.shape, &t.data)?);
                }
                wlits.push(lits);
            }
        }
        let n_sites = Site::count(cfg.n_layers);
        let opts_tp = opts.tp;
        // span recorder: the engine thread records through it (and the
        // rank workers register their own rings at boot); tracing stays
        // disabled until a caller opts in
        let tracer = Tracer::new();
        obs::install(&tracer, "engine", obs::TID_COORD);
        // event log: created next to the tracer so rank workers can
        // emit lifecycle events from boot onward; the coordinator
        // shares this instance (one sink per engine)
        let logger = obs::log::Logger::new();
        let mut eng = TpEngine {
            rt,
            cfg,
            opts,
            policy: PolicyTable::uniform(0, "none"),
            policy_specs: vec!["none".into()],
            policy_comps: vec![None],
            site_spec: vec![0; n_sites],
            site_stats: vec![SiteStat::default(); n_sites],
            group_stats: [[SiteStat::default(); 2]; 2],
            scheme_calls: vec![0],
            calib_capture: None,
            algo_choice,
            plan_cache: BTreeMap::new(),
            algo_calls: BTreeMap::new(),
            wlits,
            clock: VirtualClock::default(),
            pool: None,
            rank_busy: vec![RankBusy::default(); opts_tp],
            tracer,
            logger,
            sentinel: policy::Sentinel::new(n_sites, policy::DEFAULT_AUTO_BUDGET_PCT),
            next_step: 0,
            reduce_buf: Vec::new(),
            comm_scratch: collective::CommScratch::default(),
        };
        let policy = eng.opts.policy.clone();
        eng.set_policy(&policy)?;
        // spawn the rank-thread pool last, so it boots with the fully
        // resolved policy binding (later rebinds are broadcast)
        if workers > 0 {
            let pool = rank::RankPool::spawn(
                weights,
                &eng.cfg,
                eng.rt.root(),
                eng.opts.tp,
                workers,
                eng.bind_spec(),
                eng.tracer.clone(),
                eng.logger.clone(),
            )?;
            eng.pool = Some(pool);
        }
        Ok(eng)
    }

    /// The worker pool's view of the current policy binding.
    fn bind_spec(&self) -> rank::BindSpec {
        rank::BindSpec {
            specs: self.policy_specs.clone(),
            site_spec: self.site_spec.clone(),
        }
    }

    /// Worker threads executing the ranks (0 = sequential reference path).
    pub fn rank_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.workers())
    }

    /// The engine's span recorder, shared with its rank workers. Enable
    /// with `tracer().set_enabled(true)`; drain/snapshot for export.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// The engine's structured event log, shared with its rank workers
    /// and adopted by the coordinator as the process-wide sink.
    pub fn logger(&self) -> &Arc<obs::log::Logger> {
        &self.logger
    }

    /// `/metrics` gauges derived from the tracer — measured per-phase
    /// totals next to the virtual clock's modeled totals, so the
    /// modeled-vs-measured gap is directly visible.
    pub fn trace_metrics(&self) -> Vec<(String, f64)> {
        let mut out = self.tracer.phase_metrics();
        out.push(("virtual_compute_s".to_string(), self.clock.compute()));
        out.push(("virtual_comm_s".to_string(), self.clock.comm()));
        out
    }

    pub fn link(&self) -> &LinkModel {
        &self.opts.profile.link
    }

    /// Topology the current profile presents to this TP world.
    pub fn topology(&self) -> Topology {
        Topology::from_profile(self.opts.profile, self.opts.tp)
    }

    /// Swap the collective algorithm knob without rebuilding the engine.
    pub fn set_algo(&mut self, algo: &str) -> anyhow::Result<()> {
        self.algo_choice = AlgoChoice::parse(algo)?;
        self.opts.algo = algo.to_string();
        self.plan_cache.clear();
        Ok(())
    }

    /// Resolve and bind a policy spec: `""`/`uniform` (uniform of
    /// `opts.compress`), `uniform:<spec>`, `paper`, `auto[:budget_pct]`
    /// (synthetic calibration), `auto-live[:budget_pct]` (calibration
    /// forward pass — needs artifacts), or a rule string.
    pub fn set_policy(&mut self, spec: &str) -> anyhow::Result<()> {
        let n_layers = self.cfg.n_layers;
        let table = match spec {
            "" | "uniform" => PolicyTable::uniform(n_layers, &self.opts.compress),
            "paper" => {
                let calib = self.synthetic_calibration();
                policy::paper_policy(&calib, policy::PAPER_ERR_BUDGET_PCT)?
            }
            s if s == "auto" || s.starts_with("auto:") => {
                let budget = parse_budget(s, "auto")?;
                let calib = self.synthetic_calibration();
                self.auto_table(&calib, budget)?
            }
            s if s == "auto-live" || s.starts_with("auto-live:") => {
                let budget = parse_budget(s, "auto-live")?;
                // capture must see unquantized residuals end-to-end; if
                // the capture/search fails, restore the previous binding
                // so an erroring call leaves the engine unchanged
                let prev = self.policy.clone();
                self.bind_policy(PolicyTable::uniform(n_layers, "none"))?;
                let searched = self
                    .capture_calibration()
                    .and_then(|calib| self.auto_table(&calib, budget));
                match searched {
                    Ok(table) => table,
                    Err(e) => {
                        self.bind_policy(prev)?;
                        return Err(e);
                    }
                }
            }
            s => CompressionPolicy::parse_with_default(s, &self.opts.compress)?.table(n_layers),
        };
        self.opts.policy = spec.to_string();
        self.bind_policy(table)
    }

    /// Swap the collective compressor without rebuilding the engine
    /// (sweeps reuse one engine's compiled executables across schemes).
    /// Binds the seed-equivalent `uniform:<spec>` policy.
    pub fn set_compress(&mut self, spec: &str) -> anyhow::Result<()> {
        self.opts.compress = spec.to_string();
        self.opts.policy = String::new();
        self.bind_policy(PolicyTable::uniform(self.cfg.n_layers, spec))
    }

    /// The bound per-site policy.
    pub fn policy(&self) -> &PolicyTable {
        &self.policy
    }

    /// JSON description of the bound policy (served at `GET /policy`),
    /// with a `policy_drift` section from the online sentinel.
    pub fn policy_json(&self) -> Json {
        let mut j = self.policy.to_json();
        if let Json::Obj(map) = &mut j {
            map.insert(
                "policy_drift".to_string(),
                self.sentinel.to_json(self.cfg.n_layers),
            );
        }
        j
    }

    /// Per-site collective telemetry, indexed by [`Site::index`].
    pub fn site_stats(&self) -> &[SiteStat] {
        &self.site_stats
    }

    /// Metric rollups for `/metrics`: calls + wire bytes per
    /// (kind × phase) site group, plus calls per bound scheme. Reads
    /// the incrementally maintained counters — O(schemes), no site-grid
    /// scan — since the coordinator mirrors this every engine step.
    pub fn policy_metrics(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (ki, kind) in SiteKind::ALL.iter().enumerate() {
            for (pi, phase) in Phase::ALL.iter().enumerate() {
                let g = &self.group_stats[ki][pi];
                let tag = format!("{}_{}", kind.name(), phase.name());
                out.push((format!("policy_calls_{tag}"), g.calls as f64));
                out.push((format!("policy_wire_bytes_{tag}"), g.wire_bytes as f64));
            }
        }
        for (spec, calls) in self.policy_specs.iter().zip(&self.scheme_calls) {
            out.push((format!("policy_calls_scheme_{spec}"), *calls as f64));
        }
        out
    }

    /// Per-rank utilization gauges for `/metrics`: cumulative compute,
    /// codec, and fabric-wait seconds per rank (real concurrent
    /// measurements under the rank-thread runtime), plus the active
    /// worker count.
    pub fn rank_metrics(&self) -> Vec<(String, f64)> {
        let mut out = Vec::with_capacity(self.rank_busy.len() * 3 + 1);
        out.push(("rank_workers".to_string(), self.rank_workers() as f64));
        for (r, b) in self.rank_busy.iter().enumerate() {
            out.push((format!("rank{r}_compute_busy_s"), b.compute_s));
            out.push((format!("rank{r}_codec_busy_s"), b.codec_s));
            out.push((format!("rank{r}_fabric_wait_s"), b.fabric_wait_s));
        }
        out
    }

    /// Account one collective at `site` into the per-site, per-group
    /// and per-scheme counters.
    fn record_site(&mut self, site: Site, scheme_idx: usize, wire_bytes: u64, raw_bytes: u64) {
        let st = &mut self.site_stats[site.index()];
        st.calls += 1;
        st.wire_bytes += wire_bytes;
        st.raw_bytes += raw_bytes;
        // site.index() = (layer*2 + kind)*2 + phase
        let si = site.index();
        let g = &mut self.group_stats[(si / 2) % 2][si % 2];
        g.calls += 1;
        g.wire_bytes += wire_bytes;
        g.raw_bytes += raw_bytes;
        self.scheme_calls[scheme_idx] += 1;
    }

    /// Bind a fully resolved table: build one compressor per distinct
    /// scheme, map sites onto them, reset per-site stats and plans.
    fn bind_policy(&mut self, table: PolicyTable) -> anyhow::Result<()> {
        anyhow::ensure!(
            table.n_layers == self.cfg.n_layers,
            "policy table is for {} layers, model has {}",
            table.n_layers,
            self.cfg.n_layers
        );
        let distinct = table.distinct();
        let mut comps = Vec::with_capacity(distinct.len());
        for spec in &distinct {
            comps.push(if spec == "none" {
                None
            } else {
                Some(compressor_from_spec_ch(spec, self.cfg.d_model)?)
            });
        }
        let mut site_spec = vec![0u16; Site::count(table.n_layers)];
        for site in Site::all(table.n_layers) {
            let idx = distinct.iter().position(|s| s == table.spec(site)).unwrap();
            site_spec[site.index()] = idx as u16;
        }
        self.policy = table;
        self.scheme_calls = vec![0; distinct.len()];
        self.policy_specs = distinct;
        self.policy_comps = comps;
        self.site_spec = site_spec;
        self.site_stats = vec![SiteStat::default(); Site::count(self.cfg.n_layers)];
        self.group_stats = [[SiteStat::default(); 2]; 2];
        self.plan_cache.clear();
        // the rank workers mirror the binding (their own compressors,
        // their own plan memos)
        if let Some(pool) = &self.pool {
            pool.bind(self.bind_spec());
        }
        // a new binding means a new error budget and a clean drift slate
        self.sentinel =
            policy::Sentinel::new(Site::count(self.cfg.n_layers), self.sentinel_budget());
        Ok(())
    }

    /// Error budget (percent) the drift sentinel compares observed
    /// per-site error against: the budget the bound policy was searched
    /// under, or the default auto budget for uniform/rule policies.
    fn sentinel_budget(&self) -> f64 {
        let p = self.opts.policy.as_str();
        if p == "paper" {
            policy::PAPER_ERR_BUDGET_PCT
        } else if p == "auto" || p.starts_with("auto:") {
            parse_budget(p, "auto").unwrap_or(policy::DEFAULT_AUTO_BUDGET_PCT)
        } else if p == "auto-live" || p.starts_with("auto-live:") {
            parse_budget(p, "auto-live").unwrap_or(policy::DEFAULT_AUTO_BUDGET_PCT)
        } else {
            policy::DEFAULT_AUTO_BUDGET_PCT
        }
    }

    /// The online drift sentinel bound to the current policy.
    pub fn sentinel(&self) -> &policy::Sentinel {
        &self.sentinel
    }

    /// Mutable sentinel access (tuning cadence, injecting drift in
    /// tests).
    pub fn sentinel_mut(&mut self) -> &mut policy::Sentinel {
        &mut self.sentinel
    }

    /// Drift counters the coordinator mirrors onto `/metrics`.
    pub fn sentinel_metrics(&self) -> Vec<(&'static str, f64)> {
        self.sentinel.metrics()
    }

    /// Rebind every tripped site to the never-worse `none` scheme,
    /// keeping the drift history (and the fallback pins) across the
    /// rebind. Returns the sites that fell back; empty when no site has
    /// tripped.
    pub fn apply_drift_fallback(&mut self) -> anyhow::Result<Vec<Site>> {
        let tripped = self.sentinel.tripped();
        if tripped.is_empty() {
            return Ok(Vec::new());
        }
        let table = policy::fallback_table(&self.policy, &tripped);
        // bind_policy resets the sentinel; swap the live one out so the
        // accumulated drift evidence survives its own consequence
        let live = std::mem::replace(&mut self.sentinel, policy::Sentinel::new(0, 1.0));
        let bound = self.bind_policy(table);
        self.sentinel = live;
        bound?;
        for &si in &tripped {
            self.sentinel.mark_fallback(si);
        }
        let sites = Site::all(self.cfg.n_layers);
        Ok(tripped.iter().filter_map(|&si| sites.get(si).copied()).collect())
    }

    /// Total fabric-wait seconds across rank workers (flight-recorder
    /// attribution input; 0 under the sequential core).
    pub fn fabric_wait_total(&self) -> f64 {
        self.rank_busy.iter().map(|b| b.fabric_wait_s).sum()
    }

    /// Cumulative wire bytes per (kind × phase) site group, in
    /// [`crate::obs::flight::SITE_GROUPS`] order: attn.prefill,
    /// attn.decode, mlp.prefill, mlp.decode.
    pub fn group_wire_bytes(&self) -> [u64; 4] {
        let mut out = [0u64; 4];
        for (ki, row) in self.group_stats.iter().enumerate() {
            for (pi, g) in row.iter().enumerate() {
                out[ki * 2 + pi] = g.wire_bytes;
            }
        }
        out
    }

    /// Bound scheme per (kind × phase) site group, same order as
    /// [`TpEngine::group_wire_bytes`]: the single spec when the group is
    /// uniform, else `mixed(<n distinct>)`.
    pub fn group_schemes(&self) -> [String; 4] {
        std::array::from_fn(|gi| {
            let (ki, pi) = (gi / 2, gi % 2);
            let mut specs: Vec<&str> = Site::all(self.cfg.n_layers)
                .into_iter()
                .filter(|s| {
                    let si = s.index();
                    (si / 2) % 2 == ki && si % 2 == pi
                })
                .map(|s| self.policy.spec(s))
                .collect();
            specs.sort_unstable();
            specs.dedup();
            match specs.as_slice() {
                [one] => (*one).to_string(),
                many => format!("mixed({})", many.len()),
            }
        })
    }

    /// Synthetic per-site calibration for this engine's shape.
    fn synthetic_calibration(&self) -> Calibration {
        Calibration::synthetic(self.cfg.n_layers, self.cfg.d_model, self.opts.tp, 0xCA11B)
    }

    /// The deployment the built-in `auto` search prices against:
    /// a full prefill bucket (8×128 tokens) and an 8-wide decode step
    /// on this engine's profile/topology.
    fn search_scenario(&self) -> SearchScenario {
        SearchScenario::new(self.opts.profile, self.opts.tp, 8 * 128, 8, self.cfg.d_model)
    }

    fn auto_table(&self, calib: &Calibration, budget_pct: f64) -> anyhow::Result<PolicyTable> {
        let scen = self.search_scenario();
        let costs = policy::SiteCosts::build(calib, &scen, policy::CANDIDATES)?;
        let baseline = PolicyTable::uniform(self.cfg.n_layers, &self.opts.compress);
        let out =
            policy::auto_search(&costs, self.cfg.n_layers, budget_pct, Some(&baseline), "auto")?;
        Ok(out.table)
    }

    /// Run a calibration forward pass (one prefill bucket + one decode
    /// step) capturing each site's pre-quantization partials. Capture
    /// reflects the engine's *current* compression state; run it on an
    /// uncompressed binding for clean statistics (the `auto-live` path
    /// does).
    pub fn capture_calibration(&mut self) -> anyhow::Result<Calibration> {
        // the capture pass records partials engine-side, so it runs the
        // sequential reference path — which needs the engine-side weight
        // literals a pooled engine deliberately does not build
        anyhow::ensure!(
            self.wlits.len() == self.opts.tp,
            "auto-live calibration needs the sequential engine; \
             rebuild with --rank-threads off (RANK_THREADS=off)"
        );
        let n_sites = Site::count(self.cfg.n_layers);
        let bb = self.rt.manifest.batch_buckets.iter().copied().min().unwrap_or(1).max(1);
        let sb = self
            .rt
            .manifest
            .seq_buckets
            .iter()
            .copied()
            .filter(|&s| s > 1)
            .min()
            .unwrap_or(16);
        let tokens: Vec<i32> = (0..bb * sb).map(|i| (i * 31 + 7) as i32 % 256).collect();
        let pos = vec![0i32; bb];
        // the calibration pass is not serving traffic: keep its
        // collectives out of the per-algorithm counters, the virtual
        // clock and the per-site stats mirrored to `/metrics`
        let saved_algo_calls = self.algo_calls.clone();
        let saved_clock = self.clock.clone();
        let saved_site_stats = self.site_stats.clone();
        let saved_group_stats = self.group_stats;
        let saved_scheme_calls = self.scheme_calls.clone();
        self.calib_capture = Some(vec![Vec::new(); n_sites]);
        let run = (|| -> anyhow::Result<()> {
            let mut kv = BatchKv::new(&self.cfg.clone(), self.opts.tp, bb);
            self.prefill(&tokens, bb, sb, &pos, Some(&mut kv))?;
            let dec_tokens = vec![1i32; bb];
            let dec_pos = vec![sb as i32; bb];
            self.decode(&dec_tokens, &dec_pos, &mut kv)?;
            Ok(())
        })();
        let data = self.calib_capture.take().unwrap();
        self.algo_calls = saved_algo_calls;
        self.clock = saved_clock;
        self.site_stats = saved_site_stats;
        self.group_stats = saved_group_stats;
        self.scheme_calls = saved_scheme_calls;
        run?;
        Calibration::from_samples(self.cfg.n_layers, self.cfg.d_model, data)
    }

    fn wlit(&self, rank: usize, name: &str) -> &xla::Literal {
        self.wlits[rank].get(name).expect("weight literal")
    }

    /// Execute one artifact, advancing `timing.compute_s` by `frac` of
    /// the measured wall time (frac=1 for lock-step per-rank max, which
    /// callers implement by passing the max separately).
    fn exec_timed(
        &self,
        name: &str,
        args: &[&xla::Literal],
        out_secs: &mut f64,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let out = self.rt.execute_refs(name, args)?;
        *out_secs = t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Names of the fused quantize / dequant-reduce-add executables for
    /// `site`'s scheme at bucket (bb, sb), if they were exported
    /// (FUSED_SCHEMES × reduced buckets; see python aot.py). `forward`
    /// memoises the result per distinct scheme for the duration of one
    /// pass, so the name formatting + manifest lookups run once per
    /// scheme per forward, not per layer.
    fn fused_names_site(&self, site: Site, bb: usize, sb: usize) -> Option<(String, String)> {
        if !self.opts.fused {
            return None;
        }
        let spec = &self.policy_specs[self.site_spec[site.index()] as usize];
        if spec == "none" {
            return None;
        }
        let model = &self.opts.model;
        let tp = self.opts.tp;
        let q = format!("{model}/quant_{spec}_b{bb}_s{sb}");
        let d = format!("{model}/dqra_{spec}_tp{tp}_b{bb}_s{sb}");
        (self.rt.manifest.by_name(&q).is_some() && self.rt.manifest.by_name(&d).is_some())
            .then_some((q, d))
    }

    /// Fused on-accelerator collective (paper Fig. 1b as lowered HLO):
    /// each rank's partial is quantized by the Pallas `quantize`
    /// executable, the (simulated) all-gather moves the packed
    /// codes+scales, and the receiving side runs the fused Pallas
    /// `dequant_reduce_add`. Numerically identical to the host codec
    /// path (`fused_path_matches_rust_codec` integration test).
    #[allow(clippy::too_many_arguments)]
    fn communicate_fused(
        &mut self,
        x: &[f32],
        partial_lits: &[&xla::Literal],
        qname: &str,
        dname: &str,
        bb: usize,
        sb: usize,
        site: Site,
        timing: &mut StepTiming,
    ) -> anyhow::Result<Vec<f32>> {
        let d = self.cfg.d_model;
        let tp = self.opts.tp;
        let values = bb * sb * d;
        let spec = self.policy_specs[self.site_spec[site.index()] as usize].clone();
        let scheme = crate::mxfmt::MxScheme::parse(&spec)?;
        let block = scheme.block;
        let nb = d / block;

        let mut codes_all = Vec::with_capacity(tp * values);
        let mut scales_all = Vec::with_capacity(tp * values / block);
        let mut enc_once = 0.0f64;
        let mut dt = 0.0f64;
        for (rank, p) in partial_lits.iter().enumerate() {
            obs::set_tid(rank as u32);
            let out = {
                let _g = obs::span_arg("quant.fused", Cat::Encode, site.index() as i64);
                self.exec_timed(qname, &[p], &mut dt)?
            };
            if rank == 0 {
                enc_once = dt;
            }
            codes_all.extend(crate::runtime::to_vec_u8(&out[0])?);
            scales_all.extend(crate::runtime::to_vec_u8(&out[1])?);
        }
        let x_lit = lit_f32(&[bb, sb, d], x)?;
        let codes = crate::runtime::lit_u8(&[tp, bb, sb, d], &codes_all)?;
        let scales = crate::runtime::lit_u8(&[tp, bb, sb, nb], &scales_all)?;
        obs::set_tid(0);
        let out = {
            let _g = obs::span_arg("dqra.fused", Cat::Decode, site.index() as i64);
            self.exec_timed(dname, &[&x_lit, &codes, &scales], &mut dt)?
        };
        let reduced = to_vec_f32(&out[0])?;

        // accounting: wire size is the bit-packed size the scheme would
        // put on the link (the HLO path carries byte-per-code tensors in
        // host memory, but the *interconnect* sees packed bits)
        let shard_wire = scheme.wire_bytes(values);
        let link_s = self.opts.profile.link.all_gather_time(shard_wire, tp);
        let codec_s = match self.opts.overhead {
            OverheadModel::Measured => enc_once + dt,
            OverheadModel::Analytic { values_per_s } => (values * tp) as f64 / values_per_s,
        };
        for b in self.rank_busy.iter_mut() {
            b.codec_s += codec_s;
        }
        self.tracer.add_phase(Cat::Link, link_s);
        timing.link_s += link_s;
        timing.codec_s += codec_s;
        timing.wire_bytes += (shard_wire * (tp - 1)) as u64;
        timing.raw_bytes += (values * 2 * (tp - 1)) as u64;
        // the fused HLO executables bake in the all-gather layout, so
        // this path always accounts as the flat ring
        *self.algo_calls.entry("ring").or_insert(0) += 1;
        timing.algo = "ring";
        self.record_site(
            site,
            self.site_spec[site.index()] as usize,
            (shard_wire * (tp - 1)) as u64,
            (values * 2 * (tp - 1)) as u64,
        );
        self.clock
            .add_comm(link_s + codec_s, shard_wire * (tp - 1), values * 2 * (tp - 1));
        Ok(reduced)
    }

    /// The collective after a row-parallel stage: `site` resolves the
    /// policy's compressor, the planner picks an (algorithm × chunking)
    /// for this (message shape, scheme) on the profile's topology,
    /// execution applies compression at the algorithm's phase
    /// boundaries, and virtual time advances by the overlapped schedule.
    fn communicate(
        &mut self,
        x: &[f32],
        partials: &[Vec<f32>],
        site: Site,
        timing: &mut StepTiming,
    ) -> Vec<f32> {
        let n = partials.len();
        let len = x.len();
        let topo = self.topology();
        let si = site.index();
        let ci = self.site_spec[si] as usize;
        obs::set_tid(0);
        let _site_span = obs::span_arg("collective", Cat::Step, si as i64);
        // calibration capture: record each site's first pre-quantization
        // partials (block-aligned prefix)
        if let Some(cap) = self.calib_capture.as_mut() {
            if cap[si].is_empty() {
                let take = Calibration::sample_len(self.cfg.d_model).min(len);
                for p in partials {
                    cap[si].push(p[..take].to_vec());
                }
            }
        }
        // planning always scores codec work at the profile's calibrated
        // throughput — in Measured mode the realised codec time is this
        // CPU's, but the *choice* models the simulated hardware. The
        // per-engine memo keys on (len, profile identity, site scheme);
        // policy and algo-knob changes clear it (`set_policy`/`set_algo`).
        let memo_key = (len, self.opts.profile as *const HwProfile as usize, ci);
        let plan = match self.plan_cache.get(&memo_key).copied() {
            Some(p) => p,
            None => {
                let p = collective::plan::choose(
                    len,
                    n,
                    self.policy_comps[ci].as_deref(),
                    &topo,
                    self.opts.profile.quant_values_per_s,
                    self.algo_choice,
                );
                self.plan_cache.insert(memo_key, p);
                p
            }
        };
        let comp = self.policy_comps[ci].as_deref();
        let measure = self.opts.overhead == OverheadModel::Measured;
        let mut out = std::mem::take(&mut self.reduce_buf);
        let rep = collective::execute(
            &plan, x, partials, comp, &topo, measure, &mut out, &mut self.comm_scratch,
        );
        *self.algo_calls.entry(rep.algo).or_insert(0) += 1;
        timing.algo = rep.algo;

        let (codec_s, total_s) =
            comm_times(self.opts.overhead, &rep, &plan, len, n, comp, &topo);
        for b in self.rank_busy.iter_mut() {
            b.codec_s += codec_s;
        }
        // decompose the overlapped total into exposed link + exposed
        // codec so link_s + codec_s == total_s exactly: virtual_total
        // then equals the pipeline schedule and agrees with the clock
        // even when overlap hides part of the codec work
        let link_exposed = (total_s - codec_s).max(0.0);
        self.tracer.add_phase(Cat::Link, link_exposed);
        timing.codec_s += total_s - link_exposed;
        timing.link_s += link_exposed;
        timing.wire_bytes += rep.wire_bytes as u64;
        timing.raw_bytes += rep.raw_bytes as u64;
        self.record_site(site, ci, rep.wire_bytes as u64, rep.raw_bytes as u64);
        self.clock.add_comm(total_s, rep.wire_bytes, rep.raw_bytes);
        // drift sentinel: on sampling passes, replay a bounded prefix of
        // the live pre-quantization partials through the bound
        // compressor and stream the observed relative error
        if self.sentinel.sampling_now() {
            if let Some(c) = self.policy_comps[ci].as_deref() {
                let refs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
                let err = policy::observed_error(&refs, c, self.cfg.d_model);
                self.sentinel.observe(si, err * 100.0);
            }
        }
        let result = out.clone();
        self.reduce_buf = out;
        result
    }

    /// Forward a padded token batch. `mode` selects prefill (S>1, no KV
    /// history) or decode (S=1, `kv` holds history). `pos[b]` is each
    /// row's starting position; logits return as [bb, sb, vocab].
    ///
    /// Dispatches to the rank-thread runtime when a pool is active; the
    /// calibration-capture pass always runs the sequential reference
    /// path (it records pre-quantization partials engine-side).
    fn forward(
        &mut self,
        tokens: &[i32],
        bb: usize,
        sb: usize,
        pos: &[i32],
        kv: Option<&mut BatchKv>,
        decode: bool,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        // every forward is one trace "process": engine-thread spans are
        // stamped with the step id, and the wrapper span frames the
        // whole pass on the coordinator track
        self.next_step += 1;
        obs::set_pid(self.next_step);
        obs::set_tid(obs::TID_COORD);
        // one drift-sampling cadence decision per forward pass; both the
        // sequential and rank-thread cores read `sampling_now()` from it
        self.sentinel.begin_forward();
        let _step = obs::span(if decode { "decode" } else { "prefill" }, Cat::Step);
        if self.pool.is_some() && self.calib_capture.is_none() {
            return self.forward_parallel(tokens, bb, sb, pos, kv, decode);
        }
        self.forward_seq(tokens, bb, sb, pos, kv, decode)
    }

    /// Dispatch one forward to the rank pool and fold the workers'
    /// outcomes into the engine's accounting: stage compute advances the
    /// clock by the max of the per-rank walls (now a measurement across
    /// genuinely concurrent threads), collectives advance it once per
    /// site with codec times maxed across workers, and wire/site/algo
    /// counters are taken from the leader (deterministically identical
    /// on every worker).
    fn forward_parallel(
        &mut self,
        tokens: &[i32],
        bb: usize,
        sb: usize,
        pos: &[i32],
        kv: Option<&mut BatchKv>,
        decode: bool,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        anyhow::ensure!(tokens.len() == bb * sb && pos.len() == bb);
        let wall0 = Instant::now();
        let job = rank::RankJob {
            tokens: tokens.to_vec(),
            pos: pos.to_vec(),
            bb,
            sb,
            decode,
            model: self.opts.model.clone(),
            tp: self.opts.tp,
            profile: self.opts.profile,
            overhead: self.opts.overhead,
            fused: self.opts.fused,
            algo: self.algo_choice,
            pid: self.next_step,
            sentinel_due: self.sentinel.sampling_now(),
        };
        let outcomes = {
            let pool = self.pool.as_ref().expect("forward_parallel without pool");
            pool.forward(job, kv.map(|k| &*k))?
        };
        let mut timing = StepTiming::default();
        for (i, ev) in outcomes[0].trace.iter().enumerate() {
            match ev {
                rank::TraceEvent::Stage { walls } => {
                    let mut m = walls.iter().copied().fold(0.0f64, f64::max);
                    for o in &outcomes[1..] {
                        if let Some(rank::TraceEvent::Stage { walls }) = o.trace.get(i) {
                            m = walls.iter().copied().fold(m, f64::max);
                        }
                    }
                    timing.compute_s += m;
                    self.clock.add_compute(m);
                }
                rank::TraceEvent::Comm {
                    site,
                    scheme_idx,
                    algo,
                    wire_bytes,
                    raw_bytes,
                    codec_s,
                    total_s,
                    err_pct,
                } => {
                    let (mut codec, mut total) = (*codec_s, *total_s);
                    for o in &outcomes[1..] {
                        if let Some(rank::TraceEvent::Comm { codec_s, total_s, .. }) =
                            o.trace.get(i)
                        {
                            codec = codec.max(*codec_s);
                            total = total.max(*total_s);
                        }
                    }
                    // same exposed-link decomposition as the sequential
                    // path: link_s + codec_s == total_s exactly
                    let link_exposed = (total - codec).max(0.0);
                    // modeled wire time enters the link phase gauge once
                    // per site (on the merge, not per worker)
                    self.tracer.add_phase(Cat::Link, link_exposed);
                    timing.codec_s += total - link_exposed;
                    timing.link_s += link_exposed;
                    timing.wire_bytes += *wire_bytes;
                    timing.raw_bytes += *raw_bytes;
                    timing.algo = *algo;
                    *self.algo_calls.entry(*algo).or_insert(0) += 1;
                    self.record_site(*site, *scheme_idx, *wire_bytes, *raw_bytes);
                    self.clock.add_comm(total, *wire_bytes as usize, *raw_bytes as usize);
                    // the leader worker samples observed quantization
                    // error on sentinel passes (NaN = unsampled)
                    if err_pct.is_finite() {
                        self.sentinel.observe(site.index(), *err_pct);
                    }
                }
            }
        }
        for o in &outcomes {
            for &(r, b) in &o.busy {
                self.rank_busy[r].compute_s += b.compute_s;
                self.rank_busy[r].codec_s += b.codec_s;
                self.rank_busy[r].fabric_wait_s += b.fabric_wait_s;
            }
        }
        let logits = outcomes
            .into_iter()
            .next()
            .and_then(|o| o.logits)
            .ok_or_else(|| anyhow::anyhow!("leader rank worker returned no logits"))?;
        timing.wall_s = wall0.elapsed().as_secs_f64();
        Ok((logits, timing))
    }

    /// The sequential reference implementation (`--rank-threads off`):
    /// ranks execute one after another on this thread, exactly the
    /// seed's loop. Kept verbatim as the bit-identical anchor the
    /// parallel runtime is tested against.
    fn forward_seq(
        &mut self,
        tokens: &[i32],
        bb: usize,
        sb: usize,
        pos: &[i32],
        mut kv: Option<&mut BatchKv>,
        decode: bool,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        anyhow::ensure!(tokens.len() == bb * sb && pos.len() == bb);
        let wall0 = Instant::now();
        let mut timing = StepTiming::default();
        let model = self.opts.model.clone();
        let tp = self.opts.tp;
        let d = self.cfg.d_model;
        let phase = if decode { Phase::Decode } else { Phase::Prefill };

        // embed (replicated: every worker computes it; charge one)
        let tok_lit = lit_i32(&[bb, sb], tokens)?;
        let mut dt = 0.0;
        obs::set_tid(0);
        let emb_out = {
            let _g = obs::span("embed", Cat::Compute);
            self.exec_timed(
                &format!("{model}/embed_b{bb}_s{sb}"),
                &[&tok_lit, self.wlit(0, "embed")],
                &mut dt,
            )?
        };
        timing.compute_s += dt;
        self.clock.add_compute(dt);
        self.rank_busy[0].compute_s += dt;
        let mut x = to_vec_f32(&emb_out[0])?;

        let pos_lit = lit_i32(&[bb], pos)?;
        // fused executable names per distinct scheme, resolved lazily
        // once per forward (the site loop below would otherwise pay the
        // format + manifest lookup at every collective)
        let mut fused_memo: BTreeMap<usize, Option<(String, String)>> = BTreeMap::new();
        for l in 0..self.cfg.n_layers {
            // ---- attention ----
            let attn_name = if decode {
                format!("{model}/attn_tp{tp}_b{bb}_s{sb}")
            } else {
                format!("{model}/attn_prefill_tp{tp}_b{bb}_s{sb}")
            };
            let x_lit = lit_f32(&[bb, sb, d], &x)?;
            let mut partials = Vec::with_capacity(tp);
            let mut max_s = 0.0f64;
            for rank in 0..tp {
                obs::set_tid(rank as u32);
                let _rank_span = obs::span_arg("attn", Cat::Compute, l as i64);
                let an = format!("l{l}.attn_norm");
                let wq = format!("l{l}.wq");
                let wk = format!("l{l}.wk");
                let wv = format!("l{l}.wv");
                let wo = format!("l{l}.wo");
                let out = if decode {
                    let kvref = kv.as_deref_mut().expect("decode requires kv");
                    let (kl, vl) = kvref.cache_literals(rank, l)?;
                    let args: Vec<&xla::Literal> = vec![
                        &x_lit,
                        self.wlit(rank, &an),
                        self.wlit(rank, &wq),
                        self.wlit(rank, &wk),
                        self.wlit(rank, &wv),
                        self.wlit(rank, &wo),
                        &kl,
                        &vl,
                        &pos_lit,
                    ];
                    self.exec_timed(&attn_name, &args, &mut dt)?
                } else {
                    let args: Vec<&xla::Literal> = vec![
                        &x_lit,
                        self.wlit(rank, &an),
                        self.wlit(rank, &wq),
                        self.wlit(rank, &wk),
                        self.wlit(rank, &wv),
                        self.wlit(rank, &wo),
                        &pos_lit,
                    ];
                    self.exec_timed(&attn_name, &args, &mut dt)?
                };
                max_s = max_s.max(dt);
                self.rank_busy[rank].compute_s += dt;
                if let Some(kvref) = kv.as_deref_mut() {
                    let ks = to_vec_f32(&out[1])?;
                    let vs = to_vec_f32(&out[2])?;
                    kvref.write_slices(rank, l, sb, pos, &ks, &vs);
                }
                partials.push(out);
            }
            timing.compute_s += max_s;
            self.clock.add_compute(max_s);
            let site = Site { layer: l, kind: SiteKind::AttnOut, phase };
            // fused on-accelerator compression, when exported for this
            // site's scheme + bucket (otherwise the bit-exact host codec)
            let fused = fused_memo
                .entry(self.site_spec[site.index()] as usize)
                .or_insert_with(|| self.fused_names_site(site, bb, sb))
                .clone();
            x = if let Some((q, dq)) = fused {
                let lits: Vec<&xla::Literal> = partials.iter().map(|o| &o[0]).collect();
                self.communicate_fused(&x, &lits, &q, &dq, bb, sb, site, &mut timing)?
            } else {
                let vecs: Vec<Vec<f32>> = partials
                    .iter()
                    .map(|o| to_vec_f32(&o[0]))
                    .collect::<Result<_, _>>()?;
                self.communicate(&x, &vecs, site, &mut timing)
            };

            // ---- MLP ----
            let mlp_name = format!("{model}/mlp_tp{tp}_b{bb}_s{sb}");
            let x_lit = lit_f32(&[bb, sb, d], &x)?;
            let mut partials = Vec::with_capacity(tp);
            let mut max_s = 0.0f64;
            for rank in 0..tp {
                obs::set_tid(rank as u32);
                let _rank_span = obs::span_arg("mlp", Cat::Compute, l as i64);
                let mn = format!("l{l}.mlp_norm");
                let wg = format!("l{l}.w_gate");
                let wu = format!("l{l}.w_up");
                let wd = format!("l{l}.w_down");
                let args: Vec<&xla::Literal> = vec![
                    &x_lit,
                    self.wlit(rank, &mn),
                    self.wlit(rank, &wg),
                    self.wlit(rank, &wu),
                    self.wlit(rank, &wd),
                ];
                let out = self.exec_timed(&mlp_name, &args, &mut dt)?;
                max_s = max_s.max(dt);
                self.rank_busy[rank].compute_s += dt;
                partials.push(out);
            }
            timing.compute_s += max_s;
            self.clock.add_compute(max_s);
            let site = Site { layer: l, kind: SiteKind::MlpOut, phase };
            let fused = fused_memo
                .entry(self.site_spec[site.index()] as usize)
                .or_insert_with(|| self.fused_names_site(site, bb, sb))
                .clone();
            x = if let Some((q, dq)) = fused {
                let lits: Vec<&xla::Literal> = partials.iter().map(|o| &o[0]).collect();
                self.communicate_fused(&x, &lits, &q, &dq, bb, sb, site, &mut timing)?
            } else {
                let vecs: Vec<Vec<f32>> = partials
                    .iter()
                    .map(|o| to_vec_f32(&o[0]))
                    .collect::<Result<_, _>>()?;
                self.communicate(&x, &vecs, site, &mut timing)
            };
        }

        // final norm + logits (leader only)
        let x_lit = lit_f32(&[bb, sb, d], &x)?;
        obs::set_tid(0);
        let out = {
            let _g = obs::span("final", Cat::Compute);
            self.exec_timed(
                &format!("{model}/final_b{bb}_s{sb}"),
                &[&x_lit, self.wlit(0, "final_norm"), self.wlit(0, "lm_head")],
                &mut dt,
            )?
        };
        timing.compute_s += dt;
        self.clock.add_compute(dt);
        self.rank_busy[0].compute_s += dt;
        let logits = to_vec_f32(&out[0])?;
        timing.wall_s = wall0.elapsed().as_secs_f64();
        Ok((logits, timing))
    }

    /// Prefill a padded token batch (logits [bb, sb, vocab]).
    pub fn prefill(
        &mut self,
        tokens: &[i32],
        bb: usize,
        sb: usize,
        pos: &[i32],
        kv: Option<&mut BatchKv>,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        self.forward(tokens, bb, sb, pos, kv, false)
    }

    /// One chunked-prefill slice: run `sb` prompt tokens through the
    /// KV-aware stage so they attend to the `pos[0]` tokens already in
    /// the cache (logits [bb, sb, vocab]). Requires the decode-kind
    /// attention executable at (bb, sb) — gate on
    /// [`TpEngine::has_decode_attn`].
    pub fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        bb: usize,
        sb: usize,
        pos: &[i32],
        kv: &mut BatchKv,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        self.forward(tokens, bb, sb, pos, Some(kv), true)
    }

    /// Is the KV-aware (decode-kind) attention stage exported at bucket
    /// (bb, sb)? Decode itself uses (batch, 1); chunked prefill needs it
    /// at (1, chunk) — artifact sets exported before chunked prefill
    /// lack those, and the coordinator falls back to whole-prompt
    /// prefill.
    pub fn has_decode_attn(&self, bb: usize, sb: usize) -> bool {
        let name = format!("{}/attn_tp{}_b{bb}_s{sb}", self.opts.model, self.opts.tp);
        self.rt.manifest.by_name(&name).is_some()
    }

    /// One decode step for a batch (logits [bb, 1, vocab]).
    pub fn decode(
        &mut self,
        tokens: &[i32],
        pos: &[i32],
        kv: &mut BatchKv,
    ) -> anyhow::Result<(Vec<f32>, StepTiming)> {
        let bb = kv.batch;
        self.forward(tokens, bb, 1, pos, Some(kv), true)
    }

    /// Mean effective wire bits per value across all sites (16 when
    /// uncompressed, fp16 wire). Uniform policies report their scheme's
    /// effective bits exactly, like the seed's global compressor did.
    pub fn effective_bits(&self, n: usize) -> f64 {
        let sites = self.site_spec.len().max(1);
        let total: f64 = self
            .site_spec
            .iter()
            .map(|&ci| {
                self.policy_comps[ci as usize].as_ref().map_or(16.0, |c| c.effective_bits(n))
            })
            .sum();
        total / sites as f64
    }

    /// Display name of the bound compression: the compressor's name for
    /// uniform policies (seed behaviour), the policy summary otherwise.
    pub fn compressor_name(&self) -> String {
        match self.policy.is_uniform() {
            Some("none") => "none".into(),
            Some(_) => self
                .policy_comps
                .iter()
                .flatten()
                .next()
                .map_or_else(|| "none".to_string(), |c| c.name()),
            None => self.policy.summary(),
        }
    }
}

impl Drop for TpEngine {
    /// Clean shutdown of the rank pool: every worker drains its queue,
    /// exits its loop, and is joined before the engine's own runtime
    /// (and its PJRT client) is torn down.
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.shutdown();
        }
    }
}

/// Parse the optional `:<budget_pct>` suffix of `auto`/`auto-live`.
fn parse_budget(spec: &str, prefix: &str) -> anyhow::Result<f64> {
    match spec.strip_prefix(prefix).and_then(|r| r.strip_prefix(':')) {
        None => Ok(policy::DEFAULT_AUTO_BUDGET_PCT),
        Some(v) => {
            let b: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad budget in policy spec {spec:?}"))?;
            anyhow::ensure!(b >= 0.0, "budget must be >= 0, got {b}");
            Ok(b)
        }
    }
}
