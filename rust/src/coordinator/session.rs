//! Generation sessions: one per in-flight request.

use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Prefilling,
    Decoding,
    /// Evicted from the KV pool (blocks swapped out); awaiting restore.
    Preempted,
    Done,
}

#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub prompt_tokens: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: SessionState,
    /// absolute position of the next token to be written (== tokens seen)
    pub pos: usize,
    pub arrived: Instant,
    /// when the batcher admitted this session into a prefill batch
    /// (queue wait = admission − arrival)
    pub prefill_started_at: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// slot in the decode batch group (when Decoding)
    pub slot: Option<usize>,
    /// stop byte (e.g. b'\n' for line-oriented demos); 0 disables
    pub stop_token: i32,
    /// when the previous token was produced (per-step TPOT feed)
    pub last_token_at: Option<Instant>,
    /// prompt tokens already prefilled (chunked prefill progress)
    pub prefilled: usize,
    /// chunked-prefill steps this session has run
    pub prefill_chunks: u64,
    /// times this session was evicted from the KV pool
    pub preemptions: u64,
}

impl Session {
    pub fn new(id: u64, prompt_tokens: Vec<i32>, max_new_tokens: usize) -> Session {
        Session {
            id,
            prompt_tokens,
            generated: Vec::new(),
            max_new_tokens,
            state: SessionState::Queued,
            pos: 0,
            arrived: Instant::now(),
            prefill_started_at: None,
            first_token_at: None,
            finished_at: None,
            slot: None,
            stop_token: -1,
            last_token_at: None,
            prefilled: 0,
            prefill_chunks: 0,
            preemptions: 0,
        }
    }

    /// Mark admission into a prefill batch (the end of the queue wait).
    /// Idempotent: a chunked prompt's later slices and a preempted
    /// session's restore keep the original admission time.
    pub fn record_prefill_start(&mut self) {
        if self.prefill_started_at.is_none() {
            self.prefill_started_at = Some(Instant::now());
        }
        self.state = SessionState::Prefilling;
    }

    /// One chunked-prefill slice of `tokens` prompt tokens completed.
    pub fn record_chunk(&mut self, tokens: usize) {
        self.prefilled = (self.prefilled + tokens).min(self.prompt_tokens.len());
        self.prefill_chunks += 1;
    }

    /// Evicted from the KV pool; the session requeues for restore.
    pub fn record_preemption(&mut self) {
        self.preemptions += 1;
        self.state = SessionState::Preempted;
    }

    pub fn record_first_token(&mut self, tok: i32) {
        let now = Instant::now();
        self.first_token_at = Some(now);
        self.last_token_at = Some(now);
        self.generated.push(tok);
        self.pos = self.prompt_tokens.len();
        self.prefilled = self.prompt_tokens.len();
        self.state = SessionState::Decoding;
        self.maybe_finish(tok);
    }

    /// Record one decoded token; returns the inter-token gap in seconds
    /// (the per-step TPOT sample).
    pub fn record_token(&mut self, tok: i32) -> f64 {
        let now = Instant::now();
        let gap = self.last_token_at.map(|t| (now - t).as_secs_f64()).unwrap_or(0.0);
        self.last_token_at = Some(now);
        self.generated.push(tok);
        self.pos += 1;
        self.maybe_finish(tok);
        gap
    }

    fn maybe_finish(&mut self, tok: i32) {
        if self.generated.len() >= self.max_new_tokens || (self.stop_token >= 0 && tok == self.stop_token)
        {
            self.state = SessionState::Done;
            self.finished_at = Some(Instant::now());
        }
    }

    pub fn is_done(&self) -> bool {
        self.state == SessionState::Done
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// Time spent queued before prefill admission.
    pub fn queue_wait(&self) -> Option<f64> {
        self.prefill_started_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    pub fn e2e(&self) -> Option<f64> {
        self.finished_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// time-per-output-token over the decode phase
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_at, self.finished_at) {
            (Some(f), Some(e)) if self.generated.len() > 1 => {
                Some((e - f).as_secs_f64() / (self.generated.len() - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut s = Session::new(1, vec![1, 2, 3], 2);
        assert_eq!(s.state, SessionState::Queued);
        assert!(s.queue_wait().is_none());
        s.record_prefill_start();
        assert_eq!(s.state, SessionState::Prefilling);
        s.record_first_token(42);
        assert_eq!(s.state, SessionState::Decoding);
        assert_eq!(s.pos, 3);
        assert!(s.ttft().is_some());
        // queue wait ends at admission, so it can't exceed TTFT
        assert!(s.queue_wait().unwrap() <= s.ttft().unwrap());
        s.record_token(43);
        assert!(s.is_done());
        assert_eq!(s.generated, vec![42, 43]);
        assert!(s.e2e().unwrap() >= s.ttft().unwrap());
    }

    #[test]
    fn chunk_and_preemption_counters_accumulate() {
        let mut s = Session::new(1, vec![0; 300], 4);
        s.record_prefill_start();
        let t0 = s.prefill_started_at;
        s.record_chunk(128);
        s.record_chunk(128);
        assert_eq!((s.prefilled, s.prefill_chunks), (256, 2));
        s.record_preemption();
        assert_eq!(s.state, SessionState::Preempted);
        // restore re-enters prefill without moving the admission time
        s.record_prefill_start();
        assert_eq!(s.prefill_started_at, t0);
        s.record_chunk(44);
        assert_eq!(s.prefilled, 300);
        s.record_first_token(9);
        assert_eq!(s.pos, 300);
        let gap = s.record_token(10);
        assert!(gap >= 0.0);
        assert_eq!(s.preemptions, 1);
        assert!(s.ttft().unwrap() <= s.e2e().unwrap_or(f64::MAX));
    }

    #[test]
    fn stop_token_ends_early() {
        let mut s = Session::new(1, vec![1], 100);
        s.stop_token = 10;
        s.record_first_token(5);
        assert!(!s.is_done());
        s.record_token(10);
        assert!(s.is_done());
        assert_eq!(s.generated.len(), 2);
    }
}
