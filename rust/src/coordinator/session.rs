//! Generation sessions: one per in-flight request.

use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Prefilling,
    Decoding,
    Done,
}

#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub prompt_tokens: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new_tokens: usize,
    pub state: SessionState,
    /// absolute position of the next token to be written (== tokens seen)
    pub pos: usize,
    pub arrived: Instant,
    /// when the batcher admitted this session into a prefill batch
    /// (queue wait = admission − arrival)
    pub prefill_started_at: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// slot in the decode batch group (when Decoding)
    pub slot: Option<usize>,
    /// stop byte (e.g. b'\n' for line-oriented demos); 0 disables
    pub stop_token: i32,
}

impl Session {
    pub fn new(id: u64, prompt_tokens: Vec<i32>, max_new_tokens: usize) -> Session {
        Session {
            id,
            prompt_tokens,
            generated: Vec::new(),
            max_new_tokens,
            state: SessionState::Queued,
            pos: 0,
            arrived: Instant::now(),
            prefill_started_at: None,
            first_token_at: None,
            finished_at: None,
            slot: None,
            stop_token: -1,
        }
    }

    /// Mark admission into a prefill batch (the end of the queue wait).
    pub fn record_prefill_start(&mut self) {
        self.prefill_started_at = Some(Instant::now());
        self.state = SessionState::Prefilling;
    }

    pub fn record_first_token(&mut self, tok: i32) {
        self.first_token_at = Some(Instant::now());
        self.generated.push(tok);
        self.pos = self.prompt_tokens.len();
        self.state = SessionState::Decoding;
        self.maybe_finish(tok);
    }

    pub fn record_token(&mut self, tok: i32) {
        self.generated.push(tok);
        self.pos += 1;
        self.maybe_finish(tok);
    }

    fn maybe_finish(&mut self, tok: i32) {
        if self.generated.len() >= self.max_new_tokens || (self.stop_token >= 0 && tok == self.stop_token)
        {
            self.state = SessionState::Done;
            self.finished_at = Some(Instant::now());
        }
    }

    pub fn is_done(&self) -> bool {
        self.state == SessionState::Done
    }

    pub fn ttft(&self) -> Option<f64> {
        self.first_token_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// Time spent queued before prefill admission.
    pub fn queue_wait(&self) -> Option<f64> {
        self.prefill_started_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    pub fn e2e(&self) -> Option<f64> {
        self.finished_at.map(|t| (t - self.arrived).as_secs_f64())
    }

    /// time-per-output-token over the decode phase
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_at, self.finished_at) {
            (Some(f), Some(e)) if self.generated.len() > 1 => {
                Some((e - f).as_secs_f64() / (self.generated.len() - 1) as f64)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut s = Session::new(1, vec![1, 2, 3], 2);
        assert_eq!(s.state, SessionState::Queued);
        assert!(s.queue_wait().is_none());
        s.record_prefill_start();
        assert_eq!(s.state, SessionState::Prefilling);
        s.record_first_token(42);
        assert_eq!(s.state, SessionState::Decoding);
        assert_eq!(s.pos, 3);
        assert!(s.ttft().is_some());
        // queue wait ends at admission, so it can't exceed TTFT
        assert!(s.queue_wait().unwrap() <= s.ttft().unwrap());
        s.record_token(43);
        assert!(s.is_done());
        assert_eq!(s.generated, vec![42, 43]);
        assert!(s.e2e().unwrap() >= s.ttft().unwrap());
    }

    #[test]
    fn stop_token_ends_early() {
        let mut s = Session::new(1, vec![1], 100);
        s.stop_token = 10;
        s.record_first_token(5);
        assert!(!s.is_done());
        s.record_token(10);
        assert!(s.is_done());
        assert_eq!(s.generated.len(), 2);
    }
}
