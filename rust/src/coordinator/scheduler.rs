//! Admission + bucketing policy for the continuous batcher.
//!
//! vLLM-style two-queue design scaled to this engine: a FIFO waiting
//! queue feeds prefill batches (bucketed to the exported static shapes);
//! decoding sessions occupy slots of a fixed decode batch group.

/// Decide the (batch, seq) prefill bucket for a set of prompt lengths,
/// given the exported buckets. Returns None if any prompt exceeds the
/// largest seq bucket (caller truncates or rejects).
pub fn pick_prefill_bucket(
    lens: &[usize],
    batch_buckets: &[usize],
    seq_buckets: &[usize],
) -> Option<(usize, usize)> {
    let maxlen = *lens.iter().max()?;
    let seq = seq_buckets.iter().copied().filter(|&s| s > 1 && s >= maxlen).min()?;
    let batch = batch_buckets.iter().copied().filter(|&b| b >= lens.len()).min()?;
    Some((batch, seq))
}

/// How many queued requests to admit this round: bounded by free decode
/// slots and the largest prefill batch bucket.
pub fn admit_count(queued: usize, free_slots: usize, max_prefill_batch: usize) -> usize {
    queued.min(free_slots).min(max_prefill_batch)
}

/// Cost-model-guided check: is it worth running a partial prefill batch
/// now, or waiting for more arrivals? We run immediately when any
/// request has waited longer than `max_wait_s`, or the batch is full.
/// An empty batch never flushes — even when `max_batch == 0` (no free
/// slots), flushing zero requests is meaningless.
pub fn should_flush(oldest_wait_s: f64, count: usize, max_batch: usize, max_wait_s: f64) -> bool {
    count > 0 && (count >= max_batch || oldest_wait_s >= max_wait_s)
}

// ---- continuous-batching policy (shared by the live coordinator and
// the virtual-time simulator, so Table 7 compares the same scheduler
// it serves with) ----

/// Chunked-prefill slice size for a given admission token budget: the
/// largest exported prefill bucket that fits the budget *and* stays
/// below the top bucket, so a sliced long prompt never drags a cohort
/// into the worst-padded shape. Returns 0 when no bucket qualifies
/// (chunking disabled; prompts prefill whole).
pub fn chunk_tokens(max_batch_tokens: usize, seq_buckets: &[usize]) -> usize {
    let top = seq_buckets.iter().copied().max().unwrap_or(0);
    let fits = |s: &&usize| **s > 1 && **s <= max_batch_tokens;
    seq_buckets
        .iter()
        .filter(fits)
        .filter(|&&s| s < top)
        .max()
        .or_else(|| seq_buckets.iter().filter(fits).max())
        .copied()
        .unwrap_or(0)
}

/// Slice a prompt into per-step prefill bucket sizes: full `chunk`-sized
/// slices, then the smallest exported bucket covering the remainder.
/// A prompt at or under `chunk` gets its single covering bucket. Empty
/// when no legal bucket exists (caller falls back to whole-prompt
/// prefill via [`pick_prefill_bucket`]).
pub fn chunk_plan(prompt_len: usize, chunk: usize, seq_buckets: &[usize]) -> Vec<usize> {
    if chunk == 0 || !seq_buckets.contains(&chunk) {
        return Vec::new();
    }
    let mut plan = Vec::new();
    let mut remaining = prompt_len;
    while remaining > chunk {
        plan.push(chunk);
        remaining -= chunk;
    }
    if remaining > 0 {
        match seq_buckets.iter().copied().filter(|&s| s > 1 && s >= remaining).min() {
            Some(tail) => plan.push(tail),
            None => return Vec::new(),
        }
    }
    plan
}

/// Token-budget admission for in-flight batching: admit the FIFO prefix
/// of the waiting queue whose per-step prefill costs fit in
/// `max_batch_tokens` alongside `used_tokens` already committed this
/// step (one per decoding session plus in-flight chunk work), bounded
/// by free decode slots. Work-conserving: an idle engine always admits
/// the head of the queue, however expensive.
pub fn admit_budget(
    costs: &[usize],
    used_tokens: usize,
    max_batch_tokens: usize,
    free_slots: usize,
) -> usize {
    let mut used = used_tokens;
    let mut n = 0usize;
    for &c in costs.iter().take(free_slots) {
        if used + c > max_batch_tokens && !(n == 0 && used == 0) {
            break;
        }
        used += c;
        n += 1;
    }
    n
}

/// Preemption victim: the **youngest** session by arrival order (the
/// index of the maximum key). Restores run before new admissions and
/// the oldest session is never evicted while a younger one holds
/// blocks, so every preempted session eventually reaches the front and
/// finishes — starvation-free by induction on arrival order.
pub fn pick_victim<T: PartialOrd + Copy>(arrived: &[T]) -> Option<usize> {
    let mut best: Option<(usize, T)> = None;
    for (i, &a) in arrived.iter().enumerate() {
        match best {
            Some((_, b)) if !(a > b) => {}
            _ => best = Some((i, a)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    const BB: &[usize] = &[1, 8];
    const SB: &[usize] = &[1, 16, 64, 128, 256];

    #[test]
    fn bucket_covers_longest_prompt() {
        assert_eq!(pick_prefill_bucket(&[10, 60], BB, SB), Some((8, 64)));
        assert_eq!(pick_prefill_bucket(&[5], BB, SB), Some((1, 16)));
        assert_eq!(pick_prefill_bucket(&[200; 8], BB, SB), Some((8, 256)));
        assert_eq!(pick_prefill_bucket(&[300], BB, SB), None);
    }

    #[test]
    fn never_picks_decode_bucket_for_prefill() {
        // seq bucket 1 is the decode shape; a 1-token prompt still
        // prefills at 16
        assert_eq!(pick_prefill_bucket(&[1], BB, SB), Some((1, 16)));
    }

    #[test]
    fn admit_respects_slots() {
        assert_eq!(admit_count(10, 3, 8), 3);
        assert_eq!(admit_count(2, 8, 8), 2);
        assert_eq!(admit_count(20, 16, 8), 8);
    }

    #[test]
    fn flush_policy() {
        assert!(should_flush(0.0, 8, 8, 0.05));
        assert!(!should_flush(0.01, 3, 8, 0.05));
        assert!(should_flush(0.06, 3, 8, 0.05));
        assert!(!should_flush(10.0, 0, 8, 0.05));
    }

    #[test]
    fn flush_never_fires_on_empty_batch() {
        // count == 0 must never flush, regardless of the other knobs —
        // including the max_batch == 0 corner (no free decode slots),
        // where `count >= max_batch` would otherwise be vacuously true
        assert!(!should_flush(0.0, 0, 0, 0.05));
        assert!(!should_flush(f64::INFINITY, 0, 0, 0.0));
        assert!(!should_flush(10.0, 0, 1, 0.0));
        // and a single waiting request in a zero-slot round still
        // counts as a full batch
        assert!(should_flush(0.0, 1, 0, 10.0));
    }

    #[test]
    fn chunk_size_stays_below_the_top_bucket() {
        assert_eq!(chunk_tokens(2048, SB), 128);
        assert_eq!(chunk_tokens(128, SB), 128);
        assert_eq!(chunk_tokens(100, SB), 64);
        // budget only fits the top bucket -> still usable
        assert_eq!(chunk_tokens(2048, &[1, 256]), 256);
        // no prefill bucket at all -> chunking disabled
        assert_eq!(chunk_tokens(2048, &[1]), 0);
        assert_eq!(chunk_tokens(4, SB), 0);
    }

    #[test]
    fn chunk_plan_covers_the_prompt_with_buckets() {
        assert_eq!(chunk_plan(300, 128, SB), vec![128, 128, 64]);
        assert_eq!(chunk_plan(256, 128, SB), vec![128, 128]);
        // short prompts take one covering bucket
        assert_eq!(chunk_plan(40, 128, SB), vec![64]);
        assert_eq!(chunk_plan(5, 128, SB), vec![16]);
        // every slice is an exported bucket and the plan covers the
        // prompt without a short middle chunk
        for plen in 1..600usize {
            let plan = chunk_plan(plen, 128, SB);
            assert!(!plan.is_empty(), "plan must exist for {plen}");
            assert!(plan.iter().all(|s| SB.contains(s)));
            let total: usize = plan.iter().sum();
            assert!(total >= plen, "{plen}: plan {plan:?} too short");
            assert!(total - plan.last().unwrap() < plen, "{plen}: overlong {plan:?}");
            for s in &plan[..plan.len().saturating_sub(1)] {
                assert_eq!(*s, 128, "non-tail slices are whole chunks");
            }
        }
        // a disabled or non-bucket chunk size yields no plan
        assert!(chunk_plan(300, 0, SB).is_empty());
        assert!(chunk_plan(300, 100, SB).is_empty());
    }

    #[test]
    fn budget_admission_bounds_and_work_conservation() {
        // decode work already uses 6 of 8: only one 2-cost fits
        assert_eq!(admit_budget(&[2, 2, 2], 6, 8, 8), 1);
        // free slots cap admissions regardless of budget
        assert_eq!(admit_budget(&[1, 1, 1, 1], 0, 100, 2), 2);
        assert_eq!(admit_budget(&[1; 4], 0, 100, 0), 0);
        // an idle engine admits even an over-budget head request ...
        assert_eq!(admit_budget(&[500], 0, 128, 8), 1);
        // ... but a busy one does not
        assert_eq!(admit_budget(&[500], 1, 128, 8), 0);
        // FIFO: admission stops at the first over-budget request even
        // when a later one would fit
        assert_eq!(admit_budget(&[100, 10], 50, 128, 8), 0);
    }

    #[test]
    fn victim_is_youngest_and_restores_prevent_starvation() {
        assert_eq!(pick_victim(&[3.0, 9.0, 5.0]), Some(1));
        assert_eq!(pick_victim::<f64>(&[]), None);
        // ties resolve to the first maximum (stable, deterministic)
        assert_eq!(pick_victim(&[7, 7, 2]), Some(0));

        // starvation-freedom: sessions arrive in order; each round the
        // youngest active is evicted and the oldest preempted restores
        // first. The oldest session is never evicted while a younger
        // one is active, so it always finishes first.
        let arrivals: Vec<usize> = (0..6).collect();
        let mut active: Vec<usize> = arrivals.clone();
        let mut preempted: std::collections::VecDeque<usize> = Default::default();
        for _ in 0..100 {
            if let Some(v) = pick_victim(&active.iter().map(|&i| arrivals[i]).collect::<Vec<_>>())
            {
                let evicted = active.remove(v);
                assert_ne!(evicted, 0, "oldest session must never be the victim");
                preempted.push_back(evicted);
            }
            if let Some(r) = preempted.pop_front() {
                active.push(r);
            }
            active.sort_unstable();
        }
        assert!(active.contains(&0));
    }

    #[test]
    fn bucket_none_when_only_decode_bucket_exists() {
        // seq bucket 1 is the decode shape; with nothing else exported
        // there is no legal prefill bucket
        assert_eq!(pick_prefill_bucket(&[1], BB, &[1]), None);
        assert_eq!(pick_prefill_bucket(&[1, 2], BB, &[1]), None);
        // empty prompt set has no bucket either
        assert_eq!(pick_prefill_bucket(&[], BB, SB), None);
        // no batch bucket wide enough
        assert_eq!(pick_prefill_bucket(&[5; 9], BB, SB), None);
    }
}
