//! Admission + bucketing policy for the continuous batcher.
//!
//! vLLM-style two-queue design scaled to this engine: a FIFO waiting
//! queue feeds prefill batches (bucketed to the exported static shapes);
//! decoding sessions occupy slots of a fixed decode batch group.

/// Decide the (batch, seq) prefill bucket for a set of prompt lengths,
/// given the exported buckets. Returns None if any prompt exceeds the
/// largest seq bucket (caller truncates or rejects).
pub fn pick_prefill_bucket(
    lens: &[usize],
    batch_buckets: &[usize],
    seq_buckets: &[usize],
) -> Option<(usize, usize)> {
    let maxlen = *lens.iter().max()?;
    let seq = seq_buckets.iter().copied().filter(|&s| s > 1 && s >= maxlen).min()?;
    let batch = batch_buckets.iter().copied().filter(|&b| b >= lens.len()).min()?;
    Some((batch, seq))
}

/// How many queued requests to admit this round: bounded by free decode
/// slots and the largest prefill batch bucket.
pub fn admit_count(queued: usize, free_slots: usize, max_prefill_batch: usize) -> usize {
    queued.min(free_slots).min(max_prefill_batch)
}

/// Cost-model-guided check: is it worth running a partial prefill batch
/// now, or waiting for more arrivals? We run immediately when any
/// request has waited longer than `max_wait_s`, or the batch is full.
/// An empty batch never flushes — even when `max_batch == 0` (no free
/// slots), flushing zero requests is meaningless.
pub fn should_flush(oldest_wait_s: f64, count: usize, max_batch: usize, max_wait_s: f64) -> bool {
    count > 0 && (count >= max_batch || oldest_wait_s >= max_wait_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    const BB: &[usize] = &[1, 8];
    const SB: &[usize] = &[1, 16, 64, 128, 256];

    #[test]
    fn bucket_covers_longest_prompt() {
        assert_eq!(pick_prefill_bucket(&[10, 60], BB, SB), Some((8, 64)));
        assert_eq!(pick_prefill_bucket(&[5], BB, SB), Some((1, 16)));
        assert_eq!(pick_prefill_bucket(&[200; 8], BB, SB), Some((8, 256)));
        assert_eq!(pick_prefill_bucket(&[300], BB, SB), None);
    }

    #[test]
    fn never_picks_decode_bucket_for_prefill() {
        // seq bucket 1 is the decode shape; a 1-token prompt still
        // prefills at 16
        assert_eq!(pick_prefill_bucket(&[1], BB, SB), Some((1, 16)));
    }

    #[test]
    fn admit_respects_slots() {
        assert_eq!(admit_count(10, 3, 8), 3);
        assert_eq!(admit_count(2, 8, 8), 2);
        assert_eq!(admit_count(20, 16, 8), 8);
    }

    #[test]
    fn flush_policy() {
        assert!(should_flush(0.0, 8, 8, 0.05));
        assert!(!should_flush(0.01, 3, 8, 0.05));
        assert!(should_flush(0.06, 3, 8, 0.05));
        assert!(!should_flush(10.0, 0, 8, 0.05));
    }

    #[test]
    fn flush_never_fires_on_empty_batch() {
        // count == 0 must never flush, regardless of the other knobs —
        // including the max_batch == 0 corner (no free decode slots),
        // where `count >= max_batch` would otherwise be vacuously true
        assert!(!should_flush(0.0, 0, 0, 0.05));
        assert!(!should_flush(f64::INFINITY, 0, 0, 0.0));
        assert!(!should_flush(10.0, 0, 1, 0.0));
        // and a single waiting request in a zero-slot round still
        // counts as a full batch
        assert!(should_flush(0.0, 1, 0, 10.0));
    }

    #[test]
    fn bucket_none_when_only_decode_bucket_exists() {
        // seq bucket 1 is the decode shape; with nothing else exported
        // there is no legal prefill bucket
        assert_eq!(pick_prefill_bucket(&[1], BB, &[1]), None);
        assert_eq!(pick_prefill_bucket(&[1, 2], BB, &[1]), None);
        // empty prompt set has no bucket either
        assert_eq!(pick_prefill_bucket(&[], BB, SB), None);
        // no batch bucket wide enough
        assert_eq!(pick_prefill_bucket(&[5; 9], BB, SB), None);
    }
}
