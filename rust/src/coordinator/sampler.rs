//! Token sampling from final-stage logits.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub enum Sampling {
    Greedy,
    /// softmax temperature + optional top-k truncation
    Temperature { t: f32, top_k: usize },
}

pub struct Sampler {
    rng: Rng,
}

impl Sampler {
    pub fn new(seed: u64) -> Sampler {
        Sampler { rng: Rng::new(seed) }
    }

    pub fn sample(&mut self, logits: &[f32], mode: Sampling) -> i32 {
        match mode {
            Sampling::Greedy => argmax(logits) as i32,
            Sampling::Temperature { t, top_k } => {
                let t = t.max(1e-3);
                let k = if top_k == 0 { logits.len() } else { top_k.min(logits.len()) };
                // top-k indices by logit
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_unstable_by(|&a, &b| {
                    logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal)
                });
                idx.truncate(k);
                let m = logits[idx[0]];
                let weights: Vec<f64> =
                    idx.iter().map(|&i| (((logits[i] - m) / t) as f64).exp()).collect();
                let total: f64 = weights.iter().sum();
                let mut u = self.rng.f64() * total;
                for (j, w) in weights.iter().enumerate() {
                    u -= w;
                    if u <= 0.0 {
                        return idx[j] as i32;
                    }
                }
                idx[k - 1] as i32
            }
        }
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut s = Sampler::new(1);
        let mut l = vec![0.0f32; 10];
        l[6] = 3.0;
        assert_eq!(s.sample(&l, Sampling::Greedy), 6);
    }

    #[test]
    fn temperature_prefers_high_logits() {
        let mut s = Sampler::new(2);
        let mut l = vec![0.0f32; 8];
        l[2] = 6.0;
        let mut hits = 0;
        for _ in 0..200 {
            if s.sample(&l, Sampling::Temperature { t: 1.0, top_k: 0 }) == 2 {
                hits += 1;
            }
        }
        assert!(hits > 180, "{hits}");
    }

    #[test]
    fn top_k_excludes_tail() {
        let mut s = Sampler::new(3);
        let l = vec![5.0f32, 4.0, -10.0, -10.0];
        for _ in 0..100 {
            let t = s.sample(&l, Sampling::Temperature { t: 2.0, top_k: 2 });
            assert!(t == 0 || t == 1);
        }
    }
}
