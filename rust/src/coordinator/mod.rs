//! The serving coordinator: request queue → continuous batcher →
//! TP engine (prefill/decode) → sampled tokens → responses.
//!
//! Mirrors the vLLM router/engine split: [`Coordinator`] owns the
//! engine loop on a dedicated thread (the `xla` client is not `Send`);
//! front ends (HTTP server, trace replayer, examples) submit
//! [`GenRequest`]s over a channel and receive [`GenResponse`]s on a
//! per-request reply channel — or a per-token [`StreamEvent`] feed via
//! [`CoordinatorHandle::submit_stream`]. Under `--rank-threads` the
//! engine itself fans each forward out to its per-rank worker pool; the
//! pool is spawned by the engine builder on this thread and joined when
//! the coordinator's engine drops at loop exit (clean shutdown).
//!
//! Batching is **in-flight** (continuous): new requests join the decode
//! group between steps under a token-budget admission policy
//! ([`scheduler::admit_budget`]); long prompts are sliced into
//! chunked-prefill steps ([`scheduler::chunk_plan`]) that interleave
//! with decode instead of monopolizing a bucket; KV lives in a paged
//! block pool ([`BatchKv::paged`]) and exhausting it preempts the
//! youngest session (blocks swapped out bit-exactly, session requeued
//! with restore priority — [`scheduler::pick_victim`]).

pub mod sampler;
pub mod scheduler;
pub mod session;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::collective::AlgoKind;
use crate::metrics::{Registry, DEFAULT_SAMPLE_PERIOD_S};
use crate::obs::alert::AlertEngine;
use crate::obs::flight::{FlightRecorder, PhaseCost, RequestRecord};
use crate::obs::log::Logger;
use crate::obs::{self, Cat, Tracer};
use crate::util::json;
use crate::tokenizer::ByteTokenizer;
use crate::tp::{BatchKv, StepTiming, SwappedKv, TpEngine};

pub use sampler::{Sampler, Sampling};
pub use session::{Session, SessionState};

/// A generation request, as submitted by a front end.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub greedy: bool,
    /// optional stop byte (-1 = none)
    pub stop_token: i32,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub ttft_s: f64,
    pub e2e_s: f64,
    pub tpot_s: f64,
    /// time queued before prefill admission (NaN if never admitted)
    pub queue_wait_s: f64,
    /// virtual (interconnect-modeled) time spent in this request's
    /// prefill — the Table-3 "TTFT" under the simulated hardware profile
    pub virtual_prefill_s: f64,
}

/// Incremental output of a streaming generation
/// ([`CoordinatorHandle::submit_stream`]): one event per token as it is
/// sampled, then the final response.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    Token {
        /// 0-based index of this token within the generation
        index: usize,
        token: i32,
        /// decoded text of just this token
        text: String,
    },
    Done(GenResponse),
}

pub struct CoordinatorOptions {
    /// decode batch group size (must be an exported batch bucket)
    pub decode_batch: usize,
    /// max seconds a queued request waits before a partial prefill
    /// flush. Governs the *bucketed* baseline (the virtual-time
    /// simulator's default mode); the live continuous batcher admits on
    /// the token budget alone.
    pub max_wait_s: f64,
    /// per-step admission token budget (`--max-batch-tokens`): decoding
    /// sessions count one token each, admitted prompts their (chunked)
    /// prefill cost
    pub max_batch_tokens: usize,
    /// tokens per KV block (`--kv-block`)
    pub kv_block: usize,
    /// total KV pool blocks per rank shard (`--kv-pool`); None sizes the
    /// pool so every decode slot can reach `max_seq` (no preemption)
    pub kv_pool_blocks: Option<usize>,
    pub sampling: Sampling,
    pub seed: u64,
    /// enable the engine's span recorder at startup (`tpcc serve` /
    /// `tpcc trace`); spans are served at `GET /trace`
    pub trace: bool,
    /// metrics time-series sampling cadence (seconds); the background
    /// sampler thread pushes one registry snapshot per period into the
    /// bounded history ring served at `GET /metrics/history`
    pub sample_period_s: f64,
    /// when set, the coordinator automatically rebinds sites the drift
    /// sentinel trips to the never-worse `none` scheme
    pub drift_fallback: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            decode_batch: 8,
            max_wait_s: 0.05,
            max_batch_tokens: 2048,
            kv_block: crate::tp::DEFAULT_KV_BLOCK,
            kv_pool_blocks: None,
            sampling: Sampling::Greedy,
            seed: 0,
            trace: false,
            sample_period_s: DEFAULT_SAMPLE_PERIOD_S,
            drift_fallback: false,
        }
    }
}

/// One submitted request: the request, its reply channel, and (for
/// streaming front ends) the per-token event channel.
pub type Submission = (GenRequest, Sender<GenResponse>, Option<Sender<StreamEvent>>);

/// Fold one engine step's cost into a flight-recorder phase bucket.
fn add_timing(c: &mut PhaseCost, t: &StepTiming) {
    c.compute_s += t.compute_s;
    c.codec_s += t.codec_s;
    c.link_s += t.link_s;
    c.wire_bytes += t.wire_bytes;
}

/// Handle used by front ends to submit work (cheaply cloneable).
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Submission>,
    pub metrics: Arc<Registry>,
    /// JSON snapshot of the engine's bound compression policy (the
    /// per-site scheme table plus the sentinel's `policy_drift`
    /// section), served at `GET /policy`; the coordinator refreshes it
    /// whenever the sentinel's version moves
    pub policy_json: Arc<Mutex<String>>,
    /// the engine's span recorder, shared so front ends can serve
    /// `GET /trace` without a round-trip through the engine thread
    pub tracer: Arc<Tracer>,
    /// per-request flight recorder (slowest-K + recent-K), served at
    /// `GET /debug/requests` and read by `tpcc explain`
    pub flight: Arc<FlightRecorder>,
    /// structured event log (shared with the engine and its rank
    /// workers), served at `GET /logs`
    pub log: Arc<Logger>,
    /// alert-rule engine the sampler thread ticks, served at
    /// `GET /alerts` and as `tpcc_alert_firing` Prometheus gauges
    pub alerts: Arc<AlertEngine>,
    shutdown: Arc<AtomicBool>,
}

impl CoordinatorHandle {
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send((req, rtx, None));
        rrx
    }

    /// Submit a streaming generation: one [`StreamEvent::Token`] per
    /// sampled token as the batcher produces it, then
    /// [`StreamEvent::Done`] with the final response.
    pub fn submit_stream(&self, req: GenRequest) -> Receiver<StreamEvent> {
        let (etx, erx) = channel();
        let (rtx, _) = channel();
        let _ = self.tx.send((req, rtx, Some(etx)));
        erx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// A handle with no engine behind it: `/healthz` and `/metrics`
    /// serve (fresh registry), `/generate` answers 500. Lets front-end
    /// tests exercise the HTTP substrate (connection pool, shedding)
    /// without AOT artifacts.
    pub fn detached() -> CoordinatorHandle {
        Self::stubbed().0
    }

    /// Like [`CoordinatorHandle::detached`], but hands back the
    /// submission receiver so a test can play the engine side (answer
    /// `/generate`, drip stream tokens) without AOT artifacts.
    pub fn stubbed() -> (CoordinatorHandle, Receiver<Submission>) {
        let (tx, rx) = channel();
        let handle = CoordinatorHandle {
            tx,
            metrics: Arc::new(Registry::default()),
            policy_json: Arc::new(Mutex::new("{}".to_string())),
            tracer: Tracer::new(),
            flight: Arc::new(FlightRecorder::default()),
            log: Logger::new(),
            alerts: Arc::new(AlertEngine::new()),
            shutdown: Arc::new(AtomicBool::new(false)),
        };
        (handle, rx)
    }
}

/// The engine loop. Owns the TpEngine; runs until shutdown + drained.
pub struct Coordinator {
    eng: TpEngine,
    opts: CoordinatorOptions,
    metrics: Arc<Registry>,
    rx: Receiver<Submission>,
    shutdown: Arc<AtomicBool>,
    next_id: u64,
    sampler: Sampler,
    tokenizer: ByteTokenizer,
    flight: Arc<FlightRecorder>,
    policy_json: Arc<Mutex<String>>,
    log: Arc<Logger>,
    /// sentinel version the served `/policy` body was rendered at
    drift_version: u64,
}

struct ActiveSlot {
    session: Session,
    reply: Sender<GenResponse>,
    stream: Option<Sender<StreamEvent>>,
    virtual_prefill_s: f64,
    /// this request's prefill batch cost (window attribution: the whole
    /// batch's cost, charged to each request admitted in it)
    prefill_cost: PhaseCost,
    /// decode cost accumulated while this request was resident (each
    /// decode step's cost is charged to every resident request)
    decode_cost: PhaseCost,
    /// engine-wide per-group wire bytes when this request was admitted;
    /// the finish-time delta is the traffic the request coexisted with
    wire_at_admit: [u64; 4],
    /// engine-wide fabric-wait seconds at admission
    fabric_at_admit: f64,
    /// widest decode batch this request was resident in
    batch_peak: usize,
}

impl ActiveSlot {
    fn admit(
        session: Session,
        reply: Sender<GenResponse>,
        stream: Option<Sender<StreamEvent>>,
        eng: &TpEngine,
    ) -> ActiveSlot {
        ActiveSlot {
            session,
            reply,
            stream,
            virtual_prefill_s: 0.0,
            prefill_cost: PhaseCost::default(),
            decode_cost: PhaseCost::default(),
            wire_at_admit: eng.group_wire_bytes(),
            fabric_at_admit: eng.fabric_wait_total(),
            batch_peak: 1,
        }
    }

    fn send_token(&self, tokenizer: &ByteTokenizer, tok: i32) {
        if let Some(tx) = &self.stream {
            let _ = tx.send(StreamEvent::Token {
                index: self.session.generated.len().saturating_sub(1),
                token: tok,
                text: tokenizer.decode(&[tok]),
            });
        }
    }
}

/// A long prompt being prefilled one bucket-sized slice per step.
struct ChunkJob {
    slot: ActiveSlot,
    /// per-slice seq buckets ([`scheduler::chunk_plan`])
    plan: Vec<usize>,
    next: usize,
    /// batch-1 scratch cache the slices write through; adopted into the
    /// decode pool when the last slice lands
    kv: BatchKv,
}

/// A session evicted from the KV pool: its state plus the swapped-out
/// block image, awaiting FIFO restore.
struct PreemptedSession {
    slot: ActiveSlot,
    img: SwappedKv,
}

impl Coordinator {
    /// Build the coordinator plus its submission handle. Call
    /// [`Coordinator::run`] on a thread that owns the engine.
    pub fn new(eng: TpEngine, opts: CoordinatorOptions) -> (Coordinator, CoordinatorHandle) {
        let (tx, rx) = channel();
        let metrics = Arc::new(Registry::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let tracer = eng.tracer().clone();
        if opts.trace {
            tracer.set_enabled(true);
        }
        let flight = Arc::new(FlightRecorder::default());
        flight.set_group_schemes(eng.group_schemes());
        let policy_json = Arc::new(Mutex::new(eng.policy_json().to_string()));
        // the engine's event log becomes the process-wide sink: rank
        // workers already emit into it, the coordinator and HTTP server
        // join, and `GET /logs` serves its ring
        let log = eng.logger().clone();
        let alerts = Arc::new(AlertEngine::new());
        let handle = CoordinatorHandle {
            tx,
            metrics: metrics.clone(),
            policy_json: policy_json.clone(),
            tracer,
            flight: flight.clone(),
            log: log.clone(),
            alerts: alerts.clone(),
            shutdown: shutdown.clone(),
        };
        // background time-series sampler: one registry snapshot per
        // period into the bounded history ring, until shutdown (the run
        // loop raises the flag on its way out, so drained coordinators
        // reap the thread too). The alert engine rides the same tick:
        // rules are windowed over the history the tick just extended.
        {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let log = log.clone();
            let alerts = alerts.clone();
            let period = opts.sample_period_s.clamp(0.01, 60.0);
            let _ = std::thread::Builder::new().name("tpcc-sampler".into()).spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    metrics.sample_history();
                    alerts.tick_at(&metrics, &log, metrics.history.elapsed_s());
                    std::thread::sleep(std::time::Duration::from_secs_f64(period));
                }
            });
        }
        let seed = opts.seed;
        let drift_version = eng.sentinel().version();
        (
            Coordinator {
                eng,
                opts,
                metrics,
                rx,
                shutdown,
                next_id: 1,
                sampler: Sampler::new(seed),
                tokenizer: ByteTokenizer,
                flight,
                policy_json,
                log,
                drift_version,
            },
            handle,
        )
    }

    /// Run the continuous-batching loop until shutdown and drained.
    pub fn run(mut self) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let db = self.opts.decode_batch;
        let tp = self.eng.opts.tp;
        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();

        // paged KV pool: at minimum one max-length sequence must fit, so
        // a lone session can always run to completion
        let block = self.opts.kv_block.clamp(1, cfg.max_seq.max(1));
        let seq_blocks = BatchKv::blocks_per_seq(cfg.max_seq, block);
        let pool = self.opts.kv_pool_blocks.unwrap_or(db * seq_blocks).max(seq_blocks);
        let mut decode_kv = BatchKv::paged(&cfg, tp, db, block, pool)
            .with_gauge(self.metrics.kv_blocks_in_use.clone())
            .with_free_gauge(self.metrics.kv_blocks_free.clone());

        // chunked prefill is live only when the KV-aware attention stage
        // is exported at every chunk-sized bucket (`make artifacts`
        // exports them; older artifact sets fall back to whole-prompt
        // prefill, and the virtual-time simulator models chunking
        // regardless)
        let chunk = scheduler::chunk_tokens(self.opts.max_batch_tokens, &seq_buckets);
        let chunk_live = chunk > 1
            && seq_buckets.iter().all(|&s| s <= 1 || s > chunk || self.eng.has_decode_attn(1, s));
        let top_bucket = *seq_buckets.iter().max().unwrap_or(&256);
        let max_prompt =
            if chunk_live { cfg.max_seq.saturating_sub(1).max(1) } else { top_bucket };

        let mut slots: Vec<Option<ActiveSlot>> = (0..db).map(|_| None).collect();
        let mut waiting: VecDeque<(Session, Sender<GenResponse>, Option<Sender<StreamEvent>>)> =
            VecDeque::new();
        let mut preempted: VecDeque<PreemptedSession> = VecDeque::new();
        let mut chunk_job: Option<ChunkJob> = None;

        loop {
            // ---- intake ----
            loop {
                match self.rx.try_recv() {
                    Ok((req, reply, stream)) => {
                        let mut toks = self.tokenizer.encode(&req.prompt);
                        toks.truncate(max_prompt);
                        if toks.is_empty() {
                            toks.push(0);
                        }
                        let mut s = Session::new(self.next_id, toks, req.max_new_tokens.max(1));
                        s.stop_token = req.stop_token;
                        self.next_id += 1;
                        self.metrics.requests_received.inc();
                        self.log.debug(
                            "coordinator",
                            "request received",
                            vec![
                                ("id", json::num(s.id as f64)),
                                ("prompt_tokens", json::num(s.prompt_tokens.len() as f64)),
                                ("max_new_tokens", json::num(s.max_new_tokens as f64)),
                            ],
                        );
                        waiting.push_back((s, reply, stream));
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if waiting.is_empty()
                            && preempted.is_empty()
                            && chunk_job.is_none()
                            && slots.iter().all(Option::is_none)
                        {
                            // raise the flag so the sampler thread exits
                            self.shutdown.store(true, Ordering::SeqCst);
                            return Ok(());
                        }
                        break;
                    }
                }
            }

            // ---- restore preempted sessions (FIFO, before any new
            // admission: starvation-freedom) ----
            while let Some(front) = preempted.front() {
                let Some(fs) = slots.iter().position(Option::is_none) else { break };
                let need = decode_kv.blocks_for(front.slot.session.pos + 1);
                if decode_kv.free_blocks() < need {
                    break;
                }
                let mut p = preempted.pop_front().expect("front exists");
                anyhow::ensure!(decode_kv.swap_in(fs, &p.img), "restore failed with free blocks");
                p.slot.session.state = SessionState::Decoding;
                p.slot.session.slot = Some(fs);
                slots[fs] = Some(p.slot);
            }

            // ---- start a chunk job when the queue head is long ----
            if chunk_live && chunk_job.is_none() {
                let head_long =
                    waiting.front().is_some_and(|(s, _, _)| s.prompt_tokens.len() > chunk);
                if head_long {
                    let (mut s, reply, stream) = waiting.pop_front().expect("head exists");
                    let plan = scheduler::chunk_plan(s.prompt_tokens.len(), chunk, &seq_buckets);
                    anyhow::ensure!(!plan.is_empty(), "no chunk plan for admitted prompt");
                    self.admit_metrics(&mut s);
                    chunk_job = Some(ChunkJob {
                        slot: ActiveSlot::admit(s, reply, stream, &self.eng),
                        plan,
                        next: 0,
                        kv: BatchKv::new(&cfg, tp, 1),
                    });
                }
            }

            // ---- token-budget admission of short prompts ----
            let free: Vec<usize> = (0..db).filter(|&i| slots[i].is_none()).collect();
            let decoding = db - free.len();
            let committed = decoding
                + chunk_job
                    .as_ref()
                    .map_or(0, |j| j.plan.get(j.next).copied().unwrap_or(0));
            let mut costs = Vec::new();
            for (s, _, _) in waiting.iter() {
                let len = s.prompt_tokens.len();
                if chunk_live && len > chunk {
                    break; // strict FIFO: a long prompt waits for the chunk lane
                }
                costs.push(len);
            }
            let mut n_admit = scheduler::admit_budget(
                &costs,
                committed,
                self.opts.max_batch_tokens,
                free.len(),
            );
            // shrink until the admitted prompts' KV blocks fit the pool
            // (admission under zero free blocks admits nothing; blocks
            // free up as sessions finish or the pool preempts)
            while n_admit > 0 {
                let need: usize = waiting
                    .iter()
                    .take(n_admit)
                    .map(|(s, _, _)| decode_kv.blocks_for(s.prompt_tokens.len() + 1))
                    .sum();
                if need <= decode_kv.free_blocks() {
                    break;
                }
                n_admit -= 1;
            }
            if n_admit > 0 {
                let admitted: Vec<_> = waiting.drain(..n_admit).collect();
                self.prefill_admit(admitted, &free, &mut slots, &mut decode_kv)?;
            }

            // ---- one chunked-prefill slice, interleaved with decode ----
            if let Some(mut job) = chunk_job.take() {
                let finished = self.chunk_step(&mut job)?;
                if finished {
                    self.chunk_finish(job, &mut slots, &mut decode_kv, &mut preempted)?;
                } else {
                    chunk_job = Some(job);
                }
            }

            // ---- decode step over active slots ----
            // every active row needs a block mapped for this step's KV
            // write; when the pool is dry, evict the youngest session
            for i in 0..db {
                loop {
                    let Some(slot) = slots[i].as_ref() else { break };
                    if decode_kv.ensure_tokens(i, slot.session.pos + 1) {
                        break;
                    }
                    let vi = Self::youngest_active(&slots).expect("an active slot exists");
                    self.preempt(vi, &mut slots, &mut decode_kv, &mut preempted);
                    if vi == i {
                        break; // evicted itself; row sits out this step
                    }
                }
            }

            let active: Vec<usize> = (0..db).filter(|&i| slots[i].is_some()).collect();
            if active.is_empty() {
                if self.shutdown.load(Ordering::SeqCst)
                    && waiting.is_empty()
                    && preempted.is_empty()
                    && chunk_job.is_none()
                {
                    return Ok(());
                }
                if waiting.is_empty() && preempted.is_empty() && chunk_job.is_none() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                continue;
            }

            let mut tokens = vec![0i32; db];
            let mut pos = vec![0i32; db];
            for &i in &active {
                let slot = slots[i].as_ref().unwrap();
                tokens[i] = *slot.session.generated.last().unwrap();
                pos[i] = slot.session.pos as i32;
            }
            let (logits, timing) = self.eng.decode(&tokens, &pos, &mut decode_kv)?;
            self.metrics.batches_executed.inc();
            self.record_comm(&timing);
            // window attribution: this step's cost is charged to every
            // resident request (they shared the batch)
            for &i in &active {
                let slot = slots[i].as_mut().unwrap();
                add_timing(&mut slot.decode_cost, &timing);
                slot.batch_peak = slot.batch_peak.max(active.len());
            }
            let v = cfg.vocab;
            for &i in &active {
                let slot = slots[i].as_mut().unwrap();
                let row = &logits[i * v..(i + 1) * v];
                let tok = self.sampler.sample(row, self.sampling_for());
                let gap = slot.session.record_token(tok);
                // per-step inter-token latency feeds the TPOT histogram
                self.metrics.tpot.record(gap);
                self.metrics.tokens_generated.inc();
                slot.send_token(&self.tokenizer, tok);
                if slot.session.is_done() || slot.session.pos + 1 >= cfg.max_seq {
                    let done = slots[i].take().unwrap();
                    decode_kv.clear_slot(i);
                    self.finish(done);
                }
            }
        }
    }

    fn sampling_for(&self) -> Sampling {
        self.opts.sampling
    }

    /// Index of the youngest (latest-arrived) active session.
    fn youngest_active(slots: &[Option<ActiveSlot>]) -> Option<usize> {
        let act: Vec<(usize, Instant)> = slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.session.arrived)))
            .collect();
        let keys: Vec<Instant> = act.iter().map(|&(_, a)| a).collect();
        scheduler::pick_victim(&keys).map(|k| act[k].0)
    }

    /// Evict slot `vi` from the decode group: swap its KV blocks out to
    /// host memory and requeue the session for a bit-identical restore.
    fn preempt(
        &mut self,
        vi: usize,
        slots: &mut [Option<ActiveSlot>],
        decode_kv: &mut BatchKv,
        preempted: &mut VecDeque<PreemptedSession>,
    ) {
        let mut slot = slots[vi].take().expect("victim slot active");
        let img = decode_kv.swap_out(vi, slot.session.pos);
        slot.session.record_preemption();
        slot.session.slot = None;
        self.metrics.preemptions_total.inc();
        self.log.info(
            "coordinator",
            "session preempted",
            vec![
                ("id", json::num(slot.session.id as f64)),
                ("slot", json::num(vi as f64)),
                ("pos", json::num(slot.session.pos as f64)),
                ("preemptions", json::num(slot.session.preemptions as f64)),
                ("kv_blocks_free", json::num(decode_kv.free_blocks() as f64)),
            ],
        );
        preempted.push_back(PreemptedSession { slot, img });
    }

    /// Queue-wait accounting at first admission (chunked or classic).
    fn admit_metrics(&self, s: &mut Session) {
        s.record_prefill_start();
        if let Some(w) = s.queue_wait() {
            self.metrics.queue_wait.record(w);
            // queue-wait span on the request's own timeline (pid =
            // request id), stamped retroactively from arrival
            obs::record_abs("queue", Cat::Queue, s.id, obs::TID_COORD, s.arrived, w);
            self.log.debug(
                "coordinator",
                "request admitted",
                vec![
                    ("id", json::num(s.id as f64)),
                    ("queue_wait_s", json::num(w)),
                    ("prompt_tokens", json::num(s.prompt_tokens.len() as f64)),
                ],
            );
        }
    }

    /// Run one prefill slice of a chunk job. Returns true when the last
    /// slice (and the first token) landed.
    fn chunk_step(&mut self, job: &mut ChunkJob) -> anyhow::Result<bool> {
        let cfg = self.eng.cfg.clone();
        let sb = job.plan[job.next];
        let done = job.slot.session.prefilled;
        let plen = job.slot.session.prompt_tokens.len();
        let take = sb.min(plen - done);
        let mut tokens = vec![0i32; sb];
        tokens[..take].copy_from_slice(&job.slot.session.prompt_tokens[done..done + take]);
        let (logits, timing) = if job.next == 0 {
            // first slice has no history: the regular prefill stage
            self.eng.prefill(&tokens, 1, sb, &[0], Some(&mut job.kv))?
        } else {
            // later slices attend to the scratch cache via the KV-aware
            // stage at (1, sb)
            self.eng.prefill_chunk(&tokens, 1, sb, &[done as i32], &mut job.kv)?
        };
        self.metrics.batches_executed.inc();
        self.record_comm(&timing);
        add_timing(&mut job.slot.prefill_cost, &timing);
        job.slot.virtual_prefill_s += timing.virtual_total();
        job.slot.session.record_chunk(take);
        self.log.debug(
            "coordinator",
            "prefill chunk slice",
            vec![
                ("id", json::num(job.slot.session.id as f64)),
                ("slice", json::num(job.next as f64)),
                ("slices", json::num(job.plan.len() as f64)),
                ("tokens", json::num(take as f64)),
            ],
        );
        job.next += 1;
        if job.next < job.plan.len() {
            return Ok(false);
        }
        // last slice: sample the first token at the prompt's final row
        self.metrics.prefill_tokens.add(plen as u64);
        let v = cfg.vocab;
        let row = &logits[(take - 1) * v..take * v];
        let tok = self.sampler.sample(row, self.sampling_for());
        job.slot.session.record_first_token(tok);
        self.metrics.tokens_generated.inc();
        if let Some(ttft) = job.slot.session.ttft() {
            self.metrics.ttft.record(ttft);
        }
        job.slot.send_token(&self.tokenizer, tok);
        Ok(true)
    }

    /// Move a finished chunk job into the decode group, preempting the
    /// youngest resident sessions if the pool or slots are full.
    fn chunk_finish(
        &mut self,
        job: ChunkJob,
        slots: &mut [Option<ActiveSlot>],
        decode_kv: &mut BatchKv,
        preempted: &mut VecDeque<PreemptedSession>,
    ) -> anyhow::Result<()> {
        let ChunkJob { mut slot, kv, .. } = job;
        if slot.session.is_done() {
            self.finish(slot);
            return Ok(());
        }
        let plen = slot.session.prompt_tokens.len();
        loop {
            let fs = slots.iter().position(Option::is_none);
            if let Some(fs) = fs {
                if decode_kv.free_blocks() >= decode_kv.blocks_for(plen) {
                    decode_kv.adopt_slot(fs, &kv, 0, plen)?;
                    slot.session.slot = Some(fs);
                    slots[fs] = Some(slot);
                    return Ok(());
                }
            }
            let Some(vi) = Self::youngest_active(slots) else {
                anyhow::bail!("kv pool too small for a {plen}-token prompt");
            };
            self.preempt(vi, slots, decode_kv, preempted);
        }
    }

    fn prefill_admit(
        &mut self,
        mut admitted: Vec<(Session, Sender<GenResponse>, Option<Sender<StreamEvent>>)>,
        free: &[usize],
        slots: &mut [Option<ActiveSlot>],
        decode_kv: &mut BatchKv,
    ) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let lens: Vec<usize> = admitted.iter().map(|(s, _, _)| s.prompt_tokens.len()).collect();
        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();
        let batch_buckets = self.eng.rt.manifest.batch_buckets.clone();
        let (bb, sb) = scheduler::pick_prefill_bucket(&lens, &batch_buckets, &seq_buckets)
            .ok_or_else(|| anyhow::anyhow!("prompt exceeds largest bucket"))?;

        // queue wait ends here: admission into the prefill batch, before
        // the batch executes
        for (s, _, _) in admitted.iter_mut() {
            self.admit_metrics(s);
        }

        let mut tokens = vec![0i32; bb * sb];
        for (row, (s, _, _)) in admitted.iter().enumerate() {
            tokens[row * sb..row * sb + s.prompt_tokens.len()]
                .copy_from_slice(&s.prompt_tokens);
        }
        // flight-recorder baselines: per-group wire and fabric wait
        // before this batch's prefill, so finish-time deltas include it
        let wire_at_admit = self.eng.group_wire_bytes();
        let fabric_at_admit = self.eng.fabric_wait_total();
        let mut kv = BatchKv::new(&cfg, self.eng.opts.tp, bb);
        let (logits, timing) =
            self.eng.prefill(&tokens, bb, sb, &vec![0; bb], Some(&mut kv))?;
        self.record_comm(&timing);
        self.metrics.batches_executed.inc();
        let mut prefill_cost = PhaseCost::default();
        add_timing(&mut prefill_cost, &timing);

        let v = cfg.vocab;
        for (row, (mut session, reply, stream)) in admitted.into_iter().enumerate() {
            let len = session.prompt_tokens.len();
            self.metrics.prefill_tokens.add(len as u64);
            let row_logits = &logits[(row * sb + len - 1) * v..(row * sb + len) * v];
            let tok = self.sampler.sample(row_logits, self.sampling_for());
            session.record_first_token(tok);
            self.metrics.tokens_generated.inc();
            if let Some(ttft) = session.ttft() {
                self.metrics.ttft.record(ttft);
            }
            let slot_idx = free[row];
            decode_kv.adopt_slot(slot_idx, &kv, row, len)?;
            session.slot = Some(slot_idx);
            let active = ActiveSlot {
                session,
                reply,
                stream,
                virtual_prefill_s: timing.virtual_total(),
                prefill_cost,
                decode_cost: PhaseCost::default(),
                wire_at_admit,
                fabric_at_admit,
                batch_peak: bb,
            };
            active.send_token(&self.tokenizer, tok);
            if active.session.is_done() {
                // done at first token: release the slot it was adopted
                // into (keeps the kv_blocks_in_use gauge honest)
                decode_kv.clear_slot(slot_idx);
                self.finish(active);
            } else {
                slots[slot_idx] = Some(active);
            }
        }
        Ok(())
    }

    fn record_comm(&mut self, t: &StepTiming) {
        self.metrics.comm_bytes_sent.add(t.wire_bytes);
        self.metrics.comm_bytes_saved.add(t.raw_bytes.saturating_sub(t.wire_bytes));
        // drift sentinel: optionally rebind tripped sites to `none`,
        // then mirror the drift counters and refresh the served /policy
        // body whenever the sentinel state moved
        if self.opts.drift_fallback && !self.eng.sentinel().tripped().is_empty() {
            match self.eng.apply_drift_fallback() {
                Ok(sites) => {
                    let labels: Vec<String> = sites.iter().map(|s| s.label()).collect();
                    self.log.warn(
                        "coordinator",
                        "drift fallback: tripped sites rebound to none",
                        vec![(
                            "sites",
                            json::Json::Arr(labels.iter().map(|l| json::s(l)).collect()),
                        )],
                    );
                    self.flight.set_group_schemes(self.eng.group_schemes());
                }
                Err(e) => self.log.error(
                    "coordinator",
                    "drift fallback failed",
                    vec![("err", json::s(&format!("{e:#}")))],
                ),
            }
        }
        for (key, v) in self.eng.sentinel_metrics() {
            self.metrics.set(key, v);
        }
        let drift_v = self.eng.sentinel().version();
        if drift_v != self.drift_version {
            self.drift_version = drift_v;
            *self.policy_json.lock().unwrap() = self.eng.policy_json().to_string();
        }
        // per-site-group policy counters (engine-side rollups mirrored
        // into the registry so `/metrics` exposes where the bytes go)
        for (key, v) in self.eng.policy_metrics() {
            self.metrics.set(&key, v);
        }
        // per-rank compute/codec/fabric-wait utilization gauges (real
        // concurrent busy time under the rank-thread runtime)
        for (key, v) in self.eng.rank_metrics() {
            self.metrics.set(&key, v);
        }
        // per-phase trace gauges (compute / codec / fabric wait / link)
        for (key, v) in self.eng.trace_metrics() {
            self.metrics.set(&key, v);
        }
        // per-algorithm collective counter (engine-side total mirrored
        // into the registry so `/metrics` exposes the planner's choices);
        // only the algorithm this step ran can have moved
        let Some(kind) = AlgoKind::parse(t.algo) else {
            return; // no collective ran this step
        };
        if let Some(calls) = self.eng.algo_calls.get(t.algo) {
            self.metrics.set(kind.metric_key(), *calls as f64);
        }
    }

    fn finish(&self, slot: ActiveSlot) {
        let s = &slot.session;
        let resp = GenResponse {
            id: s.id,
            text: self.tokenizer.decode(&s.generated),
            prompt_tokens: s.prompt_tokens.len(),
            new_tokens: s.generated.len(),
            ttft_s: s.ttft().unwrap_or(f64::NAN),
            e2e_s: s.e2e().unwrap_or(f64::NAN),
            tpot_s: s.tpot().unwrap_or(f64::NAN),
            queue_wait_s: s.queue_wait().unwrap_or(f64::NAN),
            virtual_prefill_s: slot.virtual_prefill_s,
        };
        self.metrics.requests_completed.inc();
        if let Some(e2e) = s.e2e() {
            self.metrics.e2e_latency.record(e2e);
            // whole-request span (arrival → last token) on pid = req id
            obs::record_abs("request", Cat::Request, s.id, obs::TID_COORD, s.arrived, e2e);
        }
        // flight recorder: structured per-request record (slowest-K +
        // recent-K retention), attribution source for `tpcc explain`
        let wire_now = self.eng.group_wire_bytes();
        let mut site_wire_bytes = [0u64; 4];
        for (g, w) in site_wire_bytes.iter_mut().enumerate() {
            *w = wire_now[g].saturating_sub(slot.wire_at_admit[g]);
        }
        self.flight.record(RequestRecord {
            id: s.id,
            prompt_tokens: s.prompt_tokens.len(),
            new_tokens: s.generated.len(),
            batch_peak: slot.batch_peak,
            queue_wait_s: resp.queue_wait_s,
            ttft_s: resp.ttft_s,
            e2e_s: resp.e2e_s,
            tpot_s: resp.tpot_s,
            prefill: slot.prefill_cost,
            decode: slot.decode_cost,
            fabric_wait_s: (self.eng.fabric_wait_total() - slot.fabric_at_admit).max(0.0),
            site_wire_bytes,
            preemptions: s.preemptions,
            prefill_chunks: s.prefill_chunks,
        });
        self.log.debug(
            "coordinator",
            "request finished",
            vec![
                ("id", json::num(s.id as f64)),
                ("new_tokens", json::num(s.generated.len() as f64)),
                ("ttft_s", json::num_or_null(resp.ttft_s)),
                ("e2e_s", json::num_or_null(resp.e2e_s)),
                ("preemptions", json::num(s.preemptions as f64)),
            ],
        );
        if let Some(tx) = &slot.stream {
            let _ = tx.send(StreamEvent::Done(resp.clone()));
        }
        let _ = slot.reply.send(resp);
    }
}

/// Spawn the coordinator on its own engine thread. The engine (and its
/// non-Send XLA client) must be *constructed* on that thread, so the
/// caller passes a builder closure.
pub fn spawn<F>(build: F, opts: CoordinatorOptions) -> anyhow::Result<(CoordinatorHandle, std::thread::JoinHandle<anyhow::Result<()>>)>
where
    F: FnOnce() -> anyhow::Result<TpEngine> + Send + 'static,
{
    let (htx, hrx) = channel();
    let join = std::thread::Builder::new()
        .name("tpcc-engine".into())
        .spawn(move || -> anyhow::Result<()> {
            let eng = build()?;
            let (coord, handle) = Coordinator::new(eng, opts);
            htx.send(handle).map_err(|_| anyhow::anyhow!("handle channel closed"))?;
            coord.run()
        })?;
    let handle = hrx
        .recv()
        .map_err(|_| anyhow::anyhow!("engine thread failed during startup"))?;
    Ok((handle, join))
}
