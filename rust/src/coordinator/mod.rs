//! The serving coordinator: request queue → continuous batcher →
//! TP engine (prefill/decode) → sampled tokens → responses.
//!
//! Mirrors the vLLM router/engine split: [`Coordinator`] owns the
//! engine loop on a dedicated thread (the `xla` client is not `Send`);
//! front ends (HTTP server, trace replayer, examples) submit
//! [`GenRequest`]s over a channel and receive [`GenResponse`]s on a
//! per-request reply channel. Under `--rank-threads` the engine itself
//! fans each forward out to its per-rank worker pool; the pool is
//! spawned by the engine builder on this thread and joined when the
//! coordinator's engine drops at loop exit (clean shutdown).

pub mod sampler;
pub mod scheduler;
pub mod session;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;

use crate::collective::AlgoKind;
use crate::metrics::Registry;
use crate::obs::{self, Cat, Tracer};
use crate::tokenizer::ByteTokenizer;
use crate::tp::{BatchKv, StepTiming, TpEngine};

pub use sampler::{Sampler, Sampling};
pub use session::{Session, SessionState};

/// A generation request, as submitted by a front end.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub greedy: bool,
    /// optional stop byte (-1 = none)
    pub stop_token: i32,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub ttft_s: f64,
    pub e2e_s: f64,
    pub tpot_s: f64,
    /// time queued before prefill admission (NaN if never admitted)
    pub queue_wait_s: f64,
    /// virtual (interconnect-modeled) time spent in this request's
    /// prefill — the Table-3 "TTFT" under the simulated hardware profile
    pub virtual_prefill_s: f64,
}

pub struct CoordinatorOptions {
    /// decode batch group size (must be an exported batch bucket)
    pub decode_batch: usize,
    /// max seconds a queued request waits before a partial prefill flush
    pub max_wait_s: f64,
    pub sampling: Sampling,
    pub seed: u64,
    /// enable the engine's span recorder at startup (`tpcc serve` /
    /// `tpcc trace`); spans are served at `GET /trace`
    pub trace: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            decode_batch: 8,
            max_wait_s: 0.05,
            sampling: Sampling::Greedy,
            seed: 0,
            trace: false,
        }
    }
}

type Submission = (GenRequest, Sender<GenResponse>);

/// Handle used by front ends to submit work (cheaply cloneable).
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Submission>,
    pub metrics: Arc<Registry>,
    /// JSON snapshot of the engine's bound compression policy (the
    /// per-site scheme table), served at `GET /policy`
    pub policy_json: Arc<String>,
    /// the engine's span recorder, shared so front ends can serve
    /// `GET /trace` without a round-trip through the engine thread
    pub tracer: Arc<Tracer>,
    shutdown: Arc<AtomicBool>,
}

impl CoordinatorHandle {
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send((req, rtx));
        rrx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// A handle with no engine behind it: `/healthz` and `/metrics`
    /// serve (fresh registry), `/generate` answers 500. Lets front-end
    /// tests exercise the HTTP substrate (connection pool, shedding)
    /// without AOT artifacts.
    pub fn detached() -> CoordinatorHandle {
        let (tx, _) = channel();
        CoordinatorHandle {
            tx,
            metrics: Arc::new(Registry::default()),
            policy_json: Arc::new("{}".to_string()),
            tracer: Tracer::new(),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// The engine loop. Owns the TpEngine; runs until shutdown + drained.
pub struct Coordinator {
    eng: TpEngine,
    opts: CoordinatorOptions,
    metrics: Arc<Registry>,
    rx: Receiver<Submission>,
    shutdown: Arc<AtomicBool>,
    next_id: u64,
    sampler: Sampler,
    tokenizer: ByteTokenizer,
}

struct ActiveSlot {
    session: Session,
    reply: Sender<GenResponse>,
    virtual_prefill_s: f64,
}

impl Coordinator {
    /// Build the coordinator plus its submission handle. Call
    /// [`Coordinator::run`] on a thread that owns the engine.
    pub fn new(eng: TpEngine, opts: CoordinatorOptions) -> (Coordinator, CoordinatorHandle) {
        let (tx, rx) = channel();
        let metrics = Arc::new(Registry::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let tracer = eng.tracer().clone();
        if opts.trace {
            tracer.set_enabled(true);
        }
        let handle = CoordinatorHandle {
            tx,
            metrics: metrics.clone(),
            policy_json: Arc::new(eng.policy_json().to_string()),
            tracer,
            shutdown: shutdown.clone(),
        };
        let seed = opts.seed;
        (
            Coordinator {
                eng,
                opts,
                metrics,
                rx,
                shutdown,
                next_id: 1,
                sampler: Sampler::new(seed),
                tokenizer: ByteTokenizer,
            },
            handle,
        )
    }

    /// Run the continuous-batching loop until shutdown and drained.
    pub fn run(mut self) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let db = self.opts.decode_batch;
        let tp = self.eng.opts.tp;
        let mut decode_kv = BatchKv::new(&cfg, tp, db);
        let mut slots: Vec<Option<ActiveSlot>> = (0..db).map(|_| None).collect();
        let mut waiting: Vec<(Session, Sender<GenResponse>)> = Vec::new();

        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();
        let batch_buckets = self.eng.rt.manifest.batch_buckets.clone();
        let max_prompt = *seq_buckets.iter().max().unwrap_or(&256);

        loop {
            // ---- intake ----
            loop {
                match self.rx.try_recv() {
                    Ok((req, reply)) => {
                        let mut toks = self.tokenizer.encode(&req.prompt);
                        toks.truncate(max_prompt);
                        if toks.is_empty() {
                            toks.push(0);
                        }
                        let mut s = Session::new(self.next_id, toks, req.max_new_tokens.max(1));
                        s.stop_token = req.stop_token;
                        self.next_id += 1;
                        self.metrics.requests_received.inc();
                        waiting.push((s, reply));
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if waiting.is_empty() && slots.iter().all(Option::is_none) {
                            return Ok(());
                        }
                        break;
                    }
                }
            }

            let free: Vec<usize> =
                (0..db).filter(|&i| slots[i].is_none()).collect();
            let oldest_wait = waiting
                .first()
                .map(|(s, _)| s.arrived.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            let n_admit = scheduler::admit_count(
                waiting.len(),
                free.len(),
                *batch_buckets.iter().max().unwrap_or(&8),
            );

            // ---- prefill a batch of admitted requests ----
            if scheduler::should_flush(oldest_wait, n_admit, free.len().min(8), self.opts.max_wait_s)
                && n_admit > 0
            {
                let admitted: Vec<(Session, Sender<GenResponse>)> =
                    waiting.drain(..n_admit).collect();
                self.prefill_admit(admitted, &free, &mut slots, &mut decode_kv)?;
            }

            // ---- decode step over active slots ----
            let active: Vec<usize> = (0..db).filter(|&i| slots[i].is_some()).collect();
            if active.is_empty() {
                if self.shutdown.load(Ordering::SeqCst) && waiting.is_empty() {
                    return Ok(());
                }
                if waiting.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                continue;
            }

            let mut tokens = vec![0i32; db];
            let mut pos = vec![0i32; db];
            for &i in &active {
                let slot = slots[i].as_ref().unwrap();
                tokens[i] = *slot.session.generated.last().unwrap();
                pos[i] = slot.session.pos as i32;
            }
            let (logits, timing) = self.eng.decode(&tokens, &pos, &mut decode_kv)?;
            self.metrics.batches_executed.inc();
            self.record_comm(&timing);
            let v = cfg.vocab;
            for &i in &active {
                let slot = slots[i].as_mut().unwrap();
                let row = &logits[i * v..(i + 1) * v];
                let tok = self.sampler.sample(row, self.sampling_for());
                slot.session.record_token(tok);
                self.metrics.tokens_generated.inc();
                if slot.session.is_done() || slot.session.pos + 1 >= cfg.max_seq {
                    let done = slots[i].take().unwrap();
                    decode_kv.clear_slot(i);
                    self.finish(done);
                }
            }
        }
    }

    fn sampling_for(&self) -> Sampling {
        self.opts.sampling
    }

    fn prefill_admit(
        &mut self,
        mut admitted: Vec<(Session, Sender<GenResponse>)>,
        free: &[usize],
        slots: &mut [Option<ActiveSlot>],
        decode_kv: &mut BatchKv,
    ) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let lens: Vec<usize> = admitted.iter().map(|(s, _)| s.prompt_tokens.len()).collect();
        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();
        let batch_buckets = self.eng.rt.manifest.batch_buckets.clone();
        let (bb, sb) = scheduler::pick_prefill_bucket(&lens, &batch_buckets, &seq_buckets)
            .ok_or_else(|| anyhow::anyhow!("prompt exceeds largest bucket"))?;

        // queue wait ends here: admission into the prefill batch, before
        // the batch executes
        for (s, _) in admitted.iter_mut() {
            s.record_prefill_start();
            if let Some(w) = s.queue_wait() {
                self.metrics.queue_wait.record(w);
                // queue-wait span on the request's own timeline (pid =
                // request id), stamped retroactively from arrival
                obs::record_abs("queue", Cat::Queue, s.id, obs::TID_COORD, s.arrived, w);
            }
        }

        let mut tokens = vec![0i32; bb * sb];
        for (row, (s, _)) in admitted.iter().enumerate() {
            tokens[row * sb..row * sb + s.prompt_tokens.len()]
                .copy_from_slice(&s.prompt_tokens);
        }
        let mut kv = BatchKv::new(&cfg, self.eng.opts.tp, bb);
        let (logits, timing) =
            self.eng.prefill(&tokens, bb, sb, &vec![0; bb], Some(&mut kv))?;
        self.record_comm(&timing);
        self.metrics.batches_executed.inc();

        let v = cfg.vocab;
        for (row, (mut session, reply)) in admitted.into_iter().enumerate() {
            let len = session.prompt_tokens.len();
            self.metrics.prefill_tokens.add(len as u64);
            let row_logits = &logits[(row * sb + len - 1) * v..(row * sb + len) * v];
            let tok = self.sampler.sample(row_logits, self.sampling_for());
            session.record_first_token(tok);
            self.metrics.tokens_generated.inc();
            if let Some(ttft) = session.ttft() {
                self.metrics.ttft.record(ttft);
            }
            let slot_idx = free[row];
            decode_kv.adopt_slot(slot_idx, &kv, row, len);
            session.slot = Some(slot_idx);
            let active = ActiveSlot {
                session,
                reply,
                virtual_prefill_s: timing.virtual_total(),
            };
            if active.session.is_done() {
                self.finish(active);
            } else {
                slots[slot_idx] = Some(active);
            }
        }
        Ok(())
    }

    fn record_comm(&self, t: &StepTiming) {
        self.metrics.comm_bytes_sent.add(t.wire_bytes);
        self.metrics.comm_bytes_saved.add(t.raw_bytes.saturating_sub(t.wire_bytes));
        // per-site-group policy counters (engine-side rollups mirrored
        // into the registry so `/metrics` exposes where the bytes go)
        for (key, v) in self.eng.policy_metrics() {
            self.metrics.set(&key, v);
        }
        // per-rank compute/codec/fabric-wait utilization gauges (real
        // concurrent busy time under the rank-thread runtime)
        for (key, v) in self.eng.rank_metrics() {
            self.metrics.set(&key, v);
        }
        // per-phase trace gauges (compute / codec / fabric wait / link)
        for (key, v) in self.eng.trace_metrics() {
            self.metrics.set(&key, v);
        }
        // per-algorithm collective counter (engine-side total mirrored
        // into the registry so `/metrics` exposes the planner's choices);
        // only the algorithm this step ran can have moved
        let Some(kind) = AlgoKind::parse(t.algo) else {
            return; // no collective ran this step
        };
        if let Some(calls) = self.eng.algo_calls.get(t.algo) {
            self.metrics.set(kind.metric_key(), *calls as f64);
        }
    }

    fn finish(&self, slot: ActiveSlot) {
        let s = &slot.session;
        let resp = GenResponse {
            id: s.id,
            text: self.tokenizer.decode(&s.generated),
            prompt_tokens: s.prompt_tokens.len(),
            new_tokens: s.generated.len(),
            ttft_s: s.ttft().unwrap_or(f64::NAN),
            e2e_s: s.e2e().unwrap_or(f64::NAN),
            tpot_s: s.tpot().unwrap_or(f64::NAN),
            queue_wait_s: s.queue_wait().unwrap_or(f64::NAN),
            virtual_prefill_s: slot.virtual_prefill_s,
        };
        self.metrics.requests_completed.inc();
        if let Some(e2e) = s.e2e() {
            self.metrics.e2e_latency.record(e2e);
            // whole-request span (arrival → last token) on pid = req id
            obs::record_abs("request", Cat::Request, s.id, obs::TID_COORD, s.arrived, e2e);
        }
        if let Some(tpot) = s.tpot() {
            self.metrics.tpot.record(tpot);
        }
        let _ = slot.reply.send(resp);
    }
}

/// Spawn the coordinator on its own engine thread. The engine (and its
/// non-Send XLA client) must be *constructed* on that thread, so the
/// caller passes a builder closure.
pub fn spawn<F>(build: F, opts: CoordinatorOptions) -> anyhow::Result<(CoordinatorHandle, std::thread::JoinHandle<anyhow::Result<()>>)>
where
    F: FnOnce() -> anyhow::Result<TpEngine> + Send + 'static,
{
    let (htx, hrx) = channel();
    let join = std::thread::Builder::new()
        .name("tpcc-engine".into())
        .spawn(move || -> anyhow::Result<()> {
            let eng = build()?;
            let (coord, handle) = Coordinator::new(eng, opts);
            htx.send(handle).map_err(|_| anyhow::anyhow!("handle channel closed"))?;
            coord.run()
        })?;
    let handle = hrx
        .recv()
        .map_err(|_| anyhow::anyhow!("engine thread failed during startup"))?;
    Ok((handle, join))
}
