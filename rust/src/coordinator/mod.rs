//! The serving coordinator: request queue → continuous batcher →
//! TP engine (prefill/decode) → sampled tokens → responses.
//!
//! Mirrors the vLLM router/engine split: [`Coordinator`] owns the
//! engine loop on a dedicated thread (the `xla` client is not `Send`);
//! front ends (HTTP server, trace replayer, examples) submit
//! [`GenRequest`]s over a channel and receive [`GenResponse`]s on a
//! per-request reply channel. Under `--rank-threads` the engine itself
//! fans each forward out to its per-rank worker pool; the pool is
//! spawned by the engine builder on this thread and joined when the
//! coordinator's engine drops at loop exit (clean shutdown).

pub mod sampler;
pub mod scheduler;
pub mod session;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use crate::collective::AlgoKind;
use crate::metrics::{Registry, DEFAULT_SAMPLE_PERIOD_S};
use crate::obs::flight::{FlightRecorder, PhaseCost, RequestRecord};
use crate::obs::{self, Cat, Tracer};
use crate::tokenizer::ByteTokenizer;
use crate::tp::{BatchKv, StepTiming, TpEngine};

pub use sampler::{Sampler, Sampling};
pub use session::{Session, SessionState};

/// A generation request, as submitted by a front end.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub greedy: bool,
    /// optional stop byte (-1 = none)
    pub stop_token: i32,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub ttft_s: f64,
    pub e2e_s: f64,
    pub tpot_s: f64,
    /// time queued before prefill admission (NaN if never admitted)
    pub queue_wait_s: f64,
    /// virtual (interconnect-modeled) time spent in this request's
    /// prefill — the Table-3 "TTFT" under the simulated hardware profile
    pub virtual_prefill_s: f64,
}

pub struct CoordinatorOptions {
    /// decode batch group size (must be an exported batch bucket)
    pub decode_batch: usize,
    /// max seconds a queued request waits before a partial prefill flush
    pub max_wait_s: f64,
    pub sampling: Sampling,
    pub seed: u64,
    /// enable the engine's span recorder at startup (`tpcc serve` /
    /// `tpcc trace`); spans are served at `GET /trace`
    pub trace: bool,
    /// metrics time-series sampling cadence (seconds); the background
    /// sampler thread pushes one registry snapshot per period into the
    /// bounded history ring served at `GET /metrics/history`
    pub sample_period_s: f64,
    /// when set, the coordinator automatically rebinds sites the drift
    /// sentinel trips to the never-worse `none` scheme
    pub drift_fallback: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            decode_batch: 8,
            max_wait_s: 0.05,
            sampling: Sampling::Greedy,
            seed: 0,
            trace: false,
            sample_period_s: DEFAULT_SAMPLE_PERIOD_S,
            drift_fallback: false,
        }
    }
}

type Submission = (GenRequest, Sender<GenResponse>);

/// Fold one engine step's cost into a flight-recorder phase bucket.
fn add_timing(c: &mut PhaseCost, t: &StepTiming) {
    c.compute_s += t.compute_s;
    c.codec_s += t.codec_s;
    c.link_s += t.link_s;
    c.wire_bytes += t.wire_bytes;
}

/// Handle used by front ends to submit work (cheaply cloneable).
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Submission>,
    pub metrics: Arc<Registry>,
    /// JSON snapshot of the engine's bound compression policy (the
    /// per-site scheme table plus the sentinel's `policy_drift`
    /// section), served at `GET /policy`; the coordinator refreshes it
    /// whenever the sentinel's version moves
    pub policy_json: Arc<Mutex<String>>,
    /// the engine's span recorder, shared so front ends can serve
    /// `GET /trace` without a round-trip through the engine thread
    pub tracer: Arc<Tracer>,
    /// per-request flight recorder (slowest-K + recent-K), served at
    /// `GET /debug/requests` and read by `tpcc explain`
    pub flight: Arc<FlightRecorder>,
    shutdown: Arc<AtomicBool>,
}

impl CoordinatorHandle {
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send((req, rtx));
        rrx
    }

    /// Blocking convenience call.
    pub fn generate(&self, req: GenRequest) -> anyhow::Result<GenResponse> {
        let rx = self.submit(req);
        rx.recv().map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// A handle with no engine behind it: `/healthz` and `/metrics`
    /// serve (fresh registry), `/generate` answers 500. Lets front-end
    /// tests exercise the HTTP substrate (connection pool, shedding)
    /// without AOT artifacts.
    pub fn detached() -> CoordinatorHandle {
        let (tx, _) = channel();
        CoordinatorHandle {
            tx,
            metrics: Arc::new(Registry::default()),
            policy_json: Arc::new(Mutex::new("{}".to_string())),
            tracer: Tracer::new(),
            flight: Arc::new(FlightRecorder::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }
}

/// The engine loop. Owns the TpEngine; runs until shutdown + drained.
pub struct Coordinator {
    eng: TpEngine,
    opts: CoordinatorOptions,
    metrics: Arc<Registry>,
    rx: Receiver<Submission>,
    shutdown: Arc<AtomicBool>,
    next_id: u64,
    sampler: Sampler,
    tokenizer: ByteTokenizer,
    flight: Arc<FlightRecorder>,
    policy_json: Arc<Mutex<String>>,
    /// sentinel version the served `/policy` body was rendered at
    drift_version: u64,
}

struct ActiveSlot {
    session: Session,
    reply: Sender<GenResponse>,
    virtual_prefill_s: f64,
    /// this request's prefill batch cost (window attribution: the whole
    /// batch's cost, charged to each request admitted in it)
    prefill_cost: PhaseCost,
    /// decode cost accumulated while this request was resident (each
    /// decode step's cost is charged to every resident request)
    decode_cost: PhaseCost,
    /// engine-wide per-group wire bytes when this request was admitted;
    /// the finish-time delta is the traffic the request coexisted with
    wire_at_admit: [u64; 4],
    /// engine-wide fabric-wait seconds at admission
    fabric_at_admit: f64,
    /// widest decode batch this request was resident in
    batch_peak: usize,
}

impl Coordinator {
    /// Build the coordinator plus its submission handle. Call
    /// [`Coordinator::run`] on a thread that owns the engine.
    pub fn new(eng: TpEngine, opts: CoordinatorOptions) -> (Coordinator, CoordinatorHandle) {
        let (tx, rx) = channel();
        let metrics = Arc::new(Registry::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let tracer = eng.tracer().clone();
        if opts.trace {
            tracer.set_enabled(true);
        }
        let flight = Arc::new(FlightRecorder::default());
        flight.set_group_schemes(eng.group_schemes());
        let policy_json = Arc::new(Mutex::new(eng.policy_json().to_string()));
        let handle = CoordinatorHandle {
            tx,
            metrics: metrics.clone(),
            policy_json: policy_json.clone(),
            tracer,
            flight: flight.clone(),
            shutdown: shutdown.clone(),
        };
        // background time-series sampler: one registry snapshot per
        // period into the bounded history ring, until shutdown (the run
        // loop raises the flag on its way out, so drained coordinators
        // reap the thread too)
        {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let period = opts.sample_period_s.clamp(0.01, 60.0);
            let _ = std::thread::Builder::new().name("tpcc-sampler".into()).spawn(move || {
                while !shutdown.load(Ordering::SeqCst) {
                    metrics.sample_history();
                    std::thread::sleep(std::time::Duration::from_secs_f64(period));
                }
            });
        }
        let seed = opts.seed;
        let drift_version = eng.sentinel().version();
        (
            Coordinator {
                eng,
                opts,
                metrics,
                rx,
                shutdown,
                next_id: 1,
                sampler: Sampler::new(seed),
                tokenizer: ByteTokenizer,
                flight,
                policy_json,
                drift_version,
            },
            handle,
        )
    }

    /// Run the continuous-batching loop until shutdown and drained.
    pub fn run(mut self) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let db = self.opts.decode_batch;
        let tp = self.eng.opts.tp;
        let mut decode_kv =
            BatchKv::new(&cfg, tp, db).with_gauge(self.metrics.kv_blocks_in_use.clone());
        let mut slots: Vec<Option<ActiveSlot>> = (0..db).map(|_| None).collect();
        let mut waiting: Vec<(Session, Sender<GenResponse>)> = Vec::new();

        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();
        let batch_buckets = self.eng.rt.manifest.batch_buckets.clone();
        let max_prompt = *seq_buckets.iter().max().unwrap_or(&256);

        loop {
            // ---- intake ----
            loop {
                match self.rx.try_recv() {
                    Ok((req, reply)) => {
                        let mut toks = self.tokenizer.encode(&req.prompt);
                        toks.truncate(max_prompt);
                        if toks.is_empty() {
                            toks.push(0);
                        }
                        let mut s = Session::new(self.next_id, toks, req.max_new_tokens.max(1));
                        s.stop_token = req.stop_token;
                        self.next_id += 1;
                        self.metrics.requests_received.inc();
                        waiting.push((s, reply));
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        if waiting.is_empty() && slots.iter().all(Option::is_none) {
                            // raise the flag so the sampler thread exits
                            self.shutdown.store(true, Ordering::SeqCst);
                            return Ok(());
                        }
                        break;
                    }
                }
            }

            let free: Vec<usize> =
                (0..db).filter(|&i| slots[i].is_none()).collect();
            let oldest_wait = waiting
                .first()
                .map(|(s, _)| s.arrived.elapsed().as_secs_f64())
                .unwrap_or(0.0);
            let n_admit = scheduler::admit_count(
                waiting.len(),
                free.len(),
                *batch_buckets.iter().max().unwrap_or(&8),
            );

            // ---- prefill a batch of admitted requests ----
            if scheduler::should_flush(oldest_wait, n_admit, free.len().min(8), self.opts.max_wait_s)
                && n_admit > 0
            {
                let admitted: Vec<(Session, Sender<GenResponse>)> =
                    waiting.drain(..n_admit).collect();
                self.prefill_admit(admitted, &free, &mut slots, &mut decode_kv)?;
            }

            // ---- decode step over active slots ----
            let active: Vec<usize> = (0..db).filter(|&i| slots[i].is_some()).collect();
            if active.is_empty() {
                if self.shutdown.load(Ordering::SeqCst) && waiting.is_empty() {
                    return Ok(());
                }
                if waiting.is_empty() {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                continue;
            }

            let mut tokens = vec![0i32; db];
            let mut pos = vec![0i32; db];
            for &i in &active {
                let slot = slots[i].as_ref().unwrap();
                tokens[i] = *slot.session.generated.last().unwrap();
                pos[i] = slot.session.pos as i32;
            }
            let (logits, timing) = self.eng.decode(&tokens, &pos, &mut decode_kv)?;
            self.metrics.batches_executed.inc();
            self.record_comm(&timing);
            // window attribution: this step's cost is charged to every
            // resident request (they shared the batch)
            for &i in &active {
                let slot = slots[i].as_mut().unwrap();
                add_timing(&mut slot.decode_cost, &timing);
                slot.batch_peak = slot.batch_peak.max(active.len());
            }
            let v = cfg.vocab;
            for &i in &active {
                let slot = slots[i].as_mut().unwrap();
                let row = &logits[i * v..(i + 1) * v];
                let tok = self.sampler.sample(row, self.sampling_for());
                slot.session.record_token(tok);
                self.metrics.tokens_generated.inc();
                if slot.session.is_done() || slot.session.pos + 1 >= cfg.max_seq {
                    let done = slots[i].take().unwrap();
                    decode_kv.clear_slot(i);
                    self.finish(done);
                }
            }
        }
    }

    fn sampling_for(&self) -> Sampling {
        self.opts.sampling
    }

    fn prefill_admit(
        &mut self,
        mut admitted: Vec<(Session, Sender<GenResponse>)>,
        free: &[usize],
        slots: &mut [Option<ActiveSlot>],
        decode_kv: &mut BatchKv,
    ) -> anyhow::Result<()> {
        let cfg = self.eng.cfg.clone();
        let lens: Vec<usize> = admitted.iter().map(|(s, _)| s.prompt_tokens.len()).collect();
        let seq_buckets = self.eng.rt.manifest.seq_buckets.clone();
        let batch_buckets = self.eng.rt.manifest.batch_buckets.clone();
        let (bb, sb) = scheduler::pick_prefill_bucket(&lens, &batch_buckets, &seq_buckets)
            .ok_or_else(|| anyhow::anyhow!("prompt exceeds largest bucket"))?;

        // queue wait ends here: admission into the prefill batch, before
        // the batch executes
        for (s, _) in admitted.iter_mut() {
            s.record_prefill_start();
            if let Some(w) = s.queue_wait() {
                self.metrics.queue_wait.record(w);
                // queue-wait span on the request's own timeline (pid =
                // request id), stamped retroactively from arrival
                obs::record_abs("queue", Cat::Queue, s.id, obs::TID_COORD, s.arrived, w);
            }
        }

        let mut tokens = vec![0i32; bb * sb];
        for (row, (s, _)) in admitted.iter().enumerate() {
            tokens[row * sb..row * sb + s.prompt_tokens.len()]
                .copy_from_slice(&s.prompt_tokens);
        }
        // flight-recorder baselines: per-group wire and fabric wait
        // before this batch's prefill, so finish-time deltas include it
        let wire_at_admit = self.eng.group_wire_bytes();
        let fabric_at_admit = self.eng.fabric_wait_total();
        let mut kv = BatchKv::new(&cfg, self.eng.opts.tp, bb);
        let (logits, timing) =
            self.eng.prefill(&tokens, bb, sb, &vec![0; bb], Some(&mut kv))?;
        self.record_comm(&timing);
        self.metrics.batches_executed.inc();
        let mut prefill_cost = PhaseCost::default();
        add_timing(&mut prefill_cost, &timing);

        let v = cfg.vocab;
        for (row, (mut session, reply)) in admitted.into_iter().enumerate() {
            let len = session.prompt_tokens.len();
            self.metrics.prefill_tokens.add(len as u64);
            let row_logits = &logits[(row * sb + len - 1) * v..(row * sb + len) * v];
            let tok = self.sampler.sample(row_logits, self.sampling_for());
            session.record_first_token(tok);
            self.metrics.tokens_generated.inc();
            if let Some(ttft) = session.ttft() {
                self.metrics.ttft.record(ttft);
            }
            let slot_idx = free[row];
            decode_kv.adopt_slot(slot_idx, &kv, row, len);
            session.slot = Some(slot_idx);
            let active = ActiveSlot {
                session,
                reply,
                virtual_prefill_s: timing.virtual_total(),
                prefill_cost,
                decode_cost: PhaseCost::default(),
                wire_at_admit,
                fabric_at_admit,
                batch_peak: bb,
            };
            if active.session.is_done() {
                // done at first token: release the slot it was adopted
                // into (keeps the kv_blocks_in_use gauge honest)
                decode_kv.clear_slot(slot_idx);
                self.finish(active);
            } else {
                slots[slot_idx] = Some(active);
            }
        }
        Ok(())
    }

    fn record_comm(&mut self, t: &StepTiming) {
        self.metrics.comm_bytes_sent.add(t.wire_bytes);
        self.metrics.comm_bytes_saved.add(t.raw_bytes.saturating_sub(t.wire_bytes));
        // drift sentinel: optionally rebind tripped sites to `none`,
        // then mirror the drift counters and refresh the served /policy
        // body whenever the sentinel state moved
        if self.opts.drift_fallback && !self.eng.sentinel().tripped().is_empty() {
            match self.eng.apply_drift_fallback() {
                Ok(sites) => {
                    let labels: Vec<String> = sites.iter().map(|s| s.label()).collect();
                    eprintln!("[coordinator] drift fallback: {} -> none", labels.join(", "));
                    self.flight.set_group_schemes(self.eng.group_schemes());
                }
                Err(e) => eprintln!("[coordinator] drift fallback failed: {e:#}"),
            }
        }
        for (key, v) in self.eng.sentinel_metrics() {
            self.metrics.set(key, v);
        }
        let drift_v = self.eng.sentinel().version();
        if drift_v != self.drift_version {
            self.drift_version = drift_v;
            *self.policy_json.lock().unwrap() = self.eng.policy_json().to_string();
        }
        // per-site-group policy counters (engine-side rollups mirrored
        // into the registry so `/metrics` exposes where the bytes go)
        for (key, v) in self.eng.policy_metrics() {
            self.metrics.set(&key, v);
        }
        // per-rank compute/codec/fabric-wait utilization gauges (real
        // concurrent busy time under the rank-thread runtime)
        for (key, v) in self.eng.rank_metrics() {
            self.metrics.set(&key, v);
        }
        // per-phase trace gauges (compute / codec / fabric wait / link)
        for (key, v) in self.eng.trace_metrics() {
            self.metrics.set(&key, v);
        }
        // per-algorithm collective counter (engine-side total mirrored
        // into the registry so `/metrics` exposes the planner's choices);
        // only the algorithm this step ran can have moved
        let Some(kind) = AlgoKind::parse(t.algo) else {
            return; // no collective ran this step
        };
        if let Some(calls) = self.eng.algo_calls.get(t.algo) {
            self.metrics.set(kind.metric_key(), *calls as f64);
        }
    }

    fn finish(&self, slot: ActiveSlot) {
        let s = &slot.session;
        let resp = GenResponse {
            id: s.id,
            text: self.tokenizer.decode(&s.generated),
            prompt_tokens: s.prompt_tokens.len(),
            new_tokens: s.generated.len(),
            ttft_s: s.ttft().unwrap_or(f64::NAN),
            e2e_s: s.e2e().unwrap_or(f64::NAN),
            tpot_s: s.tpot().unwrap_or(f64::NAN),
            queue_wait_s: s.queue_wait().unwrap_or(f64::NAN),
            virtual_prefill_s: slot.virtual_prefill_s,
        };
        self.metrics.requests_completed.inc();
        if let Some(e2e) = s.e2e() {
            self.metrics.e2e_latency.record(e2e);
            // whole-request span (arrival → last token) on pid = req id
            obs::record_abs("request", Cat::Request, s.id, obs::TID_COORD, s.arrived, e2e);
        }
        if let Some(tpot) = s.tpot() {
            self.metrics.tpot.record(tpot);
        }
        // flight recorder: structured per-request record (slowest-K +
        // recent-K retention), attribution source for `tpcc explain`
        let wire_now = self.eng.group_wire_bytes();
        let mut site_wire_bytes = [0u64; 4];
        for (g, w) in site_wire_bytes.iter_mut().enumerate() {
            *w = wire_now[g].saturating_sub(slot.wire_at_admit[g]);
        }
        self.flight.record(RequestRecord {
            id: s.id,
            prompt_tokens: s.prompt_tokens.len(),
            new_tokens: s.generated.len(),
            batch_peak: slot.batch_peak,
            queue_wait_s: resp.queue_wait_s,
            ttft_s: resp.ttft_s,
            e2e_s: resp.e2e_s,
            tpot_s: resp.tpot_s,
            prefill: slot.prefill_cost,
            decode: slot.decode_cost,
            fabric_wait_s: (self.eng.fabric_wait_total() - slot.fabric_at_admit).max(0.0),
            site_wire_bytes,
        });
        let _ = slot.reply.send(resp);
    }
}

/// Spawn the coordinator on its own engine thread. The engine (and its
/// non-Send XLA client) must be *constructed* on that thread, so the
/// caller passes a builder closure.
pub fn spawn<F>(build: F, opts: CoordinatorOptions) -> anyhow::Result<(CoordinatorHandle, std::thread::JoinHandle<anyhow::Result<()>>)>
where
    F: FnOnce() -> anyhow::Result<TpEngine> + Send + 'static,
{
    let (htx, hrx) = channel();
    let join = std::thread::Builder::new()
        .name("tpcc-engine".into())
        .spawn(move || -> anyhow::Result<()> {
            let eng = build()?;
            let (coord, handle) = Coordinator::new(eng, opts);
            htx.send(handle).map_err(|_| anyhow::anyhow!("handle channel closed"))?;
            coord.run()
        })?;
    let handle = hrx
        .recv()
        .map_err(|_| anyhow::anyhow!("engine thread failed during startup"))?;
    Ok((handle, join))
}
