//! Inter-accelerator interconnect simulation.
//!
//! Substitute for the paper's physical links (8xL4 over PCIe Gen4 x16 at
//! 64 GB/s; 4xA100 over NVLink at 600 GB/s — §5.2): communication time
//! for a collective is a pure α+β function of message size and topology,
//! so it can be *modeled exactly* while the payload itself moves by
//! memcpy between worker threads. The simulator returns virtual
//! durations that the TTFT accounting adds to measured/modeled compute.

pub mod profile;

pub use profile::{HwProfile, PROFILES};

/// Ring all-gather cost: each of the N workers sends its shard around the
/// ring in N-1 steps; per step a worker transmits `bytes` over one link.
/// time = (N-1) * (α + bytes / β_link).
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// per-message latency (s)
    pub alpha_s: f64,
    /// link bandwidth (bytes/s), unidirectional per GPU pair
    pub beta_bytes_per_s: f64,
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bytes_per_s
    }

    /// All-gather of `shard_bytes` per worker across `n` workers (ring).
    pub fn all_gather_time(&self, shard_bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * self.transfer_time(shard_bytes)
    }

    /// All-reduce modeled as reduce-scatter + all-gather (2(N-1) steps of
    /// ⌈bytes/N⌉ each — ceiling division, so shard sizes that don't
    /// divide N don't silently drop the remainder bytes). Used by the
    /// analytic perf model's baseline where uncompressed TP uses NCCL
    /// all-reduce.
    pub fn all_reduce_time(&self, bytes: usize, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        2.0 * (n - 1) as f64 * self.transfer_time(bytes.div_ceil(n))
    }
}

/// A virtual clock accumulating simulated communication time alongside
/// real compute time. The TTFT tables report `virtual_elapsed`.
#[derive(Debug, Default, Clone)]
pub struct VirtualClock {
    comm_s: f64,
    compute_s: f64,
    comm_events: u64,
    bytes_on_wire: u64,
    bytes_saved: u64,
}

impl VirtualClock {
    pub fn add_comm(&mut self, seconds: f64, wire_bytes: usize, uncompressed_bytes: usize) {
        self.comm_s += seconds;
        self.comm_events += 1;
        self.bytes_on_wire += wire_bytes as u64;
        self.bytes_saved += uncompressed_bytes.saturating_sub(wire_bytes) as u64;
    }

    pub fn add_compute(&mut self, seconds: f64) {
        self.compute_s += seconds;
    }

    pub fn elapsed(&self) -> f64 {
        self.comm_s + self.compute_s
    }
    pub fn comm(&self) -> f64 {
        self.comm_s
    }
    pub fn compute(&self) -> f64 {
        self.compute_s
    }
    pub fn wire_bytes(&self) -> u64 {
        self.bytes_on_wire
    }
    pub fn saved_bytes(&self) -> u64 {
        self.bytes_saved
    }
    pub fn reset(&mut self) {
        *self = VirtualClock::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_gather_scales_with_workers() {
        let l = LinkModel { alpha_s: 1e-5, beta_bytes_per_s: 64e9 };
        let t2 = l.all_gather_time(1 << 20, 2);
        let t4 = l.all_gather_time(1 << 20, 4);
        let t8 = l.all_gather_time(1 << 20, 8);
        assert!(t2 < t4 && t4 < t8);
        assert_eq!(l.all_gather_time(1 << 20, 1), 0.0);
        // (N-1) proportionality
        assert!((t8 / t2 - 7.0 / 1.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_dominates_large_messages() {
        let slow = LinkModel { alpha_s: 1e-5, beta_bytes_per_s: 64e9 };
        let fast = LinkModel { alpha_s: 1e-5, beta_bytes_per_s: 600e9 };
        let b = 128 << 20;
        assert!(slow.transfer_time(b) > 8.0 * fast.transfer_time(b) * 0.9);
    }

    #[test]
    fn all_reduce_rounds_shard_up() {
        // 10 bytes over 3 workers: shards are ceil(10/3) = 4 bytes, not
        // the truncated 3 — time must match the explicit 4-byte transfer.
        let l = LinkModel { alpha_s: 0.0, beta_bytes_per_s: 1.0 };
        let t = l.all_reduce_time(10, 3);
        assert!((t - 2.0 * 2.0 * 4.0).abs() < 1e-12, "{t}");
        // and a non-divisible message is never cheaper than a slightly
        // smaller divisible one
        assert!(l.all_reduce_time(10, 3) >= l.all_reduce_time(9, 3));
    }

    #[test]
    fn virtual_clock_accumulates() {
        let mut c = VirtualClock::default();
        c.add_compute(0.5);
        c.add_comm(0.25, 100, 400);
        assert!((c.elapsed() - 0.75).abs() < 1e-12);
        assert_eq!(c.wire_bytes(), 100);
        assert_eq!(c.saved_bytes(), 300);
    }
}
