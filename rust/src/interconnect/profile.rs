//! Hardware profiles for the paper's evaluation testbeds (§5.2) plus the
//! compute-side roofline numbers used by the analytic perf model, and
//! multi-node variants for the collective engine's two-level topologies.

use super::LinkModel;

/// One accelerator type + its interconnect, as deployed in the paper.
#[derive(Debug, Clone, Copy)]
pub struct HwProfile {
    pub name: &'static str,
    /// peak dense f16 tensor throughput per GPU (FLOP/s)
    pub peak_flops: f64,
    /// achievable fraction of peak for transformer prefill GEMMs
    pub mfu: f64,
    /// HBM bandwidth (bytes/s) — bounds the memory-bound decode phase
    pub hbm_bytes_per_s: f64,
    /// intra-node link (the only link for single-node profiles)
    pub link: LinkModel,
    /// node groups in the deployment (1 = single node; >1 enables the
    /// collective engine's two-level topology)
    pub nodes: usize,
    /// node-to-node link; equal to `link` for single-node profiles so
    /// flat-topology code paths stay bit-compatible with the seed
    pub inter_link: LinkModel,
    /// throughput of the quantize/dequant kernels (values/s) — the
    /// compression overhead term. Calibrated so the A100 slowdown in
    /// Table 3 reproduces (quant ~ memory-bound elementwise op).
    pub quant_values_per_s: f64,
}

impl HwProfile {
    pub fn by_name(name: &str) -> Option<&'static HwProfile> {
        PROFILES.iter().find(|p| p.name.eq_ignore_ascii_case(name))
    }
}

const L4_LINK: LinkModel = LinkModel { alpha_s: 20e-6, beta_bytes_per_s: 4.3e9 };
const A100_LINK: LinkModel = LinkModel { alpha_s: 10e-6, beta_bytes_per_s: 74e9 };

/// L4: PCIe Gen4 x16 ~64 GB/s per the paper; FP16 tensor 121 TFLOPs
/// (realistic dense ~0.35 MFU on prefill), HBM 300 GB/s.
/// A100 (SXM, 80GB): NVLink 600 GB/s bidirectional any-to-any; FP16
/// tensor 312 TFLOPs, HBM 2.0 TB/s.
/// `2x4l4` / `2x4a100`: two-node variants of the same parts — PCIe
/// intra + 100GbE inter for L4 boxes, NVLink intra + HDR InfiniBand
/// inter for A100 boxes — the asymmetric regimes where hierarchical /
/// two-shot algorithms beat a world-spanning flat ring.
pub const PROFILES: &[HwProfile] = &[
    HwProfile {
        name: "l4",
        peak_flops: 121e12,
        mfu: 0.35,
        hbm_bytes_per_s: 300e9,
        // PCIe Gen4: paper quotes 64 GB/s node-level, but effective
        // per-pair P2P bandwidth with 8 GPUs staging through host memory
        // and contending for the same host links is far lower. β is
        // calibrated on the paper's *uncompressed* Table 3 rows
        // (70B/8xL4 2x64 -> 0.58 s): β_eff ≈ 4.3 GB/s.
        link: L4_LINK,
        nodes: 1,
        inter_link: L4_LINK,
        quant_values_per_s: 15e9,
    },
    HwProfile {
        name: "a100",
        peak_flops: 312e12,
        mfu: 0.45,
        hbm_bytes_per_s: 2.0e12,
        // NVLink3 600 GB/s bidirectional; effective collective bandwidth
        // for ~4 MB eager-mode messages calibrated on the paper's
        // uncompressed 4xA100 rows (2x128 -> 0.09 s): β_eff ≈ 74 GB/s.
        link: A100_LINK,
        nodes: 1,
        inter_link: A100_LINK,
        // same (torch, unfused) microxcaling quant kernels as L4 —
        // this is what makes compression a net loss on NVLink (Table 3).
        quant_values_per_s: 15e9,
    },
    HwProfile {
        name: "2x4l4",
        peak_flops: 121e12,
        mfu: 0.35,
        hbm_bytes_per_s: 300e9,
        link: L4_LINK,
        nodes: 2,
        // 100GbE between the boxes: 12.5 GB/s raw, effective collective
        // bandwidth with TCP framing and host staging ≈ 1.5 GB/s, and a
        // far higher per-message latency than PCIe P2P.
        inter_link: LinkModel { alpha_s: 30e-6, beta_bytes_per_s: 1.5e9 },
        quant_values_per_s: 15e9,
    },
    HwProfile {
        name: "2x4a100",
        peak_flops: 312e12,
        mfu: 0.45,
        hbm_bytes_per_s: 2.0e12,
        link: A100_LINK,
        nodes: 2,
        // HDR InfiniBand (200 Gbps): 25 GB/s raw, effective ≈ 12 GB/s —
        // fast, but still 6x below NVLink, so world-spanning flat rings
        // stall on the node boundary.
        inter_link: LinkModel { alpha_s: 15e-6, beta_bytes_per_s: 12e9 },
        quant_values_per_s: 15e9,
    },
    // our live CPU testbed: a profile that matches the single-core CPU
    // so live-mode virtual time is self-consistent.
    HwProfile {
        name: "cpu",
        peak_flops: 25e9,
        mfu: 1.0,
        hbm_bytes_per_s: 8e9,
        link: LinkModel { alpha_s: 5e-6, beta_bytes_per_s: 2e9 },
        nodes: 1,
        inter_link: LinkModel { alpha_s: 5e-6, beta_bytes_per_s: 2e9 },
        quant_values_per_s: 500e6,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert!(HwProfile::by_name("l4").is_some());
        assert!(HwProfile::by_name("A100").is_some());
        assert!(HwProfile::by_name("2x4l4").is_some());
        assert!(HwProfile::by_name("2x4A100").is_some());
        assert!(HwProfile::by_name("h100").is_none());
    }

    #[test]
    fn paper_bandwidth_ordering() {
        let l4 = HwProfile::by_name("l4").unwrap();
        let a100 = HwProfile::by_name("a100").unwrap();
        assert!(a100.link.beta_bytes_per_s / l4.link.beta_bytes_per_s > 8.0);
        assert!(a100.peak_flops > l4.peak_flops);
    }

    #[test]
    fn single_node_profiles_have_symmetric_links() {
        for p in PROFILES.iter().filter(|p| p.nodes == 1) {
            assert_eq!(p.link.beta_bytes_per_s, p.inter_link.beta_bytes_per_s, "{}", p.name);
            assert_eq!(p.link.alpha_s, p.inter_link.alpha_s, "{}", p.name);
        }
    }

    #[test]
    fn multi_node_inter_is_slower_than_intra() {
        for p in PROFILES.iter().filter(|p| p.nodes > 1) {
            assert!(
                p.inter_link.beta_bytes_per_s < p.link.beta_bytes_per_s,
                "{}: inter should be the slow level",
                p.name
            );
        }
    }
}
