//! Hardware profiles for the paper's evaluation testbeds (§5.2) plus the
//! compute-side roofline numbers used by the analytic perf model.

use super::LinkModel;

/// One accelerator type + its interconnect, as deployed in the paper.
#[derive(Debug, Clone, Copy)]
pub struct HwProfile {
    pub name: &'static str,
    /// peak dense f16 tensor throughput per GPU (FLOP/s)
    pub peak_flops: f64,
    /// achievable fraction of peak for transformer prefill GEMMs
    pub mfu: f64,
    /// HBM bandwidth (bytes/s) — bounds the memory-bound decode phase
    pub hbm_bytes_per_s: f64,
    pub link: LinkModel,
    /// throughput of the quantize/dequant kernels (values/s) — the
    /// compression overhead term. Calibrated so the A100 slowdown in
    /// Table 3 reproduces (quant ~ memory-bound elementwise op).
    pub quant_values_per_s: f64,
}

impl HwProfile {
    pub fn by_name(name: &str) -> Option<&'static HwProfile> {
        PROFILES.iter().find(|p| p.name.eq_ignore_ascii_case(name))
    }
}

/// L4: PCIe Gen4 x16 ~64 GB/s per the paper; FP16 tensor 121 TFLOPs
/// (realistic dense ~0.35 MFU on prefill), HBM 300 GB/s.
/// A100 (SXM, 80GB): NVLink 600 GB/s bidirectional any-to-any; FP16
/// tensor 312 TFLOPs, HBM 2.0 TB/s.
pub const PROFILES: &[HwProfile] = &[
    HwProfile {
        name: "l4",
        peak_flops: 121e12,
        mfu: 0.35,
        hbm_bytes_per_s: 300e9,
        // PCIe Gen4: paper quotes 64 GB/s node-level, but effective
        // per-pair P2P bandwidth with 8 GPUs staging through host memory
        // and contending for the same host links is far lower. β is
        // calibrated on the paper's *uncompressed* Table 3 rows
        // (70B/8xL4 2x64 -> 0.58 s): β_eff ≈ 4.3 GB/s.
        link: LinkModel { alpha_s: 20e-6, beta_bytes_per_s: 4.3e9 },
        quant_values_per_s: 15e9,
    },
    HwProfile {
        name: "a100",
        peak_flops: 312e12,
        mfu: 0.45,
        hbm_bytes_per_s: 2.0e12,
        // NVLink3 600 GB/s bidirectional; effective collective bandwidth
        // for ~4 MB eager-mode messages calibrated on the paper's
        // uncompressed 4xA100 rows (2x128 -> 0.09 s): β_eff ≈ 74 GB/s.
        link: LinkModel { alpha_s: 10e-6, beta_bytes_per_s: 74e9 },
        // same (torch, unfused) microxcaling quant kernels as L4 —
        // this is what makes compression a net loss on NVLink (Table 3).
        quant_values_per_s: 15e9,
    },
    // our live CPU testbed: a profile that matches the single-core CPU
    // so live-mode virtual time is self-consistent.
    HwProfile {
        name: "cpu",
        peak_flops: 25e9,
        mfu: 1.0,
        hbm_bytes_per_s: 8e9,
        link: LinkModel { alpha_s: 5e-6, beta_bytes_per_s: 2e9 },
        quant_values_per_s: 500e6,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert!(HwProfile::by_name("l4").is_some());
        assert!(HwProfile::by_name("A100").is_some());
        assert!(HwProfile::by_name("h100").is_none());
    }

    #[test]
    fn paper_bandwidth_ordering() {
        let l4 = HwProfile::by_name("l4").unwrap();
        let a100 = HwProfile::by_name("a100").unwrap();
        assert!(a100.link.beta_bytes_per_s / l4.link.beta_bytes_per_s > 8.0);
        assert!(a100.peak_flops > l4.peak_flops);
    }
}
