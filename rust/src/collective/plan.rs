//! Auto-planner: pick the cheapest (algorithm × chunking) for a
//! collective of a given size on a given topology + compressor.
//!
//! Scoring is the same virtual-time model execution reports: per-
//! algorithm α/β link time from [`super::algo`] plus the analytic codec
//! term `codec_values / quant_values_per_s · cost_factor` (the
//! profile's measured/calibrated codec throughput). Because the flat
//! ring (unchunked) is always among the candidates and `choose` returns
//! the argmin, the planned virtual time is never worse than the seed's
//! hard-coded ring — the Table-3 ablation asserts exactly that.
//!
//! The TP engine memoises plans per (message length, profile) in its
//! own map (`TpEngine::plan_cache`), so `choose` runs once per message
//! shape and the hot path pays an allocation-free lookup.

use super::algo::{AlgoKind, CollectiveAlgo};
use super::pipeline;
use super::topology::Topology;
use crate::mxfmt::Compressor;

/// Candidate chunk counts for pipelined execution. 1 must stay first:
/// it is the seed-compatible unchunked schedule and the never-worse
/// anchor.
pub const CHUNK_CANDIDATES: [usize; 4] = [1, 2, 4, 8];

/// How the engine's `algo` knob constrains the planner.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoChoice {
    /// score every supported candidate, return the argmin
    Auto,
    /// force one algorithm, monolithic schedule (the seed-compatible
    /// baseline; chunk exploration is `Auto`'s job)
    Fixed(AlgoKind),
}

impl AlgoChoice {
    /// Parse the engine/CLI spec: `auto` | any [`AlgoKind`] name.
    ///
    /// ```
    /// use tpcc::collective::plan::AlgoChoice;
    /// assert_eq!(AlgoChoice::parse("auto").unwrap(), AlgoChoice::Auto);
    /// assert_eq!(AlgoChoice::parse("").unwrap(), AlgoChoice::Auto);
    /// assert!(matches!(AlgoChoice::parse("two_shot").unwrap(), AlgoChoice::Fixed(_)));
    /// assert!(AlgoChoice::parse("bogus").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<AlgoChoice> {
        if s.is_empty() || s == "auto" {
            return Ok(AlgoChoice::Auto);
        }
        AlgoKind::parse(s)
            .map(AlgoChoice::Fixed)
            .ok_or_else(|| anyhow::anyhow!("unknown collective algo {s:?} (want auto|ring|recursive_doubling|two_shot|hierarchical)"))
    }
}

/// The planner's answer for one collective shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CollectivePlan {
    pub algo: AlgoKind,
    /// pipeline chunks (1 = monolithic)
    pub chunks: usize,
    /// estimated overlapped virtual total (link + codec with pipelining)
    pub est_total_s: f64,
    /// estimated link component (unchunked, for table breakdowns)
    pub est_link_s: f64,
    /// estimated codec component (unchunked)
    pub est_codec_s: f64,
}

/// Score one (algo, chunks) candidate at an explicit codec rate;
/// returns `(overlapped total, link, codec)`. Shared by the planner's
/// argmin and the engine's Analytic-overhead accounting so the two
/// can never drift apart.
pub fn score(
    kind: AlgoKind,
    values: usize,
    world: usize,
    comp: Option<&dyn Compressor>,
    topo: &Topology,
    quant_values_per_s: f64,
    chunks: usize,
) -> (f64, f64, f64) {
    let a = kind.implementation();
    let link_s = a.link_time(values, world, comp, topo);
    let codec_s = match comp {
        None => 0.0,
        Some(c) => {
            a.codec_values(values, world, topo) as f64 / quant_values_per_s
                * c.compute_cost_factor()
        }
    };
    // split the codec term the way execution does: one encode share,
    // world-1 decode shares (the exact split only matters for overlap)
    let enc = codec_s / world.max(1) as f64;
    let dec = codec_s - enc;
    let total = if chunks <= 1 {
        link_s + codec_s
    } else {
        pipeline::estimate(a, values, world, comp, topo, enc, dec, chunks)
    };
    (total, link_s, codec_s)
}

/// Choose the cheapest (algorithm × chunking) for a `values`-per-rank
/// collective across `world` ranks on `topo`, compressing with `comp`,
/// with codec throughput `quant_values_per_s` (values/s).
///
/// The unchunked flat ring is always among the candidates, so the plan
/// is never slower (virtual time) than the seed's hard-coded ring:
///
/// ```
/// use tpcc::collective::plan::{choose, ring_baseline, AlgoChoice};
/// use tpcc::collective::Topology;
/// use tpcc::interconnect::HwProfile;
/// let p = HwProfile::by_name("l4").unwrap();
/// let topo = Topology::from_profile(p, 4);
/// let plan = choose(8192, 4, None, &topo, p.quant_values_per_s, AlgoChoice::Auto);
/// let ring = ring_baseline(8192, 4, None, &topo, p.quant_values_per_s);
/// assert!(plan.est_total_s > 0.0 && plan.est_total_s <= ring);
/// ```
pub fn choose(
    values: usize,
    world: usize,
    comp: Option<&dyn Compressor>,
    topo: &Topology,
    quant_values_per_s: f64,
    choice: AlgoChoice,
) -> CollectivePlan {
    let candidates: Vec<AlgoKind> = match choice {
        // a forced algorithm that cannot run this configuration (e.g.
        // recursive doubling on a non-power-of-two world, hierarchical
        // on a flat topology) falls back to the flat ring instead of
        // modelling a schedule it could not execute
        AlgoChoice::Fixed(k) if k.supports(world, topo) => vec![k],
        AlgoChoice::Fixed(_) => vec![AlgoKind::FlatRing],
        AlgoChoice::Auto => AlgoKind::ALL
            .into_iter()
            .filter(|k| k.supports(world, topo))
            .collect(),
    };
    let mut best: Option<CollectivePlan> = None;
    for kind in candidates {
        // chunking only overlaps gather-style execution (two-shot and
        // hierarchical already pipeline internally via their phases) and
        // is only explored in Auto mode — Fixed pins the seed schedule
        let chunk_set: &[usize] = match kind {
            AlgoKind::FlatRing | AlgoKind::RecursiveDoubling
                if comp.is_some() && choice == AlgoChoice::Auto =>
            {
                &CHUNK_CANDIDATES
            }
            _ => &CHUNK_CANDIDATES[..1],
        };
        for &chunks in chunk_set {
            let (total, link_s, codec_s) =
                score(kind, values, world, comp, topo, quant_values_per_s, chunks);
            if best.is_none_or(|b| total < b.est_total_s) {
                best = Some(CollectivePlan {
                    algo: kind,
                    chunks,
                    est_total_s: total,
                    est_link_s: link_s,
                    est_codec_s: codec_s,
                });
            }
        }
    }
    // `candidates` is never empty (FlatRing supports everything)
    best.expect("no collective algorithm candidate")
}

/// Virtual-time score of the seed's hard-coded collective — the flat
/// ring, unchunked — used as the ablation/never-worse baseline.
pub fn ring_baseline(
    values: usize,
    world: usize,
    comp: Option<&dyn Compressor>,
    topo: &Topology,
    quant_values_per_s: f64,
) -> f64 {
    score(AlgoKind::FlatRing, values, world, comp, topo, quant_values_per_s, 1).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::HwProfile;
    use crate::mxfmt::{MxCodec, MxScheme};

    fn mx() -> MxCodec {
        MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap())
    }

    fn plan_on(profile: &str, tp: usize, values: usize, comp: Option<&dyn Compressor>) -> CollectivePlan {
        let p = HwProfile::by_name(profile).unwrap();
        let topo = Topology::from_profile(p, tp);
        choose(values, tp, comp, &topo, p.quant_values_per_s, AlgoChoice::Auto)
    }

    #[test]
    fn large_messages_on_multinode_pick_two_shot_or_hierarchical() {
        let c = mx();
        for profile in ["2x4l4", "2x4a100"] {
            let plan = plan_on(profile, 8, 2 * 128 * 8192, Some(&c));
            assert!(
                matches!(plan.algo, AlgoKind::TwoShot | AlgoKind::Hierarchical),
                "{profile}: picked {:?}",
                plan.algo
            );
        }
        // uncompressed large messages too: bandwidth dominates
        let plan = plan_on("l4", 8, 2 * 128 * 8192, None);
        assert_eq!(plan.algo, AlgoKind::TwoShot);
    }

    #[test]
    fn small_latency_bound_messages_avoid_two_shot() {
        // one decode token's partial: α-dominated — the doubled α terms
        // of two-shot lose to a single gather pass
        let c = mx();
        for profile in ["l4", "2x4l4", "2x4a100"] {
            let plan = plan_on(profile, 8, 64, Some(&c));
            assert!(
                matches!(plan.algo, AlgoKind::FlatRing | AlgoKind::RecursiveDoubling),
                "{profile}: picked {:?}",
                plan.algo
            );
            assert_eq!(plan.chunks, 1, "{profile}: tiny messages must not chunk");
        }
    }

    #[test]
    fn auto_never_worse_than_seed_ring() {
        let c = mx();
        for profile in ["l4", "a100", "2x4l4", "2x4a100", "cpu"] {
            let p = HwProfile::by_name(profile).unwrap();
            for tp in [2usize, 4, 8] {
                for values in [64usize, 8 * 128 * 192, 2 * 128 * 8192] {
                    let topo = Topology::from_profile(p, tp);
                    let auto = choose(values, tp, Some(&c), &topo, p.quant_values_per_s, AlgoChoice::Auto);
                    let ring = score(
                        AlgoKind::FlatRing, values, tp, Some(&c), &topo, p.quant_values_per_s, 1,
                    );
                    assert!(
                        auto.est_total_s <= ring.0 + 1e-15,
                        "{profile}/tp{tp}/{values}: auto {} > ring {}",
                        auto.est_total_s,
                        ring.0
                    );
                }
            }
        }
    }

    #[test]
    fn fixed_choice_is_respected() {
        let c = mx();
        let p = HwProfile::by_name("l4").unwrap();
        let topo = Topology::from_profile(p, 8);
        let plan = choose(
            2 * 128 * 8192, 8, Some(&c), &topo, p.quant_values_per_s,
            AlgoChoice::Fixed(AlgoKind::FlatRing),
        );
        assert_eq!(plan.algo, AlgoKind::FlatRing);
        // Fixed pins the monolithic seed schedule
        assert_eq!(plan.chunks, 1);
    }

    #[test]
    fn fixed_unsupported_falls_back_to_ring() {
        let c = mx();
        let p = HwProfile::by_name("l4").unwrap();
        // recursive doubling forced on a non-power-of-two world
        let topo = Topology::from_profile(p, 6);
        let plan = choose(
            1024, 6, Some(&c), &topo, p.quant_values_per_s,
            AlgoChoice::Fixed(AlgoKind::RecursiveDoubling),
        );
        assert_eq!(plan.algo, AlgoKind::FlatRing);
        // hierarchical forced on a flat single-node topology
        let topo = Topology::from_profile(p, 8);
        let plan = choose(
            1024, 8, Some(&c), &topo, p.quant_values_per_s,
            AlgoChoice::Fixed(AlgoKind::Hierarchical),
        );
        assert_eq!(plan.algo, AlgoKind::FlatRing);
    }

    #[test]
    fn choose_is_deterministic() {
        let c = mx();
        let p = HwProfile::by_name("2x4l4").unwrap();
        let topo = Topology::from_profile(p, 8);
        let a = choose(8 * 128 * 192, 8, Some(&c), &topo, p.quant_values_per_s, AlgoChoice::Auto);
        let b = choose(8 * 128 * 192, 8, Some(&c), &topo, p.quant_values_per_s, AlgoChoice::Auto);
        assert_eq!(a, b);
    }

    #[test]
    fn parse_choice() {
        assert_eq!(AlgoChoice::parse("auto").unwrap(), AlgoChoice::Auto);
        assert_eq!(
            AlgoChoice::parse("two_shot").unwrap(),
            AlgoChoice::Fixed(AlgoKind::TwoShot)
        );
        assert!(AlgoChoice::parse("bogus").is_err());
    }
}
