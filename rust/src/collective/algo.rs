//! Collective algorithms behind one [`CollectiveAlgo`] trait.
//!
//! The seed hard-coded a single flat ring all-gather; this module adds
//! the algorithm menu the planner chooses from:
//!
//! * [`FlatRing`] — the seed's ring all-gather + local reduce,
//!   bit-identical numerics and timing on flat topologies.
//! * [`RecursiveDoubling`] — butterfly all-gather: log2(N) steps of
//!   doubling payloads. Same numerics as the ring (every shard is
//!   quantized once at its source), fewer α terms.
//! * [`TwoShot`] — reduce-scatter + all-gather with compression applied
//!   per phase (à la Flash Communication, arXiv 2412.04964): moves
//!   ~2/N of the ring's bytes at the price of a second quantization of
//!   the reduced slices.
//! * [`Hierarchical`] — two-level gather for multi-node topologies:
//!   intra-node gather+reduce, inter-node exchange of node sums, intra
//!   re-broadcast; only (nodes-1) messages ever cross the slow link.
//!
//! Execution is real (payloads move, codec work is measured on this
//! thread); *link* time is modeled per algorithm from the topology's
//! α/β levels, exactly like the seed's single-level model.

use std::ops::Range;
use std::time::Instant;

use super::topology::Topology;
use super::{CommReport, CommScratch};
use crate::mxfmt::Compressor;
use crate::obs::{self, Cat};

/// Which collective algorithm to run (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    FlatRing,
    RecursiveDoubling,
    TwoShot,
    Hierarchical,
}

impl AlgoKind {
    pub const ALL: [AlgoKind; 4] = [
        AlgoKind::FlatRing,
        AlgoKind::RecursiveDoubling,
        AlgoKind::TwoShot,
        AlgoKind::Hierarchical,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlgoKind::FlatRing => "ring",
            AlgoKind::RecursiveDoubling => "recursive_doubling",
            AlgoKind::TwoShot => "two_shot",
            AlgoKind::Hierarchical => "hierarchical",
        }
    }

    /// Interned `/metrics` gauge key for this algorithm's collective
    /// counter (kept next to [`AlgoKind::name`] so a new algorithm
    /// can't miss its telemetry key).
    pub fn metric_key(self) -> &'static str {
        match self {
            AlgoKind::FlatRing => "collective_calls_ring",
            AlgoKind::RecursiveDoubling => "collective_calls_recursive_doubling",
            AlgoKind::TwoShot => "collective_calls_two_shot",
            AlgoKind::Hierarchical => "collective_calls_hierarchical",
        }
    }

    /// Parse a CLI/engine spec (`auto` is handled by the planner, not
    /// here). Accepts the full names plus short aliases.
    pub fn parse(s: &str) -> Option<AlgoKind> {
        match s {
            "ring" | "flat_ring" => Some(AlgoKind::FlatRing),
            "recursive_doubling" | "rd" | "doubling" => Some(AlgoKind::RecursiveDoubling),
            "two_shot" | "twoshot" | "flash" => Some(AlgoKind::TwoShot),
            "hierarchical" | "hier" => Some(AlgoKind::Hierarchical),
            _ => None,
        }
    }

    /// Can this algorithm run a `world`-rank collective on `topo`?
    pub fn supports(self, world: usize, topo: &Topology) -> bool {
        match self {
            AlgoKind::FlatRing | AlgoKind::TwoShot => true,
            AlgoKind::RecursiveDoubling => world.is_power_of_two(),
            AlgoKind::Hierarchical => !topo.is_flat() && world == topo.world(),
        }
    }

    pub fn implementation(self) -> &'static dyn CollectiveAlgo {
        match self {
            AlgoKind::FlatRing => &FlatRing,
            AlgoKind::RecursiveDoubling => &RecursiveDoubling,
            AlgoKind::TwoShot => &TwoShot,
            AlgoKind::Hierarchical => &Hierarchical,
        }
    }
}

/// Execution context shared by every algorithm.
pub struct ExecCtx<'a> {
    pub comp: Option<&'a dyn Compressor>,
    pub topo: &'a Topology,
    /// `true`: time every encode/decode with `Instant` (Measured
    /// overhead mode). `false`: timings are discarded by the caller
    /// (Analytic mode), so the cheaper `requant_add` path runs and the
    /// redundant bit-packing of shards is skipped entirely.
    pub measure: bool,
}

/// One collective algorithm: a virtual-time link model plus a real
/// execution that applies compression at the algorithm's phase
/// boundaries.
pub trait CollectiveAlgo: Sync {
    fn kind(&self) -> AlgoKind;

    /// Modeled link seconds for a collective of `values` f32 values per
    /// rank across `world` ranks on `topo`.
    fn link_time(
        &self,
        values: usize,
        world: usize,
        comp: Option<&dyn Compressor>,
        topo: &Topology,
    ) -> f64;

    /// Values quantized + dequantized per rank (the analytic
    /// compression-overhead term; 0-cost compressors are the caller's
    /// concern). The flat ring matches the seed's `values * world`
    /// accounting exactly.
    fn codec_values(&self, values: usize, world: usize, topo: &Topology) -> usize;

    /// Execute `out = x + Σ partials` with this algorithm's phase
    /// structure and fill a [`CommReport`]. `partials` are borrowed
    /// slices so chunked execution can hand out sub-ranges without
    /// copying payload data. All transient buffers (wire bytes, phase
    /// partials) live in `scratch` so a warmed-up caller allocates
    /// nothing per collective.
    fn run(
        &self,
        x: &[f32],
        partials: &[&[f32]],
        ctx: &ExecCtx,
        out: &mut Vec<f32>,
        scratch: &mut CommScratch,
    ) -> CommReport;
}

/// fp16 baseline wire size for an uncompressed `len`-value message.
pub(crate) fn wire_bytes_of(comp: Option<&dyn Compressor>, len: usize) -> usize {
    comp.map_or(len * 2, |c| c.wire_bytes(len))
}

/// Partition `[0, len)` into `parts` contiguous ranges whose interior
/// boundaries fall on multiples of `align` (the compressor's block
/// granularity), so every slice stays independently encodable without
/// splitting a quantization block across two messages. When `len` is
/// not a multiple of `align` the sub-block remainder rides on the last
/// non-empty slice (only the final range may end off-grid — mirroring
/// the codec's trailing partial block). Trailing ranges may be empty
/// when `parts · align > len`.
///
/// Historical bug, kept fixed by `property_collective`: this used to
/// degrade to *unit* granularity whenever `len % align != 0`, silently
/// splitting MX blocks mid-stream for any odd hidden size and changing
/// two-shot numerics vs the unchunked path.
pub(crate) fn aligned_slices(len: usize, parts: usize, align: usize) -> Vec<Range<usize>> {
    let align = align.max(1);
    let units = len / align;
    let base = units / parts;
    let rem = units % parts;
    let mut sizes = Vec::with_capacity(parts);
    for j in 0..parts {
        sizes.push((base + usize::from(j < rem)) * align);
    }
    let tail = len - units * align;
    if tail > 0 {
        let last = sizes.iter().rposition(|&s| s > 0).unwrap_or(parts - 1);
        sizes[last] += tail;
    }
    let mut out = Vec::with_capacity(parts);
    let mut at = 0usize;
    for s in sizes {
        out.push(at..at + s);
        at += s;
    }
    out
}

fn base_report(kind: AlgoKind, len: usize, world: usize, comp: Option<&dyn Compressor>) -> CommReport {
    CommReport {
        algo: kind.name(),
        shard_wire_bytes: wire_bytes_of(comp, len),
        shard_raw_bytes: len * 2,
        wire_bytes: wire_bytes_of(comp, len) * world.saturating_sub(1),
        raw_bytes: len * 2 * world.saturating_sub(1),
        ..CommReport::default()
    }
}

/// Shared gather-style execution (ring and recursive doubling produce
/// identical payloads — every shard is quantized once at its source and
/// forwarded verbatim, so only the link schedule differs).
fn gather_reduce_exec(
    x: &[f32],
    partials: &[&[f32]],
    ctx: &ExecCtx,
    out: &mut Vec<f32>,
    scratch: &mut CommScratch,
    report: &mut CommReport,
) {
    let wire = &mut scratch.wire;
    let len = x.len();
    out.clear();
    out.extend_from_slice(x);
    match ctx.comp {
        None => {
            for p in partials {
                debug_assert_eq!(p.len(), len);
                for (o, v) in out.iter_mut().zip(p.iter()) {
                    *o += v;
                }
            }
        }
        Some(c) => {
            if ctx.measure {
                // encode every shard (measure one — they run concurrently
                // on real hardware); decode-and-accumulate all of them.
                let mut enc_once = 0.0;
                for (r, p) in partials.iter().enumerate() {
                    let t0 = Instant::now();
                    {
                        let _g = obs::span("mx.encode", Cat::Encode);
                        c.encode(p, wire);
                    }
                    let dt = t0.elapsed().as_secs_f64();
                    if r == 0 {
                        enc_once = dt;
                    }
                    let t1 = Instant::now();
                    {
                        let _g = obs::span("mx.decode", Cat::Decode);
                        c.decode_add(wire, len, out);
                    }
                    report.decode_s += t1.elapsed().as_secs_f64();
                }
                report.encode_s = enc_once;
            } else {
                // Analytic mode: the caller charges values/rate and
                // discards measured time, so skip the per-shard wire
                // packing and run the fused requantize+accumulate.
                for p in partials {
                    c.requant_add(p, out, wire);
                }
            }
        }
    }
}

/// The seed's flat ring all-gather + local reduce: (N-1) steps, each
/// rank forwarding one shard per step. On a multi-node topology the
/// lock-step ring is bounded by the slowest link it crosses.
pub struct FlatRing;

impl CollectiveAlgo for FlatRing {
    fn kind(&self) -> AlgoKind {
        AlgoKind::FlatRing
    }

    fn link_time(
        &self,
        values: usize,
        world: usize,
        comp: Option<&dyn Compressor>,
        topo: &Topology,
    ) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let w = wire_bytes_of(comp, values);
        (world - 1) as f64 * topo.bottleneck().transfer_time(w)
    }

    fn codec_values(&self, values: usize, world: usize, _topo: &Topology) -> usize {
        // quantize own shard + dequantize the other N-1 (seed accounting)
        values * world
    }

    fn run(
        &self,
        x: &[f32],
        partials: &[&[f32]],
        ctx: &ExecCtx,
        out: &mut Vec<f32>,
        scratch: &mut CommScratch,
    ) -> CommReport {
        let mut report = base_report(AlgoKind::FlatRing, x.len(), partials.len(), ctx.comp);
        gather_reduce_exec(x, partials, ctx, out, scratch, &mut report);
        report.link_s = self.link_time(x.len(), partials.len(), ctx.comp, ctx.topo);
        report
    }
}

/// Recursive-doubling all-gather: log2(N) steps; at step i every rank
/// exchanges its accumulated 2^i shards with a partner at distance 2^i.
/// Bandwidth-identical to the ring ((N-1)·w bytes) but only log2(N) α
/// terms — the latency-bound small-message winner. Requires a
/// power-of-two world.
pub struct RecursiveDoubling;

impl CollectiveAlgo for RecursiveDoubling {
    fn kind(&self) -> AlgoKind {
        AlgoKind::RecursiveDoubling
    }

    fn link_time(
        &self,
        values: usize,
        world: usize,
        comp: Option<&dyn Compressor>,
        topo: &Topology,
    ) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        debug_assert!(world.is_power_of_two());
        let w = wire_bytes_of(comp, values);
        let mut t = 0.0;
        let mut dist = 1usize;
        while dist < world {
            // partners at distance < gpus_per_node sit in the same node
            let link = if dist < topo.gpus_per_node { &topo.intra } else { &topo.inter };
            t += link.transfer_time(w * dist);
            dist *= 2;
        }
        t
    }

    fn codec_values(&self, values: usize, world: usize, _topo: &Topology) -> usize {
        // payloads are forwarded verbatim, so codec work matches the ring
        values * world
    }

    fn run(
        &self,
        x: &[f32],
        partials: &[&[f32]],
        ctx: &ExecCtx,
        out: &mut Vec<f32>,
        scratch: &mut CommScratch,
    ) -> CommReport {
        let mut report = base_report(AlgoKind::RecursiveDoubling, x.len(), partials.len(), ctx.comp);
        gather_reduce_exec(x, partials, ctx, out, scratch, &mut report);
        report.link_s = self.link_time(x.len(), partials.len(), ctx.comp, ctx.topo);
        report
    }
}

/// Two-shot all-reduce (Flash Communication): ring reduce-scatter of
/// 1/N-slices, then ring all-gather of the reduced slices, compression
/// applied to each phase's payloads. Moves ~2(N-1)/N of the shard per
/// rank instead of the gather's (N-1)·shard — the bandwidth-bound
/// large-message winner — at the cost of doubled α terms and a second
/// quantization of the reduced slices.
pub struct TwoShot;

impl TwoShot {
    fn slice_align(comp: Option<&dyn Compressor>) -> usize {
        comp.map_or(1, |c| c.alignment())
    }
}

impl CollectiveAlgo for TwoShot {
    fn kind(&self) -> AlgoKind {
        AlgoKind::TwoShot
    }

    fn link_time(
        &self,
        values: usize,
        world: usize,
        comp: Option<&dyn Compressor>,
        topo: &Topology,
    ) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let slices = aligned_slices(values, world, Self::slice_align(comp));
        let w_max = slices
            .iter()
            .map(|s| wire_bytes_of(comp, s.len()))
            .max()
            .unwrap_or(0);
        // two ring phases of (N-1) lock-step slice transfers each
        2.0 * (world - 1) as f64 * topo.bottleneck().transfer_time(w_max)
    }

    fn codec_values(&self, values: usize, world: usize, _topo: &Topology) -> usize {
        if world <= 1 {
            return values;
        }
        // per rank: phase 1 encodes (N-1)/N of its shard and decodes
        // (N-1)/N into its owned slice; phase 2 encodes its 1/N reduced
        // slice and decodes the (N-1)/N it receives.
        (values * (3 * world - 2)).div_ceil(world)
    }

    fn run(
        &self,
        x: &[f32],
        partials: &[&[f32]],
        ctx: &ExecCtx,
        out: &mut Vec<f32>,
        scratch: &mut CommScratch,
    ) -> CommReport {
        let CommScratch { wire, tmp, .. } = scratch;
        let n = partials.len();
        let len = x.len();
        let mut report = base_report(AlgoKind::TwoShot, len, n, ctx.comp);
        report.link_s = self.link_time(len, n, ctx.comp, ctx.topo);
        out.clear();
        out.extend_from_slice(x);

        let Some(c) = ctx.comp else {
            // uncompressed: both phases are exact. Mirror the compressed
            // path's slice-wise owner-first summation order so the
            // NoCompress codec (a bit-exact f32 round-trip) produces the
            // same bits as this branch.
            for (j, sl) in aligned_slices(len, n, 1).iter().enumerate() {
                if sl.is_empty() {
                    continue;
                }
                tmp.clear();
                tmp.extend_from_slice(&partials[j][sl.clone()]);
                for (r, p) in partials.iter().enumerate() {
                    if r == j {
                        continue;
                    }
                    debug_assert_eq!(p.len(), len);
                    for (t, v) in tmp.iter_mut().zip(&p[sl.clone()]) {
                        *t += v;
                    }
                }
                for (o, t) in out[sl.clone()].iter_mut().zip(tmp.iter()) {
                    *o += t;
                }
            }
            report.wire_bytes = (2 * n.saturating_sub(1) * len * 2).div_ceil(n.max(1));
            return report;
        };

        let slices = aligned_slices(len, n, c.alignment());
        let mut wire_sum = 0usize;
        // measured buckets, scaled to one rank's critical path below
        let (mut enc_p1, mut dec_p1, mut enc_p2, mut dec_p2) = (0.0f64, 0.0, 0.0, 0.0);
        for (j, sl) in slices.iter().enumerate() {
            if sl.is_empty() {
                continue;
            }
            wire_sum += c.wire_bytes(sl.len());
            // phase 1 — reduce-scatter: owner j's own contribution never
            // hits the wire (exact); every other rank's is quantized.
            tmp.clear();
            tmp.extend_from_slice(&partials[j][sl.clone()]);
            for (r, p) in partials.iter().enumerate() {
                if r == j {
                    continue;
                }
                if ctx.measure {
                    let t0 = Instant::now();
                    {
                        let _g = obs::span("mx.encode", Cat::Encode);
                        c.encode(&p[sl.clone()], wire);
                    }
                    enc_p1 += t0.elapsed().as_secs_f64();
                    let t1 = Instant::now();
                    {
                        let _g = obs::span("mx.decode", Cat::Decode);
                        c.decode_add(wire, sl.len(), &mut tmp);
                    }
                    dec_p1 += t1.elapsed().as_secs_f64();
                } else {
                    c.requant_add(&p[sl.clone()], &mut tmp, wire);
                }
            }
            // phase 2 — all-gather of the reduced slice, re-quantized
            // (the canonical output is the broadcast version every
            // non-owner receives).
            if ctx.measure {
                let t0 = Instant::now();
                {
                    let _g = obs::span("mx.encode", Cat::Encode);
                    c.encode(&tmp, wire);
                }
                enc_p2 += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                {
                    let _g = obs::span("mx.decode", Cat::Decode);
                    c.decode_add(wire, sl.len(), &mut out[sl.clone()]);
                }
                dec_p2 += t1.elapsed().as_secs_f64();
            } else {
                c.requant_add(&tmp, &mut out[sl.clone()], wire);
            }
        }
        // scale the measured all-rank work to one rank's share: phase 1
        // measured N·(N-1) ops of which a rank performs (N-1); phase 2
        // measured N encodes (rank does 1) and N decodes (rank does N-1).
        let nf = n as f64;
        report.encode_s = (enc_p1 + enc_p2) / nf;
        report.decode_s = dec_p1 / nf + dec_p2 * (nf - 1.0) / nf;
        // per-rank received bytes: (N-1) phase-1 chunks of its owned
        // slice + the (N-1)/N of the reduced vector it doesn't own.
        report.wire_bytes = (2 * n.saturating_sub(1) * wire_sum).div_ceil(n.max(1));
        report
    }
}

/// Hierarchical two-level gather: ring gather+reduce inside each node
/// over the fast intra link, exchange of per-node sums between node
/// leaders over the slow inter link, then an intra re-broadcast. Only
/// (nodes-1) shard-sized messages ever cross the inter link, vs the
/// flat ring's (N-1).
pub struct Hierarchical;

impl CollectiveAlgo for Hierarchical {
    fn kind(&self) -> AlgoKind {
        AlgoKind::Hierarchical
    }

    fn link_time(
        &self,
        values: usize,
        world: usize,
        comp: Option<&dyn Compressor>,
        topo: &Topology,
    ) -> f64 {
        if world <= 1 {
            return 0.0;
        }
        let w = wire_bytes_of(comp, values);
        let g = topo.gpus_per_node;
        let m = topo.nodes;
        // intra gather of g shards, inter exchange of m node sums,
        // intra re-broadcast of the (m-1) remote sums
        (g.saturating_sub(1)) as f64 * topo.intra.transfer_time(w)
            + (m.saturating_sub(1)) as f64 * topo.inter.transfer_time(w)
            + (m.saturating_sub(1)) as f64 * topo.intra.transfer_time(w)
    }

    fn codec_values(&self, values: usize, world: usize, topo: &Topology) -> usize {
        // encode own partial + (leader) the node sum; decode the g
        // intra shards and the (m-1) remote node sums
        let g = topo.gpus_per_node.min(world.max(1));
        let m = topo.nodes.max(1);
        values * (2 + g + m.saturating_sub(1))
    }

    fn run(
        &self,
        x: &[f32],
        partials: &[&[f32]],
        ctx: &ExecCtx,
        out: &mut Vec<f32>,
        scratch: &mut CommScratch,
    ) -> CommReport {
        let CommScratch { wire, tmp, .. } = scratch;
        let n = partials.len();
        let len = x.len();
        let topo = ctx.topo;
        let mut report = base_report(AlgoKind::Hierarchical, len, n, ctx.comp);
        report.link_s = self.link_time(len, n, ctx.comp, topo);
        out.clear();
        out.extend_from_slice(x);

        let Some(c) = ctx.comp else {
            // uncompressed: mirror the compressed path's node-sum order
            // (zeros + members, then out += node sum) so NoCompress is
            // bitwise identical to this branch
            let m = topo.nodes.max(1);
            let g = topo.gpus_per_node.max(1);
            for node in 0..m {
                // ranks are node-major, so node k's members are the
                // contiguous range k·g .. (k+1)·g
                let members = node * g..((node + 1) * g).min(n);
                if members.is_empty() {
                    continue;
                }
                tmp.clear();
                tmp.resize(len, 0.0);
                for r in members {
                    debug_assert_eq!(partials[r].len(), len);
                    for (t, v) in tmp.iter_mut().zip(partials[r].iter()) {
                        *t += v;
                    }
                }
                for (o, t) in out.iter_mut().zip(tmp.iter()) {
                    *o += t;
                }
            }
            report.wire_bytes = (g + m).saturating_sub(2) * len * 2;
            return report;
        };

        let m = topo.nodes.max(1);
        let g = topo.gpus_per_node.max(1);
        let (mut enc_a, mut dec_a, mut enc_b, mut dec_b) = (0.0f64, 0.0, 0.0, 0.0);
        for node in 0..m {
            // phase A — intra-node gather + reduce (every member's
            // partial quantized once, matching the flat path's "all
            // shards compressed" semantics); ranks are node-major, so
            // node k's members are the contiguous range k·g .. (k+1)·g
            let members = node * g..((node + 1) * g).min(n);
            if members.is_empty() {
                continue;
            }
            tmp.clear();
            tmp.resize(len, 0.0);
            for r in members {
                debug_assert_eq!(partials[r].len(), len);
                if ctx.measure {
                    let t0 = Instant::now();
                    {
                        let _g = obs::span("mx.encode", Cat::Encode);
                        c.encode(partials[r], wire);
                    }
                    enc_a += t0.elapsed().as_secs_f64();
                    let t1 = Instant::now();
                    {
                        let _g = obs::span("mx.decode", Cat::Decode);
                        c.decode_add(wire, len, &mut tmp);
                    }
                    dec_a += t1.elapsed().as_secs_f64();
                } else {
                    c.requant_add(partials[r], &mut tmp, wire);
                }
            }
            // phase B/C — the node sum is quantized by the leader,
            // crosses the inter link, and is re-broadcast intra-node
            if ctx.measure {
                let t0 = Instant::now();
                {
                    let _g = obs::span("mx.encode", Cat::Encode);
                    c.encode(&tmp, wire);
                }
                enc_b += t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                {
                    let _g = obs::span("mx.decode", Cat::Decode);
                    c.decode_add(wire, len, out);
                }
                dec_b += t1.elapsed().as_secs_f64();
            } else {
                c.requant_add(&tmp, out, wire);
            }
        }
        // per-rank critical path: phase A measured N encodes (rank does
        // 1) and N decodes (rank does g = N/m); phase B measured m
        // encodes (a leader does 1) and m decodes (rank decodes the m-1
        // remote sums).
        let nf = n.max(1) as f64;
        let mf = m as f64;
        report.encode_s = enc_a / nf + enc_b / mf;
        report.decode_s = dec_a / mf + dec_b * (mf - 1.0).max(0.0) / mf;
        let w = c.wire_bytes(len);
        report.wire_bytes = (g + m).saturating_sub(2) * w;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::LinkModel;

    fn flat(n: usize) -> Topology {
        Topology::flat(n, LinkModel { alpha_s: 1e-5, beta_bytes_per_s: 1e9 })
    }

    fn two_level(m: usize, g: usize) -> Topology {
        Topology::two_level(
            m,
            g,
            LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 64e9 },
            LinkModel { alpha_s: 3e-5, beta_bytes_per_s: 1.5e9 },
        )
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for k in AlgoKind::ALL {
            assert_eq!(AlgoKind::parse(k.name()), Some(k));
        }
        assert_eq!(AlgoKind::parse("rd"), Some(AlgoKind::RecursiveDoubling));
        assert_eq!(AlgoKind::parse("flash"), Some(AlgoKind::TwoShot));
        assert_eq!(AlgoKind::parse("nccl"), None);
    }

    #[test]
    fn supports_matrix() {
        let f8 = flat(8);
        let t24 = two_level(2, 4);
        assert!(AlgoKind::FlatRing.supports(8, &f8));
        assert!(AlgoKind::RecursiveDoubling.supports(8, &f8));
        assert!(!AlgoKind::RecursiveDoubling.supports(6, &f8));
        assert!(AlgoKind::TwoShot.supports(3, &f8));
        assert!(!AlgoKind::Hierarchical.supports(8, &f8));
        assert!(AlgoKind::Hierarchical.supports(8, &t24));
        assert!(!AlgoKind::Hierarchical.supports(6, &t24));
    }

    #[test]
    fn aligned_slices_cover_and_align() {
        for (len, parts, align) in [
            (1024, 4, 32),
            (96, 3, 32),
            (192, 8, 32),
            (7, 3, 1),
            (64, 8, 16),
            // odd lengths: every interior boundary still block-aligned
            (100, 3, 32),
            (1438, 3, 32),
            (7, 3, 32),
            (33, 4, 32),
        ] {
            let sl = aligned_slices(len, parts, align);
            assert_eq!(sl.len(), parts);
            let mut at = 0;
            for s in &sl {
                assert_eq!(s.start, at);
                // interior boundaries never split a block; only the
                // final range may end off-grid (the sub-block tail)
                if s.end != len {
                    assert_eq!(s.end % align, 0, "{len}/{parts}/{align}: {s:?}");
                }
                at = s.end;
            }
            assert_eq!(at, len);
        }
        // the historical bug: len=100, align=32 degraded to unit
        // granularity ([34, 33, 33]); now the tail rides the last slice
        let sl = aligned_slices(100, 3, 32);
        assert_eq!(
            sl.iter().map(|s| s.len()).collect::<Vec<_>>(),
            vec![32, 32, 36]
        );
        // tail shorter than one block on every part: all of it lands in
        // the last slot rather than splitting
        let sl = aligned_slices(7, 3, 32);
        assert_eq!(
            sl.iter().map(|s| s.len()).collect::<Vec<_>>(),
            vec![0, 0, 7]
        );
    }

    #[test]
    fn ring_matches_seed_link_model_on_flat_topo() {
        let topo = flat(4);
        let t = FlatRing.link_time(1 << 16, 4, None, &topo);
        let seed = topo.intra.all_gather_time((1 << 16) * 2, 4);
        assert!((t - seed).abs() < 1e-15);
    }

    #[test]
    fn recursive_doubling_fewer_alpha_terms() {
        // tiny message: ring pays (N-1) α, doubling pays log2(N) α
        let topo = flat(8);
        let ring = FlatRing.link_time(16, 8, None, &topo);
        let rd = RecursiveDoubling.link_time(16, 8, None, &topo);
        assert!(rd < ring, "rd {rd} vs ring {ring}");
        // large message: same (N-1)·w/β bandwidth term, so near-equal
        let ring = FlatRing.link_time(1 << 22, 8, None, &topo);
        let rd = RecursiveDoubling.link_time(1 << 22, 8, None, &topo);
        assert!((rd - ring).abs() / ring < 0.01);
    }

    #[test]
    fn two_shot_moves_fewer_bytes_at_scale() {
        let topo = flat(8);
        let big = 1 << 22;
        let ring = FlatRing.link_time(big, 8, None, &topo);
        let ts = TwoShot.link_time(big, 8, None, &topo);
        // 2(N-1)/N vs (N-1): ~4x fewer bytes for N=8
        assert!(ts < ring * 0.35, "two-shot {ts} vs ring {ring}");
        // tiny message: doubled α terms lose
        let ring = FlatRing.link_time(8, 8, None, &topo);
        let ts = TwoShot.link_time(8, 8, None, &topo);
        assert!(ts > ring);
    }

    #[test]
    fn hierarchical_dodges_the_inter_link() {
        let topo = two_level(2, 4);
        let v = 1 << 20;
        let ring = FlatRing.link_time(v, 8, None, &topo);
        let hier = Hierarchical.link_time(v, 8, None, &topo);
        // ring pays 7 inter transfers, hierarchical pays 1
        assert!(hier < ring * 0.4, "hier {hier} vs ring {ring}");
    }
}
