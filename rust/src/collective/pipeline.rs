//! Chunked pipeline schedule for the collective engine.
//!
//! A monolithic collective serialises encode → link → decode. Splitting
//! the shard into C chunks lets the encode of chunk k+1 overlap the
//! modeled link time of chunk k (and the decode of chunk k overlap the
//! link time of chunk k+1) — the standard compression/communication
//! overlap trick. Codec time per chunk is *real measured work*; link
//! time per chunk comes from the algorithm's α/β model; the overlapped
//! total is a pure virtual-time computation over those per-chunk costs.
//!
//! The trade-off the planner weighs: overlap hides codec time behind
//! the wire, but every chunk pays the per-message α again.

use super::algo::{aligned_slices, CollectiveAlgo, ExecCtx};
use super::{CommReport, CommScratch};
use crate::mxfmt::Compressor;

/// Virtual-time cost of one pipeline chunk.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChunkCost {
    pub encode_s: f64,
    pub link_s: f64,
    pub decode_s: f64,
}

/// Overlapped completion time of a 3-stage (encode → link → decode)
/// pipeline: each stage is serial within itself; a chunk's link starts
/// once its encode *and* the previous chunk's link finish; its decode
/// once its link *and* the previous decode finish.
pub fn schedule(chunks: &[ChunkCost]) -> f64 {
    let mut enc_done = 0.0f64;
    let mut link_done = 0.0f64;
    let mut dec_done = 0.0f64;
    for c in chunks {
        enc_done += c.encode_s;
        link_done = link_done.max(enc_done) + c.link_s;
        dec_done = dec_done.max(link_done) + c.decode_s;
    }
    dec_done
}

/// Planner-side estimate: the overlapped total for `chunks` equal
/// chunks of a collective whose unchunked costs are (`encode_s`,
/// `link_of(chunk_values)` per chunk, `decode_s`).
pub fn estimate(
    algo: &dyn CollectiveAlgo,
    values: usize,
    world: usize,
    comp: Option<&dyn Compressor>,
    topo: &super::topology::Topology,
    encode_s: f64,
    decode_s: f64,
    chunks: usize,
) -> f64 {
    let chunks = chunks.max(1);
    let align = comp.map_or(1, |c| c.alignment());
    let costs: Vec<ChunkCost> = aligned_slices(values, chunks, align)
        .into_iter()
        .filter(|sl| !sl.is_empty())
        .map(|sl| {
            let frac = sl.len() as f64 / values.max(1) as f64;
            ChunkCost {
                encode_s: encode_s * frac,
                link_s: algo.link_time(sl.len(), world, comp, topo),
                decode_s: decode_s * frac,
            }
        })
        .collect();
    schedule(&costs)
}

/// Execute a gather-style collective in `chunks` pipeline chunks:
/// real codec work per chunk, per-chunk link from the algorithm's
/// model, overlapped total via [`schedule`]. Falls back to a single
/// chunk when the message can't be split (or `chunks <= 1`).
pub fn run_chunked(
    algo: &dyn CollectiveAlgo,
    x: &[f32],
    partials: &[&[f32]],
    ctx: &ExecCtx,
    chunks: usize,
    out: &mut Vec<f32>,
    scratch: &mut CommScratch,
) -> CommReport {
    let chunks = chunks.max(1);
    if chunks == 1 || x.is_empty() {
        return algo.run(x, partials, ctx, out, scratch);
    }
    let len = x.len();
    let align = ctx.comp.map_or(1, |c| c.alignment());
    let ranges: Vec<_> = aligned_slices(len, chunks, align)
        .into_iter()
        .filter(|sl| !sl.is_empty())
        .collect();
    if ranges.len() <= 1 {
        return algo.run(x, partials, ctx, out, scratch);
    }

    out.clear();
    out.reserve(len);
    let mut report = CommReport::default();
    let mut costs = Vec::with_capacity(ranges.len());
    // chunk_out is taken out of the scratch (not borrowed) so the
    // scratch can still be lent to each chunk's run
    let mut chunk_out = std::mem::take(&mut scratch.chunk_out);
    let mut chunk_parts: Vec<&[f32]> = Vec::with_capacity(partials.len());
    for sl in &ranges {
        // re-borrow each partial's sub-range — no payload copies
        chunk_parts.clear();
        chunk_parts.extend(partials.iter().map(|p| &p[sl.clone()]));
        let rep =
            algo.run(&x[sl.clone()], &chunk_parts, ctx, &mut chunk_out, scratch);
        out.extend_from_slice(&chunk_out);
        costs.push(ChunkCost {
            encode_s: rep.encode_s,
            link_s: rep.link_s,
            decode_s: rep.decode_s,
        });
        report.algo = rep.algo;
        report.shard_wire_bytes += rep.shard_wire_bytes;
        report.shard_raw_bytes += rep.shard_raw_bytes;
        report.wire_bytes += rep.wire_bytes;
        report.raw_bytes += rep.raw_bytes;
        report.link_s += rep.link_s;
        report.encode_s += rep.encode_s;
        report.decode_s += rep.decode_s;
    }
    report.chunks = costs.len();
    report.pipelined_s = schedule(&costs);
    scratch.chunk_out = chunk_out;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::algo::FlatRing;
    use crate::collective::topology::Topology;
    use crate::interconnect::LinkModel;
    use crate::mxfmt::{MxCodec, MxScheme};

    #[test]
    fn single_chunk_schedule_is_the_sum() {
        let c = [ChunkCost { encode_s: 1.0, link_s: 2.0, decode_s: 0.5 }];
        assert!((schedule(&c) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_codec_behind_link() {
        // 4 chunks, encode 1s each, link 2s each, no decode: the link
        // stage dominates — total = first encode + 4 links = 9s, not the
        // serial 12s.
        let c = vec![ChunkCost { encode_s: 1.0, link_s: 2.0, decode_s: 0.0 }; 4];
        assert!((schedule(&c) - 9.0).abs() < 1e-12);
        // encode-bound case: links hide behind encodes instead
        let c = vec![ChunkCost { encode_s: 2.0, link_s: 1.0, decode_s: 0.0 }; 4];
        assert!((schedule(&c) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn schedule_never_beats_the_bottleneck_stage() {
        let c = vec![ChunkCost { encode_s: 0.5, link_s: 2.0, decode_s: 0.25 }; 8];
        let total = schedule(&c);
        assert!(total >= 16.0); // the link stage alone
        assert!(total <= 0.5 * 8.0 + 2.0 * 8.0 + 0.25 * 8.0); // never worse than serial
    }

    #[test]
    fn chunked_run_matches_unchunked_numerics() {
        let topo = Topology::flat(4, LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 });
        let n = 256;
        let x = vec![0.1f32; n];
        let mut parts = vec![vec![0.0f32; n]; 4];
        let mut rng = crate::util::rng::Rng::new(5);
        for p in &mut parts {
            rng.fill_activations(p, 2.0);
        }
        let c = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let ctx = ExecCtx { comp: Some(&c), topo: &topo, measure: true };
        let refs: Vec<&[f32]> = parts.iter().map(Vec::as_slice).collect();
        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        let mut scratch = CommScratch::default();
        let r1 = FlatRing.run(&x, &refs, &ctx, &mut o1, &mut scratch);
        let r4 = run_chunked(&FlatRing, &x, &refs, &ctx, 4, &mut o2, &mut scratch);
        // chunking respects block boundaries, so the quantization grid —
        // and therefore the payload — is identical
        assert_eq!(o1, o2);
        assert_eq!(r4.chunks, 4);
        assert!(r4.pipelined_s > 0.0);
        // the overlapped total can't beat the link stage or lose to the
        // serial sum
        assert!(r4.pipelined_s <= r4.link_s + r4.encode_s + r4.decode_s + 1e-12);
        assert!(r4.pipelined_s >= r4.link_s - 1e-12);
        assert_eq!(r1.algo, r4.algo);
    }
}
