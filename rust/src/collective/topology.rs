//! Deployment topology for the collective engine.
//!
//! The seed's interconnect model was a single flat ring over one
//! `LinkModel`; real TP deployments are hierarchical — GPUs grouped
//! into nodes with a fast intra-node fabric (PCIe, NVLink) and a much
//! slower inter-node one (Ethernet, InfiniBand). Algorithm choice flips
//! with that asymmetry (arXiv 2507.14392), so [`Topology`] makes the
//! levels explicit: `nodes` groups of `gpus_per_node` ranks, an `intra`
//! link within a group and an `inter` link between groups. A flat
//! single-node world is the degenerate `nodes == 1` case, keeping every
//! seed profile bit-compatible.

use crate::interconnect::{HwProfile, LinkModel};

/// Node-grouped world layout plus per-level link models.
#[derive(Debug, Clone, Copy)]
pub struct Topology {
    /// number of node groups (1 = single node, flat world)
    pub nodes: usize,
    /// ranks per node group
    pub gpus_per_node: usize,
    /// link between two ranks in the same node
    pub intra: LinkModel,
    /// link between two ranks in different nodes (== `intra` when flat)
    pub inter: LinkModel,
}

impl Topology {
    /// Single-node world of `world` ranks over one link (seed behavior).
    pub fn flat(world: usize, link: LinkModel) -> Topology {
        Topology { nodes: 1, gpus_per_node: world.max(1), intra: link, inter: link }
    }

    /// Two-level world: `nodes` groups of `gpus_per_node`.
    pub fn two_level(
        nodes: usize,
        gpus_per_node: usize,
        intra: LinkModel,
        inter: LinkModel,
    ) -> Topology {
        Topology { nodes: nodes.max(1), gpus_per_node: gpus_per_node.max(1), intra, inter }
    }

    /// Build the topology a `world`-rank TP group sees on `profile`.
    /// Multi-node profiles split the ranks evenly across their nodes;
    /// when the world does not divide (or fits in one node) the layout
    /// degenerates to a flat single-node group over the intra link.
    pub fn from_profile(profile: &HwProfile, world: usize) -> Topology {
        let world = world.max(1);
        if profile.nodes > 1 && world > profile.nodes && world % profile.nodes == 0 {
            Topology::two_level(
                profile.nodes,
                world / profile.nodes,
                profile.link,
                profile.inter_link,
            )
        } else {
            Topology::flat(world, profile.link)
        }
    }

    pub fn world(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    pub fn is_flat(&self) -> bool {
        self.nodes == 1
    }

    /// Node group index of a rank (ranks are laid out node-major).
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// The link that bounds a step of a flat collective spanning the
    /// whole world: the inter-node link as soon as a ring/butterfly has
    /// to cross node boundaries, else the intra link.
    pub fn bottleneck(&self) -> &LinkModel {
        if self.is_flat() {
            &self.intra
        } else {
            &self.inter
        }
    }

    /// Stable cache key for planner memoisation (hashes the layout and
    /// the exact α/β bit patterns).
    pub fn cache_key(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for w in [
            self.nodes as u64,
            self.gpus_per_node as u64,
            self.intra.alpha_s.to_bits(),
            self.intra.beta_bytes_per_s.to_bits(),
            self.inter.alpha_s.to_bits(),
            self.inter.beta_bytes_per_s.to_bits(),
        ] {
            h ^= w;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link(beta: f64) -> LinkModel {
        LinkModel { alpha_s: 1e-6, beta_bytes_per_s: beta }
    }

    #[test]
    fn flat_world_is_single_node() {
        let t = Topology::flat(8, link(1e9));
        assert!(t.is_flat());
        assert_eq!(t.world(), 8);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.bottleneck().beta_bytes_per_s, 1e9);
    }

    #[test]
    fn two_level_groups_ranks_node_major() {
        let t = Topology::two_level(2, 4, link(64e9), link(1e9));
        assert_eq!(t.world(), 8);
        assert!(!t.is_flat());
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.node_of(7), 1);
        // a world-spanning ring is bounded by the slow inter link
        assert_eq!(t.bottleneck().beta_bytes_per_s, 1e9);
    }

    #[test]
    fn from_profile_degenerates_cleanly() {
        let l4 = HwProfile::by_name("l4").unwrap();
        let t = Topology::from_profile(l4, 8);
        assert!(t.is_flat());

        let multi = HwProfile::by_name("2x4l4").unwrap();
        let t = Topology::from_profile(multi, 8);
        assert_eq!((t.nodes, t.gpus_per_node), (2, 4));
        // world that doesn't divide the node count -> flat fallback
        let t = Topology::from_profile(multi, 3);
        assert!(t.is_flat());
        // world that fits in one node -> flat
        let t = Topology::from_profile(multi, 2);
        assert!(t.is_flat());
    }

    #[test]
    fn cache_key_distinguishes_layouts() {
        let a = Topology::flat(8, link(1e9));
        let b = Topology::two_level(2, 4, link(1e9), link(1e8));
        let c = Topology::two_level(2, 4, link(1e9), link(1e8));
        assert_ne!(a.cache_key(), b.cache_key());
        assert_eq!(b.cache_key(), c.cache_key());
    }
}
