//! TP collective: all-gather of row-parallel partials + local reduce,
//! with pluggable compression (paper Fig. 1b).
//!
//! Payloads move by memcpy (the workers share an address space);
//! *time* comes from two sources:
//!   - real, measured encode/decode work (the compression overhead the
//!     paper warns about — it runs on this thread and is timed), and
//!   - modeled link time from the interconnect simulator (α + bytes/β
//!     ring all-gather), since there is no real NVLink/PCIe here.

use std::time::Instant;

use crate::interconnect::LinkModel;
use crate::mxfmt::Compressor;

/// Outcome of one collective, for virtual-time accounting + telemetry.
#[derive(Debug, Clone, Default)]
pub struct CommReport {
    /// bytes each worker put on the wire (its shard)
    pub shard_wire_bytes: usize,
    /// uncompressed (fp16 baseline) shard size
    pub shard_raw_bytes: usize,
    /// modeled ring all-gather time (link simulator)
    pub link_s: f64,
    /// measured encode time (one worker's shard; workers run in
    /// parallel on real hardware, so per-step cost is ONE encode)
    pub encode_s: f64,
    /// measured decode+reduce time for the N-1 received shards
    pub decode_s: f64,
}

impl CommReport {
    /// Virtual elapsed time for the whole collective step.
    pub fn total_s(&self) -> f64 {
        self.link_s + self.encode_s + self.decode_s
    }
}

/// All-gather + reduce over `partials` (one slice per worker, equal
/// lengths); returns the elementwise sum plus the residual `x`, i.e.
/// `x + Σ_r partials[r]`, matching the model's `dequant_reduce_add` /
/// `reduce_add` stages.
///
/// With `comp = Some(..)`, every worker's shard is encoded and the
/// receivers decode; quantization error is therefore applied to ALL
/// shards (as in the paper, every worker compresses before the gather).
pub fn all_gather_reduce_add(
    x: &[f32],
    partials: &[Vec<f32>],
    comp: Option<&dyn Compressor>,
    link: &LinkModel,
    out: &mut Vec<f32>,
    wire: &mut Vec<u8>,
) -> CommReport {
    let n = partials.len();
    let len = x.len();
    out.clear();
    out.extend_from_slice(x);

    let mut report = CommReport {
        shard_raw_bytes: len * 2, // fp16 on-the-wire baseline
        ..Default::default()
    };

    match comp {
        None => {
            // uncompressed: fp16 wire accounting, f32 local math
            report.shard_wire_bytes = len * 2;
            for p in partials {
                debug_assert_eq!(p.len(), len);
                for (o, v) in out.iter_mut().zip(p) {
                    *o += v;
                }
            }
        }
        Some(c) => {
            report.shard_wire_bytes = c.wire_bytes(len);
            // encode every shard (measure one — they run concurrently on
            // real hardware); decode-and-accumulate all of them.
            let mut enc_once = 0.0;
            for (r, p) in partials.iter().enumerate() {
                let t0 = Instant::now();
                c.encode(p, wire);
                let dt = t0.elapsed().as_secs_f64();
                if r == 0 {
                    enc_once = dt;
                }
                let t1 = Instant::now();
                c.decode_add(wire, len, out);
                report.decode_s += t1.elapsed().as_secs_f64();
            }
            report.encode_s = enc_once;
        }
    }

    report.link_s = link.all_gather_time(report.shard_wire_bytes, n);
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::{MxCodec, MxScheme, NoCompress};
    use crate::util::rng::Rng;

    fn link() -> LinkModel {
        LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 }
    }

    #[test]
    fn uncompressed_reduce_is_exact() {
        let x = vec![1.0f32; 64];
        let parts = vec![vec![0.5f32; 64], vec![0.25f32; 64]];
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep = all_gather_reduce_add(&x, &parts, None, &link(), &mut out, &mut wire);
        assert!(out.iter().all(|&v| (v - 1.75).abs() < 1e-7));
        assert_eq!(rep.shard_wire_bytes, 64 * 2);
        assert!(rep.link_s > 0.0);
        assert_eq!(rep.encode_s, 0.0);
    }

    #[test]
    fn compressed_reduce_close_and_smaller() {
        let mut rng = Rng::new(1);
        let n = 512;
        let x = vec![0.0f32; n];
        let mut parts = vec![vec![0.0f32; n], vec![0.0f32; n]];
        for p in &mut parts {
            rng.fill_activations(p, 2.0);
        }
        let c = MxCodec::new(MxScheme::parse("fp5_e2m2_b16_e8m0").unwrap());
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep = all_gather_reduce_add(&x, &parts, Some(&c), &link(), &mut out, &mut wire);
        assert!(rep.shard_wire_bytes < rep.shard_raw_bytes / 2);
        // exact sum for comparison
        let exact: Vec<f32> = (0..n).map(|i| parts[0][i] + parts[1][i]).collect();
        let mut err_num = 0.0f64;
        let mut err_den = 0.0f64;
        for i in 0..n {
            err_num += ((out[i] - exact[i]) as f64).powi(2);
            err_den += (exact[i] as f64).powi(2);
        }
        let rel = (err_num / err_den).sqrt();
        // fp5 e2m2: 2 mantissa bits -> worst-case ~6% per block; partial
        // sums can cancel, so allow a little headroom over the per-shard
        // bound.
        assert!(rel < 0.09, "relative reduce error {rel}");
        assert!(rep.decode_s > 0.0 && rep.encode_s > 0.0);
    }

    #[test]
    fn compressed_link_time_beats_uncompressed() {
        let n = 1 << 16;
        let x = vec![0.0f32; n];
        let parts = vec![vec![1.0f32; n]; 4];
        let c = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep_c = all_gather_reduce_add(&x, &parts, Some(&c), &link(), &mut out, &mut wire);
        let rep_u = all_gather_reduce_add(&x, &parts, None, &link(), &mut out, &mut wire);
        assert!(rep_c.link_s < rep_u.link_s * 0.35);
    }

    #[test]
    fn nocompress_codec_matches_none_path() {
        let x = vec![0.5f32; 32];
        let parts = vec![vec![1.5f32; 32]];
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        let mut wire = Vec::new();
        all_gather_reduce_add(&x, &parts, None, &link(), &mut out1, &mut wire);
        all_gather_reduce_add(&x, &parts, Some(&NoCompress), &link(), &mut out2, &mut wire);
        assert_eq!(out1, out2);
    }
}
