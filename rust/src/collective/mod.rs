//! TP collective engine: all-gather/all-reduce of row-parallel partials
//! with pluggable compression (paper Fig. 1b), a menu of algorithms, a
//! two-level topology model, pipelined chunking, and an auto-planner.
//!
//! Payloads move by memcpy (the workers share an address space);
//! *time* comes from two sources:
//!   - real, measured encode/decode work (the compression overhead the
//!     paper warns about — it runs on this thread and is timed), and
//!   - modeled link time from the interconnect simulator (per-algorithm
//!     α/β schedules over the topology's links), since there is no real
//!     NVLink/PCIe/IB here.
//!
//! Submodules:
//!   - [`algo`]     — `CollectiveAlgo` trait + flat ring, recursive
//!                    doubling, two-shot (Flash-Communication style),
//!                    hierarchical two-level gather.
//!   - [`topology`] — node-grouped world layout + per-level links.
//!   - [`pipeline`] — chunked encode/link/decode overlap schedule.
//!   - [`plan`]     — auto-planner scoring {algorithm × chunking}.

pub mod algo;
pub mod pipeline;
pub mod plan;
pub mod topology;

pub use algo::{AlgoKind, CollectiveAlgo, ExecCtx};
pub use plan::{AlgoChoice, CollectivePlan};
pub use topology::Topology;

use crate::interconnect::LinkModel;
use crate::mxfmt::Compressor;

/// Reusable scratch buffers threaded through every collective so a
/// warmed-up caller (rank worker, engine step loop) allocates nothing
/// per collective: wire bytes, phase partials, and pipeline staging all
/// live here and only ever grow to the high-water mark.
///
/// The fields are disjoint on purpose — algorithms destructure the
/// struct to borrow `wire` and `tmp` simultaneously.
#[derive(Debug, Default)]
pub struct CommScratch {
    /// packed wire bytes (encode target / decode source)
    pub wire: Vec<u8>,
    /// slice-length partial accumulator (two-shot reduce-scatter slices,
    /// hierarchical node sums)
    pub tmp: Vec<f32>,
    /// per-chunk output staging for the pipelined schedule
    pub chunk_out: Vec<f32>,
}

/// Outcome of one collective, for virtual-time accounting + telemetry.
#[derive(Debug, Clone)]
pub struct CommReport {
    /// algorithm that ran (see [`AlgoKind::name`])
    pub algo: &'static str,
    /// pipeline chunks used (1 = monolithic)
    pub chunks: usize,
    /// bytes each worker put on the wire for its full shard
    pub shard_wire_bytes: usize,
    /// uncompressed (fp16 baseline) shard size
    pub shard_raw_bytes: usize,
    /// accounted per-worker received wire bytes for the whole collective
    pub wire_bytes: usize,
    /// fp16-baseline equivalent of `wire_bytes` (what an uncompressed
    /// ring all-gather would have moved per worker)
    pub raw_bytes: usize,
    /// modeled link time for the algorithm's schedule (link simulator)
    pub link_s: f64,
    /// measured encode time on one rank's critical path (workers run in
    /// parallel on real hardware, so per-step cost is ONE rank's share)
    pub encode_s: f64,
    /// measured decode+reduce time on one rank's critical path
    pub decode_s: f64,
    /// overlapped virtual total when pipelined (`chunks > 1`), else 0
    pub pipelined_s: f64,
}

impl Default for CommReport {
    fn default() -> CommReport {
        CommReport {
            algo: AlgoKind::FlatRing.name(),
            chunks: 1,
            shard_wire_bytes: 0,
            shard_raw_bytes: 0,
            wire_bytes: 0,
            raw_bytes: 0,
            link_s: 0.0,
            encode_s: 0.0,
            decode_s: 0.0,
            pipelined_s: 0.0,
        }
    }
}

impl CommReport {
    /// Virtual elapsed time for the whole collective step (the
    /// overlapped schedule when pipelined).
    pub fn total_s(&self) -> f64 {
        if self.chunks > 1 && self.pipelined_s > 0.0 {
            self.pipelined_s
        } else {
            self.link_s + self.encode_s + self.decode_s
        }
    }
}

/// Execute one collective under `plan`: `out = x + Σ partials`, with
/// compression applied at the chosen algorithm's phase boundaries and
/// pipelined over `plan.chunks` when chunked. `measure == false` skips
/// per-shard wall-clock timing and the redundant wire packing (Analytic
/// overhead mode — the caller charges values/rate instead).
pub fn execute(
    plan: &CollectivePlan,
    x: &[f32],
    partials: &[Vec<f32>],
    comp: Option<&dyn Compressor>,
    topo: &Topology,
    measure: bool,
    out: &mut Vec<f32>,
    scratch: &mut CommScratch,
) -> CommReport {
    let ctx = ExecCtx { comp, topo, measure };
    let refs: Vec<&[f32]> = partials.iter().map(Vec::as_slice).collect();
    pipeline::run_chunked(plan.algo.implementation(), x, &refs, &ctx, plan.chunks, out, scratch)
}

/// All-gather + reduce over `partials` (one slice per worker, equal
/// lengths); returns the elementwise sum plus the residual `x`, i.e.
/// `x + Σ_r partials[r]`, matching the model's `dequant_reduce_add` /
/// `reduce_add` stages.
///
/// With `comp = Some(..)`, every worker's shard is encoded and the
/// receivers decode; quantization error is therefore applied to ALL
/// shards (as in the paper, every worker compresses before the gather).
///
/// This is the seed's flat-ring entry point, preserved bit-identically;
/// the engine's planned path goes through [`execute`].
pub fn all_gather_reduce_add(
    x: &[f32],
    partials: &[Vec<f32>],
    comp: Option<&dyn Compressor>,
    link: &LinkModel,
    out: &mut Vec<f32>,
    wire: &mut Vec<u8>,
) -> CommReport {
    let topo = Topology::flat(partials.len(), *link);
    let ctx = ExecCtx { comp, topo: &topo, measure: true };
    let refs: Vec<&[f32]> = partials.iter().map(Vec::as_slice).collect();
    // keep the historical (out, wire) signature: lend the caller's wire
    // buffer to a scratch for the duration of the collective
    let mut scratch = CommScratch::default();
    std::mem::swap(&mut scratch.wire, wire);
    let rep = algo::FlatRing.run(x, &refs, &ctx, out, &mut scratch);
    std::mem::swap(&mut scratch.wire, wire);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::{MxCodec, MxScheme, NoCompress};
    use crate::util::rng::Rng;

    fn link() -> LinkModel {
        LinkModel { alpha_s: 1e-6, beta_bytes_per_s: 1e9 }
    }

    #[test]
    fn uncompressed_reduce_is_exact() {
        let x = vec![1.0f32; 64];
        let parts = vec![vec![0.5f32; 64], vec![0.25f32; 64]];
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep = all_gather_reduce_add(&x, &parts, None, &link(), &mut out, &mut wire);
        assert!(out.iter().all(|&v| (v - 1.75).abs() < 1e-7));
        assert_eq!(rep.shard_wire_bytes, 64 * 2);
        assert!(rep.link_s > 0.0);
        assert_eq!(rep.encode_s, 0.0);
        assert_eq!(rep.algo, "ring");
        assert_eq!(rep.wire_bytes, 64 * 2);
        assert_eq!(rep.raw_bytes, 64 * 2);
    }

    #[test]
    fn compressed_reduce_close_and_smaller() {
        let mut rng = Rng::new(1);
        let n = 512;
        let x = vec![0.0f32; n];
        let mut parts = vec![vec![0.0f32; n], vec![0.0f32; n]];
        for p in &mut parts {
            rng.fill_activations(p, 2.0);
        }
        let c = MxCodec::new(MxScheme::parse("fp5_e2m2_b16_e8m0").unwrap());
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep = all_gather_reduce_add(&x, &parts, Some(&c), &link(), &mut out, &mut wire);
        assert!(rep.shard_wire_bytes < rep.shard_raw_bytes / 2);
        // exact sum for comparison
        let exact: Vec<f32> = (0..n).map(|i| parts[0][i] + parts[1][i]).collect();
        let mut err_num = 0.0f64;
        let mut err_den = 0.0f64;
        for i in 0..n {
            err_num += ((out[i] - exact[i]) as f64).powi(2);
            err_den += (exact[i] as f64).powi(2);
        }
        let rel = (err_num / err_den).sqrt();
        // fp5 e2m2: 2 mantissa bits -> worst-case ~6% per block; partial
        // sums can cancel, so allow a little headroom over the per-shard
        // bound.
        assert!(rel < 0.09, "relative reduce error {rel}");
        assert!(rep.decode_s > 0.0 && rep.encode_s > 0.0);
    }

    #[test]
    fn compressed_link_time_beats_uncompressed() {
        let n = 1 << 16;
        let x = vec![0.0f32; n];
        let parts = vec![vec![1.0f32; n]; 4];
        let c = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let mut out = Vec::new();
        let mut wire = Vec::new();
        let rep_c = all_gather_reduce_add(&x, &parts, Some(&c), &link(), &mut out, &mut wire);
        let rep_u = all_gather_reduce_add(&x, &parts, None, &link(), &mut out, &mut wire);
        assert!(rep_c.link_s < rep_u.link_s * 0.35);
    }

    #[test]
    fn nocompress_codec_matches_none_path() {
        let x = vec![0.5f32; 32];
        let parts = vec![vec![1.5f32; 32]];
        let mut out1 = Vec::new();
        let mut out2 = Vec::new();
        let mut wire = Vec::new();
        all_gather_reduce_add(&x, &parts, None, &link(), &mut out1, &mut wire);
        all_gather_reduce_add(&x, &parts, Some(&NoCompress), &link(), &mut out2, &mut wire);
        assert_eq!(out1, out2);
    }

    #[test]
    fn planned_execute_matches_direct_ring() {
        // a plan pinned to the unchunked ring reproduces the seed path
        let n = 256;
        let mut rng = Rng::new(4);
        let x = vec![0.0f32; n];
        let mut parts = vec![vec![0.0f32; n]; 4];
        for p in &mut parts {
            rng.fill_activations(p, 2.0);
        }
        let c = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let topo = Topology::flat(4, link());
        let plan = CollectivePlan {
            algo: AlgoKind::FlatRing,
            chunks: 1,
            est_total_s: 0.0,
            est_link_s: 0.0,
            est_codec_s: 0.0,
        };
        let (mut o1, mut o2) = (Vec::new(), Vec::new());
        let mut wire = Vec::new();
        let mut scratch = CommScratch::default();
        let r1 = all_gather_reduce_add(&x, &parts, Some(&c), &link(), &mut o1, &mut wire);
        let r2 = execute(&plan, &x, &parts, Some(&c), &topo, true, &mut o2, &mut scratch);
        assert_eq!(o1, o2);
        assert_eq!(r1.link_s, r2.link_s);
        assert_eq!(r1.wire_bytes, r2.wire_bytes);
    }

    #[test]
    fn analytic_mode_skips_packing_but_not_numerics() {
        let n = 512;
        let mut rng = Rng::new(8);
        let x = vec![0.1f32; n];
        let mut parts = vec![vec![0.0f32; n]; 3];
        for p in &mut parts {
            rng.fill_activations(p, 2.0);
        }
        let c = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let topo = Topology::flat(3, link());
        let ctx_m = ExecCtx { comp: Some(&c), topo: &topo, measure: true };
        let ctx_a = ExecCtx { comp: Some(&c), topo: &topo, measure: false };
        let refs: Vec<&[f32]> = parts.iter().map(Vec::as_slice).collect();
        let (mut om, mut oa) = (Vec::new(), Vec::new());
        let mut scratch = CommScratch::default();
        let rm = algo::FlatRing.run(&x, &refs, &ctx_m, &mut om, &mut scratch);
        let ra = algo::FlatRing.run(&x, &refs, &ctx_a, &mut oa, &mut scratch);
        assert_eq!(om, oa, "requant path must be bit-equal to the wire path");
        assert!(rm.encode_s > 0.0 && rm.decode_s > 0.0);
        assert_eq!(ra.encode_s, 0.0);
        assert_eq!(ra.decode_s, 0.0);
        assert_eq!(rm.link_s, ra.link_s);
    }
}
