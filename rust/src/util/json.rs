//! Minimal JSON parser + writer (manifest.json, metrics, HTTP bodies).
//!
//! Supports the full JSON grammar; numbers are kept as f64 with an i64
//! fast path (shapes, counts). Not performance-critical: used at load
//! time and for telemetry, never on the token hot path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- writer ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
/// `Num` for finite values, `Null` otherwise — JSON has no NaN/Inf, so
/// latency fields from empty histograms (or requests that never
/// produced a token) must serialize as `null`, not `NaN`.
pub fn num_or_null(n: f64) -> Json {
    if n.is_finite() {
        Json::Num(n)
    } else {
        Json::Null
    }
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.peek().is_some_and(|c| c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"version": 1, "artifacts": [{"name": "a/b", "inputs": [{"shape": [1, 2], "dtype": "float32"}]}], "ok": true, "x": null, "pi": 3.25}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_i64(), Some(1));
        assert_eq!(
            j.path("artifacts").unwrap().idx(0).unwrap().get("name").unwrap().as_str(),
            Some("a/b")
        );
        assert_eq!(j.get("pi").unwrap().as_f64(), Some(3.25));
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("x"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,"x\n\"y\"",null,true,{"b":[]}]}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""aA\t""#).unwrap();
        assert_eq!(j.as_str(), Some("aA\t"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn nested_deep() {
        let j = Json::parse("[[[[[[1]]]]]]").unwrap();
        let mut cur = &j;
        for _ in 0..6 {
            cur = cur.idx(0).unwrap();
        }
        assert_eq!(cur.as_i64(), Some(1));
    }

    #[test]
    fn non_finite_numbers_become_null() {
        assert_eq!(num_or_null(1.5).to_string(), "1.5");
        assert_eq!(num_or_null(f64::NAN).to_string(), "null");
        assert_eq!(num_or_null(f64::INFINITY).to_string(), "null");
        assert_eq!(num_or_null(f64::NEG_INFINITY).to_string(), "null");
        // and the result round-trips as valid JSON
        let j = obj(vec![("x", num_or_null(f64::NAN))]).to_string();
        assert!(Json::parse(&j).is_ok(), "{j}");
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""héllo – ö""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo – ö"));
    }
}
