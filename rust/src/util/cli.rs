//! Tiny argv parser: `cmd --key value --key=value --flag positional`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn mixed_forms() {
        // note: `--flag positional` is ambiguous (flag would swallow the
        // positional as its value), so positionals precede bare flags.
        let a = parse("serve input.txt --model micro --tp=4 --verbose");
        assert_eq!(a.positional, vec!["serve", "input.txt"]);
        assert_eq!(a.get("model"), Some("micro"));
        assert_eq!(a.get_usize("tp", 1), 4);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("model", "nano"), "nano");
        assert_eq!(a.get_f64("rate", 2.5), 2.5);
    }

    #[test]
    fn flag_before_positional_not_greedy() {
        let a = parse("--flag --key val");
        assert!(a.has("flag"));
        assert_eq!(a.get("key"), Some("val"));
    }
}
