//! Deterministic xorshift64* RNG.
//!
//! Used by the workload generator, property tests and samplers — the
//! offline vendor set has no `rand`, and determinism matters more than
//! statistical perfection here.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Activation-like values: normal with lognormal magnitude spread
    /// (produces the outliers the paper's fine-grained schemes target).
    pub fn activation(&mut self, spread: f32) -> f32 {
        self.normal() * (self.normal() * spread / 2.0).exp()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrivals).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn fill_activations(&mut self, out: &mut [f32], spread: f32) {
        for v in out.iter_mut() {
            *v = self.activation(spread);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
            let n = r.below(17);
            assert!(n < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let vals: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = vals.iter().sum::<f32>() / n as f32;
        let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let m = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((m - 0.25).abs() < 0.02, "mean {}", m);
    }
}
