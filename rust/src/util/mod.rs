//! From-scratch substrates: the offline vendor set has no serde / clap /
//! rand / proptest, so the coordinator carries its own minimal JSON,
//! NumPy-format, CLI and RNG implementations (DESIGN.md substrate list).

pub mod cli;
pub mod json;
pub mod npy;
pub mod rng;
