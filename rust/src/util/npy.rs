//! NumPy `.npy` (format v1/v2) reader + writer.
//!
//! The build-time python side saves model weights and golden vectors with
//! `np.save`; the coordinator loads them through this parser. Supports
//! little-endian f32/f64/i32/i64/u8 C-order arrays — exactly what the
//! exporter produces.

use std::fs;
use std::io::Write;
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    I32,
    I64,
    U8,
}

impl Dtype {
    fn from_descr(d: &str) -> Option<Dtype> {
        match d {
            "<f4" => Some(Dtype::F32),
            "<f8" => Some(Dtype::F64),
            "<i4" => Some(Dtype::I32),
            "<i8" => Some(Dtype::I64),
            "|u1" | "<u1" => Some(Dtype::U8),
            _ => None,
        }
    }
    pub fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::F64 => "<f8",
            Dtype::I32 => "<i4",
            Dtype::I64 => "<i8",
            Dtype::U8 => "|u1",
        }
    }
    pub fn size(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F64 | Dtype::I64 => 8,
        }
    }
}

/// A loaded array: raw little-endian buffer + shape + dtype.
#[derive(Debug, Clone)]
pub struct Npy {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

#[derive(Debug, thiserror::Error)]
pub enum NpyError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("not an npy file")]
    BadMagic,
    #[error("unsupported npy: {0}")]
    Unsupported(String),
}

impl Npy {
    pub fn load(path: &Path) -> Result<Npy, NpyError> {
        Self::parse(&fs::read(path)?)
    }

    pub fn parse(raw: &[u8]) -> Result<Npy, NpyError> {
        if raw.len() < 10 || &raw[..6] != b"\x93NUMPY" {
            return Err(NpyError::BadMagic);
        }
        let major = raw[6];
        let (header_len, header_start) = match major {
            1 => (u16::from_le_bytes([raw[8], raw[9]]) as usize, 10),
            2 | 3 => (
                u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize,
                12,
            ),
            v => return Err(NpyError::Unsupported(format!("version {}", v))),
        };
        let header = std::str::from_utf8(&raw[header_start..header_start + header_len])
            .map_err(|_| NpyError::Unsupported("non-utf8 header".into()))?;
        let descr = dict_str(header, "descr")
            .ok_or_else(|| NpyError::Unsupported("missing descr".into()))?;
        let dtype = Dtype::from_descr(&descr)
            .ok_or_else(|| NpyError::Unsupported(format!("dtype {}", descr)))?;
        if dict_raw(header, "fortran_order").map(|v| v.trim().to_string())
            == Some("True".to_string())
        {
            return Err(NpyError::Unsupported("fortran order".into()));
        }
        let shape_txt = dict_raw(header, "shape")
            .ok_or_else(|| NpyError::Unsupported("missing shape".into()))?;
        let shape: Vec<usize> = shape_txt
            .trim()
            .trim_start_matches('(')
            .trim_end_matches(')')
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| t.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| NpyError::Unsupported(format!("shape {}", shape_txt)))?;
        let n: usize = shape.iter().product::<usize>().max(1) * if shape.is_empty() { 1 } else { 1 };
        let count: usize = shape.iter().product();
        let count = if shape.is_empty() { 1 } else { count };
        let _ = n;
        let data_start = header_start + header_len;
        let need = count * dtype.size();
        if raw.len() < data_start + need {
            return Err(NpyError::Unsupported("short data".into()));
        }
        Ok(Npy {
            dtype,
            shape,
            data: raw[data_start..data_start + need].to_vec(),
        })
    }

    pub fn len(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape.iter().product()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<Vec<f32>> {
        match self.dtype {
            Dtype::F32 => Some(
                self.data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            Dtype::F64 => Some(
                self.data
                    .chunks_exact(8)
                    .map(|c| {
                        f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                    })
                    .collect(),
            ),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<Vec<i32>> {
        match self.dtype {
            Dtype::I32 => Some(
                self.data
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            Dtype::I64 => Some(
                self.data
                    .chunks_exact(8)
                    .map(|c| {
                        i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as i32
                    })
                    .collect(),
            ),
            _ => None,
        }
    }

    pub fn as_u8(&self) -> Option<&[u8]> {
        match self.dtype {
            Dtype::U8 => Some(&self.data),
            _ => None,
        }
    }

    // -- writer ------------------------------------------------------------

    pub fn from_f32(shape: &[usize], vals: &[f32]) -> Npy {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Npy { dtype: Dtype::F32, shape: shape.to_vec(), data }
    }

    pub fn from_u8(shape: &[usize], vals: &[u8]) -> Npy {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        Npy { dtype: Dtype::U8, shape: shape.to_vec(), data: vals.to_vec() }
    }

    pub fn save(&self, path: &Path) -> Result<(), NpyError> {
        let shape_txt = match self.shape.len() {
            0 => "()".to_string(),
            1 => format!("({},)", self.shape[0]),
            _ => format!(
                "({})",
                self.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
            ),
        };
        let mut header = format!(
            "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
            self.dtype.descr(),
            shape_txt
        );
        // pad so that data starts at a multiple of 64
        let base = 10 + header.len() + 1;
        let pad = (64 - base % 64) % 64;
        header.push_str(&" ".repeat(pad));
        header.push('\n');
        let mut f = fs::File::create(path)?;
        f.write_all(b"\x93NUMPY\x01\x00")?;
        f.write_all(&(header.len() as u16).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        f.write_all(&self.data)?;
        Ok(())
    }
}

/// Extract `'key': <value>` from the python-dict-literal header.
fn dict_raw(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{}':", key);
    let start = header.find(&pat)? + pat.len();
    let rest = &header[start..];
    // value ends at the next top-level comma or closing brace
    let mut depth = 0usize;
    let mut end = rest.len();
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => {
                end = i;
                break;
            }
            _ => {}
        }
    }
    Some(rest[..end].trim().to_string())
}

fn dict_str(header: &str, key: &str) -> Option<String> {
    let raw = dict_raw(header, key)?;
    Some(raw.trim_matches(|c| c == '\'' || c == '"').to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let vals: Vec<f32> = (0..24).map(|i| i as f32 * 0.5 - 3.0).collect();
        let a = Npy::from_f32(&[2, 3, 4], &vals);
        let tmp = std::env::temp_dir().join("tpcc_npy_rt.npy");
        a.save(&tmp).unwrap();
        let b = Npy::load(&tmp).unwrap();
        assert_eq!(b.shape, vec![2, 3, 4]);
        assert_eq!(b.as_f32().unwrap(), vals);
    }

    #[test]
    fn roundtrip_u8() {
        let vals: Vec<u8> = (0..10).collect();
        let a = Npy::from_u8(&[10], &vals);
        let tmp = std::env::temp_dir().join("tpcc_npy_u8.npy");
        a.save(&tmp).unwrap();
        let b = Npy::load(&tmp).unwrap();
        assert_eq!(b.shape, vec![10]);
        assert_eq!(b.as_u8().unwrap(), &vals[..]);
    }

    #[test]
    fn header_parser() {
        assert_eq!(
            dict_str("{'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }", "descr"),
            Some("<f4".to_string())
        );
        assert_eq!(
            dict_raw("{'descr': '<f4', 'fortran_order': False, 'shape': (3, 4), }", "shape"),
            Some("(3, 4)".to_string())
        );
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(matches!(Npy::parse(b"not an npy"), Err(NpyError::BadMagic)));
    }

    #[test]
    fn scalar_shape() {
        let a = Npy::from_f32(&[], &[1.5]);
        let tmp = std::env::temp_dir().join("tpcc_npy_scalar.npy");
        a.save(&tmp).unwrap();
        let b = Npy::load(&tmp).unwrap();
        assert!(b.shape.is_empty());
        assert_eq!(b.as_f32().unwrap(), vec![1.5]);
    }
}
