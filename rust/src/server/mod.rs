//! Minimal HTTP/1.1 front end (std TCP — the offline crate set has no
//! tokio/hyper, so this substrate is hand-rolled).
//!
//! Endpoints:
//!   POST /generate  {"prompt": "...", "max_tokens": 32, "greedy": true}
//!                   (+ "stream": true -> chunked NDJSON: one line per
//!                   token as the batcher emits it, then a final line
//!                   with "done": true and the full response)
//!   GET  /metrics   -> JSON snapshot of the registry
//!                      (?format=prom -> Prometheus text exposition)
//!   GET  /metrics/history -> bounded time-series ring of registry
//!                      snapshots with windowed rates + SLO burn rate
//!   GET  /debug/requests -> flight recorder: per-request records
//!                      (recent-K + slowest-K), read by `tpcc explain`
//!   GET  /policy    -> JSON of the engine's per-site compression policy
//!                      (+ `policy_drift` from the error sentinel)
//!   GET  /trace     -> Chrome-trace JSON of recorded spans (?last=N
//!                      keeps the newest N; snapshot, non-destructive)
//!   GET  /logs      -> structured event log tail (?last=N newest N,
//!                      ?level=warn filters to warn-and-above)
//!   GET  /alerts    -> alert-rule states (firing/pending/inactive,
//!                      fired/resolved counts); the same rules export
//!                      as `tpcc_alert_firing` gauges on ?format=prom
//!   GET  /healthz
//!
//! Every answered connection lands in the per-(route, status) counters
//! (`http_requests_total`) and emits one `server` access-log event
//! (path, status, latency) — including 400s for malformed requests and
//! 503s for shed connections.
//!
//! Connections are served by a **fixed worker pool** over a bounded
//! pending queue, not thread-per-connection: a burst can never spawn an
//! unbounded number of OS threads. When the queue is full the accept
//! loop answers `503 Service Unavailable` immediately instead of
//! letting the backlog grow without limit — every connection gets an
//! HTTP answer, bounded by `workers + backlog` in-flight at once
//! (pinned by `tests/server_pool.rs`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};

use crate::coordinator::{CoordinatorHandle, GenRequest, StreamEvent};
use crate::obs::log::Level;
use crate::util::json::{self, Json};

/// Observable pool behaviour (tests assert the cap holds under burst).
#[derive(Default)]
pub struct PoolStats {
    active: AtomicUsize,
    /// high-watermark of concurrently-handling workers
    peak_active: AtomicUsize,
    /// connections answered by a worker
    served: AtomicUsize,
    /// connections answered 503 because the pending queue was full
    shed: AtomicUsize,
}

impl PoolStats {
    pub fn peak_active(&self) -> usize {
        self.peak_active.load(Ordering::SeqCst)
    }
    pub fn served(&self) -> usize {
        self.served.load(Ordering::SeqCst)
    }
    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::SeqCst)
    }

    fn enter(&self) {
        let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak_active.fetch_max(now, Ordering::SeqCst);
    }
    fn exit(&self) {
        self.active.fetch_sub(1, Ordering::SeqCst);
        self.served.fetch_add(1, Ordering::SeqCst);
    }
}

pub struct Server {
    listener: TcpListener,
    handle: CoordinatorHandle,
    workers: usize,
    backlog: usize,
    io_timeout: std::time::Duration,
    stats: Arc<PoolStats>,
}

/// Default worker-pool size: enough for the single-engine coordinator
/// behind it (requests serialize on the engine anyway) plus headroom
/// for the cheap read-only endpoints.
pub const DEFAULT_WORKERS: usize = 8;
/// Default bound on queued-but-unhandled connections before shedding.
pub const DEFAULT_BACKLOG: usize = 64;
/// Per-*operation* socket I/O timeout. A fixed pool turns a client that
/// connects and sends nothing into a wedged worker; with the timeout
/// the read errors out and the worker moves on (the old
/// thread-per-connection model merely leaked the thread). The deadline
/// is armed per socket operation — and for streaming responses re-armed
/// after every successful token write — never once for the whole
/// request, so a long generation streaming steadily is never killed
/// mid-stream no matter its total duration. Engine *compute* between
/// read and write is not bounded by this.
pub const CLIENT_IO_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

impl Server {
    pub fn bind(addr: &str, handle: CoordinatorHandle) -> anyhow::Result<Server> {
        Ok(Server {
            listener: TcpListener::bind(addr)?,
            handle,
            workers: DEFAULT_WORKERS,
            backlog: DEFAULT_BACKLOG,
            io_timeout: CLIENT_IO_TIMEOUT,
            stats: Arc::new(PoolStats::default()),
        })
    }

    /// Override the worker-pool size and pending-connection cap.
    pub fn with_pool(mut self, workers: usize, backlog: usize) -> Server {
        self.workers = workers.max(1);
        self.backlog = backlog.max(1);
        self
    }

    /// Override the per-operation socket I/O timeout (tests shrink it to
    /// keep slow-client regressions fast).
    pub fn with_io_timeout(mut self, t: std::time::Duration) -> Server {
        self.io_timeout = t;
        self
    }

    /// Pool observability handle (live counters; cloneable before
    /// `serve_*` consumes the server).
    pub fn stats(&self) -> Arc<PoolStats> {
        self.stats.clone()
    }

    pub fn local_addr(&self) -> anyhow::Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    fn spawn_workers(
        &self,
        rx: Arc<Mutex<Receiver<TcpStream>>>,
    ) -> Vec<std::thread::JoinHandle<()>> {
        (0..self.workers)
            .map(|i| {
                let rx = rx.clone();
                let handle = self.handle.clone();
                let stats = self.stats.clone();
                let io_timeout = self.io_timeout;
                std::thread::Builder::new()
                    .name(format!("tpcc-http{i}"))
                    .spawn(move || loop {
                        // hold the lock only to dequeue, never while
                        // handling, or the pool would serialize; a
                        // poisoned lock (panicking peer) must not
                        // cascade through the whole pool
                        let stream = {
                            let guard =
                                rx.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                            match guard.recv() {
                                Ok(s) => s,
                                Err(_) => break,
                            }
                        };
                        // a silent client must not wedge a pool worker
                        let _ = stream.set_read_timeout(Some(io_timeout));
                        let _ = stream.set_write_timeout(Some(io_timeout));
                        stats.enter();
                        // a handler panic costs this connection, not the
                        // worker (thread-per-connection parity)
                        let handle = handle.clone();
                        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            move || {
                                let _ = handle_conn(stream, handle, io_timeout);
                            },
                        ));
                        stats.exit();
                    })
                    .expect("spawn http worker")
            })
            .collect()
    }

    /// Dispatch one accepted connection: queue it for a worker, or shed
    /// it with a 503 when the pending queue is full. Sheds count into
    /// the registry (`requests_shed`, `http_requests_total`) and emit a
    /// warn event — an operator must be able to see load being turned
    /// away.
    fn dispatch(
        stream: TcpStream,
        tx: &std::sync::mpsc::SyncSender<TcpStream>,
        stats: &PoolStats,
        handle: &CoordinatorHandle,
    ) {
        match tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(mut stream)) => {
                stats.shed.fetch_add(1, Ordering::SeqCst);
                handle.metrics.requests_shed.inc();
                handle.metrics.record_http("(shed)", 503);
                handle.log.warn(
                    "server",
                    "connection shed: pending queue full",
                    vec![("shed_total", json::num(handle.metrics.requests_shed.get() as f64))],
                );
                let _ = respond(&mut stream, 503, r#"{"error":"server overloaded"}"#);
            }
            Err(TrySendError::Disconnected(_)) => {}
        }
    }

    /// Serve until the process exits (fixed worker pool).
    pub fn serve_forever(self) -> anyhow::Result<()> {
        let (tx, rx) = sync_channel::<TcpStream>(self.backlog);
        let rx = Arc::new(Mutex::new(rx));
        let workers = self.spawn_workers(rx);
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            Self::dispatch(stream, &tx, &self.stats, &self.handle);
        }
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }

    /// Accept exactly `n` connections (tests / bounded demos), then
    /// drain the pool and join the workers.
    pub fn serve_n(self, n: usize) -> anyhow::Result<()> {
        let (tx, rx) = sync_channel::<TcpStream>(self.backlog);
        let rx = Arc::new(Mutex::new(rx));
        let workers = self.spawn_workers(rx);
        for stream in self.listener.incoming().take(n) {
            let stream = stream?;
            Self::dispatch(stream, &tx, &self.stats, &self.handle);
        }
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

#[derive(Debug)]
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn parse_request(stream: &mut TcpStream) -> anyhow::Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    anyhow::ensure!(!method.is_empty() && !path.is_empty(), "malformed request line");

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, path, body })
}

fn respond(stream: &mut TcpStream, status: u32, body: &str) -> anyhow::Result<()> {
    respond_typed(stream, status, "application/json", body)
}

/// Prometheus text exposition content type (`/metrics?format=prom`).
pub const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

fn respond_typed(
    stream: &mut TcpStream,
    status: u32,
    content_type: &str,
    body: &str,
) -> anyhow::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

/// Serialize a completed generation as the response JSON object.
/// Latency fields can be NaN (e.g. a request that never decoded a
/// second token has no TPOT) — those serialize as null, NaN is not
/// valid JSON.
fn response_json(resp: &crate::coordinator::GenResponse) -> Json {
    json::obj(vec![
        ("id", json::num(resp.id as f64)),
        ("text", json::s(&resp.text)),
        ("prompt_tokens", json::num(resp.prompt_tokens as f64)),
        ("new_tokens", json::num(resp.new_tokens as f64)),
        ("ttft_s", json::num_or_null(resp.ttft_s)),
        ("e2e_s", json::num_or_null(resp.e2e_s)),
        ("tpot_s", json::num_or_null(resp.tpot_s)),
        ("queue_wait_s", json::num_or_null(resp.queue_wait_s)),
        ("virtual_prefill_s", json::num(resp.virtual_prefill_s)),
    ])
}

/// Write one HTTP/1.1 chunk (hex size line + payload).
fn write_chunk(stream: &mut TcpStream, data: &str) -> anyhow::Result<()> {
    write!(stream, "{:x}\r\n{}\r\n", data.len(), data)?;
    stream.flush()?;
    Ok(())
}

/// Stream a generation as chunked NDJSON: one
/// `{"index":i,"token":t,"text":"..."}` line per token as the batcher
/// emits it, then a final `{"done":true,...}` line with the full
/// response. The socket deadline is re-armed after every successful
/// token write, so the stream lives as long as tokens keep flowing —
/// only a *stalled* client (or engine) for more than `io_timeout` kills
/// it, never total generation time.
fn stream_generate(
    stream: &mut TcpStream,
    events: std::sync::mpsc::Receiver<StreamEvent>,
    io_timeout: std::time::Duration,
) -> anyhow::Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    loop {
        match events.recv_timeout(io_timeout) {
            Ok(StreamEvent::Token { index, token, text }) => {
                let line = json::obj(vec![
                    ("index", json::num(index as f64)),
                    ("token", json::num(token as f64)),
                    ("text", json::s(&text)),
                ])
                .to_string();
                write_chunk(stream, &format!("{line}\n"))?;
                // the write above succeeded: the client is draining.
                // Re-arm the per-token deadline for the next one.
                let _ = stream.set_write_timeout(Some(io_timeout));
            }
            Ok(StreamEvent::Done(resp)) => {
                let mut obj = response_json(&resp);
                if let Json::Obj(map) = &mut obj {
                    map.insert("done".to_string(), Json::Bool(true));
                }
                let line = obj.to_string();
                write_chunk(stream, &format!("{line}\n"))?;
                break;
            }
            Err(_) => {
                // engine stalled or died mid-stream: say so in-band
                // before terminating the chunk stream
                let line = json::obj(vec![("error", json::s("generation stalled"))]).to_string();
                write_chunk(stream, &format!("{line}\n"))?;
                break;
            }
        }
    }
    write!(stream, "0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Record one answered connection: bump the per-(route, status) counter
/// and emit the access-log event. `route` is a normalized literal
/// (known path, `"(other)"`, or `"(malformed)"`) so counter cardinality
/// stays bounded no matter what clients send; the log keeps the raw
/// path for debugging.
fn finish_access(
    handle: &CoordinatorHandle,
    route: &str,
    path: &str,
    status: u32,
    t0: std::time::Instant,
) {
    handle.metrics.record_http(route, status as u16);
    handle.log.info(
        "server",
        "access",
        vec![
            ("path", json::s(path)),
            ("status", json::num(status as f64)),
            ("latency_s", json::num(t0.elapsed().as_secs_f64())),
        ],
    );
}

fn handle_conn(
    mut stream: TcpStream,
    handle: CoordinatorHandle,
    io_timeout: std::time::Duration,
) -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    // a malformed request (empty request line, truncated body) is the
    // client's fault: answer 400 instead of dropping the connection
    let req = match parse_request(&mut stream) {
        Ok(r) => r,
        Err(_) => {
            finish_access(&handle, "(malformed)", "(malformed)", 400, t0);
            return respond(&mut stream, 400, r#"{"error":"malformed request"}"#);
        }
    };
    // split the query string off so routes match path-only
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    let route = match (req.method.as_str(), path) {
        ("GET", "/healthz")
        | ("GET", "/metrics")
        | ("GET", "/metrics/history")
        | ("GET", "/debug/requests")
        | ("GET", "/policy")
        | ("GET", "/trace")
        | ("GET", "/logs")
        | ("GET", "/alerts")
        | ("POST", "/generate") => path.to_string(),
        _ => "(other)".to_string(),
    };
    let outcome = route_request(&mut stream, &handle, &req, path, query, io_timeout);
    // 499 (client closed / write failed mid-response): the route ran
    // but the answer never fully landed
    let status = *outcome.as_ref().unwrap_or(&499);
    finish_access(&handle, &route, path, status, t0);
    outcome.map(|_| ())
}

/// Serve one parsed request and return the HTTP status it was answered
/// with (`Err` only for I/O failures writing the response).
fn route_request(
    stream: &mut TcpStream,
    handle: &CoordinatorHandle,
    req: &HttpRequest,
    path: &str,
    query: &str,
    io_timeout: std::time::Duration,
) -> anyhow::Result<u32> {
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            respond(stream, 200, r#"{"ok":true}"#)?;
            Ok(200)
        }
        ("GET", "/metrics") => {
            // ?format=prom switches to the Prometheus text exposition;
            // alert gauges ride along with the registry counters
            let prom = query.split('&').any(|kv| kv == "format=prom" || kv == "format=prometheus");
            if prom {
                let mut body = handle.metrics.to_prometheus();
                body.push_str(&handle.alerts.to_prometheus());
                respond_typed(stream, 200, PROM_CONTENT_TYPE, &body)?;
            } else {
                let body = handle.metrics.to_json().to_string();
                respond(stream, 200, &body)?;
            }
            Ok(200)
        }
        ("GET", "/metrics/history") => {
            let body = handle.metrics.history_json().to_string();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("GET", "/debug/requests") => {
            let body = handle.flight.to_json().to_string();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("GET", "/policy") => {
            let body = handle.policy_json.lock().unwrap().clone();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("GET", "/trace") => {
            // ?last=N trims to the newest N spans (by end time)
            let last = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("last="))
                .and_then(|v| v.parse::<usize>().ok());
            let mut dump = handle.tracer.snapshot();
            if let Some(n) = last {
                dump = dump.tail(n);
            }
            let body = dump.to_chrome_json().to_string();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("GET", "/logs") => {
            // ?last=N tail size (default 100), ?level=warn min level
            let last = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("last="))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(100);
            let min_level = query
                .split('&')
                .find_map(|kv| kv.strip_prefix("level="))
                .and_then(Level::parse)
                .unwrap_or(Level::Debug);
            let body = handle.log.to_json(last, min_level).to_string();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("GET", "/alerts") => {
            let body = handle.alerts.to_json().to_string();
            respond(stream, 200, &body)?;
            Ok(200)
        }
        ("POST", "/generate") => {
            let parsed = std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| Json::parse(s).ok());
            let Some(doc) = parsed else {
                respond(stream, 400, r#"{"error":"bad json"}"#)?;
                return Ok(400);
            };
            let Some(prompt) = doc.get("prompt").and_then(|p| p.as_str()) else {
                respond(stream, 400, r#"{"error":"missing prompt"}"#)?;
                return Ok(400);
            };
            let max_tokens = doc.get("max_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
            let greedy = doc.get("greedy").and_then(|v| v.as_bool()).unwrap_or(true);
            let streaming = doc.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
            let gen = GenRequest {
                prompt: prompt.to_string(),
                max_new_tokens: max_tokens,
                greedy,
                stop_token: -1,
            };
            if streaming {
                let events = handle.submit_stream(gen);
                stream_generate(stream, events, io_timeout)?;
                return Ok(200);
            }
            match handle.generate(gen) {
                Ok(resp) => {
                    respond(stream, 200, &response_json(&resp).to_string())?;
                    Ok(200)
                }
                // error text goes through the JSON writer: a raw
                // format! would break the body on quotes/newlines in
                // the message
                Err(e) => {
                    let body =
                        json::obj(vec![("error", json::s(&format!("{e:#}")))]).to_string();
                    respond(stream, 500, &body)?;
                    Ok(500)
                }
            }
        }
        _ => {
            respond(stream, 404, r#"{"error":"not found"}"#)?;
            Ok(404)
        }
    }
}

/// Tiny blocking HTTP client for tests and the trace replayer.
pub fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<(u32, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    read_response(stream)
}

pub fn http_get(addr: &str, path: &str) -> anyhow::Result<(u32, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    read_response(stream)
}

/// POST and read a chunked (streaming) response: returns the status and
/// each chunk's payload in arrival order. `on_chunk` fires as each
/// chunk is read — timing-sensitive tests use it to timestamp arrivals.
pub fn http_post_stream(
    addr: &str,
    path: &str,
    body: &str,
    mut on_chunk: impl FnMut(&str),
) -> anyhow::Result<(u32, Vec<String>)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u32 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("transfer-encoding")
                && v.trim().eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }
    anyhow::ensure!(chunked, "response is not chunked (status {status})");
    let mut chunks = Vec::new();
    loop {
        let mut size_line = String::new();
        reader.read_line(&mut size_line)?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .map_err(|_| anyhow::anyhow!("bad chunk size line: {size_line:?}"))?;
        let mut payload = vec![0u8; size + 2]; // chunk data + trailing CRLF
        reader.read_exact(&mut payload)?;
        if size == 0 {
            break;
        }
        payload.truncate(size);
        let text = String::from_utf8_lossy(&payload).into_owned();
        on_chunk(&text);
        chunks.push(text);
    }
    Ok((status, chunks))
}

fn read_response(stream: TcpStream) -> anyhow::Result<(u32, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u32 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}
