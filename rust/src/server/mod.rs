//! Minimal HTTP/1.1 front end (std TCP — the offline crate set has no
//! tokio/hyper, so this substrate is hand-rolled).
//!
//! Endpoints:
//!   POST /generate  {"prompt": "...", "max_tokens": 32, "greedy": true}
//!   GET  /metrics   -> JSON snapshot of the registry
//!   GET  /policy    -> JSON of the engine's per-site compression policy
//!   GET  /healthz

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};

use crate::coordinator::{CoordinatorHandle, GenRequest};
use crate::util::json::{self, Json};

pub struct Server {
    listener: TcpListener,
    handle: CoordinatorHandle,
}

impl Server {
    pub fn bind(addr: &str, handle: CoordinatorHandle) -> anyhow::Result<Server> {
        Ok(Server { listener: TcpListener::bind(addr)?, handle })
    }

    pub fn local_addr(&self) -> anyhow::Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until the process exits (thread-per-connection).
    pub fn serve_forever(self) -> anyhow::Result<()> {
        for stream in self.listener.incoming() {
            let stream = match stream {
                Ok(s) => s,
                Err(_) => continue,
            };
            let handle = self.handle.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, handle);
            });
        }
        Ok(())
    }

    /// Serve exactly `n` connections (tests / bounded demos).
    pub fn serve_n(self, n: usize) -> anyhow::Result<()> {
        let mut joins = Vec::new();
        for stream in self.listener.incoming().take(n) {
            let stream = stream?;
            let handle = self.handle.clone();
            joins.push(std::thread::spawn(move || {
                let _ = handle_conn(stream, handle);
            }));
        }
        for j in joins {
            let _ = j.join();
        }
        Ok(())
    }
}

#[derive(Debug)]
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

fn parse_request(stream: &mut TcpStream) -> anyhow::Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    anyhow::ensure!(!method.is_empty() && !path.is_empty(), "malformed request line");

    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, path, body })
}

fn respond(stream: &mut TcpStream, status: u32, body: &str) -> anyhow::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    Ok(())
}

fn handle_conn(mut stream: TcpStream, handle: CoordinatorHandle) -> anyhow::Result<()> {
    // a malformed request (empty request line, truncated body) is the
    // client's fault: answer 400 instead of dropping the connection
    let req = match parse_request(&mut stream) {
        Ok(r) => r,
        Err(_) => return respond(&mut stream, 400, r#"{"error":"malformed request"}"#),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => respond(&mut stream, 200, r#"{"ok":true}"#),
        ("GET", "/metrics") => {
            let body = handle.metrics.to_json().to_string();
            respond(&mut stream, 200, &body)
        }
        ("GET", "/policy") => respond(&mut stream, 200, &handle.policy_json),
        ("POST", "/generate") => {
            let parsed = std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| Json::parse(s).ok());
            let Some(doc) = parsed else {
                return respond(&mut stream, 400, r#"{"error":"bad json"}"#);
            };
            let Some(prompt) = doc.get("prompt").and_then(|p| p.as_str()) else {
                return respond(&mut stream, 400, r#"{"error":"missing prompt"}"#);
            };
            let max_tokens = doc.get("max_tokens").and_then(|v| v.as_usize()).unwrap_or(32);
            let greedy = doc.get("greedy").and_then(|v| v.as_bool()).unwrap_or(true);
            let gen = GenRequest {
                prompt: prompt.to_string(),
                max_new_tokens: max_tokens,
                greedy,
                stop_token: -1,
            };
            match handle.generate(gen) {
                Ok(resp) => {
                    // latency fields can be NaN (e.g. a request that
                    // never decoded a second token has no TPOT) —
                    // serialize those as null, NaN is not valid JSON
                    let body = json::obj(vec![
                        ("id", json::num(resp.id as f64)),
                        ("text", json::s(&resp.text)),
                        ("prompt_tokens", json::num(resp.prompt_tokens as f64)),
                        ("new_tokens", json::num(resp.new_tokens as f64)),
                        ("ttft_s", json::num_or_null(resp.ttft_s)),
                        ("e2e_s", json::num_or_null(resp.e2e_s)),
                        ("tpot_s", json::num_or_null(resp.tpot_s)),
                        ("queue_wait_s", json::num_or_null(resp.queue_wait_s)),
                        ("virtual_prefill_s", json::num(resp.virtual_prefill_s)),
                    ])
                    .to_string();
                    respond(&mut stream, 200, &body)
                }
                // error text goes through the JSON writer: a raw
                // format! would break the body on quotes/newlines in
                // the message
                Err(e) => {
                    let body =
                        json::obj(vec![("error", json::s(&format!("{e:#}")))]).to_string();
                    respond(&mut stream, 500, &body)
                }
            }
        }
        _ => respond(&mut stream, 404, r#"{"error":"not found"}"#),
    }
}

/// Tiny blocking HTTP client for tests and the trace replayer.
pub fn http_post(addr: &str, path: &str, body: &str) -> anyhow::Result<(u32, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(
        stream,
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    read_response(stream)
}

pub fn http_get(addr: &str, path: &str) -> anyhow::Result<(u32, String)> {
    let mut stream = TcpStream::connect(addr)?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    read_response(stream)
}

fn read_response(stream: TcpStream) -> anyhow::Result<(u32, String)> {
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u32 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        if h.trim_end().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}
