//! Bit-packing for sub-byte element codes (the real wire format).
//!
//! Codes are packed LSB-first into a little-endian bit stream: code `i`
//! occupies bits `[i*w, (i+1)*w)`. This is what actually crosses the
//! (simulated) interconnect, so compressed message sizes are real, not
//! just accounted.

/// Pack `codes` (each < 2^width) into `out` as a contiguous bit stream.
pub fn pack_bits(codes: &[u8], width: u32, out: &mut Vec<u8>) {
    let w = width as usize;
    out.resize((codes.len() * w).div_ceil(8), 0);
    let mut bitpos = 0usize;
    for &c in codes {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + w > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += w;
    }
}

/// Unpack a bit stream produced by [`pack_bits`] into `out` (len = count).
pub fn unpack_into(wire: &[u8], width: u32, out: &mut [u8]) {
    let w = width as usize;
    let mask = ((1u16 << w) - 1) as u16;
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = wire[byte] as u16 >> off;
        let val = if off + w > 8 {
            lo | ((wire[byte + 1] as u16) << (8 - off))
        } else {
            lo
        };
        *o = (val & mask) as u8;
        bitpos += w;
    }
}

/// Unpack allocating.
pub fn unpack_bits(wire: &[u8], width: u32, count: usize) -> Vec<u8> {
    let mut out = vec![0u8; count];
    unpack_into(wire, width, &mut out);
    out
}

/// Streaming LSB-first bit packer over a `u64` accumulator — the wide
/// word hot path. Produces the exact byte stream of [`pack_bits`]
/// (fuzz + `writer_matches_pack_bits` enforce it) but stores eight
/// bytes per flush instead of read-modify-writing each byte, so the
/// quantize loop that feeds it stays branch-light and store-bound.
///
/// In-bounds by arithmetic, no unsafe: a flush fires only when >= 64
/// bits are pending, and 64 pending bits imply >= 8 unwritten bytes
/// remain in a buffer sized `ceil(total_bits/8)`; `finish` writes the
/// tail one byte at a time. The accumulator's bits above the pending
/// count are always zero, so trailing pad bits land as zeros exactly
/// like `pack_bits`' zero-filled buffer.
pub struct BitWriter<'a> {
    buf: &'a mut [u8],
    acc: u64,
    bits: u32,
    pos: usize,
}

impl<'a> BitWriter<'a> {
    /// `buf` must hold `ceil(sum(width)/8)` bytes for all pushes to
    /// come; it does not need to be zeroed (every byte is overwritten).
    pub fn new(buf: &'a mut [u8]) -> BitWriter<'a> {
        BitWriter { buf, acc: 0, bits: 0, pos: 0 }
    }

    /// Append `width` bits of `code` (callers pass `code < 2^width`).
    #[inline]
    pub fn push(&mut self, code: u64, width: u32) {
        self.acc |= code << self.bits;
        self.bits += width;
        if self.bits >= 64 {
            self.buf[self.pos..self.pos + 8].copy_from_slice(&self.acc.to_le_bytes());
            self.pos += 8;
            self.bits -= 64;
            // bits of `code` that didn't fit before the flush
            self.acc = if self.bits == 0 { 0 } else { code >> (width - self.bits) };
        }
    }

    /// Flush the partial tail word (one byte at a time).
    pub fn finish(mut self) {
        let mut acc = self.acc;
        let mut bits = self.bits;
        while bits > 0 {
            self.buf[self.pos] = acc as u8;
            self.pos += 1;
            acc >>= 8;
            bits = bits.saturating_sub(8);
        }
    }
}

/// Streaming LSB-first bit reader, dual of [`BitWriter`]: refills the
/// `u64` accumulator up to eight bytes at a time. Construct it over
/// exactly the code region (`&wire[..ceil(n*width/8)]`) — the region
/// always holds at least `n*width` bits, so `next` never underruns
/// when called at most `n` times.
pub struct BitReader<'a> {
    buf: &'a [u8],
    acc: u64,
    bits: u32,
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, acc: 0, bits: 0, pos: 0 }
    }

    /// Read the next `width` bits (1..=8).
    #[inline]
    pub fn next(&mut self, width: u32) -> u64 {
        if self.bits < width {
            let want = ((64 - self.bits) >> 3) as usize;
            let take = want.min(self.buf.len() - self.pos);
            let mut chunk = [0u8; 8];
            chunk[..take].copy_from_slice(&self.buf[self.pos..self.pos + take]);
            self.acc |= u64::from_le_bytes(chunk) << self.bits;
            self.pos += take;
            self.bits += (take * 8) as u32;
        }
        let v = self.acc & ((1u64 << width) - 1);
        self.acc >>= width;
        self.bits -= width;
        v
    }
}

/// A packed MX message (codes + scales), used by tests and tools.
#[derive(Debug, Clone)]
pub struct PackedMx {
    pub codes: Vec<u8>,
    pub scales: Vec<u8>,
    pub n_values: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(5);
        for w in 1..=8u32 {
            let n = 257; // deliberately not a multiple of 8
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & ((1 << w) - 1)) as u8).collect();
            let mut wire = Vec::new();
            pack_bits(&codes, w, &mut wire);
            assert_eq!(wire.len(), (n * w as usize).div_ceil(8));
            let back = unpack_bits(&wire, w, n);
            assert_eq!(back, codes, "width {w}");
        }
    }

    #[test]
    fn packed_density() {
        // 4-bit codes: exactly 2 per byte
        let codes = vec![0xFu8; 100];
        let mut wire = Vec::new();
        pack_bits(&codes, 4, &mut wire);
        assert_eq!(wire.len(), 50);
        assert!(wire.iter().all(|&b| b == 0xFF));
    }

    #[test]
    fn three_bit_cross_byte() {
        let codes = vec![0b101u8, 0b011, 0b110, 0b001];
        let mut wire = Vec::new();
        pack_bits(&codes, 3, &mut wire);
        let back = unpack_bits(&wire, 3, 4);
        assert_eq!(back, codes);
    }

    #[test]
    fn writer_matches_pack_bits() {
        // The u64 pump must emit byte-for-byte what the scalar packer
        // emits, including tail-byte zero padding — every width, odd
        // lengths, dirty destination buffer.
        let mut rng = Rng::new(77);
        for w in 1..=8u32 {
            for n in [1usize, 7, 63, 64, 65, 257, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << w) - 1)) as u8).collect();
                let mut want = Vec::new();
                pack_bits(&codes, w, &mut want);
                let mut got = vec![0xAAu8; (n * w as usize).div_ceil(8)];
                let mut bw = BitWriter::new(&mut got);
                for &c in &codes {
                    bw.push(c as u64, w);
                }
                bw.finish();
                assert_eq!(got, want, "width {w} n {n}");
            }
        }
    }

    #[test]
    fn reader_matches_unpack_into() {
        let mut rng = Rng::new(78);
        for w in 1..=8u32 {
            for n in [1usize, 7, 64, 65, 257] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << w) - 1)) as u8).collect();
                let mut wire = Vec::new();
                pack_bits(&codes, w, &mut wire);
                let mut br = BitReader::new(&wire);
                for (i, &c) in codes.iter().enumerate() {
                    assert_eq!(br.next(w) as u8, c, "width {w} n {n} idx {i}");
                }
            }
        }
    }
}
