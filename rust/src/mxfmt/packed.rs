//! Bit-packing for sub-byte element codes (the real wire format).
//!
//! Codes are packed LSB-first into a little-endian bit stream: code `i`
//! occupies bits `[i*w, (i+1)*w)`. This is what actually crosses the
//! (simulated) interconnect, so compressed message sizes are real, not
//! just accounted.

/// Pack `codes` (each < 2^width) into `out` as a contiguous bit stream.
pub fn pack_bits(codes: &[u8], width: u32, out: &mut Vec<u8>) {
    let w = width as usize;
    out.resize((codes.len() * w).div_ceil(8), 0);
    let mut bitpos = 0usize;
    for &c in codes {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + w > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += w;
    }
}

/// Unpack a bit stream produced by [`pack_bits`] into `out` (len = count).
pub fn unpack_into(wire: &[u8], width: u32, out: &mut [u8]) {
    let w = width as usize;
    let mask = ((1u16 << w) - 1) as u16;
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let lo = wire[byte] as u16 >> off;
        let val = if off + w > 8 {
            lo | ((wire[byte + 1] as u16) << (8 - off))
        } else {
            lo
        };
        *o = (val & mask) as u8;
        bitpos += w;
    }
}

/// Unpack allocating.
pub fn unpack_bits(wire: &[u8], width: u32, count: usize) -> Vec<u8> {
    let mut out = vec![0u8; count];
    unpack_into(wire, width, &mut out);
    out
}

/// A packed MX message (codes + scales), used by tests and tools.
#[derive(Debug, Clone)]
pub struct PackedMx {
    pub codes: Vec<u8>,
    pub scales: Vec<u8>,
    pub n_values: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_widths() {
        let mut rng = Rng::new(5);
        for w in 1..=8u32 {
            let n = 257; // deliberately not a multiple of 8
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & ((1 << w) - 1)) as u8).collect();
            let mut wire = Vec::new();
            pack_bits(&codes, w, &mut wire);
            assert_eq!(wire.len(), (n * w as usize).div_ceil(8));
            let back = unpack_bits(&wire, w, n);
            assert_eq!(back, codes, "width {w}");
        }
    }

    #[test]
    fn packed_density() {
        // 4-bit codes: exactly 2 per byte
        let codes = vec![0xFu8; 100];
        let mut wire = Vec::new();
        pack_bits(&codes, 4, &mut wire);
        assert_eq!(wire.len(), 50);
        assert!(wire.iter().all(|&b| b == 0xFF));
    }

    #[test]
    fn three_bit_cross_byte() {
        let codes = vec![0b101u8, 0b011, 0b110, 0b001];
        let mut wire = Vec::new();
        pack_bits(&codes, 3, &mut wire);
        let back = unpack_bits(&wire, 3, 4);
        assert_eq!(back, codes);
    }
}
