//! OCP Microscaling (MX) quantization codec + SoTA baselines.
//!
//! This is the rust twin of `python/compile/kernels/ref.py` / the Pallas
//! kernels: every arithmetic step (exponent extraction, power-of-two
//! assembly, ties-to-even rounding) mirrors the jnp reference so the two
//! implementations are **bit-exact** — enforced by the golden-vector
//! tests (`artifacts/golden/codec`, exported at AOT time).
//!
//! The codec runs on the collective path: each TP worker encodes its
//! row-parallel partial result before the all-gather and decodes the
//! N-1 received shards before the reduce (paper Fig. 1b). Encode /
//! decode throughput therefore IS the paper's "compression overhead"
//! term, and is benchmarked (`benches/codec.rs`) and perf-tuned
//! (EXPERIMENTS.md §Perf).

pub mod baselines;
pub mod codec;
pub mod fuzz;
pub mod golden;
pub mod packed;
pub mod reference;
pub mod types;

pub use baselines::{ChannelInt, TopK};
pub use codec::MxCodec;
pub use packed::{pack_bits, unpack_bits, PackedMx};
pub use reference::RefMxCodec;
pub use types::{ElemFormat, MxScheme, ScaleFormat, ELEM_FORMATS};

/// Decode-side failure on untrusted wire bytes. The contract for every
/// [`Compressor::try_decode_add`]: arbitrary input may *error* with one
/// of these, but must never panic or touch memory out of bounds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The wire buffer is shorter than the message header/layout demands.
    Truncated { needed: usize, got: usize },
    /// The bytes are long enough but internally inconsistent
    /// (out-of-range index, impossible count, ...).
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated { needed, got } => {
                write!(f, "truncated wire: need {needed} bytes, got {got}")
            }
            CodecError::Malformed(why) => write!(f, "malformed wire: {why}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Any compression applied to TP collective traffic.
///
/// `encode` returns the wire representation; `decode_add` accumulates the
/// decoded tensor into `acc` (fused decompress+reduce, like the Pallas
/// `mx_dequant_reduce` kernel).
///
/// Implementations are resolved from spec strings — globally via
/// `--compress`, or per collective site via `--policy`
/// ([`crate::policy`]):
///
/// ```
/// use tpcc::mxfmt::{compressor_from_spec, Compressor};
/// let c = compressor_from_spec("fp4_e2m1_b32_e8m0").unwrap();
/// assert_eq!(c.effective_bits(64), 4.25); // paper §4.2: 4 + 8/32 bits
/// let x = vec![1.0f32; 64];
/// let mut wire = Vec::new();
/// c.encode(&x, &mut wire);
/// assert_eq!(wire.len(), c.wire_bytes(64));
/// // 1.0 is exactly representable in FP4 E2M1 with a 2^0 block scale
/// assert_eq!(c.decode(&wire, 64), x);
/// ```
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;
    /// Bits per source value on the wire (the paper's "effective bits").
    fn effective_bits(&self, n_values: usize) -> f64;
    fn encode(&self, x: &[f32], out: &mut Vec<u8>);
    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]);

    /// Relative encode+decode cost per value vs the MX codec (=1.0).
    /// Drives the analytic perf model's compression-overhead term:
    /// channel-wise INT is a plain scale+round (cheap, which is exactly
    /// why the paper's Table 4 shows it faster despite worse PPL);
    /// TopK pays a selection pass.
    fn compute_cost_factor(&self) -> f64 {
        1.0
    }

    /// Wire bytes for an n-value message (defaults to effective-bits math).
    fn wire_bytes(&self, n_values: usize) -> usize {
        ((self.effective_bits(n_values) * n_values as f64) / 8.0).ceil() as usize
    }

    /// Smallest message granularity this codec can encode independently
    /// (the MX block size, the channel count for channel-wise schemes).
    /// The collective engine slices messages on multiples of this so
    /// every phase payload stays encodable.
    fn alignment(&self) -> usize {
        1
    }

    /// Quantize `x` and accumulate the dequantized values into `acc`
    /// (`acc[i] += Q(x[i])`). Numerically identical to `encode` +
    /// `decode_add`, but implementations may skip the wire round-trip:
    /// the collective engine uses this in `Analytic` overhead mode,
    /// where measured codec wall time is discarded and the bit-packing
    /// of shards would be pure waste.
    fn requant_add(&self, x: &[f32], acc: &mut [f32], scratch: &mut Vec<u8>) {
        self.encode(x, scratch);
        self.decode_add(scratch, x.len(), acc);
    }

    /// Actual bytes `encode` emits for an n-value message. Defaults to
    /// the *accounted* [`Compressor::wire_bytes`]; codecs whose stored
    /// layout differs (e.g. MX stores byte-per-block scales, channel-wise
    /// INT stores f32 scale headers) must override so
    /// [`Compressor::try_decode_add`] validates against real bytes.
    fn encoded_len(&self, n_values: usize) -> usize {
        self.wire_bytes(n_values)
    }

    /// Validating decode for **untrusted** wire bytes: length/layout
    /// checks first, then the fused decode. Arbitrary (truncated,
    /// corrupt, adversarial) input must return `Err`, never panic or
    /// read/write out of bounds — the decoder fuzz targets enforce
    /// this. Codecs that read indices or counts out of the wire (TopK)
    /// must override and range-check them.
    fn try_decode_add(
        &self,
        wire: &[u8],
        n_values: usize,
        acc: &mut [f32],
    ) -> Result<(), CodecError> {
        let need = self.encoded_len(n_values);
        if wire.len() < need {
            return Err(CodecError::Truncated { needed: need, got: wire.len() });
        }
        if acc.len() < n_values {
            return Err(CodecError::Malformed(format!(
                "accumulator holds {} values, message carries {}",
                acc.len(),
                n_values
            )));
        }
        self.decode_add(wire, n_values, acc);
        Ok(())
    }

    /// Convenience: decode into a fresh zeroed buffer.
    fn decode(&self, wire: &[u8], n_values: usize) -> Vec<f32> {
        let mut out = vec![0.0; n_values];
        self.decode_add(wire, n_values, &mut out);
        out
    }
}

/// The identity "compressor": f32 pass-through (uncompressed baseline).
pub struct NoCompress;

impl Compressor for NoCompress {
    fn name(&self) -> String {
        "fp32".into()
    }
    fn effective_bits(&self, _n: usize) -> f64 {
        32.0
    }
    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(x.len() * 4);
        for v in x {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        assert!(wire.len() >= n_values * 4);
        for (i, c) in wire.chunks_exact(4).take(n_values).enumerate() {
            acc[i] += f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
}

/// Parse a compressor spec string:
/// `none` | `fp16` | `<elem>_b<block>_<scale>` (MX) | `int4_channelwise` |
/// `topk<ratio>` (e.g. `topk3`).
///
/// `channels` is the per-row channel count of the tensors this
/// compressor will see (the model's hidden dim for TP partials) —
/// required by the channel-wise baselines, ignored by the rest.
///
/// ```
/// use tpcc::mxfmt::compressor_from_spec_ch;
/// // the uncompressed pass-through round-trips exactly
/// let c = compressor_from_spec_ch("none", 4096).unwrap();
/// let x = vec![1.5f32, -2.25, 0.0, 8.0];
/// let mut wire = Vec::new();
/// c.encode(&x, &mut wire);
/// assert_eq!(c.decode(&wire, 4), x);
/// assert!(compressor_from_spec_ch("bogus_spec", 4096).is_err());
/// ```
pub fn compressor_from_spec_ch(
    spec: &str,
    channels: usize,
) -> anyhow::Result<Box<dyn Compressor>> {
    match spec {
        "none" | "fp32" => Ok(Box::new(NoCompress)),
        "fp16" => Ok(Box::new(baselines::Fp16)),
        "int4_channelwise" => Ok(Box::new(ChannelInt::with_channels(4, channels))),
        "int8_channelwise" => Ok(Box::new(ChannelInt::with_channels(8, channels))),
        s if s.starts_with("topk") => {
            let ratio: f64 = s[4..].parse()?;
            Ok(Box::new(TopK::new(ratio)))
        }
        s => Ok(Box::new(MxCodec::new(MxScheme::parse(s)?))),
    }
}

/// [`compressor_from_spec_ch`] without a known channel count (fine for
/// every spec except the channel-wise baselines).
pub fn compressor_from_spec(spec: &str) -> anyhow::Result<Box<dyn Compressor>> {
    compressor_from_spec_ch(spec, 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    /// The rank-thread runtime shares compressor *specs* (not objects)
    /// with its workers, but every codec must still be `Send + Sync`:
    /// the trait requires it, and this pins the concrete types so a new
    /// codec with interior mutability (e.g. a non-synchronized scratch
    /// cache) fails to compile rather than failing under concurrency.
    #[test]
    fn compressors_are_send_sync() {
        assert_send_sync::<NoCompress>();
        assert_send_sync::<MxCodec>();
        assert_send_sync::<RefMxCodec>();
        assert_send_sync::<ChannelInt>();
        assert_send_sync::<TopK>();
        assert_send_sync::<baselines::Fp16>();
        assert_send_sync::<Box<dyn Compressor>>();
    }
}
