//! The scalar reference MX codec — the differential oracle.
//!
//! This is the original per-element, per-byte implementation the fast
//! path in [`super::codec`] replaced. It stays in the tree on purpose:
//! every fused wide-word trick in `MxCodec` is only trusted because the
//! fuzz/property/golden suites prove its output **byte-identical** to
//! this code. Keep it boring: one element at a time, allocating, no
//! bit pumps, no lookup tables — each arithmetic step visible.
//!
//! Oracle invariant (see DESIGN.md §Codec hot path): `RefMxCodec`
//! deliberately does NOT override [`Compressor::requant_add`], so its
//! requantization semantic is exactly `encode` + `decode_add`. That
//! makes the oracle single-valued: there is one reference answer per
//! input, the wire answer. (The historical `quantize_elem_float`
//! shortcut disagrees with the wire path on NaN inputs — NaN saturates
//! to `max_value` element-wise but encodes to the `2^(emax-1)` code —
//! so it must not serve as the oracle.)

use super::codec::{
    block_scale_exp, decode_elem_float, decode_elem_int, quantize_code_float, quantize_code_int,
};
use super::packed::{pack_bits, unpack_into};
use super::types::{exp2i, MxScheme};
use super::Compressor;

/// Reference MX codec for one scheme. Same wire layout as the fast
/// [`super::MxCodec`]: `[codes: ceil(n*elem_bits/8) bytes][scales:
/// nblocks bytes]`, tail blocks (n not a multiple of `block`) scaled
/// over the elements they actually contain.
#[derive(Debug, Clone, Copy)]
pub struct RefMxCodec {
    pub scheme: MxScheme,
}

impl RefMxCodec {
    pub fn new(scheme: MxScheme) -> RefMxCodec {
        RefMxCodec { scheme }
    }

    /// Quantize into unpacked (code, scale) bytes, one code byte per
    /// value, one scale byte per (possibly partial) block.
    pub fn quantize_unpacked(&self, x: &[f32], codes: &mut Vec<u8>, scales: &mut Vec<u8>) {
        let s = &self.scheme;
        codes.clear();
        scales.clear();
        codes.reserve(x.len());
        scales.reserve(x.len().div_ceil(s.block.max(1)));
        let e = &s.elem;
        for blk in x.chunks(s.block) {
            let mut amax = 0.0f32;
            for &v in blk {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            scales.push((sexp + s.scale.bias()) as u8);
            if e.is_float {
                for &v in blk {
                    codes.push(quantize_code_float(v * inv, e));
                }
            } else {
                for &v in blk {
                    codes.push(quantize_code_int(v * inv, e));
                }
            }
        }
    }

    /// Inverse of [`RefMxCodec::quantize_unpacked`].
    pub fn dequantize_unpacked(&self, codes: &[u8], scales: &[u8], out: &mut Vec<f32>) {
        let s = &self.scheme;
        out.clear();
        out.reserve(codes.len());
        for (bi, blk) in codes.chunks(s.block).enumerate() {
            let scale = exp2i(scales[bi] as i32 - s.scale.bias());
            if s.elem.is_float {
                for &c in blk {
                    out.push(decode_elem_float(c, &s.elem) * scale);
                }
            } else {
                for &c in blk {
                    out.push(decode_elem_int(c, &s.elem) * scale);
                }
            }
        }
    }
}

impl Compressor for RefMxCodec {
    fn name(&self) -> String {
        format!("ref:{}", self.scheme.name())
    }

    fn effective_bits(&self, _n: usize) -> f64 {
        self.scheme.effective_bits()
    }

    fn wire_bytes(&self, n_values: usize) -> usize {
        self.scheme.wire_bytes(n_values)
    }

    fn alignment(&self) -> usize {
        self.scheme.block
    }

    fn encoded_len(&self, n_values: usize) -> usize {
        let code_bytes = (n_values * self.scheme.elem.bits() as usize).div_ceil(8);
        code_bytes + n_values.div_ceil(self.scheme.block)
    }

    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        self.quantize_unpacked(x, &mut codes, &mut scales);
        out.clear();
        pack_bits(&codes, self.scheme.elem.bits(), out);
        out.extend_from_slice(&scales);
    }

    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        let s = &self.scheme;
        let nb = s.elem.bits();
        let code_bytes = (n_values * nb as usize).div_ceil(8);
        let nblocks = n_values.div_ceil(s.block);
        let scales = &wire[code_bytes..code_bytes + nblocks];
        let mut codes = vec![0u8; n_values];
        unpack_into(&wire[..code_bytes], nb, &mut codes);
        for (bi, blk) in codes.chunks(s.block).enumerate() {
            let scale = exp2i(scales[bi] as i32 - s.scale.bias());
            let dst = &mut acc[bi * s.block..bi * s.block + blk.len()];
            if s.elem.is_float {
                for (d, &c) in dst.iter_mut().zip(blk) {
                    *d += decode_elem_float(c, &s.elem) * scale;
                }
            } else {
                for (d, &c) in dst.iter_mut().zip(blk) {
                    *d += decode_elem_int(c, &s.elem) * scale;
                }
            }
        }
    }

    // NO requant_add override — see the module docs: the trait default
    // (encode + decode_add) IS the oracle semantic.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn codec(name: &str) -> RefMxCodec {
        RefMxCodec::new(MxScheme::parse(name).unwrap())
    }

    #[test]
    fn grid_values_survive_reference() {
        let c = codec("fp4_e2m1_b8_e8m0");
        let x = [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        assert_eq!(c.decode(&wire, 8), x);
    }

    #[test]
    fn tail_block_scales_over_actual_elements() {
        // 5 values, block 4: tail block of 1 must scale on its own amax,
        // not inherit garbage from a phantom full block.
        let c = codec("fp4_e2m1_b4_e8m0");
        let x = [1.0f32, 1.0, 1.0, 1.0, 1024.0];
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        c.quantize_unpacked(&x, &mut codes, &mut scales);
        assert_eq!(scales.len(), 2);
        let mut out = Vec::new();
        c.dequantize_unpacked(&codes, &scales, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn odd_length_wire_roundtrip() {
        let mut rng = Rng::new(13);
        for n in [1usize, 7, 31, 33, 100, 199] {
            let c = codec("fp5_e2m2_b32_e8m0");
            let mut x = vec![0.0f32; n];
            rng.fill_activations(&mut x, 2.0);
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            assert_eq!(wire.len(), c.encoded_len(n));
            let out = c.decode(&wire, n);
            assert_eq!(out.len(), n);
            for (a, b) in x.iter().zip(&out) {
                assert!((a - b).abs() <= a.abs() * 0.26 + 1e-6);
            }
        }
    }
}
