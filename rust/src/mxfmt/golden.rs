//! Golden-vector generator for the MX codec — `tpcc golden --emit`.
//!
//! Emits `rust/tests/golden_codec.json`: a fixed input slice (special
//! f32 bit patterns + deterministic RNG fill) pushed through the
//! reference codec ([`super::RefMxCodec`]) for a grid of schemes, with
//! every intermediate recorded — unpacked codes, scale bytes, packed
//! wire, decoded bits. The committed file is the regression anchor: the
//! golden test regenerates it via [`emit`] and diffs byte-for-byte, so
//! any semantic change to the codec (reference *or* fast path — the
//! generator asserts their wires identical) shows up as a readable
//! per-scheme diff instead of a silent drift.
//!
//! Everything here is integer-derived (bit patterns, not float
//! literals) so regeneration is exact across hosts and toolchains.

use std::fmt::Write;

use super::reference::RefMxCodec;
use super::types::MxScheme;
use super::{Compressor, MxCodec};
use crate::util::rng::Rng;

/// Seed for the RNG-derived tail of the input slice (date-stamped at
/// first emission; changing it invalidates the committed golden file).
pub const GOLDEN_SEED: u64 = 20260807;

/// Input length. Deliberately odd and non-block-aligned so every
/// scheme in the grid exercises a partial tail block.
pub const GOLDEN_N: usize = 199;

/// Hand-picked f32 bit patterns covering the codec's edge cases:
/// ±0, ±Inf, quiet/signaling NaN, min/max subnormal, smallest normal,
/// ±f32::MAX, exact grid points, ties, π, a subnormal-scale value,
/// 2^127 (scale clamp), a 25-bit integer (rounding), and magnitudes
/// far below/above the representable block range.
pub const GOLDEN_SPECIALS: [u32; 24] = [
    0x0000_0000, 0x8000_0000, 0x7F80_0000, 0xFF80_0000, 0x7FC0_0000, 0xFFC0_0000,
    0x7F80_0001, 0xFF80_0001, 0x0000_0001, 0x8000_0001, 0x007F_FFFF, 0x0080_0000,
    0x7F7F_FFFF, 0xFF7F_FFFF, 0x3F80_0000, 0xBF80_0000, 0x3F00_0000, 0x3FC0_0000,
    0x4049_0FDB, 0x3586_37BD, 0x7F00_0000, 0x00FF_FFFF, 0x3380_0000, 0x4B80_0000,
];

/// The scheme grid the golden file covers: every element format at
/// blocks {8, 32} with the standard E8M0 scale, plus oddities — a b16
/// point, a narrow E4M0 scale, a 5-bit INT with E5M0, and a block-3
/// scheme (scale byte granularity ≠ code byte granularity).
pub fn golden_schemes() -> Vec<MxScheme> {
    let mut grid = Vec::new();
    for e in super::ELEM_FORMATS {
        for block in [8usize, 32] {
            grid.push(MxScheme::new(e.name, block, 8).unwrap());
        }
    }
    grid.push(MxScheme::new("fp4_e2m1", 16, 8).unwrap());
    grid.push(MxScheme::new("fp4_e2m1", 8, 4).unwrap());
    grid.push(MxScheme::new("int5", 32, 5).unwrap());
    grid.push(MxScheme::new("fp5_e1m3", 3, 8).unwrap());
    grid
}

/// The golden input slice as raw f32 bit patterns: the special table
/// first, then RNG words (the RNG only advances on non-special
/// indices, so the tail is independent of the table length).
pub fn golden_input_bits() -> Vec<u32> {
    let mut rng = Rng::new(GOLDEN_SEED);
    (0..GOLDEN_N)
        .map(|i| match GOLDEN_SPECIALS.get(i) {
            Some(&b) => b,
            None => rng.next_u64() as u32,
        })
        .collect()
}

fn push_hex(out: &mut String, bytes: &[u8]) {
    for b in bytes {
        write!(out, "{b:02x}").unwrap();
    }
}

fn push_bits_array(out: &mut String, bits: &[u32]) {
    for (i, b) in bits.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        write!(out, "\"{b:08x}\"").unwrap();
    }
}

/// Render the golden JSON document. Byte-stable: fixed key order,
/// fixed float-free integer/hex formatting, trailing newline. Panics
/// (never silently emits) if the fast codec's wire diverges from the
/// reference wire on any scheme — the file must only ever record
/// vectors both implementations agree on.
pub fn emit() -> String {
    let bits = golden_input_bits();
    let x: Vec<f32> = bits.iter().map(|&b| f32::from_bits(b)).collect();

    let mut out = String::with_capacity(80_000);
    out.push_str("{\n  \"generator\": \"tpcc golden --emit\",\n");
    write!(out, "  \"seed\": {GOLDEN_SEED},\n  \"n\": {GOLDEN_N},\n").unwrap();
    out.push_str("  \"x_bits\": [");
    push_bits_array(&mut out, &bits);
    out.push_str("],\n  \"schemes\": [\n");

    let mut codes = Vec::new();
    let mut scales = Vec::new();
    let mut wire = Vec::new();
    let mut fast_wire = Vec::new();
    for (gi, scheme) in golden_schemes().into_iter().enumerate() {
        let r = RefMxCodec::new(scheme);
        let f = MxCodec::new(scheme);
        r.quantize_unpacked(&x, &mut codes, &mut scales);
        r.encode(&x, &mut wire);
        f.encode(&x, &mut fast_wire);
        assert_eq!(
            wire,
            fast_wire,
            "golden: fast/ref wire mismatch for {}",
            scheme.name()
        );
        let mut dec = vec![0.0f32; GOLDEN_N];
        r.decode_add(&wire, GOLDEN_N, &mut dec);
        let dec_bits: Vec<u32> = dec.iter().map(|v| v.to_bits()).collect();

        if gi > 0 {
            out.push_str(",\n");
        }
        write!(out, "    {{\"scheme\": \"{}\", \"codes\": \"", scheme.name()).unwrap();
        push_hex(&mut out, &codes);
        out.push_str("\", \"scales\": \"");
        push_hex(&mut out, &scales);
        out.push_str("\", \"wire\": \"");
        push_hex(&mut out, &wire);
        out.push_str("\", \"dec_bits\": [");
        push_bits_array(&mut out, &dec_bits);
        out.push_str("]}");
    }
    out.push_str("\n  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inputs_are_deterministic_and_cover_specials() {
        let a = golden_input_bits();
        let b = golden_input_bits();
        assert_eq!(a, b);
        assert_eq!(a.len(), GOLDEN_N);
        assert_eq!(&a[..GOLDEN_SPECIALS.len()], &GOLDEN_SPECIALS[..]);
        // the RNG tail actually varies (not stuck on one word)
        let tail = &a[GOLDEN_SPECIALS.len()..];
        assert!(tail.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn grid_names_are_unique_and_parse_back() {
        let grid = golden_schemes();
        assert_eq!(grid.len(), 22);
        let mut names: Vec<String> = grid.iter().map(|s| s.name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 22, "duplicate scheme in golden grid");
        for s in &grid {
            assert_eq!(MxScheme::parse(&s.name()).unwrap(), *s);
        }
    }

    #[test]
    fn emit_is_stable_and_well_formed() {
        let doc = emit();
        assert_eq!(doc, emit());
        assert!(doc.starts_with("{\n  \"generator\": \"tpcc golden --emit\",\n"));
        assert!(doc.ends_with("\n  ]\n}\n"));
        assert_eq!(doc.matches("\"scheme\": ").count(), 22);
        let v = crate::util::json::Json::parse(&doc).expect("golden emit must be valid JSON");
        assert_eq!(v.get("n").and_then(|n| n.as_i64()), Some(GOLDEN_N as i64));
    }
}
