//! SoTA comparison codecs from Bian et al. 2024 (paper §5.3, Table 4):
//! channel-wise INT quantization and TopK sparsification, plus an FP16
//! truncation baseline.

use super::{CodecError, Compressor};

/// Channel-wise INTk: one f32 absmax scale per channel (the last-axis
/// stride), symmetric integer codes. For a `[rows, channels]` partial
/// activation this is the paper's "channel-wise INT4": coarse-grained —
/// one scale per channel over *all* rows — which is exactly why it
/// degrades worse than MX block scaling (Table 4) while being cheaper.
pub struct ChannelInt {
    pub bits: u32,
    /// channel count; set per-tensor via `with_channels` or inferred as
    /// sqrt-ish fallback. The collective knows the row length and always
    /// sets it.
    pub channels: usize,
}

impl ChannelInt {
    pub fn new(bits: u32) -> ChannelInt {
        ChannelInt { bits, channels: 0 }
    }

    pub fn with_channels(bits: u32, channels: usize) -> ChannelInt {
        ChannelInt { bits, channels }
    }

    fn qmax(&self) -> f32 {
        ((1i32 << (self.bits - 1)) - 1) as f32
    }

    fn resolve_channels(&self, n: usize) -> usize {
        if self.channels > 0 && n % self.channels == 0 {
            self.channels
        } else {
            n // degenerate: one scale per value-row of 1 channel... treat whole tensor as one channel row
        }
    }
}

impl Compressor for ChannelInt {
    fn name(&self) -> String {
        format!("int{}_channelwise", self.bits)
    }

    /// k bits per value + 32-bit scale per channel amortized over rows.
    fn effective_bits(&self, n: usize) -> f64 {
        let ch = self.resolve_channels(n);
        let rows = n / ch;
        self.bits as f64 + 32.0 / rows as f64
    }

    /// Wire: per-channel f32 scales, then row-major i8 codes (one byte
    /// per value regardless of k<=8; accounted size uses effective_bits).
    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        let ch = self.resolve_channels(x.len());
        let rows = x.len() / ch;
        out.clear();
        out.reserve(ch * 4 + x.len());
        let qmax = self.qmax();
        // channel c = column index; scale over all rows of that column
        let mut scales = vec![0.0f32; ch];
        for r in 0..rows {
            for c in 0..ch {
                scales[c] = scales[c].max(x[r * ch + c].abs());
            }
        }
        for s in &mut scales {
            *s = if *s > 0.0 { *s / qmax } else { 1.0 };
            out.extend_from_slice(&s.to_le_bytes());
        }
        for r in 0..rows {
            for c in 0..ch {
                let q = (x[r * ch + c] / scales[c]).round_ties_even().clamp(-qmax, qmax);
                out.push(q as i8 as u8);
            }
        }
    }

    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        let ch = self.resolve_channels(n_values);
        let rows = n_values / ch;
        let mut scales = vec![0.0f32; ch];
        for (c, chunk) in wire[..ch * 4].chunks_exact(4).enumerate() {
            scales[c] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        let codes = &wire[ch * 4..ch * 4 + n_values];
        for r in 0..rows {
            for c in 0..ch {
                acc[r * ch + c] += (codes[r * ch + c] as i8) as f32 * scales[c];
            }
        }
    }

    /// Plain per-channel scale+round: far fewer ops than MX block-wise
    /// exponent extraction + sub-byte packing (paper §5.3: "INT4 ...
    /// minimal computational overhead").
    fn compute_cost_factor(&self) -> f64 {
        0.35
    }

    /// Slicing on channel-row multiples keeps the per-channel scales
    /// meaningful on every phase payload.
    fn alignment(&self) -> usize {
        self.channels.max(1)
    }

    /// Stored layout: one f32 scale per channel, then a byte per value.
    fn encoded_len(&self, n_values: usize) -> usize {
        self.resolve_channels(n_values) * 4 + n_values
    }
}

/// TopK sparsification: keep the `1/ratio_den` largest-magnitude values
/// (value f32 + index u32 each), zero the rest. "TopK 3x" in the paper
/// means 3x wire compression vs fp16 => keep fraction = 16 / (3 * 64).
pub struct TopK {
    /// compression factor vs fp16 (paper's "3x")
    pub compression: f64,
}

impl TopK {
    pub fn new(compression: f64) -> TopK {
        TopK { compression }
    }

    pub fn keep_count(&self, n: usize) -> usize {
        // each kept value costs 64 wire bits; match 16/compression bits/value
        let frac = 16.0 / (self.compression * 64.0);
        ((n as f64 * frac).round() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("topk{:.0}x", self.compression)
    }

    fn effective_bits(&self, n: usize) -> f64 {
        self.keep_count(n) as f64 * 64.0 / n as f64
    }

    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        let k = self.keep_count(x.len());
        // partial selection: indices of the k largest |x|
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        out.clear();
        out.reserve(k * 8);
        for &i in &idx[..k] {
            out.extend_from_slice(&i.to_le_bytes());
            out.extend_from_slice(&x[i as usize].to_le_bytes());
        }
    }

    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        let k = self.keep_count(n_values);
        for rec in wire.chunks_exact(8).take(k) {
            let i = u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]) as usize;
            let v = f32::from_le_bytes([rec[4], rec[5], rec[6], rec[7]]);
            acc[i] += v;
        }
    }

    /// selection pass over all values, but trivial decode
    fn compute_cost_factor(&self) -> f64 {
        0.8
    }

    /// Stored layout: k records of (u32 index, f32 value).
    fn encoded_len(&self, n_values: usize) -> usize {
        if n_values == 0 {
            return 0;
        }
        self.keep_count(n_values) * 8
    }

    /// TopK is the one codec whose wire carries *addresses*: a corrupt
    /// index would scatter-add out of bounds, so the untrusted path
    /// range-checks every record before applying any of them.
    fn try_decode_add(
        &self,
        wire: &[u8],
        n_values: usize,
        acc: &mut [f32],
    ) -> Result<(), CodecError> {
        if n_values == 0 {
            return Ok(());
        }
        let k = self.keep_count(n_values);
        let need = k * 8;
        if wire.len() < need {
            return Err(CodecError::Truncated { needed: need, got: wire.len() });
        }
        if acc.len() < n_values {
            return Err(CodecError::Malformed(format!(
                "accumulator holds {} values, message carries {}",
                acc.len(),
                n_values
            )));
        }
        for rec in wire.chunks_exact(8).take(k) {
            let i = u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]) as usize;
            if i >= n_values {
                return Err(CodecError::Malformed(format!(
                    "topk index {i} out of range for {n_values} values"
                )));
            }
        }
        self.decode_add(wire, n_values, acc);
        Ok(())
    }
}

/// FP16 truncation (the paper's *uncompressed* baseline: TP traffic is
/// fp16 activations; our tensors are f32 in memory, so "uncompressed"
/// on the wire = fp16, 16 effective bits).
pub struct Fp16;

fn f32_to_f16_bits(v: f32) -> u16 {
    // round-to-nearest-even f32 -> IEEE binary16
    let b = v.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xFF) as i32;
    let mant = b & 0x7F_FFFF;
    if exp == 0xFF {
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign;
        }
        let m = mant | 0x80_0000;
        let shift = 14 - e;
        let half = 1u32 << (shift - 1);
        let rounded = (m + half - 1 + ((m >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    let half = 0x1000u32;
    let m = mant + (half - 1) + ((mant >> 13) & 1);
    if m & 0x80_0000 != 0 {
        // mantissa carry bumps the exponent
        let e2 = e + 1;
        if e2 >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e2 as u16) << 10);
    }
    sign | ((e as u16) << 10) | (m >> 13) as u16
}

fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let mant = (h & 0x3FF) as u32;
    let bits = match exp {
        0 => {
            if mant == 0 {
                sign
            } else {
                // subnormal: value = mant * 2^-24; normalize (k shifts to
                // set bit 10) => (1+frac) * 2^(-14-k), biased = 113 - k.
                let mut k = 0i32;
                let mut m = mant;
                while m & 0x400 == 0 {
                    m <<= 1;
                    k += 1;
                }
                m &= 0x3FF;
                sign | (((113 - k) as u32) << 23) | (m << 13)
            }
        }
        0x1F => sign | 0x7F80_0000 | (mant << 13),
        _ => sign | ((exp as u32 + 127 - 15) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

impl Compressor for Fp16 {
    fn name(&self) -> String {
        "fp16".into()
    }
    fn effective_bits(&self, _n: usize) -> f64 {
        16.0
    }
    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(x.len() * 2);
        for &v in x {
            out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
        }
    }
    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        for (i, c) in wire.chunks_exact(2).take(n_values).enumerate() {
            acc[i] += f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channelwise_int4_roundtrip() {
        let mut rng = Rng::new(1);
        let (rows, ch) = (64, 32);
        let mut x = vec![0.0f32; rows * ch];
        rng.fill_activations(&mut x, 2.0);
        let c = ChannelInt::with_channels(4, ch);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let out = c.decode(&wire, x.len());
        // per-channel error bound: scale = amax/7 => max err 0.5*scale
        for col in 0..ch {
            let amax = (0..rows).fold(0.0f32, |a, r| a.max(x[r * ch + col].abs()));
            for r in 0..rows {
                let err = (x[r * ch + col] - out[r * ch + col]).abs();
                assert!(err <= amax / 7.0 * 0.51 + 1e-6);
            }
        }
        // effective bits ~ 4 + 32/rows
        assert!((c.effective_bits(x.len()) - (4.0 + 32.0 / 64.0)).abs() < 1e-9);
    }

    #[test]
    fn channelwise_outlier_poisons_channel() {
        // the Table 4 failure mode: one outlier crushes its whole channel
        let ch = 8;
        let rows = 16;
        let mut x = vec![0.1f32; rows * ch];
        x[3] = 1000.0; // outlier in channel 3
        let c = ChannelInt::with_channels(4, ch);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let out = c.decode(&wire, x.len());
        // channel 3's small values are destroyed (quantized to 0)
        assert_eq!(out[ch + 3], 0.0);
        // other channels survive
        assert!((out[ch + 4] - 0.1).abs() < 0.01);
    }

    #[test]
    fn topk_keeps_largest() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.3, 0.05, 1.0, 0.0];
        let t = TopK::new(16.0); // keep 16/(16*64) = 1/64 -> clamps to 1
        assert_eq!(t.keep_count(x.len()), 1);
        let mut wire = Vec::new();
        t.encode(&x, &mut wire);
        let out = t.decode(&wire, x.len());
        assert_eq!(out[1], -5.0);
        assert_eq!(out.iter().filter(|v| **v != 0.0).count(), 1);
    }

    #[test]
    fn topk_3x_effective_bits() {
        let t = TopK::new(3.0);
        let n = 1200;
        let eb = t.effective_bits(n);
        assert!((eb - 16.0 / 3.0).abs() < 0.2, "{eb}");
    }

    #[test]
    fn fp16_roundtrip_exact_for_halves() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -2.75, 1e-5] {
            let h = f32_to_f16_bits(v);
            let back = f16_bits_to_f32(h);
            let rel = if v == 0.0 { back.abs() } else { ((back - v) / v).abs() };
            // subnormals (|v| < 2^-14) only carry mantissa bits of the
            // fixed 2^-24 grid -> coarser relative error
            let tol = if v != 0.0 && v.abs() < 6.1e-5 { 1e-2 } else { 1e-3 };
            assert!(rel < tol, "{v} -> {back}");
        }
    }

    #[test]
    fn topk_try_decode_rejects_corrupt_index() {
        let x = vec![1.0f32; 64];
        let t = TopK::new(3.0);
        let mut wire = Vec::new();
        t.encode(&x, &mut wire);
        let mut acc = vec![0.0f32; 64];
        assert!(t.try_decode_add(&wire, 64, &mut acc).is_ok());
        // corrupt the first record's index to something out of range
        wire[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let before = acc.clone();
        let err = t.try_decode_add(&wire, 64, &mut acc);
        assert!(matches!(err, Err(CodecError::Malformed(_))), "{err:?}");
        // validation happens before any mutation: acc untouched
        assert_eq!(acc, before);
    }

    #[test]
    fn fp16_compressor_roundtrip() {
        let mut rng = Rng::new(2);
        let mut x = vec![0.0f32; 512];
        rng.fill_activations(&mut x, 2.0);
        let c = Fp16;
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        assert_eq!(wire.len(), 1024);
        let out = c.decode(&wire, 512);
        for (a, b) in x.iter().zip(&out) {
            assert!(((a - b) / a.abs().max(1e-6)).abs() < 1e-3);
        }
    }
}
