//! Shared fuzz drivers for the codec differential + robustness harness.
//!
//! The same bodies run in three places (dnglab-style):
//!
//! * `rust/tests/fuzz_codec.rs` — fixed-seed smoke (default 500 cases)
//!   on every `cargo test`, so CI exercises the harness unconditionally;
//!   `TPCC_FUZZ_ITERS` raises the count for soak runs.
//! * `rust/fuzz/fuzz_targets/*` — `cargo fuzz` coverage-guided entry
//!   points feeding arbitrary bytes into the same drivers.
//! * the property suite replays `rust/tests/corpus/*.json` regression
//!   cases (previously-shrunk failures) through
//!   [`differential_slice`].
//!
//! Two properties are load-bearing:
//!
//! 1. **Differential**: for arbitrary f32 slices (NaN/Inf/subnormal/±0,
//!    odd lengths, every block size) the fast [`MxCodec`] must produce
//!    byte-identical wires, bit-identical decodes, and bit-identical
//!    requantization vs the [`RefMxCodec`] oracle.
//! 2. **Robustness**: `try_decode_add` on arbitrary (truncated,
//!    corrupt, adversarial) bytes must return `Err` or decode garbage
//!    values — but never panic or touch memory out of bounds.

use super::reference::RefMxCodec;
use super::types::{MxScheme, ELEM_FORMATS};
use super::{ChannelInt, Compressor, MxCodec, NoCompress, TopK};
use crate::util::rng::Rng;

/// Block sizes the structure-aware generator draws from — deliberately
/// including 1, primes, and non-powers-of-two.
pub const FUZZ_BLOCKS: &[usize] = &[1, 2, 3, 8, 16, 32, 64, 100];
/// Scale exponent widths: the full e8m0 plus the clamping small formats.
pub const FUZZ_SCALE_EBITS: &[u32] = &[4, 5, 8];

/// Hostile-but-deterministic f32 bit patterns: ±0, ±inf, quiet/signaling
/// NaN (both signs), min/max subnormal, min normal, max finite, and a
/// few grid-adjacent values.
pub const SPECIAL_BITS: &[u32] = &[
    0x0000_0000, 0x8000_0000, 0x7F80_0000, 0xFF80_0000, 0x7FC0_0000, 0xFFC0_0000,
    0x7F80_0001, 0x0000_0001, 0x8000_0001, 0x007F_FFFF, 0x0080_0000, 0x7F7F_FFFF,
    0xFF7F_FFFF, 0x3F80_0000, 0x3380_0000,
];

/// Draw one value; `mode` picks the distribution (raw bits / uniform /
/// special / near-grid-tie).
pub fn fuzz_value(rng: &mut Rng, mode: u64) -> f32 {
    match mode {
        0 => f32::from_bits(rng.next_u64() as u32),
        1 => {
            let u = (rng.next_u64() >> 40) as f32 / (1u32 << 24) as f32;
            (u - 0.5) * 8.0
        }
        2 => f32::from_bits(SPECIAL_BITS[(rng.next_u64() % SPECIAL_BITS.len() as u64) as usize]),
        _ => {
            // values sitting on or near grid steps, where ties-to-even
            // and guard/sticky handling actually matter
            let base = super::types::exp2i((rng.next_u64() % 16) as i32 - 8);
            let m = (rng.next_u64() % 32) as f32 / 8.0;
            let sign = if rng.next_u64() & 1 == 1 { -1.0 } else { 1.0 };
            sign * base * m
        }
    }
}

/// Draw a whole slice, mixing modes within the slice when `mode == 3`.
pub fn fuzz_values(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mode_mix = rng.next_u64() % 4;
    (0..n)
        .map(|_| {
            let m = if mode_mix == 3 { rng.next_u64() % 4 } else { mode_mix };
            fuzz_value(rng, m)
        })
        .collect()
}

/// Draw a scheme across every element format × hostile block sizes ×
/// all scale widths.
pub fn fuzz_scheme(rng: &mut Rng) -> MxScheme {
    let e = &ELEM_FORMATS[(rng.next_u64() % ELEM_FORMATS.len() as u64) as usize];
    let block = FUZZ_BLOCKS[(rng.next_u64() % FUZZ_BLOCKS.len() as u64) as usize];
    let se = FUZZ_SCALE_EBITS[(rng.next_u64() % FUZZ_SCALE_EBITS.len() as u64) as usize];
    MxScheme::new(e.name, block, se).expect("interned format")
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str, scheme: &MxScheme, n: usize) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what} diverged: scheme {} n {n} index {i}: fast {g:?} ({:#010x}) vs ref {w:?} ({:#010x})",
            scheme.name(),
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// The differential body: fast codec vs reference oracle on one slice.
/// Panics (= fuzz finding) on any divergence.
pub fn differential_slice(x: &[f32], scheme: MxScheme) {
    let fast = MxCodec::new(scheme);
    let oracle = RefMxCodec::new(scheme);
    let n = x.len();

    // 1. byte-identical wires
    let (mut wf, mut wr) = (Vec::new(), Vec::new());
    fast.encode(x, &mut wf);
    oracle.encode(x, &mut wr);
    assert_eq!(
        wf,
        wr,
        "encode wire diverged: scheme {} n {n} x {x:?}",
        scheme.name()
    );
    assert_eq!(wf.len(), fast.encoded_len(n), "stored-length accounting drifted");

    // 2. bit-identical decode-accumulate into a non-trivial accumulator
    let seed_acc: Vec<f32> = (0..n).map(|i| i as f32 * 0.25 - 1.0).collect();
    let (mut af, mut ar) = (seed_acc.clone(), seed_acc.clone());
    fast.decode_add(&wf, n, &mut af);
    oracle.decode_add(&wr, n, &mut ar);
    assert_bits_eq(&af, &ar, "decode_add", &scheme, n);

    // 3. bit-identical requantization (the Analytic-mode path) — the
    //    oracle's requant is the trait default (encode + decode_add)
    let (mut qf, mut qr) = (seed_acc.clone(), seed_acc);
    let mut scratch = Vec::new();
    fast.requant_add(x, &mut qf, &mut scratch);
    oracle.requant_add(x, &mut qr, &mut scratch);
    assert_bits_eq(&qf, &qr, "requant_add", &scheme, n);

    // 4. the validating decoder accepts its own wire and rejects any
    //    truncation of it
    let mut acc = vec![0.0f32; n];
    fast.try_decode_add(&wf, n, &mut acc).expect("own wire must validate");
    if !wf.is_empty() {
        for cut in [0usize, wf.len() / 2, wf.len() - 1] {
            assert!(
                fast.try_decode_add(&wf[..cut], n, &mut acc).is_err(),
                "truncated wire ({cut}/{} bytes) must error",
                wf.len()
            );
        }
    }
}

/// One seeded differential case: derive (scheme, length, values) from
/// the seed and run [`differential_slice`].
pub fn differential_case(seed: u64) {
    let mut rng = Rng::new(seed ^ 0xD1FF_C0DE);
    let scheme = fuzz_scheme(&mut rng);
    let n = (rng.next_u64() % 778) as usize; // 0..=777, odd lengths included
    let x = fuzz_values(&mut rng, n);
    differential_slice(&x, scheme);
}

/// The robustness body: feed one byte buffer to every codec family's
/// validating decoder. Any `Result` is acceptable; panics and OOB are
/// findings. (Safe Rust turns OOB into a panic, so "no panic" covers
/// both.)
pub fn decoder_arbitrary_bytes(bytes: &[u8], n_values: usize) {
    let mut codecs: Vec<Box<dyn Compressor>> = vec![
        Box::new(NoCompress),
        Box::new(super::baselines::Fp16),
        Box::new(ChannelInt::with_channels(4, 32)),
        Box::new(TopK::new(3.0)),
    ];
    for name in ["fp4_e2m1_b32_e8m0", "fp5_e1m3_b3_e8m0", "int5_b8_e4m0", "fp3_e1m1_b1_e8m0"] {
        codecs.push(Box::new(MxCodec::new(MxScheme::parse(name).unwrap())));
        codecs.push(Box::new(RefMxCodec::new(MxScheme::parse(name).unwrap())));
    }
    for c in &codecs {
        let mut acc = vec![0.0f32; n_values];
        let _ = c.try_decode_add(bytes, n_values, &mut acc);
    }
}

/// One seeded robustness case: random length/bytes, sometimes a valid
/// wire with flipped bytes or a lying `n_values` (structure-aware
/// corruption finds more than pure noise).
pub fn decoder_case(seed: u64) {
    let mut rng = Rng::new(seed ^ 0xDEC0_DE00);
    let n = (rng.next_u64() % 600) as usize;
    match rng.next_u64() % 3 {
        0 => {
            // pure noise
            let len = (rng.next_u64() % 4096) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            decoder_arbitrary_bytes(&bytes, n);
        }
        1 => {
            // valid wire, corrupted bytes
            let scheme = fuzz_scheme(&mut rng);
            let c = MxCodec::new(scheme);
            let x = fuzz_values(&mut rng, n);
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            for _ in 0..(rng.next_u64() % 8 + 1) {
                if wire.is_empty() {
                    break;
                }
                let at = (rng.next_u64() % wire.len() as u64) as usize;
                wire[at] ^= rng.next_u64() as u8;
            }
            decoder_arbitrary_bytes(&wire, n);
        }
        _ => {
            // valid wire, lying n_values (decoder must length-check,
            // not trust the caller's count against the byte count)
            let scheme = fuzz_scheme(&mut rng);
            let c = MxCodec::new(scheme);
            let x = fuzz_values(&mut rng, n);
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            let lied = (rng.next_u64() % 1200) as usize;
            decoder_arbitrary_bytes(&wire, lied);
        }
    }
}

#[cfg(test)]
mod tests {
    // The real workout lives in rust/tests/fuzz_codec.rs (seeded smoke)
    // and rust/fuzz/ (coverage-guided). Here: just pin the drivers run.
    #[test]
    fn drivers_execute() {
        super::differential_case(1);
        super::decoder_case(1);
    }

    #[test]
    fn empty_slice_roundtrips() {
        let scheme = crate::mxfmt::MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap();
        super::differential_slice(&[], scheme);
    }
}
