//! MX format descriptors — rust twin of `python/compile/kernels/formats.py`.

/// Element (value) data type of an MX block: tiny float `ExMy` or
/// sign-magnitude `INTk`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ElemFormat {
    pub name: &'static str,
    pub is_float: bool,
    /// exponent bits (floats; 0 for INT)
    pub ebits: u32,
    /// mantissa bits (floats) / magnitude bits excl. sign (INT)
    pub mbits: u32,
}

impl ElemFormat {
    /// Total storage bits per element including sign.
    pub const fn bits(&self) -> u32 {
        1 + self.ebits + self.mbits
    }

    pub const fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }

    /// Largest unbiased exponent (MX: no inf/nan; see formats.py).
    pub const fn emax(&self) -> i32 {
        if self.is_float {
            1 << (self.ebits - 1)
        } else {
            self.mbits as i32 - 1
        }
    }

    /// Smallest normal unbiased exponent (floats).
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }

    pub fn max_value(&self) -> f32 {
        if self.is_float {
            exp2i(self.emax()) * (2.0 - exp2i(-(self.mbits as i32)))
        } else {
            self.int_qmax() as f32
        }
    }

    pub const fn int_qmax(&self) -> i32 {
        (1 << self.mbits) - 1
    }
}

pub const ELEM_FORMATS: &[ElemFormat] = &[
    ElemFormat { name: "fp5_e3m1", is_float: true, ebits: 3, mbits: 1 },
    ElemFormat { name: "fp5_e2m2", is_float: true, ebits: 2, mbits: 2 },
    ElemFormat { name: "fp5_e1m3", is_float: true, ebits: 1, mbits: 3 },
    ElemFormat { name: "fp4_e2m1", is_float: true, ebits: 2, mbits: 1 },
    ElemFormat { name: "fp4_e1m2", is_float: true, ebits: 1, mbits: 2 },
    ElemFormat { name: "fp3_e1m1", is_float: true, ebits: 1, mbits: 1 },
    ElemFormat { name: "int3", is_float: false, ebits: 0, mbits: 2 },
    ElemFormat { name: "int4", is_float: false, ebits: 0, mbits: 3 },
    ElemFormat { name: "int5", is_float: false, ebits: 0, mbits: 4 },
];

pub fn elem_by_name(name: &str) -> Option<ElemFormat> {
    ELEM_FORMATS.iter().copied().find(|e| e.name == name)
}

/// `EdM0` power-of-two scale: d-bit biased exponent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleFormat {
    pub ebits: u32,
}

impl ScaleFormat {
    pub const fn bias(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }
    /// Symmetric clamp range (E8M0: [-127, 127], 0xFF reserved).
    pub const fn emax(&self) -> i32 {
        (1 << (self.ebits - 1)) - 1
    }
    pub const fn emin(&self) -> i32 {
        -self.emax()
    }
    pub fn name(&self) -> String {
        format!("e{}m0", self.ebits)
    }
}

/// A complete MX scheme (element dtype × scale dtype × block size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MxScheme {
    pub elem: ElemFormat,
    pub scale: ScaleFormat,
    pub block: usize,
}

impl MxScheme {
    pub fn new(elem: &str, block: usize, scale_ebits: u32) -> anyhow::Result<MxScheme> {
        let elem = elem_by_name(elem)
            .ok_or_else(|| anyhow::anyhow!("unknown elem format {elem}"))?;
        anyhow::ensure!(block > 0, "block must be positive");
        Ok(MxScheme { elem, scale: ScaleFormat { ebits: scale_ebits }, block })
    }

    /// Parse `fp4_e2m1_b32_e8m0`.
    pub fn parse(name: &str) -> anyhow::Result<MxScheme> {
        let parts: Vec<&str> = name.split('_').collect();
        anyhow::ensure!(parts.len() >= 3, "bad scheme name {name}");
        let scale_part = parts[parts.len() - 1];
        let block_part = parts[parts.len() - 2];
        let elem = parts[..parts.len() - 2].join("_");
        anyhow::ensure!(
            block_part.starts_with('b') && scale_part.starts_with('e') && scale_part.ends_with("m0"),
            "bad scheme name {name}"
        );
        let block: usize = block_part[1..].parse()?;
        let sb: u32 = scale_part[1..scale_part.len() - 2].parse()?;
        MxScheme::new(&elem, block, sb)
    }

    pub fn name(&self) -> String {
        format!("{}_b{}_{}", self.elem.name, self.block, self.scale.name())
    }

    /// Paper §4.2: value bits + amortized scale bits.
    pub fn effective_bits(&self) -> f64 {
        self.elem.bits() as f64 + self.scale.ebits as f64 / self.block as f64
    }

    pub fn compression_ratio(&self) -> f64 {
        16.0 / self.effective_bits()
    }

    /// Bit-packed wire size for any value count; a trailing partial
    /// block still pays one full scale. (For block-aligned counts this
    /// is exactly the historical `nblocks * (block*elem + scale)` math.)
    pub fn wire_bytes(&self, n_values: usize) -> usize {
        let nblocks = n_values.div_ceil(self.block);
        let bits = n_values * self.elem.bits() as usize + nblocks * self.scale.ebits as usize;
        bits.div_ceil(8)
    }

    /// Analytic worst-case absolute error for one element of a block
    /// whose absolute max is `amax` — the bound the property suite
    /// holds every codec round trip to. Three regimes, take the max:
    ///
    /// * **rounding**: scale 2^s puts every |v| <= amax below
    ///   2^(emax+1) in scaled units; the grid step there is at most
    ///   2^(emax-mbits), and in-range rounding plus top-of-binade
    ///   saturation both stay within one step (INT: one unit step).
    /// * **flush**: when the scale clamps *up* (tiny amax vs the EdM0
    ///   range), values below half the smallest subnormal flush to
    ///   zero — bounded by half a subnormal step at the clamped scale.
    /// * **clamp**: when the scale clamps *down* (huge amax), the
    ///   representable max falls short of amax by `amax - maxv*2^s`.
    ///
    /// NaN inputs have no meaningful error bound (they quantize to an
    /// arbitrary grid point) and are excluded by contract.
    pub fn block_error_bound(&self, amax: f32) -> f32 {
        let e = &self.elem;
        let sexp = {
            // mirror codec::block_scale_exp without the circular import
            let raw = if amax > 0.0 { floor_log2(amax) - e.emax() } else { self.scale.emin() };
            raw.clamp(self.scale.emin(), self.scale.emax())
        };
        let scale = exp2i(sexp);
        let rounding = if e.is_float {
            exp2i(sexp + e.emax() - e.mbits as i32)
        } else {
            // one full unit step: half for rounding, plus the top of the
            // scaled range (just under 2^mbits) clamping onto qmax
            scale
        };
        let flush = if e.is_float { exp2i(sexp + e.emin() - e.mbits as i32 - 1) } else { 0.5 * scale };
        let clamp = (amax - e.max_value() * scale).max(0.0);
        rounding.max(flush).max(clamp)
    }
}

/// Exact 2^e for e in [-126, 127] by assembling the f32 exponent field —
/// identical to ref.py `_exp2i` (clamped, never subnormal/inf).
#[inline]
pub fn exp2i(e: i32) -> f32 {
    let e = e.clamp(-126, 127);
    f32::from_bits(((e + 127) as u32) << 23)
}

/// floor(log2(|x|)) via the f32 exponent field — identical to ref.py
/// `_floor_log2` (subnormals map to -127).
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    ((x.to_bits() >> 23) & 0xFF) as i32 - 127
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_bits_match_paper() {
        // FP4 E2M1 b32 e8m0 -> 4.25 effective bits (paper Table 3 caption)
        let s = MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap();
        assert!((s.effective_bits() - 4.25).abs() < 1e-12);
        // FP4 b8 -> 5.0, FP5 b32 -> 5.25 (Table 1 "Eff. Bits" column family)
        assert_eq!(MxScheme::parse("fp4_e2m1_b8_e8m0").unwrap().effective_bits(), 5.0);
        assert_eq!(MxScheme::parse("fp5_e2m2_b32_e8m0").unwrap().effective_bits(), 5.25);
    }

    #[test]
    fn parse_roundtrip() {
        for e in ELEM_FORMATS {
            for b in [8usize, 16, 32] {
                for sb in [4u32, 5, 8] {
                    let s = MxScheme::new(e.name, b, sb).unwrap();
                    let r = MxScheme::parse(&s.name()).unwrap();
                    assert_eq!(s, r);
                }
            }
        }
        assert!(MxScheme::parse("bogus").is_err());
        assert!(MxScheme::parse("fp4_e2m1_x32_e8m0").is_err());
    }

    #[test]
    fn format_ranges() {
        let e2m1 = elem_by_name("fp4_e2m1").unwrap();
        assert_eq!(e2m1.emax(), 2);
        assert_eq!(e2m1.max_value(), 6.0); // MX spec FP4 max
        let e8m0 = ScaleFormat { ebits: 8 };
        assert_eq!(e8m0.emax(), 127);
        assert_eq!(e8m0.bias(), 127);
        let int4 = elem_by_name("int4").unwrap();
        assert_eq!(int4.int_qmax(), 7);
        assert_eq!(int4.bits(), 4);
    }

    #[test]
    fn wire_bytes_tail_blocks() {
        let s = MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap();
        // aligned counts keep the historical accounting
        assert_eq!(s.wire_bytes(64), (64 * 4 + 2 * 8) / 8);
        // a 33rd value opens a second block: 33*4 + 2*8 bits = 148 -> 19
        assert_eq!(s.wire_bytes(33), 19);
        assert_eq!(s.wire_bytes(1), 2); // 4 + 8 bits -> 2 bytes
        assert_eq!(s.wire_bytes(0), 0);
    }

    #[test]
    fn block_error_bound_regimes() {
        let s = MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap();
        // mid-range: bound is amax-relative (2^(emax-mbits) scaled)
        let b = s.block_error_bound(1.0);
        assert!(b > 0.0 && b <= 1.0, "{b}");
        // huge amax with a small scale format: clamp term dominates
        let s4 = MxScheme::parse("fp4_e2m1_b8_e4m0").unwrap();
        let b = s4.block_error_bound(1e20);
        assert!(b > 1e19, "{b}");
        // zero block: bound collapses to the smallest representable step
        assert!(s.block_error_bound(0.0) < 1e-35);
    }

    #[test]
    fn exp2_floor_log2_exact() {
        for e in -126..=127 {
            assert_eq!(exp2i(e), (e as f32).exp2());
            assert_eq!(floor_log2(exp2i(e)), e);
        }
        assert_eq!(floor_log2(3.999), 1);
        assert_eq!(floor_log2(4.0), 2);
        assert_eq!(floor_log2(0.75), -1);
    }
}
