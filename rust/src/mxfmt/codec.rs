//! The MX quantize/dequantize codec — the fused wide-word hot path.
//!
//! Wire layout per message (shared with [`super::reference`]):
//! `[codes: ceil(n*elem_bits/8) bytes][scales: ceil(n/block) bytes]`.
//!
//! §Perf — how the hot path earns its keep (DESIGN.md §Codec hot path):
//!
//! * **Table-driven element encode.** `quantize_code_float` costs an
//!   exponent extract, clamp, multiply, `round_ties_even` and a pair of
//!   saturating integer ops per element. The fast path replaces all of
//!   it with one 16..32 KiB lookup keyed on the scaled value's sign
//!   bit, biased exponent, top `mbits+1` mantissa bits, and a sticky-OR
//!   of the rest — exactly the bits that can influence the sign test
//!   and ties-to-even rounding at any representable step size, so the
//!   lookup is completely branchless and the table is *provably* exact,
//!   not approximately so (an exhaustive 2^32-input sweep per format
//!   checked every f32 bit pattern; the fuzz/property/golden suites
//!   keep enforcing it against [`super::reference`]).
//! * **u64 bit pump.** Codes stream through [`packed::BitWriter`] /
//!   [`packed::BitReader`]: eight-byte accumulator stores/loads instead
//!   of the reference's per-code read-modify-write bytes. (An earlier
//!   fused attempt with byte-granularity stores measured *slower* than
//!   two-pass — 193 vs 242 MB/s — which is why the pump is the load-
//!   bearing piece; see EXPERIMENTS.md §Perf iteration log.)
//! * **Zero steady-state allocation.** `encode` sizes its output with
//!   resize/truncate and overwrites every byte; `decode_add`/`requant_add`
//!   build a 32-entry decode table on the stack and borrow everything
//!   else. Rank workers and the collective engine thread one
//!   [`crate::collective::CommScratch`] through, so forward steps reuse
//!   the same wire/partial buffers forever.
//!
//! The scalar original lives on as [`super::reference::RefMxCodec`] —
//! the differential oracle every change here is judged against.

use std::sync::OnceLock;

use super::packed::{BitReader, BitWriter};
use super::types::{exp2i, floor_log2, ElemFormat, MxScheme, ELEM_FORMATS};
use super::{CodecError, Compressor};

/// Stateless MX codec for one scheme. Wire layout (per message):
/// `[codes: ceil(n*elem_bits/8) bytes][scales: nblocks bytes]`
/// (scales are stored byte-per-block on the wire for decode speed; the
///  *accounted* size uses `MxScheme::wire_bytes`, which bit-packs both —
///  the interconnect simulator charges the accounted size.)
///
/// Inputs of any length are accepted: a trailing partial block is
/// scaled over the elements it actually contains.
#[derive(Debug, Clone, Copy)]
pub struct MxCodec {
    pub scheme: MxScheme,
}

impl MxCodec {
    pub fn new(scheme: MxScheme) -> MxCodec {
        MxCodec { scheme }
    }

    /// Quantize into unpacked (code, scale) bytes — the scalar view
    /// used by golden-vector tests and tools. Delegates to the
    /// reference codec: unpacked output is not a hot path.
    pub fn quantize_unpacked(&self, x: &[f32], codes: &mut Vec<u8>, scales: &mut Vec<u8>) {
        super::reference::RefMxCodec::new(self.scheme).quantize_unpacked(x, codes, scales)
    }

    /// Inverse of `quantize_unpacked`.
    pub fn dequantize_unpacked(&self, codes: &[u8], scales: &[u8], out: &mut Vec<f32>) {
        super::reference::RefMxCodec::new(self.scheme).dequantize_unpacked(codes, scales, out)
    }

    /// quantize -> dequantize round trip (error-injection view; used by
    /// the eval harness when simulating compression without the wire).
    ///
    /// Stays on the scalar element grid (`quantize_elem_*`): this is an
    /// error model, not a wire path, and its historical NaN semantics
    /// (saturate to `max_value`) are part of the eval contract.
    pub fn fake_quantize(&self, x: &mut [f32]) {
        let s = &self.scheme;
        for blk in x.chunks_mut(s.block) {
            let mut amax = 0.0f32;
            for &v in blk.iter() {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            let scale = exp2i(sexp);
            if s.elem.is_float {
                for v in blk.iter_mut() {
                    *v = quantize_elem_float(*v * inv, &s.elem) * scale;
                }
            } else {
                for v in blk.iter_mut() {
                    *v = quantize_elem_int(*v * inv, &s.elem) * scale;
                }
            }
        }
    }

    /// Actual bytes this codec writes for an n-value message (codes
    /// region + byte-per-block scales). The *accounted* wire size is
    /// `MxScheme::wire_bytes` (bit-packed scales).
    #[inline]
    pub fn stored_len(&self, n_values: usize) -> usize {
        let code_bytes = (n_values * self.scheme.elem.bits() as usize).div_ceil(8);
        code_bytes + n_values.div_ceil(self.scheme.block)
    }
}

/// MX shared exponent: floor(log2(amax)) - emax_elem, clamped to EdM0.
#[inline]
pub fn block_scale_exp(amax: f32, s: &MxScheme) -> i32 {
    let raw = if amax > 0.0 {
        floor_log2(amax) - s.elem.emax()
    } else {
        s.scale.emin()
    };
    raw.clamp(s.scale.emin(), s.scale.emax())
}

/// Round v (pre-divided by the block scale) onto the ExMy grid.
/// Mirrors ref.quantize_elem_float exactly.
#[inline]
pub fn quantize_elem_float(v: f32, e: &ElemFormat) -> f32 {
    let sign = if v < 0.0 { -1.0f32 } else { 1.0 };
    let a = v.abs();
    if a == 0.0 {
        return 0.0;
    }
    let maxv = e.max_value();
    let be = floor_log2(a).clamp(e.emin(), e.emax());
    let step = exp2i(be - e.mbits as i32);
    let q = ((a / step).round_ties_even() * step).min(maxv);
    sign * q
}

/// Round v onto the signed-magnitude INTk grid.
#[inline]
pub fn quantize_elem_int(v: f32, e: &ElemFormat) -> f32 {
    let qmax = e.int_qmax() as f32;
    v.round_ties_even().clamp(-qmax, qmax)
}

/// Fused quantize+encode: v (pre-divided by the block scale) -> ExMy
/// code. Equivalent to `encode_elem_float(quantize_elem_float(v))` but
/// one pass: with code = ((be+bias-1)<<M) + round(a * 2^(M-be)),
/// mantissa carries roll into the exponent field automatically and
/// over-the-top carries saturate. Bit-exact vs the two-step path.
#[inline]
pub fn quantize_code_float(v: f32, e: &ElemFormat) -> u8 {
    let sign = ((v < 0.0) as u32) << (e.ebits + e.mbits);
    let a = v.abs();
    let be = floor_log2(a).clamp(e.emin(), e.emax());
    // `as u32` saturates for huge a (then min(max_mag) clamps — same
    // result as the reference's min(q, maxv) saturation)
    let m = (a * exp2i(e.mbits as i32 - be)).round_ties_even() as u32;
    // (be + bias - 1) << M; for subnormals (be == emin, bias+emin == 1)
    // this is 0 and m itself is the code; a == 0 gives m == 0.
    let mag = (((be + e.bias() - 1) as u32) << e.mbits).saturating_add(m);
    let max_mag = (((e.emax() + e.bias()) as u32) << e.mbits) | ((1 << e.mbits) - 1);
    let mag = mag.min(max_mag);
    // values that quantize to zero drop the sign (ref path: -0.0 < 0 is
    // false, so the reference also emits +0)
    if mag == 0 {
        0
    } else {
        (sign | mag) as u8
    }
}

/// Fused quantize+encode for sign-magnitude INTk.
#[inline]
pub fn quantize_code_int(v: f32, e: &ElemFormat) -> u8 {
    let sign = ((v < 0.0) as u32) << e.mbits;
    let m = (v.abs().round_ties_even() as u32).min(e.int_qmax() as u32);
    if m == 0 {
        0
    } else {
        (sign | m) as u8
    }
}

/// Bit-encode an exactly-representable ExMy value (sign|exp|mant).
#[inline]
pub fn encode_elem_float(q: f32, e: &ElemFormat) -> u8 {
    let sign = (q < 0.0) as u32;
    let a = q.abs();
    let be = floor_log2(a);
    let (exp_f, mant) = if a == 0.0 || be < e.emin() {
        // subnormal: mant = a / 2^(emin - M)
        let m = (a / exp2i(e.emin() - e.mbits as i32)).round_ties_even() as u32;
        (0u32, m)
    } else {
        let m = (a / exp2i(be - e.mbits as i32)).round_ties_even() as u32 - (1 << e.mbits);
        ((be + e.bias()) as u32, m)
    };
    ((sign << (e.ebits + e.mbits)) | (exp_f << e.mbits) | mant) as u8
}

#[inline]
pub fn decode_elem_float(code: u8, e: &ElemFormat) -> f32 {
    let c = code as u32;
    let sign = (c >> (e.ebits + e.mbits)) & 1;
    let exp_f = (c >> e.mbits) & ((1 << e.ebits) - 1);
    let mant = c & ((1 << e.mbits) - 1);
    let mag = if exp_f == 0 {
        mant as f32 * exp2i(e.emin() - e.mbits as i32)
    } else {
        ((1u32 << e.mbits) + mant) as f32 * exp2i(exp_f as i32 - e.bias() - e.mbits as i32)
    };
    if sign == 1 {
        -mag
    } else {
        mag
    }
}

#[inline]
pub fn encode_elem_int(q: f32, e: &ElemFormat) -> u8 {
    let sign = (q < 0.0) as u32;
    let mag = q.abs() as u32;
    ((sign << e.mbits) | mag) as u8
}

#[inline]
pub fn decode_elem_int(code: u8, e: &ElemFormat) -> f32 {
    let c = code as u32;
    let sign = (c >> e.mbits) & 1;
    let mag = (c & ((1 << e.mbits) - 1)) as f32;
    if sign == 1 {
        -mag
    } else {
        mag
    }
}

// ---------------------------------------------------------------------
// Table-driven element encode.
//
// Key = (sign bit || 8-bit biased exponent || top mbits+1 mantissa bits
// || sticky-OR of the remaining mantissa bits) of v. Those bits fully
// determine the reference code: rounding at any binade needs at most
// the kept bits plus a guard, the sticky bit settles ties-to-even and
// the deepest emin-clamp depths, and folding the sign into the key
// makes the lookup completely branchless (negative NaNs and -0.0 land
// on sign-dropping entries exactly like the reference's `(v < 0.0)`
// test, because the table builder runs the reference on each key's
// representative). Proven by an exhaustive 2^32 sweep per format
// against `quantize_code_float`/`_int` and re-enforced forever by the
// differential fuzz suite.
// ---------------------------------------------------------------------

const N_LUTS: usize = ELEM_FORMATS.len();
static ENC_LUTS: [OnceLock<Box<[u8]>>; N_LUTS] = [const { OnceLock::new() }; N_LUTS];

struct EncLut {
    table: &'static [u8],
    shift: u32,
    low_mask: u32,
}

fn build_enc_lut(e: &ElemFormat) -> Box<[u8]> {
    let keep = e.mbits + 1;
    let shift = 23 - keep;
    let n_keys = 1usize << (9 + keep);
    let mut table = vec![0u8; n_keys << 1];
    for key in 0..n_keys as u32 {
        for sticky in 0..2u32 {
            // representative: sign + kept bits in place, sticky sets the
            // lowest mantissa bit (any nonzero dropped-bit pattern
            // rounds alike)
            let rep = f32::from_bits((key << shift) | sticky);
            let code = if e.is_float {
                quantize_code_float(rep, e)
            } else {
                quantize_code_int(rep, e)
            };
            table[((key << 1) | sticky) as usize] = code;
        }
    }
    table.into_boxed_slice()
}

/// Lazily-built shared table for an interned element format. `None`
/// for a hand-rolled `ElemFormat` outside `ELEM_FORMATS` (the scalar
/// fallback handles those).
fn enc_lut(e: &ElemFormat) -> Option<EncLut> {
    let idx = ELEM_FORMATS.iter().position(|f| f == e)?;
    let keep = e.mbits + 1;
    let shift = 23 - keep;
    Some(EncLut {
        table: ENC_LUTS[idx].get_or_init(|| build_enc_lut(e)),
        shift,
        low_mask: (1u32 << shift) - 1,
    })
}

#[inline(always)]
fn lut_code(l: &EncLut, v: f32) -> u8 {
    let bits = v.to_bits();
    let idx = ((bits >> l.shift) << 1) | ((bits & l.low_mask) != 0) as u32;
    l.table[idx as usize]
}

#[inline]
fn scalar_code(v: f32, e: &ElemFormat) -> u8 {
    if e.is_float {
        quantize_code_float(v, e)
    } else {
        quantize_code_int(v, e)
    }
}

/// Per-call stack decode table: code -> element value (unscaled).
/// At most 32 entries for <=5-bit formats; cheap next to any message.
#[inline]
fn build_dec_lut(e: &ElemFormat) -> [f32; 256] {
    let mut dlut = [0.0f32; 256];
    for c in 0..(1u32 << e.bits()) {
        dlut[c as usize] = if e.is_float {
            decode_elem_float(c as u8, e)
        } else {
            decode_elem_int(c as u8, e)
        };
    }
    dlut
}

impl Compressor for MxCodec {
    fn name(&self) -> String {
        self.scheme.name()
    }

    fn effective_bits(&self, _n: usize) -> f64 {
        self.scheme.effective_bits()
    }

    fn wire_bytes(&self, n_values: usize) -> usize {
        self.scheme.wire_bytes(n_values)
    }

    fn encoded_len(&self, n_values: usize) -> usize {
        self.stored_len(n_values)
    }

    /// Fused single pass per block: amax scan, scale, table encode,
    /// u64 bit pump — no intermediate code buffer, no allocation once
    /// `out` has warmed up (resize/truncate + full overwrite).
    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        let s = &self.scheme;
        let n = x.len();
        let w = s.elem.bits();
        let code_bytes = (n * w as usize).div_ceil(8);
        let nblocks = n.div_ceil(s.block);
        let total = code_bytes + nblocks;
        if out.len() < total {
            out.resize(total, 0);
        } else {
            out.truncate(total);
        }
        let (code_buf, scale_buf) = out.split_at_mut(code_bytes);
        let mut bw = BitWriter::new(code_buf);
        let lut = enc_lut(&s.elem);
        let mut i = 0usize;
        for b in 0..nblocks {
            let end = (i + s.block).min(n);
            let blk = &x[i..end];
            let mut amax = 0.0f32;
            for &v in blk {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            scale_buf[b] = (sexp + s.scale.bias()) as u8;
            match &lut {
                Some(l) => {
                    // assemble 8 codes per u64 word: one pump branch per
                    // 8 elements instead of per element (8*w <= 40 bits)
                    let mut it = blk.chunks_exact(8);
                    for ch in &mut it {
                        let mut word = 0u64;
                        for (k, &v) in ch.iter().enumerate() {
                            word |= (lut_code(l, v * inv) as u64) << (k as u32 * w);
                        }
                        bw.push(word, 8 * w);
                    }
                    for &v in it.remainder() {
                        bw.push(lut_code(l, v * inv) as u64, w);
                    }
                }
                None => {
                    for &v in blk {
                        bw.push(scalar_code(v * inv, &s.elem) as u64, w);
                    }
                }
            }
            i = end;
        }
        bw.finish();
    }

    fn alignment(&self) -> usize {
        self.scheme.block
    }

    /// Fused quantize+dequantize+accumulate without the bit-packing
    /// round-trip: encode table in, decode table out, same `v * inv`
    /// multiply — bit-equal to `encode` + `decode_add` by construction
    /// (packing is lossless), ~2x cheaper. The collective engine's
    /// Analytic-mode path.
    fn requant_add(&self, x: &[f32], acc: &mut [f32], _scratch: &mut Vec<u8>) {
        let s = &self.scheme;
        let n = x.len();
        let dlut = build_dec_lut(&s.elem);
        let lut = enc_lut(&s.elem);
        let nblocks = n.div_ceil(s.block);
        let mut i = 0usize;
        for _ in 0..nblocks {
            let end = (i + s.block).min(n);
            let blk = &x[i..end];
            let mut amax = 0.0f32;
            for &v in blk {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            let scale = exp2i(sexp);
            let dst = &mut acc[i..end];
            match &lut {
                Some(l) => {
                    for (d, &v) in dst.iter_mut().zip(blk) {
                        *d += dlut[lut_code(l, v * inv) as usize] * scale;
                    }
                }
                None => {
                    for (d, &v) in dst.iter_mut().zip(blk) {
                        *d += dlut[scalar_code(v * inv, &s.elem) as usize] * scale;
                    }
                }
            }
            i = end;
        }
    }

    /// Streaming table decode: u64 refills, per-block scale, fused add.
    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        let s = &self.scheme;
        let w = s.elem.bits();
        let code_bytes = (n_values * w as usize).div_ceil(8);
        let nblocks = n_values.div_ceil(s.block);
        let scales = &wire[code_bytes..code_bytes + nblocks];
        let dlut = build_dec_lut(&s.elem);
        let mut br = BitReader::new(&wire[..code_bytes]);
        let mut i = 0usize;
        for &sb in scales {
            let scale = exp2i(sb as i32 - s.scale.bias());
            let end = (i + s.block).min(n_values);
            for d in &mut acc[i..end] {
                *d += dlut[br.next(w) as usize] * scale;
            }
            i = end;
        }
    }

    fn try_decode_add(
        &self,
        wire: &[u8],
        n_values: usize,
        acc: &mut [f32],
    ) -> Result<(), CodecError> {
        let need = self.stored_len(n_values);
        if wire.len() < need {
            return Err(CodecError::Truncated { needed: need, got: wire.len() });
        }
        if acc.len() < n_values {
            return Err(CodecError::Malformed(format!(
                "accumulator holds {} values, message carries {}",
                acc.len(),
                n_values
            )));
        }
        // length checks are sufficient: the bit reader is constructed
        // over exactly the code region and every scale byte decodes to
        // a (possibly huge) power of two — no byte pattern is invalid.
        self.decode_add(wire, n_values, acc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::reference::RefMxCodec;
    use crate::util::rng::Rng;

    fn codec(name: &str) -> MxCodec {
        MxCodec::new(MxScheme::parse(name).unwrap())
    }

    #[test]
    fn fp4_grid_values_survive() {
        // E2M1 representable magnitudes: 0, .5, 1, 1.5, 2, 3, 4, 6
        let c = codec("fp4_e2m1_b8_e8m0");
        let x = [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        c.quantize_unpacked(&x, &mut codes, &mut scales);
        let mut out = Vec::new();
        c.dequantize_unpacked(&codes, &scales, &mut out);
        assert_eq!(out, x);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        c.quantize_unpacked(&neg, &mut codes, &mut scales);
        c.dequantize_unpacked(&codes, &scales, &mut out);
        assert_eq!(out, neg);
    }

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(42);
        for name in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b16_e8m0", "int4_b8_e5m0", "fp3_e1m1_b8_e8m0"] {
            let c = codec(name);
            let n = 4096;
            let mut x = vec![0.0f32; n];
            rng.fill_activations(&mut x, 3.0);
            let mut codes = Vec::new();
            let mut scales = Vec::new();
            c.quantize_unpacked(&x, &mut codes, &mut scales);
            let mut out = Vec::new();
            c.dequantize_unpacked(&codes, &scales, &mut out);
            for (blk_i, blk) in x.chunks_exact(c.scheme.block).enumerate() {
                let amax = blk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let bound = if c.scheme.elem.is_float {
                    amax * 2.0f32.powi(-(c.scheme.elem.mbits as i32)) * 1.01
                } else {
                    amax / c.scheme.elem.int_qmax() as f32 * 1.01
                };
                for (j, &v) in blk.iter().enumerate() {
                    let err = (v - out[blk_i * c.scheme.block + j]).abs();
                    assert!(err <= bound.max(1e-30), "{name}: err {err} > {bound}");
                }
            }
        }
    }

    #[test]
    fn wire_roundtrip_via_compressor_trait() {
        let mut rng = Rng::new(7);
        let c = codec("fp4_e2m1_b32_e8m0");
        let n = 1024;
        let mut x = vec![0.0f32; n];
        rng.fill_activations(&mut x, 2.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        // accounted size: 4.25 bits/value
        assert_eq!(c.wire_bytes(n), (n * 4 + (n / 32) * 8) / 8);
        let decoded = c.decode(&wire, n);
        // must equal the unpacked path exactly
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        c.quantize_unpacked(&x, &mut codes, &mut scales);
        let mut direct = Vec::new();
        c.dequantize_unpacked(&codes, &scales, &mut direct);
        assert_eq!(decoded, direct);
    }

    #[test]
    fn decode_add_accumulates() {
        let c = codec("fp5_e2m2_b8_e8m0");
        let x = vec![1.0f32; 16];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let mut acc = vec![0.5f32; 16];
        c.decode_add(&wire, 16, &mut acc);
        for v in acc {
            assert!((v - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn zeros_and_extremes() {
        for name in ["fp4_e2m1_b8_e8m0", "int4_b8_e4m0", "fp5_e1m3_b8_e8m0"] {
            let c = codec(name);
            let x = [0.0f32, 0.0, 3e38, -3e38, 1e-38, -1e-38, 1.0, -1.0];
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            let out = c.decode(&wire, 8);
            assert!(out.iter().all(|v| v.is_finite()), "{name}: {out:?}");
            assert_eq!(out[0], 0.0);
        }
    }

    #[test]
    fn requant_add_matches_wire_roundtrip() {
        use crate::mxfmt::Compressor;
        let mut rng = Rng::new(9);
        for name in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b16_e8m0", "int4_b8_e5m0"] {
            let c = codec(name);
            let mut x = vec![0.0f32; 512];
            rng.fill_activations(&mut x, 3.0);
            let mut via_wire = vec![0.25f32; 512];
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            c.decode_add(&wire, 512, &mut via_wire);
            let mut via_requant = vec![0.25f32; 512];
            let mut scratch = Vec::new();
            c.requant_add(&x, &mut via_requant, &mut scratch);
            assert_eq!(via_wire, via_requant, "{name}");
        }
    }

    #[test]
    fn fake_quantize_matches_roundtrip() {
        let mut rng = Rng::new(3);
        let c = codec("fp4_e2m1_b32_e8m0");
        let mut x = vec![0.0f32; 256];
        rng.fill_activations(&mut x, 4.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let via_wire = c.decode(&wire, 256);
        c.fake_quantize(&mut x);
        assert_eq!(x, via_wire);
    }

    #[test]
    fn scale_clamp_small_scale_format() {
        // e4m0 bottoms out at 2^-7: tiny blocks flush toward zero
        let c = codec("fp4_e2m1_b8_e4m0");
        let x = [2.0f32.powi(-30); 8];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let out = c.decode(&wire, 8);
        // representable magnitude is at least 2^-7 * 0.5 or 0 (flush)
        for v in out {
            assert!(v == 0.0 || v >= exp2i(-8), "{v}");
        }
    }

    #[test]
    fn error_ordering_fp5_fp4_fp3() {
        // Table 1's dtype axis: FP5 < FP4 < FP3 damage on the same data.
        let mut rng = Rng::new(11);
        let n = 8192;
        let mut x = vec![0.0f32; n];
        rng.fill_activations(&mut x, 3.0);
        let mut errs = Vec::new();
        for name in ["fp5_e2m2_b32_e8m0", "fp4_e2m1_b32_e8m0", "fp3_e1m1_b32_e8m0"] {
            let c = codec(name);
            let mut y = x.clone();
            c.fake_quantize(&mut y);
            let mse: f64 = x
                .iter()
                .zip(&y)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / n as f64;
            errs.push(mse);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }

    #[test]
    fn fast_wire_matches_reference_wire() {
        // Quick in-crate differential (the heavy version lives in the
        // fuzz/property suites): byte-identical wires, bit-identical
        // decodes, every format, odd lengths, hostile values.
        let mut rng = Rng::new(0xC0DEC);
        for e in ELEM_FORMATS {
            for (block, n) in [(8usize, 256usize), (32, 199), (3, 100), (16, 1)] {
                let scheme = MxScheme::new(e.name, block, 8).unwrap();
                let fast = MxCodec::new(scheme);
                let refc = RefMxCodec::new(scheme);
                let mut x = vec![0.0f32; n];
                rng.fill_activations(&mut x, 4.0);
                x[0] = f32::NAN;
                if n > 4 {
                    x[1] = f32::INFINITY;
                    x[2] = -0.0;
                    x[3] = 1e-40;
                    x[4] = -f32::NAN;
                }
                let (mut wf, mut wr) = (Vec::new(), Vec::new());
                fast.encode(&x, &mut wf);
                refc.encode(&x, &mut wr);
                assert_eq!(wf, wr, "{} b{} n{}", e.name, block, n);
                let (mut af, mut ar) = (vec![0.5f32; n], vec![0.5f32; n]);
                fast.decode_add(&wf, n, &mut af);
                refc.decode_add(&wr, n, &mut ar);
                let fb: Vec<u32> = af.iter().map(|v| v.to_bits()).collect();
                let rb: Vec<u32> = ar.iter().map(|v| v.to_bits()).collect();
                assert_eq!(fb, rb, "{} b{} n{}", e.name, block, n);
            }
        }
    }

    #[test]
    fn encode_reuses_buffer_without_realloc() {
        let c = codec("fp4_e2m1_b32_e8m0");
        let mut rng = Rng::new(21);
        let mut x = vec![0.0f32; 4096];
        rng.fill_activations(&mut x, 2.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let cap = wire.capacity();
        let ptr = wire.as_ptr();
        for _ in 0..10 {
            c.encode(&x, &mut wire);
            // same allocation, steady state: no growth, no move
            assert_eq!(wire.capacity(), cap);
            assert_eq!(wire.as_ptr(), ptr);
        }
        // shrinking message reuses the same buffer too
        c.encode(&x[..1024], &mut wire);
        assert_eq!(wire.as_ptr(), ptr);
        assert_eq!(wire.len(), c.stored_len(1024));
    }

    #[test]
    fn try_decode_add_rejects_truncated() {
        let c = codec("fp4_e2m1_b32_e8m0");
        let x = vec![1.0f32; 64];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let mut acc = vec![0.0f32; 64];
        assert!(c.try_decode_add(&wire, 64, &mut acc).is_ok());
        let err = c.try_decode_add(&wire[..wire.len() - 1], 64, &mut acc);
        assert!(matches!(err, Err(CodecError::Truncated { .. })), "{err:?}");
        let err = c.try_decode_add(&wire, 65, &mut acc);
        assert!(err.is_err(), "n_values beyond acc must error");
    }
}
