//! The MX quantize/dequantize codec — bit-exact twin of ref.py.

use super::packed::{pack_bits, unpack_into};
use super::types::{exp2i, floor_log2, ElemFormat, MxScheme};
use super::Compressor;

/// Stateless MX codec for one scheme. Wire layout (per message):
/// `[codes: ceil(n*elem_bits/8) bytes][scales: nblocks bytes]`
/// (scales are stored byte-per-block on the wire for decode speed; the
///  *accounted* size uses `MxScheme::wire_bytes`, which bit-packs both —
///  the interconnect simulator charges the accounted size.)
#[derive(Debug, Clone, Copy)]
pub struct MxCodec {
    pub scheme: MxScheme,
}

impl MxCodec {
    pub fn new(scheme: MxScheme) -> MxCodec {
        MxCodec { scheme }
    }

    /// Quantize one block-scale-worth of values into (code, scale) bytes.
    /// Exposed unpacked for the golden-vector tests.
    ///
    /// Hot path (§Perf): element quantize+encode are fused into a direct
    /// integer-code computation (`quantize_code_float`) — one exponent
    /// extraction, one multiply, one round per element; binade carries
    /// and saturation fall out of integer-code arithmetic. Bit-equal to
    /// the two-step reference path (golden-vector tests enforce it).
    pub fn quantize_unpacked(&self, x: &[f32], codes: &mut Vec<u8>, scales: &mut Vec<u8>) {
        let s = &self.scheme;
        assert_eq!(x.len() % s.block, 0, "input not block-aligned");
        codes.clear();
        scales.clear();
        codes.reserve(x.len());
        scales.reserve(x.len() / s.block);
        let e = &s.elem;
        for blk in x.chunks_exact(s.block) {
            let mut amax = 0.0f32;
            for &v in blk {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            scales.push((sexp + s.scale.bias()) as u8);
            if e.is_float {
                for &v in blk {
                    codes.push(quantize_code_float(v * inv, e));
                }
            } else {
                for &v in blk {
                    codes.push(quantize_code_int(v * inv, e));
                }
            }
        }
    }

    /// Inverse of `quantize_unpacked`.
    pub fn dequantize_unpacked(&self, codes: &[u8], scales: &[u8], out: &mut Vec<f32>) {
        let s = &self.scheme;
        out.clear();
        out.reserve(codes.len());
        for (bi, blk) in codes.chunks_exact(s.block).enumerate() {
            let scale = exp2i(scales[bi] as i32 - s.scale.bias());
            if s.elem.is_float {
                for &c in blk {
                    out.push(decode_elem_float(c, &s.elem) * scale);
                }
            } else {
                for &c in blk {
                    out.push(decode_elem_int(c, &s.elem) * scale);
                }
            }
        }
    }

    /// quantize -> dequantize round trip (error-injection view; used by
    /// the eval harness when simulating compression without the wire).
    pub fn fake_quantize(&self, x: &mut [f32]) {
        let s = &self.scheme;
        assert_eq!(x.len() % s.block, 0);
        for blk in x.chunks_exact_mut(s.block) {
            let mut amax = 0.0f32;
            for &v in blk.iter() {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            let scale = exp2i(sexp);
            if s.elem.is_float {
                for v in blk.iter_mut() {
                    *v = quantize_elem_float(*v * inv, &s.elem) * scale;
                }
            } else {
                for v in blk.iter_mut() {
                    *v = quantize_elem_int(*v * inv, &s.elem) * scale;
                }
            }
        }
    }
}

/// MX shared exponent: floor(log2(amax)) - emax_elem, clamped to EdM0.
#[inline]
pub fn block_scale_exp(amax: f32, s: &MxScheme) -> i32 {
    let raw = if amax > 0.0 {
        floor_log2(amax) - s.elem.emax()
    } else {
        s.scale.emin()
    };
    raw.clamp(s.scale.emin(), s.scale.emax())
}

/// Round v (pre-divided by the block scale) onto the ExMy grid.
/// Mirrors ref.quantize_elem_float exactly.
#[inline]
pub fn quantize_elem_float(v: f32, e: &ElemFormat) -> f32 {
    let sign = if v < 0.0 { -1.0f32 } else { 1.0 };
    let a = v.abs();
    if a == 0.0 {
        return 0.0;
    }
    let maxv = e.max_value();
    let be = floor_log2(a).clamp(e.emin(), e.emax());
    let step = exp2i(be - e.mbits as i32);
    let q = ((a / step).round_ties_even() * step).min(maxv);
    sign * q
}

/// Round v onto the signed-magnitude INTk grid.
#[inline]
pub fn quantize_elem_int(v: f32, e: &ElemFormat) -> f32 {
    let qmax = e.int_qmax() as f32;
    v.round_ties_even().clamp(-qmax, qmax)
}

/// Fused quantize+encode: v (pre-divided by the block scale) -> ExMy
/// code. Equivalent to `encode_elem_float(quantize_elem_float(v))` but
/// one pass: with code = ((be+bias-1)<<M) + round(a * 2^(M-be)),
/// mantissa carries roll into the exponent field automatically and
/// over-the-top carries saturate. Bit-exact vs the two-step path.
#[inline]
pub fn quantize_code_float(v: f32, e: &ElemFormat) -> u8 {
    let sign = ((v < 0.0) as u32) << (e.ebits + e.mbits);
    let a = v.abs();
    let be = floor_log2(a).clamp(e.emin(), e.emax());
    // `as u32` saturates for huge a (then min(max_mag) clamps — same
    // result as the reference's min(q, maxv) saturation)
    let m = (a * exp2i(e.mbits as i32 - be)).round_ties_even() as u32;
    // (be + bias - 1) << M; for subnormals (be == emin, bias+emin == 1)
    // this is 0 and m itself is the code; a == 0 gives m == 0.
    let mag = (((be + e.bias() - 1) as u32) << e.mbits).saturating_add(m);
    let max_mag = (((e.emax() + e.bias()) as u32) << e.mbits) | ((1 << e.mbits) - 1);
    let mag = mag.min(max_mag);
    // values that quantize to zero drop the sign (ref path: -0.0 < 0 is
    // false, so the reference also emits +0)
    if mag == 0 {
        0
    } else {
        (sign | mag) as u8
    }
}

/// Fused quantize+encode for sign-magnitude INTk.
#[inline]
pub fn quantize_code_int(v: f32, e: &ElemFormat) -> u8 {
    let sign = ((v < 0.0) as u32) << e.mbits;
    let m = (v.abs().round_ties_even() as u32).min(e.int_qmax() as u32);
    if m == 0 {
        0
    } else {
        (sign | m) as u8
    }
}

/// Bit-encode an exactly-representable ExMy value (sign|exp|mant).
#[inline]
pub fn encode_elem_float(q: f32, e: &ElemFormat) -> u8 {
    let sign = (q < 0.0) as u32;
    let a = q.abs();
    let be = floor_log2(a);
    let (exp_f, mant) = if a == 0.0 || be < e.emin() {
        // subnormal: mant = a / 2^(emin - M)
        let m = (a / exp2i(e.emin() - e.mbits as i32)).round_ties_even() as u32;
        (0u32, m)
    } else {
        let m = (a / exp2i(be - e.mbits as i32)).round_ties_even() as u32 - (1 << e.mbits);
        ((be + e.bias()) as u32, m)
    };
    ((sign << (e.ebits + e.mbits)) | (exp_f << e.mbits) | mant) as u8
}

#[inline]
pub fn decode_elem_float(code: u8, e: &ElemFormat) -> f32 {
    let c = code as u32;
    let sign = (c >> (e.ebits + e.mbits)) & 1;
    let exp_f = (c >> e.mbits) & ((1 << e.ebits) - 1);
    let mant = c & ((1 << e.mbits) - 1);
    let mag = if exp_f == 0 {
        mant as f32 * exp2i(e.emin() - e.mbits as i32)
    } else {
        ((1u32 << e.mbits) + mant) as f32 * exp2i(exp_f as i32 - e.bias() - e.mbits as i32)
    };
    if sign == 1 {
        -mag
    } else {
        mag
    }
}

#[inline]
pub fn encode_elem_int(q: f32, e: &ElemFormat) -> u8 {
    let sign = (q < 0.0) as u32;
    let mag = q.abs() as u32;
    ((sign << e.mbits) | mag) as u8
}

#[inline]
pub fn decode_elem_int(code: u8, e: &ElemFormat) -> f32 {
    let c = code as u32;
    let sign = (c >> e.mbits) & 1;
    let mag = (c & ((1 << e.mbits) - 1)) as f32;
    if sign == 1 {
        -mag
    } else {
        mag
    }
}

impl Compressor for MxCodec {
    fn name(&self) -> String {
        self.scheme.name()
    }

    fn effective_bits(&self, _n: usize) -> f64 {
        self.scheme.effective_bits()
    }

    fn wire_bytes(&self, n_values: usize) -> usize {
        self.scheme.wire_bytes(n_values)
    }

    /// Wire: bit-packed codes, then byte-per-block scales.
    ///
    /// §Perf note: a fused quantize+pack single-pass variant was tried
    /// and measured SLOWER than this two-pass form (193 vs 242 MB/s —
    /// the byte-at-a-time accumulator store defeats vectorization of
    /// the quantize loop); see EXPERIMENTS.md §Perf iteration log.
    fn encode(&self, x: &[f32], out: &mut Vec<u8>) {
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        self.quantize_unpacked(x, &mut codes, &mut scales);
        out.clear();
        pack_bits(&codes, self.scheme.elem.bits(), out);
        out.extend_from_slice(&scales);
    }

    fn alignment(&self) -> usize {
        self.scheme.block
    }

    /// Fused quantize+dequantize+accumulate without the bit-packing
    /// round-trip. Bit-equal to `encode` + `decode_add` (packing is
    /// lossless and `fake_quantize_matches_roundtrip` pins the grid
    /// math), ~2x cheaper — the collective engine's Analytic-mode path.
    fn requant_add(&self, x: &[f32], acc: &mut [f32], _scratch: &mut Vec<u8>) {
        let s = &self.scheme;
        assert_eq!(x.len() % s.block, 0, "input not block-aligned");
        for (bi, blk) in x.chunks_exact(s.block).enumerate() {
            let mut amax = 0.0f32;
            for &v in blk {
                amax = amax.max(v.abs());
            }
            let sexp = block_scale_exp(amax, s);
            let inv = exp2i(-sexp);
            let scale = exp2i(sexp);
            let dst = &mut acc[bi * s.block..(bi + 1) * s.block];
            if s.elem.is_float {
                for (d, &v) in dst.iter_mut().zip(blk) {
                    *d += quantize_elem_float(v * inv, &s.elem) * scale;
                }
            } else {
                for (d, &v) in dst.iter_mut().zip(blk) {
                    *d += quantize_elem_int(v * inv, &s.elem) * scale;
                }
            }
        }
    }

    fn decode_add(&self, wire: &[u8], n_values: usize, acc: &mut [f32]) {
        let s = &self.scheme;
        let nb = s.elem.bits();
        let code_bytes = (n_values * nb as usize).div_ceil(8);
        let nblocks = n_values / s.block;
        let scales = &wire[code_bytes..code_bytes + nblocks];
        let mut codes = vec![0u8; n_values];
        unpack_into(&wire[..code_bytes], nb, &mut codes);
        for (bi, blk) in codes.chunks_exact(s.block).enumerate() {
            let scale = exp2i(scales[bi] as i32 - s.scale.bias());
            let dst = &mut acc[bi * s.block..(bi + 1) * s.block];
            if s.elem.is_float {
                for (d, &c) in dst.iter_mut().zip(blk) {
                    *d += decode_elem_float(c, &s.elem) * scale;
                }
            } else {
                for (d, &c) in dst.iter_mut().zip(blk) {
                    *d += decode_elem_int(c, &s.elem) * scale;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn codec(name: &str) -> MxCodec {
        MxCodec::new(MxScheme::parse(name).unwrap())
    }

    #[test]
    fn fp4_grid_values_survive() {
        // E2M1 representable magnitudes: 0, .5, 1, 1.5, 2, 3, 4, 6
        let c = codec("fp4_e2m1_b8_e8m0");
        let x = [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        c.quantize_unpacked(&x, &mut codes, &mut scales);
        let mut out = Vec::new();
        c.dequantize_unpacked(&codes, &scales, &mut out);
        assert_eq!(out, x);
        let neg: Vec<f32> = x.iter().map(|v| -v).collect();
        c.quantize_unpacked(&neg, &mut codes, &mut scales);
        c.dequantize_unpacked(&codes, &scales, &mut out);
        assert_eq!(out, neg);
    }

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(42);
        for name in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b16_e8m0", "int4_b8_e5m0", "fp3_e1m1_b8_e8m0"] {
            let c = codec(name);
            let n = 4096;
            let mut x = vec![0.0f32; n];
            rng.fill_activations(&mut x, 3.0);
            let mut codes = Vec::new();
            let mut scales = Vec::new();
            c.quantize_unpacked(&x, &mut codes, &mut scales);
            let mut out = Vec::new();
            c.dequantize_unpacked(&codes, &scales, &mut out);
            for (blk_i, blk) in x.chunks_exact(c.scheme.block).enumerate() {
                let amax = blk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let bound = if c.scheme.elem.is_float {
                    amax * 2.0f32.powi(-(c.scheme.elem.mbits as i32)) * 1.01
                } else {
                    amax / c.scheme.elem.int_qmax() as f32 * 1.01
                };
                for (j, &v) in blk.iter().enumerate() {
                    let err = (v - out[blk_i * c.scheme.block + j]).abs();
                    assert!(err <= bound.max(1e-30), "{name}: err {err} > {bound}");
                }
            }
        }
    }

    #[test]
    fn wire_roundtrip_via_compressor_trait() {
        let mut rng = Rng::new(7);
        let c = codec("fp4_e2m1_b32_e8m0");
        let n = 1024;
        let mut x = vec![0.0f32; n];
        rng.fill_activations(&mut x, 2.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        // accounted size: 4.25 bits/value
        assert_eq!(c.wire_bytes(n), (n * 4 + (n / 32) * 8) / 8);
        let decoded = c.decode(&wire, n);
        // must equal the unpacked path exactly
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        c.quantize_unpacked(&x, &mut codes, &mut scales);
        let mut direct = Vec::new();
        c.dequantize_unpacked(&codes, &scales, &mut direct);
        assert_eq!(decoded, direct);
    }

    #[test]
    fn decode_add_accumulates() {
        let c = codec("fp5_e2m2_b8_e8m0");
        let x = vec![1.0f32; 16];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let mut acc = vec![0.5f32; 16];
        c.decode_add(&wire, 16, &mut acc);
        for v in acc {
            assert!((v - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn zeros_and_extremes() {
        for name in ["fp4_e2m1_b8_e8m0", "int4_b8_e4m0", "fp5_e1m3_b8_e8m0"] {
            let c = codec(name);
            let x = [0.0f32, 0.0, 3e38, -3e38, 1e-38, -1e-38, 1.0, -1.0];
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            let out = c.decode(&wire, 8);
            assert!(out.iter().all(|v| v.is_finite()), "{name}: {out:?}");
            assert_eq!(out[0], 0.0);
        }
    }

    #[test]
    fn requant_add_matches_wire_roundtrip() {
        use crate::mxfmt::Compressor;
        let mut rng = Rng::new(9);
        for name in ["fp4_e2m1_b32_e8m0", "fp5_e2m2_b16_e8m0", "int4_b8_e5m0"] {
            let c = codec(name);
            let mut x = vec![0.0f32; 512];
            rng.fill_activations(&mut x, 3.0);
            let mut via_wire = vec![0.25f32; 512];
            let mut wire = Vec::new();
            c.encode(&x, &mut wire);
            c.decode_add(&wire, 512, &mut via_wire);
            let mut via_requant = vec![0.25f32; 512];
            let mut scratch = Vec::new();
            c.requant_add(&x, &mut via_requant, &mut scratch);
            assert_eq!(via_wire, via_requant, "{name}");
        }
    }

    #[test]
    fn fake_quantize_matches_roundtrip() {
        let mut rng = Rng::new(3);
        let c = codec("fp4_e2m1_b32_e8m0");
        let mut x = vec![0.0f32; 256];
        rng.fill_activations(&mut x, 4.0);
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let via_wire = c.decode(&wire, 256);
        c.fake_quantize(&mut x);
        assert_eq!(x, via_wire);
    }

    #[test]
    fn scale_clamp_small_scale_format() {
        // e4m0 bottoms out at 2^-7: tiny blocks flush toward zero
        let c = codec("fp4_e2m1_b8_e4m0");
        let x = [2.0f32.powi(-30); 8];
        let mut wire = Vec::new();
        c.encode(&x, &mut wire);
        let out = c.decode(&wire, 8);
        // representable magnitude is at least 2^-7 * 0.5 or 0 (flush)
        for v in out {
            assert!(v == 0.0 || v >= exp2i(-8), "{v}");
        }
    }

    #[test]
    fn error_ordering_fp5_fp4_fp3() {
        // Table 1's dtype axis: FP5 < FP4 < FP3 damage on the same data.
        let mut rng = Rng::new(11);
        let n = 8192;
        let mut x = vec![0.0f32; n];
        rng.fill_activations(&mut x, 3.0);
        let mut errs = Vec::new();
        for name in ["fp5_e2m2_b32_e8m0", "fp4_e2m1_b32_e8m0", "fp3_e1m1_b32_e8m0"] {
            let c = codec(name);
            let mut y = x.clone();
            c.fake_quantize(&mut y);
            let mse: f64 = x
                .iter()
                .zip(&y)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / n as f64;
            errs.push(mse);
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }
}
