//! `tpcc bench --codec` — codec roofline snapshot: fast-path vs
//! reference encode/decode throughput per scheme × block, against the
//! host's measured `memcpy` ceiling (`BENCH_codec.json`).
//!
//! The measured quantity is GB/s of the **f32 side** of the transform
//! (4 bytes per value regardless of wire width), so rows are
//! comparable across element formats and directly placeable under the
//! memcpy roofline: a codec at the ceiling would compress for free.
//! `enc_speedup` / `dec_speedup` are the fast path over
//! [`RefMxCodec`] — the acceptance floor in `tests/bench_trend.rs`
//! wants ≥ 3× encode on at least one scheme × block point.

use std::time::Instant;

use crate::mxfmt::{Compressor, MxCodec, MxScheme, RefMxCodec};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Element formats the roofline sweeps (the paper's working set: the
/// headline FP4, the wider FP5, the narrow FP3, and the INT4 baseline).
pub const ELEMS: &[&str] = &["fp4_e2m1", "fp5_e2m2", "fp3_e1m1", "int4"];

/// Block sizes per element format.
pub const BLOCKS: &[usize] = &[8, 16, 32];

/// Values per measured payload (1 Mi f32 = 4 MiB: large enough to
/// stream past L2 and amortize timer overhead on one pass).
pub const N_VALUES: usize = 1 << 20;

#[derive(Debug, Clone)]
pub struct CodecRow {
    pub scheme: String,
    pub block: usize,
    pub n_values: usize,
    pub fast_enc_gbps: f64,
    pub ref_enc_gbps: f64,
    pub enc_speedup: f64,
    pub fast_dec_gbps: f64,
    pub ref_dec_gbps: f64,
    pub dec_speedup: f64,
    pub memcpy_gbps: f64,
}

/// Time `f` in a repeat-until-budget loop (min one run) and return the
/// best per-iteration seconds — min, not median: for a fixed-work
/// kernel the minimum is the least-noise estimate.
fn bench_loop(mut f: impl FnMut(), budget_s: f64) -> f64 {
    // one untimed warmup populates caches / faults pages
    f();
    let mut best = f64::INFINITY;
    let start = Instant::now();
    loop {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() >= budget_s {
            return best.max(1e-12);
        }
    }
}

/// Measured `memcpy` ceiling (GB/s) over the same payload size.
fn memcpy_ceiling(n_values: usize, budget_s: f64) -> f64 {
    let src = vec![1.0f32; n_values];
    let mut dst = vec![0.0f32; n_values];
    let t = bench_loop(
        || {
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
        },
        budget_s,
    );
    n_values as f64 * 4.0 / 1e9 / t
}

/// Run the roofline sweep. `budget_s` is the per-measurement time
/// budget (the CLI uses ~0.1s; tests use a tiny budget for speed).
pub fn run(budget_s: f64) -> Vec<CodecRow> {
    let mut rng = Rng::new(0xC0DEC);
    let mut x = vec![0.0f32; N_VALUES];
    rng.fill_activations(&mut x, 2.0);
    let memcpy_gbps = memcpy_ceiling(N_VALUES, budget_s);
    let gb = N_VALUES as f64 * 4.0 / 1e9;

    let mut rows = Vec::new();
    for elem in ELEMS {
        for &block in BLOCKS {
            let scheme = MxScheme::new(elem, block, 8).unwrap();
            let fast = MxCodec::new(scheme);
            let refc = RefMxCodec::new(scheme);
            let mut wire = Vec::new();
            fast.encode(&x, &mut wire); // size + warm the scratch
            let mut acc = vec![0.0f32; N_VALUES];

            let fe = bench_loop(|| fast.encode(&x, &mut wire), budget_s);
            let re = bench_loop(|| refc.encode(&x, &mut wire), budget_s);
            // re-encode with the fast path so both decoders read the
            // same (bit-identical anyway) wire bytes
            fast.encode(&x, &mut wire);
            let fd = bench_loop(
                || {
                    fast.decode_add(&wire, N_VALUES, &mut acc);
                    std::hint::black_box(&mut acc);
                },
                budget_s,
            );
            let rd = bench_loop(
                || {
                    refc.decode_add(&wire, N_VALUES, &mut acc);
                    std::hint::black_box(&mut acc);
                },
                budget_s,
            );
            rows.push(CodecRow {
                scheme: scheme.name(),
                block,
                n_values: N_VALUES,
                fast_enc_gbps: gb / fe,
                ref_enc_gbps: gb / re,
                enc_speedup: re / fe,
                fast_dec_gbps: gb / fd,
                ref_dec_gbps: gb / rd,
                dec_speedup: rd / fd,
                memcpy_gbps,
            });
        }
    }
    rows
}

pub fn print(rows: &[CodecRow]) {
    let ceiling = rows.first().map(|r| r.memcpy_gbps).unwrap_or(0.0);
    println!("\ncodec roofline — f32-side GB/s, memcpy ceiling {ceiling:.2} GB/s");
    println!(
        "{:<20} {:>6} {:>9} {:>9} {:>7} {:>9} {:>9} {:>7}",
        "scheme", "block", "fast enc", "ref enc", "spd", "fast dec", "ref dec", "spd"
    );
    println!("{}", "-".repeat(82));
    for r in rows {
        println!(
            "{:<20} {:>6} {:>9.3} {:>9.3} {:>6.2}x {:>9.3} {:>9.3} {:>6.2}x",
            r.scheme,
            r.block,
            r.fast_enc_gbps,
            r.ref_enc_gbps,
            r.enc_speedup,
            r.fast_dec_gbps,
            r.ref_dec_gbps,
            r.dec_speedup
        );
    }
}

/// The tracked `BENCH_codec.json` snapshot.
pub fn to_json(rows: &[CodecRow]) -> Json {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let memcpy = rows.first().map(|r| r.memcpy_gbps).unwrap_or(0.0);
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("scheme", json::s(&r.scheme)),
                ("block", json::num(r.block as f64)),
                ("n_values", json::num(r.n_values as f64)),
                ("fast_enc_gbps", json::num_or_null(r.fast_enc_gbps)),
                ("ref_enc_gbps", json::num_or_null(r.ref_enc_gbps)),
                ("enc_speedup", json::num_or_null(r.enc_speedup)),
                ("fast_dec_gbps", json::num_or_null(r.fast_dec_gbps)),
                ("ref_dec_gbps", json::num_or_null(r.ref_dec_gbps)),
                ("dec_speedup", json::num_or_null(r.dec_speedup)),
                ("memcpy_gbps", json::num_or_null(r.memcpy_gbps)),
            ])
        })
        .collect();
    json::obj(vec![
        ("bench", json::s("codec")),
        ("schema", json::num(1.0)),
        (
            "metric",
            json::s(
                "codec roofline: encode/decode GB/s of f32 payload per scheme x block, \
                 fast path vs mxfmt::reference, against the measured memcpy ceiling",
            ),
        ),
        ("status", json::s("measured")),
        ("host_cores", json::num(cores as f64)),
        ("memcpy_gbps", json::num_or_null(memcpy)),
        ("rows", json::arr(row_objs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid_and_emits_schema() {
        // tiny budget: one timed pass per cell — shape test, not perf
        let rows = run(0.0);
        assert_eq!(rows.len(), ELEMS.len() * BLOCKS.len());
        for r in &rows {
            assert!(r.fast_enc_gbps > 0.0 && r.ref_enc_gbps > 0.0);
            assert!(r.fast_dec_gbps > 0.0 && r.ref_dec_gbps > 0.0);
            assert!(r.memcpy_gbps > 0.0);
            assert!(r.scheme.contains(&format!("_b{}_", r.block)));
        }
        let parsed = Json::parse(&to_json(&rows).to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("codec"));
        assert_eq!(
            parsed.get("rows").unwrap().as_arr().unwrap().len(),
            rows.len()
        );
        let row = parsed.get("rows").unwrap().idx(0).unwrap();
        for key in [
            "scheme",
            "block",
            "n_values",
            "fast_enc_gbps",
            "ref_enc_gbps",
            "enc_speedup",
            "fast_dec_gbps",
            "ref_dec_gbps",
            "dec_speedup",
            "memcpy_gbps",
        ] {
            assert!(row.get(key).is_some(), "row missing {key}");
        }
    }
}
