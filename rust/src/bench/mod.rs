//! Hand-rolled benchmark harness (the offline vendor set has no
//! criterion). `cargo bench` targets use `harness = false` and call
//! [`Bench::run`], which warms up, measures wall time per iteration with
//! outlier-robust statistics, and prints aligned rows.
//!
//! [`rankpar`] is the `tpcc bench` subcommand: the tracked
//! sequential-vs-parallel rank-runtime snapshot (`BENCH_rankpar.json`).
//! [`codec`] is `tpcc bench --codec`: the codec roofline snapshot
//! (`BENCH_codec.json`).

pub mod codec;
pub mod rankpar;

use std::time::Instant;

pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub target_secs: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, min_iters: 10, max_iters: 1000, target_secs: 1.0 }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup_iters: 1, min_iters: 3, max_iters: 50, target_secs: 0.3 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (start.elapsed().as_secs_f64() < self.target_secs && times.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = times.len();
        let median = times[n / 2];
        let mean = times.iter().sum::<f64>() / n as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            median_s: median,
            mean_s: mean,
            stddev_s: var.sqrt(),
            min_s: times[0],
        };
        println!(
            "{:<48} {:>10} {:>12} {:>12} {:>6}",
            r.name,
            fmt_time(r.median_s),
            fmt_time(r.mean_s),
            fmt_time(r.stddev_s),
            r.iters
        );
        r
    }

    pub fn header() {
        println!(
            "{:<48} {:>10} {:>12} {:>12} {:>6}",
            "benchmark", "median", "mean", "stddev", "iters"
        );
        println!("{}", "-".repeat(92));
    }
}

pub fn fmt_time(s: f64) -> String {
    if s.is_nan() {
        "-".into()
    } else if s >= 1.0 {
        format!("{:.3}s", s)
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Throughput helper: bytes/sec pretty printer.
pub fn fmt_throughput(bytes: usize, secs: f64) -> String {
    let bps = bytes as f64 / secs;
    if bps >= 1e9 {
        format!("{:.2} GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.2} MB/s", bps / 1e6)
    } else {
        format!("{:.2} KB/s", bps / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::quick();
        let r = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.median_s >= 0.0);
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert_eq!(fmt_time(0.0025), "2.500ms");
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-9).contains("ns"));
    }
}
