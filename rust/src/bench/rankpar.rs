//! `tpcc bench` — rank-runtime perf snapshot: sequential vs parallel
//! wall-clock TTFT per Table-3 live config, with a `--json` emitter so
//! the repo tracks a bench trajectory (`BENCH_rankpar.json`).
//!
//! The measured quantity is the live engine's **prefill wall clock**
//! (the `StepTiming::wall_s` of one bucket-shaped prefill on the micro
//! model), the same pass Table 3's live section medians — the
//! rank-thread runtime should push it toward `1/tp` of the sequential
//! reference on a host with ≥ tp cores. Virtual-time TTFT is identical
//! between the modes by construction (pinned by `tests/rank_parallel.rs`);
//! this bench tracks the *real* speedup.
//!
//! A third leg re-runs the parallel engine with the **full telemetry
//! stack** enabled — the span recorder, a live metrics time-series
//! sampler at the serving cadence, one flight-recorder record per
//! pass, a structured-log event per pass, and an alert-rule evaluation
//! per pass: the per-phase breakdown columns (compute / codec / fabric
//! wait / link) come from the recorder's measured phase accumulators,
//! and `trace_overhead_pct` pins the whole stack's cost against the
//! untraced parallel wall (asserted under `TPCC_TRACE_OVERHEAD_PCT`,
//! default 5%).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::metrics::{Registry, DEFAULT_SAMPLE_PERIOD_S};
use crate::model::weights::Weights;
use crate::obs::alert::AlertEngine;
use crate::obs::flight::{FlightRecorder, PhaseCost, RequestRecord};
use crate::obs::log::Logger;
use crate::runtime::Runtime;
use crate::tp::{BatchKv, EngineOptions, RankThreads, TpEngine};
use crate::util::json::{self, Json};

/// Default ceiling (percent) on the recorder's wall-clock overhead;
/// override with the `TPCC_TRACE_OVERHEAD_PCT` env var (`0` disables
/// the assertion for noisy hosts).
pub const DEFAULT_TRACE_OVERHEAD_PCT: f64 = 5.0;

/// The scheme every config compresses with (the paper's headline pick).
pub const SCHEME: &str = "fp4_e2m1_b32_e8m0";
/// Model the live bench runs (micro: the Table-3 live stand-in).
pub const MODEL: &str = "micro";

/// Candidate (tp, batch, seq) prefill shapes — filtered against the
/// manifest's exported buckets at run time.
pub const CONFIGS: &[(usize, usize, usize)] = &[(2, 8, 128), (4, 8, 128), (8, 8, 128)];

#[derive(Debug, Clone)]
pub struct RankparRow {
    pub tp: usize,
    pub batch: usize,
    pub seq: usize,
    /// worker threads the parallel leg used
    pub workers: usize,
    /// median sequential (`--rank-threads off`) prefill wall seconds
    pub seq_wall_s: f64,
    /// median parallel prefill wall seconds (recorder off)
    pub par_wall_s: f64,
    pub speedup: f64,
    /// median parallel wall with the span recorder enabled
    pub traced_wall_s: f64,
    /// recorder cost: (traced/untraced - 1) · 100
    pub trace_overhead_pct: f64,
    /// measured per-phase thread-seconds per rep, from the recorder's
    /// phase accumulators over the traced reps
    pub phase_compute_s: f64,
    pub phase_codec_s: f64,
    pub phase_fabric_wait_s: f64,
    pub phase_link_s: f64,
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        // an even rep count must not bias the tracked snapshot upward
        (xs[n / 2 - 1] + xs[n / 2]) / 2.0
    }
}

fn build_engine(
    root: &std::path::Path,
    tp: usize,
    rt_knob: RankThreads,
) -> anyhow::Result<TpEngine> {
    let rt = Runtime::load(root)?;
    let weights = Weights::load(&root.join("weights").join(MODEL))?;
    let opts = EngineOptions::new(MODEL, tp)
        .with_compress(SCHEME)
        .with_profile("l4")
        .with_rank_threads(rt_knob);
    TpEngine::new(rt, &weights, opts)
}

fn measure(eng: &mut TpEngine, batch: usize, seq: usize, reps: usize) -> anyhow::Result<f64> {
    let tokens: Vec<i32> = (0..batch * seq).map(|i| (i * 31 + 7) as i32 % 256).collect();
    let pos = vec![0i32; batch];
    let mut kv = BatchKv::new(&eng.cfg.clone(), eng.opts.tp, batch);
    // one warmup pass compiles the executables off the clock
    let _ = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
    let mut walls = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let (_, t) = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
        walls.push(t.wall_s);
    }
    Ok(median(walls))
}

/// Re-measure with the full telemetry stack on — span recorder, a
/// background time-series sampler at the serving cadence, one
/// flight-recorder record, one structured-log event, and one alert-rule
/// evaluation per pass — returning the median wall and the per-rep
/// phase deltas [compute, codec, fabric_wait, link]. The
/// traced/untraced delta is therefore the cost of everything a serving
/// deployment's observability adds.
fn measure_traced(
    eng: &mut TpEngine,
    batch: usize,
    seq: usize,
    reps: usize,
) -> anyhow::Result<(f64, [f64; 4])> {
    eng.tracer().set_enabled(true);
    let before = eng.tracer().phase_snapshot();
    let registry = Arc::new(Registry::default());
    let stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let (registry, stop) = (registry.clone(), stop.clone());
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                registry.sample_history();
                std::thread::sleep(std::time::Duration::from_secs_f64(DEFAULT_SAMPLE_PERIOD_S));
            }
        })
    };
    let flight = FlightRecorder::default();
    let log = Logger::new();
    let alerts = AlertEngine::new();
    let tokens: Vec<i32> = (0..batch * seq).map(|i| (i * 31 + 7) as i32 % 256).collect();
    let pos = vec![0i32; batch];
    let mut kv = BatchKv::new(&eng.cfg.clone(), eng.opts.tp, batch);
    let _ = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
    let mut walls = Vec::with_capacity(reps);
    for rep in 0..reps.max(1) {
        let (_, t) = eng.prefill(&tokens, batch, seq, &pos, Some(&mut kv))?;
        // the per-request bookkeeping a serving coordinator does, so
        // the gate prices it: counters, a flight record, one sample
        registry.requests_received.inc();
        registry.requests_completed.inc();
        registry.tokens_generated.inc();
        registry.comm_bytes_sent.add(t.wire_bytes);
        registry.comm_bytes_saved.add(t.raw_bytes.saturating_sub(t.wire_bytes));
        registry.ttft.record(t.wall_s);
        flight.record(RequestRecord {
            id: rep as u64,
            prompt_tokens: batch * seq,
            new_tokens: 1,
            batch_peak: batch,
            queue_wait_s: 0.0,
            ttft_s: t.wall_s,
            e2e_s: t.wall_s,
            tpot_s: f64::NAN,
            prefill: PhaseCost {
                compute_s: t.compute_s,
                codec_s: t.codec_s,
                link_s: t.link_s,
                wire_bytes: t.wire_bytes,
            },
            decode: PhaseCost::default(),
            fabric_wait_s: eng.fabric_wait_total(),
            site_wire_bytes: eng.group_wire_bytes(),
            ..RequestRecord::default()
        });
        registry.sample_history();
        // one log event + one full alert-rule sweep per pass, exactly
        // the per-tick work the serving sampler thread does
        log.debug(
            "bench",
            "request finished",
            vec![("rep", json::num(rep as f64)), ("wall_s", json::num(t.wall_s))],
        );
        alerts.tick_at(&registry, &log, registry.history.elapsed_s());
        walls.push(t.wall_s);
    }
    stop.store(true, Ordering::Relaxed);
    let wall = median(walls);
    let after = eng.tracer().phase_snapshot();
    eng.tracer().set_enabled(false);
    let _ = sampler.join();
    // the loop runs one warmup pass + reps timed passes on the clock;
    // the phase accumulators see warmup too, so scale by reps+1
    let passes = (reps.max(1) + 1) as f64;
    let mut phases = [0.0f64; 4];
    for i in 0..4 {
        phases[i] = (after[i] - before[i]) / passes;
    }
    Ok((wall, phases))
}

/// Run the sequential-vs-parallel sweep. `rank_threads` picks the
/// parallel leg's worker policy (`auto` by default); configs whose
/// stage programs are not in the manifest are skipped.
pub fn run(reps: usize, rank_threads: RankThreads) -> anyhow::Result<Vec<RankparRow>> {
    let root = crate::tables::common::artifacts_root()?;
    let probe = Runtime::load(&root)?;
    let mut rows = Vec::new();
    for &(tp, batch, seq) in CONFIGS {
        let name = format!("{MODEL}/attn_prefill_tp{tp}_b{batch}_s{seq}");
        if probe.manifest.by_name(&name).is_none() {
            continue;
        }
        let mut seq_eng = build_engine(&root, tp, RankThreads::Off)?;
        let seq_wall_s = measure(&mut seq_eng, batch, seq, reps)?;
        drop(seq_eng);
        let mut par_eng = build_engine(&root, tp, rank_threads)?;
        let workers = par_eng.rank_workers();
        let par_wall_s = measure(&mut par_eng, batch, seq, reps)?;
        // third leg: same engine (already warm), full telemetry stack
        // on — the traced/untraced delta prices recorder + sampler +
        // flight recorder together
        let (traced_wall_s, phases) = measure_traced(&mut par_eng, batch, seq, reps)?;
        let trace_overhead_pct = (traced_wall_s / par_wall_s - 1.0) * 100.0;
        let limit = std::env::var("TPCC_TRACE_OVERHEAD_PCT")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .unwrap_or(DEFAULT_TRACE_OVERHEAD_PCT);
        if limit > 0.0 {
            anyhow::ensure!(
                trace_overhead_pct < limit,
                "span recorder overhead {trace_overhead_pct:.2}% exceeds {limit}% \
                 (tp={tp}; raise/disable via TPCC_TRACE_OVERHEAD_PCT)"
            );
        }
        rows.push(RankparRow {
            tp,
            batch,
            seq,
            workers,
            seq_wall_s,
            par_wall_s,
            speedup: seq_wall_s / par_wall_s,
            traced_wall_s,
            trace_overhead_pct,
            phase_compute_s: phases[0],
            phase_codec_s: phases[1],
            phase_fabric_wait_s: phases[2],
            phase_link_s: phases[3],
        });
    }
    anyhow::ensure!(!rows.is_empty(), "no bench config matches the exported buckets");
    Ok(rows)
}

pub fn print(rows: &[RankparRow]) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("\nrankpar bench — {MODEL} + {SCHEME}, seq vs --rank-threads ({cores} cores)");
    println!(
        "{:<4} {:>8} {:>8} {:>12} {:>12} {:>8} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "tp", "input", "workers", "seq wall", "par wall", "speedup", "compute", "codec",
        "fabwait", "link", "trace%"
    );
    println!("{}", "-".repeat(110));
    for r in rows {
        println!(
            "{:<4} {:>8} {:>8} {:>11.1}ms {:>11.1}ms {:>7.2}x {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>8.1}ms {:>7.2}%",
            r.tp,
            format!("{}x{}", r.batch, r.seq),
            r.workers,
            r.seq_wall_s * 1e3,
            r.par_wall_s * 1e3,
            r.speedup,
            r.phase_compute_s * 1e3,
            r.phase_codec_s * 1e3,
            r.phase_fabric_wait_s * 1e3,
            r.phase_link_s * 1e3,
            r.trace_overhead_pct
        );
    }
}

/// The tracked `BENCH_rankpar.json` snapshot.
pub fn to_json(rows: &[RankparRow], reps: usize) -> Json {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let row_objs: Vec<Json> = rows
        .iter()
        .map(|r| {
            json::obj(vec![
                ("tp", json::num(r.tp as f64)),
                ("batch", json::num(r.batch as f64)),
                ("seq", json::num(r.seq as f64)),
                ("workers", json::num(r.workers as f64)),
                ("seq_wall_s", json::num_or_null(r.seq_wall_s)),
                ("par_wall_s", json::num_or_null(r.par_wall_s)),
                ("speedup", json::num_or_null(r.speedup)),
                ("traced_wall_s", json::num_or_null(r.traced_wall_s)),
                ("trace_overhead_pct", json::num_or_null(r.trace_overhead_pct)),
                ("phase_compute_s", json::num_or_null(r.phase_compute_s)),
                ("phase_codec_s", json::num_or_null(r.phase_codec_s)),
                ("phase_fabric_wait_s", json::num_or_null(r.phase_fabric_wait_s)),
                ("phase_link_s", json::num_or_null(r.phase_link_s)),
            ])
        })
        .collect();
    json::obj(vec![
        ("bench", json::s("rankpar")),
        ("schema", json::num(2.0)),
        ("model", json::s(MODEL)),
        ("scheme", json::s(SCHEME)),
        ("metric", json::s("median live prefill wall seconds (TTFT compute+collective)")),
        ("status", json::s("measured")),
        ("host_cores", json::num(cores as f64)),
        ("reps", json::num(reps as f64)),
        ("rows", json::arr(row_objs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_of_odd_and_even() {
        assert_eq!(median(vec![3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(vec![4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn json_snapshot_shape() {
        let rows = vec![RankparRow {
            tp: 4,
            batch: 8,
            seq: 128,
            workers: 4,
            seq_wall_s: 0.4,
            par_wall_s: 0.1,
            speedup: 4.0,
            traced_wall_s: 0.102,
            trace_overhead_pct: 2.0,
            phase_compute_s: 0.08,
            phase_codec_s: 0.01,
            phase_fabric_wait_s: 0.005,
            phase_link_s: 0.002,
        }];
        let j = to_json(&rows, 5);
        // round-trips as valid JSON with the tracked fields present
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("rankpar"));
        assert_eq!(parsed.get("schema").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 1);
        let row = parsed.get("rows").unwrap().idx(0).unwrap();
        assert_eq!(row.get("speedup").unwrap().as_f64(), Some(4.0));
        assert_eq!(row.get("phase_compute_s").unwrap().as_f64(), Some(0.08));
        assert_eq!(row.get("trace_overhead_pct").unwrap().as_f64(), Some(2.0));
    }
}
