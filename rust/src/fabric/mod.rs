//! Shared-memory collective fabric for the rank-thread runtime.
//!
//! The parallel TP engine runs one OS thread per worker, each executing
//! the stage programs of the ranks it owns. Between stages the workers
//! meet at this fabric: a poisonable generation-counted **barrier** plus
//! a set of **rendezvous slots** (one per rank) through which they
//! publish their partial activations. `exchange` is the all-gather
//! primitive: deposit the payloads for your owned ranks, wait for every
//! participant, read back clones of *all* slots in rank order, and wait
//! again so no fast participant can overwrite a slot before a slow one
//! has read it.
//!
//! Payloads are generic (`T: Clone`); the engine exchanges `Arc`-backed
//! activation buffers so the clone in the gather step is a refcount
//! bump, not a copy — workers share one address space, which is exactly
//! the fidelity the virtual-time link model is layered on top of.
//!
//! Error discipline: a worker that fails mid-forward calls [`Fabric::poison`]
//! before replying, so peers blocked at a barrier wake with
//! [`FabricPoisoned`] instead of deadlocking. The orchestrator calls
//! [`Fabric::reset`] once every worker has replied (i.e. no thread is
//! inside a fabric call) to arm the next forward.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// Error returned by fabric operations after [`Fabric::poison`]: the
/// message names the failure of the worker that poisoned it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FabricPoisoned(pub String);

impl std::fmt::Display for FabricPoisoned {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "fabric poisoned: {}", self.0)
    }
}

impl std::error::Error for FabricPoisoned {}

struct Inner<T> {
    /// participants currently waiting at the barrier
    arrived: usize,
    /// bumped every time a barrier releases (sense-reversal)
    generation: u64,
    poisoned: Option<String>,
    /// rendezvous slots, one per rank
    slots: Vec<Option<T>>,
}

/// Barrier + rendezvous slots shared by the rank workers of one engine.
///
/// `world` is the number of *participants* (worker threads); the slot
/// count is the number of *ranks* — with rank multiplexing (`tp` ranks
/// on fewer threads) the two differ, and each participant deposits one
/// payload per rank it owns.
pub struct Fabric<T> {
    world: usize,
    inner: Mutex<Inner<T>>,
    cv: Condvar,
}

impl<T: Clone> Fabric<T> {
    /// A fabric for `world` participants exchanging over `slots` ranks.
    pub fn new(world: usize, slots: usize) -> Fabric<T> {
        assert!(world >= 1, "fabric needs at least one participant");
        Fabric {
            world,
            inner: Mutex::new(Inner {
                arrived: 0,
                generation: 0,
                poisoned: None,
                slots: (0..slots).map(|_| None).collect(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Number of barrier participants.
    pub fn world(&self) -> usize {
        self.world
    }

    /// Number of rendezvous slots (ranks).
    pub fn slot_count(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    fn err(msg: &str) -> FabricPoisoned {
        FabricPoisoned(msg.to_string())
    }

    /// Block until every participant has arrived (or the fabric is
    /// poisoned). Reusable: each release bumps the generation.
    pub fn barrier(&self) -> Result<(), FabricPoisoned> {
        self.barrier_wait().map(|_| ())
    }

    /// [`Fabric::barrier`], returning the seconds this participant
    /// spent blocked waiting for its peers (0 for the last arriver).
    pub fn barrier_wait(&self) -> Result<f64, FabricPoisoned> {
        let g = self.inner.lock().unwrap();
        self.barrier_locked(g, false)
    }

    /// `clear_slots`: the last arriver empties the rendezvous slots
    /// before releasing — used by [`Fabric::exchange`]'s trailing
    /// barrier so the missing-deposit guard stays live on *every*
    /// round, not just the first (every participant has already read
    /// its clones by the time it arrives here). Returns the seconds
    /// spent blocked in the condvar wait.
    fn barrier_locked(
        &self,
        mut g: MutexGuard<'_, Inner<T>>,
        clear_slots: bool,
    ) -> Result<f64, FabricPoisoned> {
        if let Some(m) = &g.poisoned {
            return Err(Self::err(m));
        }
        g.arrived += 1;
        if g.arrived == self.world {
            g.arrived = 0;
            g.generation = g.generation.wrapping_add(1);
            if clear_slots {
                for s in g.slots.iter_mut() {
                    *s = None;
                }
            }
            self.cv.notify_all();
            return Ok(0.0);
        }
        let gen = g.generation;
        let t0 = Instant::now();
        while g.generation == gen && g.poisoned.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        let waited = t0.elapsed().as_secs_f64();
        match &g.poisoned {
            Some(m) => Err(Self::err(m)),
            None => Ok(waited),
        }
    }

    /// Rendezvous all-gather: deposit `(slot, payload)` for every rank
    /// this participant owns, synchronize, and return clones of all
    /// slots in rank order. The trailing barrier guarantees every
    /// participant has read the slots before any of them can deposit
    /// the next round's payloads.
    pub fn exchange(&self, posts: Vec<(usize, T)>) -> Result<Vec<T>, FabricPoisoned> {
        self.exchange_timed(posts).map(|(out, _)| out)
    }

    /// [`Fabric::exchange`], additionally returning the seconds this
    /// participant spent *blocked* waiting for peers across the two
    /// barriers (excluding deposit and gather work) — the fabric-wait
    /// signal behind the `rank{r}_fabric_wait_s` gauges and the
    /// `phase_fabric_wait_s` trace phase.
    pub fn exchange_timed(
        &self,
        posts: Vec<(usize, T)>,
    ) -> Result<(Vec<T>, f64), FabricPoisoned> {
        {
            let mut g = self.inner.lock().unwrap();
            if let Some(m) = &g.poisoned {
                return Err(Self::err(m));
            }
            for (slot, v) in posts {
                g.slots[slot] = Some(v);
            }
        }
        let mut waited = self.barrier_wait()?;
        let gathered = {
            let g = self.inner.lock().unwrap();
            if let Some(m) = &g.poisoned {
                return Err(Self::err(m));
            }
            let mut out = Vec::with_capacity(g.slots.len());
            for (i, s) in g.slots.iter().enumerate() {
                match s {
                    Some(v) => out.push(v.clone()),
                    None => return Err(Self::err(&format!("slot {i} never deposited"))),
                }
            }
            out
        };
        {
            let g = self.inner.lock().unwrap();
            waited += self.barrier_locked(g, true)?;
        }
        Ok((gathered, waited))
    }

    /// Mark the fabric failed: every blocked or future fabric call
    /// returns [`FabricPoisoned`] until [`Fabric::reset`]. The first
    /// poisoner's message wins (later ones would describe knock-on
    /// failures).
    pub fn poison(&self, msg: &str) {
        let mut g = self.inner.lock().unwrap();
        if g.poisoned.is_none() {
            g.poisoned = Some(msg.to_string());
        }
        self.cv.notify_all();
    }

    /// Re-arm after a failed round. Only sound once no participant is
    /// inside a fabric call (the orchestrator calls this after every
    /// worker has replied for the round).
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.arrived = 0;
        g.generation = g.generation.wrapping_add(1);
        g.poisoned = None;
        for s in g.slots.iter_mut() {
            *s = None;
        }
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// Contiguous rank assignment: worker `w` of `workers` owns its
    /// share of `ranks` (used by the engine's pool and these tests).
    fn owned(ranks: usize, workers: usize, w: usize) -> Vec<usize> {
        let base = ranks / workers;
        let rem = ranks % workers;
        let start = w * base + w.min(rem);
        let n = base + usize::from(w < rem);
        (start..start + n).collect()
    }

    #[test]
    fn single_participant_exchange_is_identity() {
        let f: Fabric<u64> = Fabric::new(1, 3);
        let out = f.exchange(vec![(0, 10), (1, 11), (2, 12)]).unwrap();
        assert_eq!(out, vec![10, 11, 12]);
        // slots are reusable round after round
        let out = f.exchange(vec![(0, 20), (1, 21), (2, 22)]).unwrap();
        assert_eq!(out, vec![20, 21, 22]);
    }

    #[test]
    fn exchange_gathers_all_ranks_across_thread_counts() {
        // stress the barrier + rendezvous across worker counts and
        // multiplexing shapes, many rounds each
        for (workers, ranks) in [(1usize, 4usize), (2, 2), (2, 4), (3, 8), (4, 4), (8, 8), (16, 16)]
        {
            let f: Arc<Fabric<u64>> = Arc::new(Fabric::new(workers, ranks));
            let rounds = 50;
            let joins: Vec<_> = (0..workers)
                .map(|w| {
                    let f = f.clone();
                    std::thread::spawn(move || {
                        for round in 0..rounds {
                            let posts: Vec<(usize, u64)> = owned(ranks, workers, w)
                                .into_iter()
                                .map(|r| (r, (round * 1000 + r) as u64))
                                .collect();
                            let got = f.exchange(posts).unwrap();
                            let want: Vec<u64> =
                                (0..ranks).map(|r| (round * 1000 + r) as u64).collect();
                            assert_eq!(got, want, "workers={workers} round={round}");
                        }
                    })
                })
                .collect();
            for j in joins {
                j.join().unwrap();
            }
        }
    }

    #[test]
    fn every_rank_owned_exactly_once() {
        for workers in 1..=8 {
            for ranks in workers..=16 {
                let mut seen = vec![0usize; ranks];
                for w in 0..workers {
                    for r in owned(ranks, workers, w) {
                        seen[r] += 1;
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "workers={workers} ranks={ranks}");
            }
        }
    }

    #[test]
    fn poison_unblocks_waiters_and_reset_revives() {
        let f: Arc<Fabric<u64>> = Arc::new(Fabric::new(2, 2));
        let f2 = f.clone();
        let waiter = std::thread::spawn(move || f2.barrier());
        // give the waiter time to block, then poison instead of joining
        std::thread::sleep(std::time::Duration::from_millis(20));
        f.poison("peer failed");
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("peer failed"), "{err}");
        // poisoned fabric rejects new calls ...
        assert!(f.barrier().is_err());
        assert!(f.exchange(vec![(0, 1)]).is_err());
        // ... until reset re-arms it
        f.reset();
        let f3 = f.clone();
        let a = std::thread::spawn(move || f3.exchange(vec![(0, 7)]));
        let b = f.exchange(vec![(1, 9)]).unwrap();
        assert_eq!(b, vec![7, 9]);
        assert_eq!(a.join().unwrap().unwrap(), vec![7, 9]);
    }

    #[test]
    fn missing_deposit_is_an_error_not_a_hang() {
        // one participant, two slots, only one deposited
        let f: Fabric<u64> = Fabric::new(1, 2);
        let err = f.exchange(vec![(0, 1)]).unwrap_err();
        assert!(err.to_string().contains("slot 1"), "{err}");
    }

    #[test]
    fn slots_clear_between_rounds_so_the_guard_stays_live() {
        let f: Fabric<u64> = Fabric::new(1, 2);
        assert_eq!(f.exchange(vec![(0, 1), (1, 2)]).unwrap(), vec![1, 2]);
        // a later round that misses a deposit must error, not silently
        // hand back round 1's stale payload
        let err = f.exchange(vec![(0, 3)]).unwrap_err();
        assert!(err.to_string().contains("slot 1"), "{err}");
    }

    #[test]
    fn exchange_timed_measures_blocked_time() {
        // A arrives immediately, B arrives ~100 ms late: A's measured
        // wait must cover most of that gap, and both waits are finite
        // and non-negative. Generous margins keep this robust on a
        // loaded host.
        let f: Arc<Fabric<u64>> = Arc::new(Fabric::new(2, 2));
        let f2 = f.clone();
        let early = std::thread::spawn(move || f2.exchange_timed(vec![(0, 1)]).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(100));
        let (_, late_wait) = f.exchange_timed(vec![(1, 2)]).unwrap();
        let (got, early_wait) = early.join().unwrap();
        assert_eq!(got, vec![1, 2]);
        assert!(early_wait >= 0.05, "early participant waited {early_wait}s");
        assert!(late_wait >= 0.0 && late_wait < early_wait, "late waited {late_wait}s");
    }

    #[test]
    fn arc_payloads_share_not_copy() {
        let f: Arc<Fabric<Arc<Vec<f32>>>> = Arc::new(Fabric::new(2, 2));
        let f2 = f.clone();
        let payload = Arc::new(vec![1.0f32; 1024]);
        let p2 = payload.clone();
        let t = std::thread::spawn(move || f2.exchange(vec![(1, p2)]).unwrap());
        let got = f.exchange(vec![(0, payload.clone())]).unwrap();
        let other = t.join().unwrap();
        // both participants see the same allocation, not a copy
        assert!(Arc::ptr_eq(&got[0], &payload));
        assert!(Arc::ptr_eq(&got[0], &other[0]));
        assert_eq!(other[1].len(), 1024);
    }
}
