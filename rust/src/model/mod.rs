//! Model-side plumbing: runtime configs (mirroring python configs.py via
//! the manifest), weight loading + TP sharding, and the analytic
//! performance model for paper-scale Llama-2 deployments (Table 3).

pub mod perf_model;
pub mod weights;

use crate::util::json::Json;

/// Runtime model configuration, read from `artifacts/manifest.json`
/// (written by the AOT exporter from python `configs.MODELS`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub params: usize,
}

impl ModelConfig {
    pub fn from_manifest(name: &str, manifest: &Json) -> anyhow::Result<ModelConfig> {
        let m = manifest
            .path(&format!("models.{name}"))
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest"))?;
        let g = |k: &str| -> anyhow::Result<usize> {
            m.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("manifest missing models.{name}.{k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            head_dim: g("head_dim")?,
            d_ff: g("d_ff")?,
            max_seq: g("max_seq")?,
            params: g("params")?,
        })
    }

    pub fn shard_heads(&self, tp: usize) -> usize {
        assert_eq!(self.n_heads % tp, 0, "{} heads not divisible by tp={}", self.n_heads, tp);
        self.n_heads / tp
    }

    pub fn shard_ff(&self, tp: usize) -> usize {
        assert_eq!(self.d_ff % tp, 0);
        self.d_ff / tp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Json {
        Json::parse(
            r#"{"models": {"nano": {"vocab": 256, "d_model": 128, "n_layers": 2,
                "n_heads": 8, "head_dim": 16, "d_ff": 384, "max_seq": 320,
                "params": 490000}}}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_from_manifest() {
        let c = ModelConfig::from_manifest("nano", &manifest()).unwrap();
        assert_eq!(c.d_model, 128);
        assert_eq!(c.shard_heads(4), 2);
        assert_eq!(c.shard_ff(8), 48);
        assert!(ModelConfig::from_manifest("bogus", &manifest()).is_err());
    }
}
