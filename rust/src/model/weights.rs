//! Weight loading (.npy, saved by python train.py) + Megatron-style TP
//! shard slicing, mirroring python `model.shard_params` exactly.

use std::collections::BTreeMap;
use std::path::Path;

use super::ModelConfig;
use crate::util::npy::Npy;

/// A named f32 tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn rows(&self) -> usize {
        self.shape[0]
    }
    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap()
    }

    /// Slice columns [a, b) of a 2-D tensor.
    pub fn col_slice(&self, a: usize, b: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        assert!(b <= c && a < b);
        let w = b - a;
        let mut data = Vec::with_capacity(r * w);
        for row in 0..r {
            data.extend_from_slice(&self.data[row * c + a..row * c + b]);
        }
        Tensor { shape: vec![r, w], data }
    }

    /// Slice rows [a, b) of a 2-D tensor.
    pub fn row_slice(&self, a: usize, b: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        Tensor { shape: vec![b - a, c], data: self.data[a * c..b * c].to_vec() }
    }
}

/// All weights of one model, keyed by the python export names
/// (`l0.wq`, `final_norm`, ...).
#[derive(Debug, Clone, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(dir: &Path) -> anyhow::Result<Weights> {
        let mut tensors = BTreeMap::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("npy") {
                continue;
            }
            let name = path.file_stem().unwrap().to_string_lossy().to_string();
            let npy = Npy::load(&path)?;
            let data = npy
                .as_f32()
                .ok_or_else(|| anyhow::anyhow!("{name}: expected float tensor"))?;
            tensors.insert(name, Tensor { shape: npy.shape, data });
        }
        anyhow::ensure!(!tensors.is_empty(), "no .npy weights in {}", dir.display());
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing weight {name}"))
    }

    /// Worker `rank`'s TP shard (mirrors python `shard_params`):
    /// wq/wk/wv/w_gate/w_up column-split, wo/w_down row-split, norms and
    /// embed/head replicated.
    pub fn shard(&self, cfg: &ModelConfig, tp: usize, rank: usize) -> anyhow::Result<Weights> {
        let hn = cfg.shard_heads(tp);
        let fn_ = cfg.shard_ff(tp);
        let hd = cfg.head_dim;
        let (qa, qb) = (rank * hn * hd, (rank + 1) * hn * hd);
        let (fa, fb) = (rank * fn_, (rank + 1) * fn_);
        let mut out = BTreeMap::new();
        for key in ["embed", "final_norm", "lm_head"] {
            out.insert(key.to_string(), self.get(key)?.clone());
        }
        for l in 0..cfg.n_layers {
            let g = |n: &str| self.get(&format!("l{l}.{n}"));
            out.insert(format!("l{l}.attn_norm"), g("attn_norm")?.clone());
            out.insert(format!("l{l}.mlp_norm"), g("mlp_norm")?.clone());
            out.insert(format!("l{l}.wq"), g("wq")?.col_slice(qa, qb));
            out.insert(format!("l{l}.wk"), g("wk")?.col_slice(qa, qb));
            out.insert(format!("l{l}.wv"), g("wv")?.col_slice(qa, qb));
            out.insert(format!("l{l}.wo"), g("wo")?.row_slice(qa, qb));
            out.insert(format!("l{l}.w_gate"), g("w_gate")?.col_slice(fa, fb));
            out.insert(format!("l{l}.w_up"), g("w_up")?.col_slice(fa, fb));
            out.insert(format!("l{l}.w_down"), g("w_down")?.row_slice(fa, fb));
        }
        Ok(Weights { tensors: out })
    }

    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| i as f32).collect() }
    }

    #[test]
    fn col_slice() {
        let a = t(&[2, 4]); // [[0,1,2,3],[4,5,6,7]]
        let s = a.col_slice(1, 3);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![1.0, 2.0, 5.0, 6.0]);
    }

    #[test]
    fn row_slice() {
        let a = t(&[3, 2]);
        let s = a.row_slice(1, 3);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn shards_tile_weight_exactly() {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 1,
            n_heads: 4,
            head_dim: 2,
            d_ff: 8,
            max_seq: 16,
            params: 0,
        };
        let mut w = Weights::default();
        w.tensors.insert("embed".into(), t(&[16, 8]));
        w.tensors.insert("final_norm".into(), t(&[8]));
        w.tensors.insert("lm_head".into(), t(&[8, 16]));
        for n in ["wq", "wk", "wv"] {
            w.tensors.insert(format!("l0.{n}"), t(&[8, 8]));
        }
        w.tensors.insert("l0.wo".into(), t(&[8, 8]));
        w.tensors.insert("l0.attn_norm".into(), t(&[8]));
        w.tensors.insert("l0.mlp_norm".into(), t(&[8]));
        w.tensors.insert("l0.w_gate".into(), t(&[8, 8]));
        w.tensors.insert("l0.w_up".into(), t(&[8, 8]));
        w.tensors.insert("l0.w_down".into(), t(&[8, 8]));

        let tp = 2;
        let shards: Vec<Weights> = (0..tp).map(|r| w.shard(&cfg, tp, r).unwrap()).collect();
        // wq column split: concatenating shard columns reproduces original
        let full = w.get("l0.wq").unwrap();
        let s0 = shards[0].get("l0.wq").unwrap();
        let s1 = shards[1].get("l0.wq").unwrap();
        for row in 0..8 {
            for c in 0..4 {
                assert_eq!(s0.data[row * 4 + c], full.data[row * 8 + c]);
                assert_eq!(s1.data[row * 4 + c], full.data[row * 8 + 4 + c]);
            }
        }
        // wo row split
        let full_o = w.get("l0.wo").unwrap();
        let o1 = shards[1].get("l0.wo").unwrap();
        assert_eq!(o1.data[..], full_o.data[4 * 8..]);
    }
}
