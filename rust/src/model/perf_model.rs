//! Analytic TTFT model for paper-scale deployments (Table 3).
//!
//! Our physical testbed is one CPU core; Llama-2 7B/13B/70B on L4/A100
//! nodes exist here only as a calibrated roofline model:
//!
//!   TTFT = prefill compute (dense GEMM roofline, MFU-discounted)
//!        + per-layer communication (2 row-parallel collectives/layer,
//!          modeled as ring all-gather of the full partial activation —
//!          matching the paper's framework, which swaps the tensors
//!          inside `all_gather` and reduces locally, Fig. 1b)
//!        + compression overhead (quantize own shard + dequantize N-1
//!          received shards at the profile's element throughput).
//!
//! Calibration targets the paper's *uncompressed* L4/A100 rows; the
//! compressed rows and crossovers are then predictions — EXPERIMENTS.md
//! compares them against all eight Table 3 rows.

use crate::interconnect::HwProfile;
use crate::mxfmt::Compressor;

/// Paper-scale model dims (Llama-2 family).
#[derive(Debug, Clone, Copy)]
pub struct PaperModel {
    pub name: &'static str,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

pub const LLAMA2_7B: PaperModel = PaperModel {
    name: "llama2-7b",
    d_model: 4096,
    n_layers: 32,
    n_heads: 32,
    n_kv_heads: 32,
    d_ff: 11008,
    vocab: 32000,
};
pub const LLAMA2_13B: PaperModel = PaperModel {
    name: "llama2-13b",
    d_model: 5120,
    n_layers: 40,
    n_heads: 40,
    n_kv_heads: 40,
    d_ff: 13824,
    vocab: 32000,
};
pub const LLAMA2_70B: PaperModel = PaperModel {
    name: "llama2-70b",
    d_model: 8192,
    n_layers: 80,
    n_heads: 64,
    n_kv_heads: 8,
    d_ff: 28672,
    vocab: 32000,
};

impl PaperModel {
    /// Matmul parameter count (what prefill FLOPs scale with).
    pub fn matmul_params(&self) -> f64 {
        let d = self.d_model as f64;
        let hd = d / self.n_heads as f64;
        let kv = self.n_kv_heads as f64 * hd;
        let per_layer = d * d // wq
            + 2.0 * d * kv    // wk, wv (GQA)
            + d * d           // wo
            + 3.0 * d * self.d_ff as f64;
        self.n_layers as f64 * per_layer + 2.0 * d * self.vocab as f64
    }

    /// Dense prefill FLOPs for `tokens` total tokens (batch*seq).
    pub fn prefill_flops(&self, batch: usize, seq: usize) -> f64 {
        let tokens = (batch * seq) as f64;
        let d = self.d_model as f64;
        // GEMMs + quadratic attention (scores + AV)
        2.0 * self.matmul_params() * tokens
            + 4.0 * batch as f64 * (seq as f64) * (seq as f64) * d
    }
}

/// One Table 3 deployment scenario.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub model: PaperModel,
    pub profile: &'static HwProfile,
    pub tp: usize,
    pub batch: usize,
    pub seq: usize,
}

#[derive(Debug, Clone)]
pub struct TtftBreakdown {
    pub compute_s: f64,
    pub comm_s: f64,
    pub quant_s: f64,
    pub wire_bytes: usize,
}

impl TtftBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.quant_s
    }
}

impl Scenario {
    /// Per-collective partial-activation element count on each worker.
    fn partial_values(&self) -> usize {
        self.batch * self.seq * self.model.d_model
    }

    /// Number of row-parallel collectives in one prefill pass.
    fn collectives(&self) -> usize {
        2 * self.model.n_layers
    }

    /// TTFT with communication payload defined by `comp` (Fp16 =
    /// uncompressed baseline; MxCodec = the paper's method; etc.).
    pub fn ttft(&self, comp: &dyn Compressor) -> TtftBreakdown {
        let p = self.profile;
        let n = self.tp;
        let values = self.partial_values();

        let compute_s = self.model.prefill_flops(self.batch, self.seq)
            / (n as f64 * p.peak_flops * p.mfu);

        let shard_bytes = comp.wire_bytes(values);
        let comm_s = self.collectives() as f64 * p.link.all_gather_time(shard_bytes, n);

        // compression overhead: encode own shard once + decode (n-1)
        // received shards, per collective. fp16/fp32 pass-through is free
        // (the cast is fused into the producing GEMM on GPU).
        let eb = comp.effective_bits(values);
        let quant_s = if eb >= 16.0 {
            0.0
        } else {
            self.collectives() as f64 * (values as f64 * n as f64)
                / p.quant_values_per_s
                * comp.compute_cost_factor()
        };

        TtftBreakdown {
            compute_s,
            comm_s,
            quant_s,
            wire_bytes: self.collectives() * (n - 1).max(0) * shard_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::baselines::Fp16;
    use crate::mxfmt::{MxCodec, MxScheme};

    fn scenario(model: PaperModel, prof: &str, tp: usize, b: usize, s: usize) -> Scenario {
        Scenario { model, profile: HwProfile::by_name(prof).unwrap(), tp, batch: b, seq: s }
    }

    #[test]
    fn params_are_llama_sized() {
        assert!((LLAMA2_7B.matmul_params() - 6.5e9).abs() < 0.5e9);
        assert!((LLAMA2_70B.matmul_params() - 68e9).abs() < 4e9);
    }

    #[test]
    fn l4_70b_is_comm_bound_a100_is_not() {
        let s_l4 = scenario(LLAMA2_70B, "l4", 8, 2, 64);
        let t = s_l4.ttft(&Fp16);
        assert!(t.comm_s > t.compute_s, "L4 8x should be comm-bound: {t:?}");

        let s_a100 = scenario(LLAMA2_70B, "a100", 4, 2, 128);
        let t = s_a100.ttft(&Fp16);
        assert!(t.compute_s > t.comm_s, "A100 should be compute-bound: {t:?}");
    }

    #[test]
    fn compression_speedup_crossover() {
        // Table 3's core result: MX4 wins on L4 (slow link), loses on A100.
        let mx = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        let l4 = scenario(LLAMA2_70B, "l4", 8, 2, 64);
        let speedup_l4 = l4.ttft(&Fp16).total() / l4.ttft(&mx).total();
        assert!(speedup_l4 > 1.3, "L4 speedup {speedup_l4}");

        let a100 = scenario(LLAMA2_70B, "a100", 4, 2, 128);
        let speedup_a100 = a100.ttft(&Fp16).total() / a100.ttft(&mx).total();
        assert!(speedup_a100 < 1.0, "A100 should slow down: {speedup_a100}");
    }

    #[test]
    fn ttft_magnitude_vs_paper() {
        // paper: Llama-2 70B, 8xL4, 2x64 -> 0.58 s uncompressed
        let s = scenario(LLAMA2_70B, "l4", 8, 2, 64);
        let t = s.ttft(&Fp16).total();
        assert!(t > 0.2 && t < 1.2, "TTFT {t} out of paper's magnitude range");
        // paper: 4xA100, 2x128 -> 0.09 s uncompressed
        let s = scenario(LLAMA2_70B, "a100", 4, 2, 128);
        let t = s.ttft(&Fp16).total();
        assert!(t > 0.03 && t < 0.2, "TTFT {t}");
    }
}
