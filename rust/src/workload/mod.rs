//! Workload engine: trace-driven load generation, latency percentiles
//! and SLO-capacity search — "serving under load" (Table 7).
//!
//! Tables 1–6 measure one request at a time; production serving is
//! judged by QPS-at-SLO under real traffic shapes (arXiv 2507.14392
//! shows communication cost is workload-shape-dependent). This
//! subsystem turns the repo's per-request TTFT wins into measured
//! capacity wins:
//!
//! * [`trace`] — arrival processes (Poisson, bursty/Gamma,
//!   closed-loop) × prompt/output length distributions (fixed,
//!   uniform, heavy-tailed lognormal), seeded through
//!   [`crate::util::rng::Rng`]; JSONL replay format for recorded
//!   traces.
//! * [`driver`] — a wall-clock open-loop driver for the live
//!   [`crate::coordinator::CoordinatorHandle`], and a virtual-time
//!   discrete-event driver that replays the same trace against a
//!   [`driver::ServiceModel`] using the *live coordinator's own*
//!   scheduler policy functions, so simulated hardware profiles see
//!   correct queueing.
//! * [`stats`] — log-bucketed streaming histogram (HDR-style,
//!   mergeable, bounded relative error) behind the TTFT/TPOT/e2e/
//!   queue-wait percentiles and the goodput metric.
//! * [`capacity`] — [`capacity::ModeledEngine`] (paper-scale service
//!   model resolved through a compression [`crate::policy::PolicyTable`])
//!   plus bisection search for max sustainable arrival rate at a TTFT
//!   SLO — the engine behind `tpcc table7`.

pub mod capacity;
pub mod driver;
pub mod stats;
pub mod trace;

pub use capacity::{capacity, max_sustainable_rate, CapacityResult, LoadShape, ModeledEngine, SloSpec};
pub use driver::{
    drive, simulate, BatchMode, DriveOptions, FixedService, LoadReport, ServiceModel, SimOptions,
};
pub use stats::LogHistogram;
pub use trace::{Arrival, ClosedLoop, LenDist, Trace, TraceEvent, TraceSpec};
