//! SLO-capacity search: the maximum sustainable arrival rate at a TTFT
//! SLO for a {policy × collective plan × hardware profile} deployment.
//!
//! [`ModeledEngine`] prices the virtual driver's engine intervals for
//! a paper-scale deployment: prefill compute from the Table 3 roofline
//! ([`PaperModel::prefill_flops`]), decode compute from the HBM
//! weight-read bound, and per-site communication from the *same*
//! collective auto-planner score the live engine charges
//! ([`crate::collective::plan::choose`]) — resolved through a bound
//! [`PolicyTable`], so `uniform:none`, `paper` and `auto` price
//! exactly the collectives they would run.
//!
//! [`max_sustainable_rate`] wraps the generic search: exponential
//! growth to bracket the knee, then bisection on "goodput ≥ target".
//! Traces are regenerated per probed rate from one seed, so every
//! policy is judged on the identical arrival sequence at each rate.

use std::collections::BTreeMap;

use crate::collective::plan::{self, AlgoChoice};
use crate::collective::Topology;
use crate::interconnect::HwProfile;
use crate::model::perf_model::PaperModel;
use crate::mxfmt::{compressor_from_spec_ch, Compressor};
use crate::policy::{Phase, PolicyTable, Site};

use super::driver::{simulate, LoadReport, ServiceModel, SimOptions};
use super::trace::{Arrival, LenDist, TraceSpec};

/// Per-phase site groups: how many collectives of one scheme a forward
/// pass runs (cost depends only on (scheme, message size), not layer).
type SchemeGroups = Vec<(usize, Option<Box<dyn Compressor>>)>;

/// Virtual-time service model of a paper-scale TP deployment under a
/// per-site compression policy.
pub struct ModeledEngine {
    pub model: PaperModel,
    pub profile: &'static HwProfile,
    pub tp: usize,
    topo: Topology,
    prefill_groups: SchemeGroups,
    decode_groups: SchemeGroups,
    prefill_memo: BTreeMap<(usize, usize), f64>,
    decode_memo: BTreeMap<usize, f64>,
}

fn scheme_groups(
    table: &PolicyTable,
    phase: Phase,
    d_model: usize,
) -> anyhow::Result<SchemeGroups> {
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();
    for site in Site::all(table.n_layers) {
        if site.phase == phase {
            *counts.entry(table.spec(site).to_string()).or_insert(0) += 1;
        }
    }
    let mut groups = Vec::with_capacity(counts.len());
    for (spec, count) in counts {
        let comp = if spec == "none" {
            None
        } else {
            Some(compressor_from_spec_ch(&spec, d_model)?)
        };
        groups.push((count, comp));
    }
    Ok(groups)
}

/// Planner-scored virtual seconds of one forward pass's collectives at
/// `values` per-rank message size, summed over the phase's site groups.
fn comm_s(
    groups: &SchemeGroups,
    values: usize,
    tp: usize,
    topo: &Topology,
    quant_values_per_s: f64,
) -> f64 {
    groups
        .iter()
        .map(|(count, comp)| {
            let p = plan::choose(
                values,
                tp,
                comp.as_deref(),
                topo,
                quant_values_per_s,
                AlgoChoice::Auto,
            );
            *count as f64 * p.est_total_s
        })
        .sum()
}

impl ModeledEngine {
    pub fn new(
        model: PaperModel,
        profile: &'static HwProfile,
        tp: usize,
        table: &PolicyTable,
    ) -> anyhow::Result<ModeledEngine> {
        anyhow::ensure!(
            table.n_layers == model.n_layers,
            "policy table is for {} layers, model {} has {}",
            table.n_layers,
            model.name,
            model.n_layers
        );
        anyhow::ensure!(tp >= 1, "tp must be >= 1");
        Ok(ModeledEngine {
            model,
            profile,
            tp,
            topo: Topology::from_profile(profile, tp),
            prefill_groups: scheme_groups(table, Phase::Prefill, model.d_model)?,
            decode_groups: scheme_groups(table, Phase::Decode, model.d_model)?,
            prefill_memo: BTreeMap::new(),
            decode_memo: BTreeMap::new(),
        })
    }
}

impl ServiceModel for ModeledEngine {
    fn prefill_s(&mut self, batch: usize, seq: usize) -> f64 {
        if let Some(&t) = self.prefill_memo.get(&(batch, seq)) {
            return t;
        }
        let compute = self.model.prefill_flops(batch, seq)
            / (self.tp as f64 * self.profile.peak_flops * self.profile.mfu);
        let values = batch * seq * self.model.d_model;
        let comm = comm_s(
            &self.prefill_groups,
            values,
            self.tp,
            &self.topo,
            self.profile.quant_values_per_s,
        );
        let t = compute + comm;
        self.prefill_memo.insert((batch, seq), t);
        t
    }

    fn decode_s(&mut self, batch: usize) -> f64 {
        if let Some(&t) = self.decode_memo.get(&batch) {
            return t;
        }
        // decode is memory-bound: every step streams the weight shard
        // (fp16) from HBM once per rank
        let compute = self.model.matmul_params() * 2.0
            / (self.tp as f64 * self.profile.hbm_bytes_per_s);
        let values = batch * self.model.d_model;
        let comm = comm_s(
            &self.decode_groups,
            values,
            self.tp,
            &self.topo,
            self.profile.quant_values_per_s,
        );
        let t = compute + comm;
        self.decode_memo.insert(batch, t);
        t
    }
}

/// The SLO a deployment must sustain.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// TTFT bound (seconds)
    pub ttft_s: f64,
    /// minimum fraction of submitted requests meeting it
    pub min_goodput: f64,
}

impl Default for SloSpec {
    fn default() -> Self {
        SloSpec { ttft_s: 0.25, min_goodput: 0.95 }
    }
}

/// The workload shape a capacity search probes with (arrival rate is
/// the searched variable; everything else is pinned).
#[derive(Debug, Clone, Copy)]
pub struct LoadShape {
    pub prompt_len: LenDist,
    pub output_len: LenDist,
    pub requests: usize,
    pub seed: u64,
}

impl Default for LoadShape {
    fn default() -> Self {
        LoadShape {
            prompt_len: LenDist::LogNormal { median: 48.0, sigma: 1.0, cap: 224 },
            output_len: LenDist::LogNormal { median: 16.0, sigma: 0.7, cap: 64 },
            requests: 240,
            seed: 0xC0FFEE,
        }
    }
}

/// Outcome of one capacity search.
#[derive(Debug, Clone)]
pub struct CapacityResult {
    /// max sustainable arrival rate (requests/s); 0 when even the
    /// lightest probe misses the SLO
    pub qps: f64,
    /// goodput evaluations spent
    pub evals: usize,
    /// the load report at the found rate (re-simulated), None when
    /// qps == 0
    pub report: Option<LoadReport>,
}

/// Upper bracket cap for the growth phase (requests/s). A deployment
/// sustaining this is reported as `RATE_CAP` — effectively unbounded
/// for the modeled engine intervals.
pub const RATE_CAP: f64 = 4096.0;

/// Find the largest rate with `eval(rate) >= min_goodput` by doubling
/// from `lo` to bracket the knee, then `iters` bisection steps.
/// `eval` must be deterministic; it is called O(log RATE_CAP + iters)
/// times.
pub fn max_sustainable_rate(
    lo: f64,
    min_goodput: f64,
    iters: usize,
    mut eval: impl FnMut(f64) -> f64,
) -> f64 {
    let mut lo = lo.max(1e-3);
    if eval(lo) < min_goodput {
        return 0.0;
    }
    let mut hi = lo * 2.0;
    loop {
        if hi >= RATE_CAP {
            // never claim the cap without measuring it
            if eval(RATE_CAP) >= min_goodput {
                return RATE_CAP;
            }
            hi = RATE_CAP;
            break;
        }
        if eval(hi) >= min_goodput {
            lo = hi;
            hi *= 2.0;
        } else {
            break;
        }
    }
    for _ in 0..iters {
        let mid = 0.5 * (lo + hi);
        if eval(mid) >= min_goodput {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Capacity of `svc` under `shape` against `slo`: bisect the Poisson
/// arrival rate, regenerating the trace (same seed) per probe.
pub fn capacity(
    svc: &mut dyn ServiceModel,
    shape: &LoadShape,
    slo: &SloSpec,
    sim: &SimOptions,
    iters: usize,
) -> CapacityResult {
    let mut opts = sim.clone();
    opts.slo_ttft_s = slo.ttft_s;
    let run = |svc: &mut dyn ServiceModel, rate: f64| -> LoadReport {
        let trace = TraceSpec {
            arrival: Arrival::Poisson { rate },
            prompt_len: shape.prompt_len,
            output_len: shape.output_len,
            requests: shape.requests,
            seed: shape.seed,
        }
        .generate();
        simulate(&trace, svc, &opts)
    };
    let mut evals = 0usize;
    let qps = max_sustainable_rate(0.25, slo.min_goodput, iters, |rate| {
        evals += 1;
        run(&mut *svc, rate).goodput()
    });
    let report = (qps > 0.0).then(|| run(&mut *svc, qps));
    CapacityResult { qps, evals, report }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf_model::LLAMA2_7B;
    use crate::workload::driver::{BatchMode, FixedService};

    #[test]
    fn bisection_finds_known_knee() {
        // goodput 1.0 below rate 10, 0 above: capacity must land near 10
        let q = max_sustainable_rate(0.25, 0.95, 20, |r| if r <= 10.0 { 1.0 } else { 0.0 });
        assert!((q - 10.0).abs() < 0.05, "{q}");
        // never sustainable
        assert_eq!(max_sustainable_rate(0.25, 0.95, 8, |_| 0.0), 0.0);
        // always sustainable saturates at the cap
        assert_eq!(max_sustainable_rate(0.25, 0.95, 8, |_| 1.0), RATE_CAP);
    }

    #[test]
    fn capacity_monotone_in_service_time() {
        let shape = LoadShape { requests: 150, ..LoadShape::default() };
        let slo = SloSpec::default();
        let sim = SimOptions::default();
        let mut fast = FixedService { prefill_s: 0.01, decode_s: 0.004 };
        let mut slow = FixedService { prefill_s: 0.04, decode_s: 0.016 };
        let cf = capacity(&mut fast, &shape, &slo, &sim, 8);
        let cs = capacity(&mut slow, &shape, &slo, &sim, 8);
        assert!(cf.qps > 0.0 && cs.qps > 0.0);
        assert!(cf.qps >= cs.qps, "fast {} < slow {}", cf.qps, cs.qps);
        let rep = cf.report.unwrap();
        assert!(rep.goodput() >= slo.min_goodput);
        assert!(rep.ttft.percentile(50.0).is_finite());
    }

    #[test]
    fn continuous_capacity_is_at_least_bucketed() {
        // the heavy-tailed default shape (prompts capped at 224) drags
        // bucketed cohorts into the padded (8, 256) prefill whenever a
        // long prompt lands in the batch; the continuous loop slices
        // those on the chunk lane, so its SLO capacity cannot be lower
        let profile = HwProfile::by_name("l4").unwrap();
        let table = PolicyTable::uniform(LLAMA2_7B.n_layers, "none");
        let shape = LoadShape { requests: 120, ..LoadShape::default() };
        let slo = SloSpec::default();
        let mut eng = ModeledEngine::new(LLAMA2_7B, profile, 2, &table).unwrap();
        let qb = capacity(&mut eng, &shape, &slo, &SimOptions::default(), 6).qps;
        let cont = SimOptions { mode: BatchMode::Continuous, ..SimOptions::default() };
        let qc = capacity(&mut eng, &shape, &slo, &cont, 6).qps;
        assert!(qb > 0.0, "bucketed capacity must be positive");
        assert!(qc >= qb * 0.99, "continuous {qc} < bucketed {qb}");
    }

    #[test]
    fn modeled_engine_prices_compression_in() {
        let profile = HwProfile::by_name("l4").unwrap();
        let none = PolicyTable::uniform(LLAMA2_7B.n_layers, "none");
        let fp4 = PolicyTable::uniform(LLAMA2_7B.n_layers, "fp4_e2m1_b32_e8m0");
        let mut e_none = ModeledEngine::new(LLAMA2_7B, profile, 2, &none).unwrap();
        let mut e_fp4 = ModeledEngine::new(LLAMA2_7B, profile, 2, &fp4).unwrap();
        // compressed prefill collectives are cheaper on the slow link
        let pn = e_none.prefill_s(8, 128);
        let pc = e_fp4.prefill_s(8, 128);
        assert!(pc < pn, "compressed {pc} >= uncompressed {pn}");
        // both phases price compute > 0 and memoise
        let d1 = e_none.decode_s(8);
        let d2 = e_none.decode_s(8);
        assert!(d1 > 0.0 && d1 == d2);
        // layer-count mismatch is an error
        assert!(ModeledEngine::new(LLAMA2_7B, profile, 2, &PolicyTable::uniform(4, "none"))
            .is_err());
    }
}
