//! Log-bucketed streaming latency histogram (HDR-style).
//!
//! The exact-sample [`crate::metrics::Histogram`] keeps every
//! observation — fine for one benchmark run, wrong for a load test
//! that records hundreds of thousands of latencies across merged
//! worker shards. [`LogHistogram`] holds a fixed array of
//! geometrically spaced buckets instead: O(1) record, O(buckets)
//! quantile, bounded memory, and **mergeable** (two histograms with
//! the same layout add bucket-wise, so merge == concat exactly —
//! pinned by `tests/property_workload.rs`).
//!
//! Accuracy: bucket boundaries grow by [`GROWTH`] per bucket, so any
//! reported quantile is within one bucket of the exact order
//! statistic — a bounded *relative* error of at most `GROWTH` (~4.4%),
//! independent of the latency's magnitude. Reported values are the
//! geometric midpoint of the owning bucket, clamped to the observed
//! [min, max].

/// Ratio between adjacent bucket upper bounds: 2^(1/16) ≈ 1.0443.
/// Every quantile is exact to within this factor.
pub const GROWTH: f64 = 1.044273782427414; // 2f64.powf(1.0 / 16.0)

/// Lower bound of the first bucket (1 µs). Latencies below it land in
/// a dedicated underflow bucket and report as the recorded minimum.
pub const MIN_VALUE: f64 = 1e-6;

/// Bucket count: covers [1 µs, ~2.8 h) at 16 buckets per octave
/// (MIN_VALUE · 2^(544/16) ≈ 1.7e4 s).
pub const BUCKETS: usize = 544;

/// Streaming histogram over positive seconds-scale values.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    /// values below [`MIN_VALUE`] (incl. zero and negatives)
    underflow: u64,
    total: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: vec![0; BUCKETS],
            underflow: 0,
            total: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

/// Bucket index of `v` (callers guarantee `v >= MIN_VALUE`).
fn bucket_of(v: f64) -> usize {
    let i = ((v / MIN_VALUE).ln() / GROWTH.ln()).floor();
    (i.max(0.0) as usize).min(BUCKETS - 1)
}

/// Lower bound of bucket `i`.
fn bucket_lo(i: usize) -> f64 {
    MIN_VALUE * GROWTH.powi(i as i32)
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Record one observation. Non-finite values are ignored (they
    /// carry no latency information); values below [`MIN_VALUE`] count
    /// in the underflow bucket.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.total += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v < MIN_VALUE {
            self.underflow += 1;
        } else {
            self.counts[bucket_of(v)] += 1;
        }
    }

    /// Add every observation of `other` into `self`. Layouts are
    /// static, so this is exact: merge(a, b) reports the same
    /// quantiles as recording a's and b's samples into one histogram.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.underflow += other.underflow;
        self.total += other.total;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.sum / self.total as f64
    }

    /// Sample standard deviation (Bessel-corrected) from the exact
    /// streaming moments; 0 with fewer than two observations.
    pub fn stddev(&self) -> f64 {
        if self.total < 2 {
            return 0.0;
        }
        let n = self.total as f64;
        let var = (self.sum_sq - self.sum * self.sum / n) / (n - 1.0);
        var.max(0.0).sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Value at percentile `p` in [0, 100]: the geometric midpoint of
    /// the bucket holding the rank-`⌈p/100·n⌉` observation, clamped to
    /// the observed [min, max]. NaN when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        // the extreme order statistics are tracked exactly
        if rank == 1 {
            return self.min;
        }
        if rank == self.total {
            return self.max;
        }
        let mut cum = self.underflow;
        if rank <= cum {
            return self.min;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if rank <= cum {
                let lo = bucket_lo(i);
                let mid = lo * GROWTH.sqrt();
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fraction of observations `<= threshold` — the goodput metric
    /// when `threshold` is a latency SLO. Exact at bucket granularity
    /// (a bucket straddling the threshold counts fully when its lower
    /// bound clears it). NaN when empty.
    pub fn fraction_below(&self, threshold: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let mut below = self.underflow;
        for (i, &c) in self.counts.iter().enumerate() {
            if bucket_lo(i) <= threshold {
                below += c;
            } else {
                break;
            }
        }
        below as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_nan() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.percentile(50.0).is_nan());
        assert!(h.mean().is_nan());
        assert!(h.min().is_nan());
        assert!(h.fraction_below(1.0).is_nan());
    }

    #[test]
    fn quantiles_within_growth_bound() {
        let mut h = LogHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1 ms .. 1 s
        }
        for p in [1.0, 10.0, 50.0, 90.0, 99.0] {
            let exact = (p / 100.0 * 1000.0).ceil() * 1e-3;
            let got = h.percentile(p);
            assert!(
                got / exact <= GROWTH + 1e-9 && exact / got <= GROWTH + 1e-9,
                "p{p}: got {got}, exact {exact}"
            );
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn extremes_clamp_to_observed() {
        let mut h = LogHistogram::new();
        h.record(0.25);
        h.record(0.50);
        assert_eq!(h.percentile(0.0), 0.25);
        assert_eq!(h.percentile(100.0), 0.50);
        assert_eq!(h.min(), 0.25);
        assert_eq!(h.max(), 0.50);
    }

    #[test]
    fn underflow_and_nonfinite() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(1e-9);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 3); // non-finite ignored
        assert_eq!(h.percentile(50.0), -1.0); // underflow reports min
        assert!((h.fraction_below(1e-3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_concat() {
        let mut all = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut x = 0.37f64;
        for i in 0..500 {
            x = (x * 1.37 + 0.11) % 3.0; // deterministic scatter
            let v = 1e-4 + x;
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        for p in [5.0, 25.0, 50.0, 75.0, 95.0, 99.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p{p}");
        }
        assert_eq!(a.fraction_below(1.0), all.fraction_below(1.0));
        assert!((a.sum() - all.sum()).abs() < 1e-9);
        assert!((a.stddev() - all.stddev()).abs() < 1e-9);
    }

    #[test]
    fn stddev_matches_direct_computation() {
        let mut h = LogHistogram::new();
        assert_eq!(h.stddev(), 0.0);
        h.record(0.5);
        assert_eq!(h.stddev(), 0.0); // one sample: no spread
        let vals = [0.1, 0.2, 0.3, 0.4, 0.5];
        let mut h = LogHistogram::new();
        for v in vals {
            h.record(v);
        }
        let m = vals.iter().sum::<f64>() / vals.len() as f64;
        let want = (vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (vals.len() - 1) as f64)
            .sqrt();
        assert!((h.stddev() - want).abs() < 1e-12, "{} vs {want}", h.stddev());
    }

    #[test]
    fn goodput_fraction() {
        let mut h = LogHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-2); // 10 ms .. 1 s
        }
        let f = h.fraction_below(0.25);
        assert!((0.20..=0.30).contains(&f), "{f}");
        assert!((h.fraction_below(10.0) - 1.0).abs() < 1e-12);
    }
}
