//! Load drivers: a wall-clock open-loop driver for the live engine and
//! a virtual-time discrete-event driver for simulated hardware
//! profiles.
//!
//! Two clocks, one scheduling policy. The **live** driver
//! ([`drive`]) submits [`GenRequest`]s to a [`CoordinatorHandle`] from
//! a clock thread at trace-scheduled wall times; submission is a
//! channel send, so collection never back-pressures arrivals. The
//! **virtual** driver ([`simulate`]) replays the same trace against a
//! [`ServiceModel`] in modeled time: arrivals are scheduled against
//! the interconnect-modeled clock, and the engine-busy intervals come
//! from the service model, so a simulated 8×L4 sees the queueing *it*
//! would see, not what this CPU core sees. Both drivers run the
//! **same** admission policy — [`crate::coordinator::scheduler`]'s
//! `admit_count` / `should_flush` / `pick_prefill_bucket` — so the
//! simulated batcher cannot drift from the real one.
//!
//! Both produce a [`LoadReport`]: log-bucketed TTFT/TPOT/e2e/queue-wait
//! histograms ([`super::stats::LogHistogram`]), goodput against a TTFT
//! SLO, and throughput, publishable into a [`Registry`] for `/metrics`.

use std::collections::VecDeque;
use std::time::Instant;

use crate::coordinator::scheduler;
use crate::coordinator::{CoordinatorHandle, GenRequest, GenResponse};
use crate::metrics::Registry;

use super::stats::LogHistogram;
use super::trace::Trace;

/// Prices engine-occupancy intervals in virtual seconds. One prefill
/// batch or one decode step is one exclusive engine interval — the
/// same serialization the live coordinator exhibits.
pub trait ServiceModel {
    /// One prefill batch at bucket shape (batch, seq).
    fn prefill_s(&mut self, batch: usize, seq: usize) -> f64;
    /// One decode step over a `batch`-wide decode group.
    fn decode_s(&mut self, batch: usize) -> f64;
}

/// Constant-cost service model (tests, back-of-envelope sizing).
#[derive(Debug, Clone, Copy)]
pub struct FixedService {
    pub prefill_s: f64,
    pub decode_s: f64,
}

impl ServiceModel for FixedService {
    fn prefill_s(&mut self, _batch: usize, _seq: usize) -> f64 {
        self.prefill_s
    }
    fn decode_s(&mut self, _batch: usize) -> f64 {
        self.decode_s
    }
}

/// Which serving loop the virtual driver mirrors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// Batch-at-a-time: every admitted cohort prefills in one bucket
    /// sized by its longest prompt (the seed behaviour).
    #[default]
    Bucketed,
    /// In-flight batching: long prompts are sliced into chunk-sized
    /// prefill steps on a dedicated lane ([`scheduler::chunk_plan`]) so
    /// they never drag a cohort into the worst-padded bucket, and
    /// admission is capped by the token budget
    /// ([`scheduler::admit_budget`]). On traces with no long prompts
    /// and a non-binding budget this is *identical* to `Bucketed`.
    Continuous,
}

/// Batcher shape the virtual driver mirrors (defaults match the AOT
/// manifest's exported buckets and [`crate::coordinator::CoordinatorOptions`]).
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub decode_batch: usize,
    pub max_wait_s: f64,
    pub batch_buckets: Vec<usize>,
    pub seq_buckets: Vec<usize>,
    /// TTFT SLO the report's goodput is measured against
    pub slo_ttft_s: f64,
    /// serving loop to model (Table 7's continuous-vs-bucketed column)
    pub mode: BatchMode,
    /// per-step admission token budget (continuous mode)
    pub max_batch_tokens: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            decode_batch: 8,
            max_wait_s: 0.05,
            batch_buckets: vec![1, 8],
            seq_buckets: vec![1, 16, 64, 128, 256],
            slo_ttft_s: 0.25,
            mode: BatchMode::Bucketed,
            max_batch_tokens: 2048,
        }
    }
}

/// Aggregated outcome of one load run (live or simulated).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub submitted: usize,
    pub completed: usize,
    /// submitted but never answered (coordinator gone) or aborted by
    /// the simulator's safety valve
    pub failed: usize,
    /// wall time (live) or virtual makespan (simulated), seconds
    pub makespan_s: f64,
    pub tokens_out: u64,
    pub slo_ttft_s: f64,
    slo_hits: usize,
    pub ttft: LogHistogram,
    pub tpot: LogHistogram,
    pub e2e: LogHistogram,
    pub queue_wait: LogHistogram,
}

impl LoadReport {
    pub fn new(submitted: usize, slo_ttft_s: f64) -> LoadReport {
        LoadReport {
            submitted,
            completed: 0,
            failed: 0,
            makespan_s: 0.0,
            tokens_out: 0,
            slo_ttft_s,
            slo_hits: 0,
            ttft: LogHistogram::new(),
            tpot: LogHistogram::new(),
            e2e: LogHistogram::new(),
            queue_wait: LogHistogram::new(),
        }
    }

    /// Record one completed request (non-finite latencies are skipped
    /// by the histograms and count as SLO misses).
    pub fn record(&mut self, ttft_s: f64, e2e_s: f64, tpot_s: f64, queue_wait_s: f64, new_tokens: usize) {
        self.completed += 1;
        self.tokens_out += new_tokens as u64;
        self.ttft.record(ttft_s);
        self.e2e.record(e2e_s);
        self.tpot.record(tpot_s);
        self.queue_wait.record(queue_wait_s);
        if ttft_s.is_finite() && ttft_s <= self.slo_ttft_s {
            self.slo_hits += 1;
        }
    }

    /// Fraction of **submitted** requests that completed within the
    /// TTFT SLO (failures and drops count as misses).
    pub fn goodput(&self) -> f64 {
        self.slo_hits as f64 / self.submitted.max(1) as f64
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.tokens_out as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Completed requests per second over the makespan.
    pub fn qps(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.completed as f64 / self.makespan_s
        } else {
            0.0
        }
    }

    /// Mirror the report into a metric registry (`workload_*` keys on
    /// `/metrics`). Non-finite aggregates are skipped — `/metrics`
    /// must stay valid JSON.
    pub fn publish(&self, reg: &Registry) {
        let mut put = |k: &str, v: f64| {
            if v.is_finite() {
                reg.set(k, v);
            }
        };
        put("workload_submitted", self.submitted as f64);
        put("workload_completed", self.completed as f64);
        put("workload_failed", self.failed as f64);
        put("workload_makespan_s", self.makespan_s);
        put("workload_throughput_tok_s", self.throughput_tok_s());
        put("workload_qps", self.qps());
        put("workload_goodput", self.goodput());
        put("workload_slo_ttft_s", self.slo_ttft_s);
        for (name, h) in [
            ("ttft", &self.ttft),
            ("tpot", &self.tpot),
            ("e2e", &self.e2e),
            ("queue_wait", &self.queue_wait),
        ] {
            put(&format!("workload_{name}_p50_s"), h.percentile(50.0));
            put(&format!("workload_{name}_p95_s"), h.percentile(95.0));
            put(&format!("workload_{name}_p99_s"), h.percentile(99.0));
        }
    }

    pub fn print(&self, label: &str) {
        println!(
            "{label}: {}/{} completed ({} failed) in {:.2}s — {:.1} tok/s, {:.2} req/s",
            self.completed,
            self.submitted,
            self.failed,
            self.makespan_s,
            self.throughput_tok_s(),
            self.qps()
        );
        println!(
            "  ttft  p50 {:>9} p95 {:>9} p99 {:>9}   goodput {:.1}% @ {:.0}ms SLO",
            crate::bench::fmt_time(self.ttft.percentile(50.0)),
            crate::bench::fmt_time(self.ttft.percentile(95.0)),
            crate::bench::fmt_time(self.ttft.percentile(99.0)),
            self.goodput() * 100.0,
            self.slo_ttft_s * 1e3
        );
        println!(
            "  e2e   p50 {:>9} p95 {:>9}   tpot p50 {:>9}   queue-wait p50 {:>9} p95 {:>9}",
            crate::bench::fmt_time(self.e2e.percentile(50.0)),
            crate::bench::fmt_time(self.e2e.percentile(95.0)),
            crate::bench::fmt_time(self.tpot.percentile(50.0)),
            crate::bench::fmt_time(self.queue_wait.percentile(50.0)),
            crate::bench::fmt_time(self.queue_wait.percentile(95.0)),
        );
    }
}

// ---------------------------------------------------------------------
// Virtual-time discrete-event driver
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct SimReq {
    arrive_s: f64,
    prompt: usize,
    out: usize,
}

#[derive(Debug, Clone, Copy)]
struct SimActive {
    arrive_s: f64,
    first_token_s: f64,
    out: usize,
    produced: usize,
}

/// Event-count safety valve: no sane run needs more engine intervals
/// than this; hitting it marks the remaining requests failed instead
/// of spinning forever on a buggy service model.
const MAX_SIM_STEPS: usize = 50_000_000;

/// Replay `trace` against `svc` in virtual time, mirroring the live
/// coordinator's batcher: FIFO admission through the same
/// [`scheduler`] policy functions the live loop runs, prefill
/// bucketing through [`scheduler::pick_prefill_bucket`], and a fixed
/// `decode_batch`-slot decode group. The engine is one serial
/// resource; when it idles, the virtual clock jumps to the next
/// arrival (or the pending flush deadline). `opts.mode` selects the
/// bucketed (batch-at-a-time) or continuous (chunked long prompts +
/// token-budget admission) loop.
pub fn simulate(trace: &Trace, svc: &mut dyn ServiceModel, opts: &SimOptions) -> LoadReport {
    match opts.mode {
        BatchMode::Bucketed => simulate_bucketed(trace, svc, opts),
        BatchMode::Continuous => {
            let chunk = scheduler::chunk_tokens(opts.max_batch_tokens, &opts.seq_buckets);
            if chunk == 0 {
                // no chunkable bucket: continuous degenerates to bucketed
                simulate_bucketed(trace, svc, opts)
            } else {
                simulate_continuous(trace, svc, opts, chunk)
            }
        }
    }
}

/// Seed the arrival queues from the trace (shared by both modes).
fn seed_arrivals(
    trace: &Trace,
    upcoming: &mut VecDeque<SimReq>,
    pending: &mut VecDeque<(usize, usize)>,
) {
    if let Some(cl) = trace.closed_loop {
        for (i, ev) in trace.events.iter().enumerate() {
            if i < cl.concurrency {
                upcoming.push_back(SimReq {
                    arrive_s: 0.0,
                    prompt: ev.prompt_tokens,
                    out: ev.max_new_tokens,
                });
            } else {
                pending.push_back((ev.prompt_tokens, ev.max_new_tokens));
            }
        }
    } else {
        let mut events = trace.events.clone();
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        for ev in events {
            upcoming.push_back(SimReq {
                arrive_s: ev.at_s,
                prompt: ev.prompt_tokens,
                out: ev.max_new_tokens,
            });
        }
    }
}

fn simulate_bucketed(trace: &Trace, svc: &mut dyn ServiceModel, opts: &SimOptions) -> LoadReport {
    let db = opts.decode_batch.max(1);
    let max_pb = *opts.batch_buckets.iter().max().unwrap_or(&8);
    let max_seq = opts
        .seq_buckets
        .iter()
        .copied()
        .filter(|&s| s > 1)
        .max()
        .expect("sim needs a prefill seq bucket (> 1)");

    let mut report = LoadReport::new(trace.events.len(), opts.slo_ttft_s);
    let mut upcoming: VecDeque<SimReq> = VecDeque::new();
    // closed loop: completions release the next pending request
    let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
    seed_arrivals(trace, &mut upcoming, &mut pending);
    let think_s = trace.closed_loop.map(|cl| cl.think_s).unwrap_or(0.0);

    let mut now = 0.0f64;
    let mut waiting: VecDeque<SimReq> = VecDeque::new();
    let mut slots: Vec<Option<SimActive>> = vec![None; db];
    let mut steps = 0usize;

    loop {
        steps += 1;
        if steps > MAX_SIM_STEPS {
            report.failed = report.submitted - report.completed;
            break;
        }
        // ---- intake ----
        while upcoming.front().is_some_and(|r| r.arrive_s <= now + 1e-12) {
            waiting.push_back(upcoming.pop_front().unwrap());
        }

        // ---- admission (the live coordinator's policy functions) ----
        let free: Vec<usize> = (0..db).filter(|&i| slots[i].is_none()).collect();
        let oldest_wait = waiting.front().map(|r| now - r.arrive_s).unwrap_or(0.0);
        let n_admit = scheduler::admit_count(waiting.len(), free.len(), max_pb);
        if scheduler::should_flush(oldest_wait, n_admit, free.len().min(8), opts.max_wait_s)
            && n_admit > 0
        {
            let admitted: Vec<SimReq> = waiting.drain(..n_admit).collect();
            let lens: Vec<usize> = admitted.iter().map(|r| r.prompt.min(max_seq)).collect();
            let (bb, sb) =
                scheduler::pick_prefill_bucket(&lens, &opts.batch_buckets, &opts.seq_buckets)
                    .expect("prompt fits the largest bucket after clamping");
            let dt = svc.prefill_s(bb, sb);
            let end = now + dt;
            for (i, r) in admitted.into_iter().enumerate() {
                report.queue_wait.record(now - r.arrive_s);
                if r.out <= 1 {
                    // done at the first token
                    report.record(end - r.arrive_s, end - r.arrive_s, f64::NAN, f64::NAN, 1);
                    if let Some((p, o)) = pending.pop_front() {
                        upcoming.push_back(SimReq { arrive_s: end + think_s, prompt: p, out: o });
                    }
                } else {
                    slots[free[i]] = Some(SimActive {
                        arrive_s: r.arrive_s,
                        first_token_s: end,
                        out: r.out,
                        produced: 1,
                    });
                }
            }
            now = end;
            continue;
        }

        // ---- decode step over the active group ----
        if slots.iter().any(Option::is_some) {
            now += svc.decode_s(db);
            for slot in slots.iter_mut() {
                let Some(a) = slot else { continue };
                a.produced += 1;
                if a.produced >= a.out {
                    let ttft = a.first_token_s - a.arrive_s;
                    let e2e = now - a.arrive_s;
                    let tpot = if a.produced > 1 {
                        (e2e - ttft) / (a.produced - 1) as f64
                    } else {
                        f64::NAN
                    };
                    report.record(ttft, e2e, tpot, f64::NAN, a.produced);
                    if let Some((p, o)) = pending.pop_front() {
                        upcoming.push_back(SimReq { arrive_s: now + think_s, prompt: p, out: o });
                    }
                    *slot = None;
                }
            }
            continue;
        }

        // ---- idle: jump the virtual clock ----
        let flush_at = waiting.front().map(|r| r.arrive_s + opts.max_wait_s);
        let next_arrival = upcoming.front().map(|r| r.arrive_s);
        match (flush_at, next_arrival) {
            (Some(f), Some(a)) => now = f.min(a).max(now),
            (Some(f), None) => now = f.max(now),
            (None, Some(a)) => now = a.max(now),
            (None, None) => break, // drained
        }
    }
    report.makespan_s = now;
    report
}

/// One long prompt being chunk-prefilled on the dedicated lane.
#[derive(Debug, Clone)]
struct SimChunk {
    arrive_s: f64,
    out: usize,
    /// per-slice seq buckets, [`scheduler::chunk_plan`] order
    plan: Vec<usize>,
    next: usize,
    /// decode slot reserved for it at lane entry
    slot: usize,
}

/// The continuous (in-flight) serving loop in virtual time. Identical
/// to [`simulate_bucketed`] except: a prompt longer than `chunk` leaves
/// the FIFO head for a one-at-a-time chunk lane whose slices interleave
/// with decode steps (so it never drags a cohort of shorts into the
/// top padded bucket), and grouped admission is additionally capped by
/// [`scheduler::admit_budget`]. On a trace with no long prompts and a
/// non-binding budget the control flow is step-for-step the same as
/// bucketed — the modes then produce identical reports.
fn simulate_continuous(
    trace: &Trace,
    svc: &mut dyn ServiceModel,
    opts: &SimOptions,
    chunk: usize,
) -> LoadReport {
    let db = opts.decode_batch.max(1);
    let max_pb = *opts.batch_buckets.iter().max().unwrap_or(&8);
    let max_seq = opts
        .seq_buckets
        .iter()
        .copied()
        .filter(|&s| s > 1)
        .max()
        .expect("sim needs a prefill seq bucket (> 1)");

    let mut report = LoadReport::new(trace.events.len(), opts.slo_ttft_s);
    let mut upcoming: VecDeque<SimReq> = VecDeque::new();
    let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
    seed_arrivals(trace, &mut upcoming, &mut pending);
    let think_s = trace.closed_loop.map(|cl| cl.think_s).unwrap_or(0.0);

    let mut now = 0.0f64;
    let mut waiting: VecDeque<SimReq> = VecDeque::new();
    let mut slots: Vec<Option<SimActive>> = vec![None; db];
    let mut chunk_job: Option<SimChunk> = None;
    let mut steps = 0usize;

    loop {
        steps += 1;
        if steps > MAX_SIM_STEPS {
            report.failed = report.submitted - report.completed;
            break;
        }
        // ---- intake ----
        while upcoming.front().is_some_and(|r| r.arrive_s <= now + 1e-12) {
            waiting.push_back(upcoming.pop_front().unwrap());
        }

        // ---- chunk lane: a long prompt at the FIFO head claims it
        // (and reserves a decode slot) when the lane is idle ----
        if chunk_job.is_none() && waiting.front().is_some_and(|r| r.prompt > chunk) {
            if let Some(slot) = (0..db).find(|&i| slots[i].is_none()) {
                let r = waiting.pop_front().expect("head exists");
                report.queue_wait.record(now - r.arrive_s);
                let plan = scheduler::chunk_plan(r.prompt, chunk, &opts.seq_buckets);
                debug_assert!(plan.len() > 1, "long prompt must chunk");
                // reserve the slot with a placeholder so grouped
                // admission cannot take it while the prompt prefills
                slots[slot] = Some(SimActive {
                    arrive_s: r.arrive_s,
                    first_token_s: f64::INFINITY,
                    out: r.out,
                    produced: 0,
                });
                chunk_job = Some(SimChunk { arrive_s: r.arrive_s, out: r.out, plan, next: 0, slot });
            }
        }

        // ---- grouped admission of short prompts, budget-capped ----
        // strict FIFO: the prefix stops at the first long prompt (it
        // waits for the chunk lane), exactly like the live loop
        let free: Vec<usize> = (0..db).filter(|&i| slots[i].is_none()).collect();
        let mut costs = Vec::new();
        for r in waiting.iter() {
            if r.prompt > chunk {
                break;
            }
            costs.push(r.prompt.min(max_seq));
        }
        let decoding = slots.iter().flatten().filter(|a| a.produced > 0).count();
        let committed = decoding
            + chunk_job.as_ref().map_or(0, |j| j.plan.get(j.next).copied().unwrap_or(0));
        let n_budget =
            scheduler::admit_budget(&costs, committed, opts.max_batch_tokens, free.len());
        let oldest_wait = waiting.front().map(|r| now - r.arrive_s).unwrap_or(0.0);
        let n_admit = scheduler::admit_count(costs.len(), free.len(), max_pb).min(n_budget);
        if scheduler::should_flush(oldest_wait, n_admit, free.len().min(8), opts.max_wait_s)
            && n_admit > 0
        {
            let admitted: Vec<SimReq> = waiting.drain(..n_admit).collect();
            let lens: Vec<usize> = admitted.iter().map(|r| r.prompt.min(max_seq)).collect();
            let (bb, sb) =
                scheduler::pick_prefill_bucket(&lens, &opts.batch_buckets, &opts.seq_buckets)
                    .expect("prompt fits the largest bucket after clamping");
            let dt = svc.prefill_s(bb, sb);
            let end = now + dt;
            for (i, r) in admitted.into_iter().enumerate() {
                report.queue_wait.record(now - r.arrive_s);
                if r.out <= 1 {
                    report.record(end - r.arrive_s, end - r.arrive_s, f64::NAN, f64::NAN, 1);
                    if let Some((p, o)) = pending.pop_front() {
                        upcoming.push_back(SimReq { arrive_s: end + think_s, prompt: p, out: o });
                    }
                } else {
                    slots[free[i]] = Some(SimActive {
                        arrive_s: r.arrive_s,
                        first_token_s: end,
                        out: r.out,
                        produced: 1,
                    });
                }
            }
            now = end;
            continue;
        }

        // ---- one chunk-lane slice, interleaved with decode ----
        let mut worked = false;
        if let Some(job) = chunk_job.as_mut() {
            worked = true;
            let sb = job.plan[job.next];
            now += svc.prefill_s(1, sb);
            job.next += 1;
            if job.next >= job.plan.len() {
                // last slice lands the first token
                let job = chunk_job.take().expect("job exists");
                if job.out <= 1 {
                    slots[job.slot] = None;
                    report.record(now - job.arrive_s, now - job.arrive_s, f64::NAN, f64::NAN, 1);
                    if let Some((p, o)) = pending.pop_front() {
                        upcoming.push_back(SimReq { arrive_s: now + think_s, prompt: p, out: o });
                    }
                } else {
                    slots[job.slot] = Some(SimActive {
                        arrive_s: job.arrive_s,
                        first_token_s: now,
                        out: job.out,
                        produced: 1,
                    });
                }
            }
        }

        // ---- decode step over sessions holding a first token ----
        if slots.iter().flatten().any(|a| a.produced > 0) {
            worked = true;
            now += svc.decode_s(db);
            for slot in slots.iter_mut() {
                let Some(a) = slot else { continue };
                if a.produced == 0 {
                    continue; // chunk-lane reservation, not decoding yet
                }
                a.produced += 1;
                if a.produced >= a.out {
                    let ttft = a.first_token_s - a.arrive_s;
                    let e2e = now - a.arrive_s;
                    let tpot = if a.produced > 1 {
                        (e2e - ttft) / (a.produced - 1) as f64
                    } else {
                        f64::NAN
                    };
                    report.record(ttft, e2e, tpot, f64::NAN, a.produced);
                    if let Some((p, o)) = pending.pop_front() {
                        upcoming.push_back(SimReq { arrive_s: now + think_s, prompt: p, out: o });
                    }
                    *slot = None;
                }
            }
        }
        if worked {
            continue;
        }

        // ---- idle: jump the virtual clock ----
        let flush_at = waiting.front().map(|r| r.arrive_s + opts.max_wait_s);
        let next_arrival = upcoming.front().map(|r| r.arrive_s);
        match (flush_at, next_arrival) {
            (Some(f), Some(a)) => now = f.min(a).max(now),
            (Some(f), None) => now = f.max(now),
            (None, Some(a)) => now = a.max(now),
            (None, None) => break, // drained
        }
    }
    report.makespan_s = now;
    report
}

// ---------------------------------------------------------------------
// Live wall-clock driver
// ---------------------------------------------------------------------

/// Options for the live driver.
#[derive(Debug, Clone, Copy)]
pub struct DriveOptions {
    /// TTFT SLO for the report's goodput
    pub slo_ttft_s: f64,
}

impl Default for DriveOptions {
    fn default() -> Self {
        DriveOptions { slo_ttft_s: 0.25 }
    }
}

/// Deterministic filler prompt of `n` byte-level tokens.
pub fn synth_prompt(n: usize) -> String {
    const TEXT: &[u8] = b"The quick brown fox jumps over the lazy dog. ";
    (0..n.max(1)).map(|i| TEXT[i % TEXT.len()] as char).collect()
}

fn gen_request(prompt_tokens: usize, max_new_tokens: usize) -> GenRequest {
    GenRequest {
        prompt: synth_prompt(prompt_tokens),
        max_new_tokens,
        greedy: true,
        stop_token: -1,
    }
}

fn record_response(report: &mut LoadReport, resp: &GenResponse) {
    report.record(resp.ttft_s, resp.e2e_s, resp.tpot_s, resp.queue_wait_s, resp.new_tokens);
}

/// Drive the live coordinator with `trace`. Open-loop traces are
/// submitted from a dedicated clock thread at their scheduled wall
/// times (submission is a non-blocking channel send, so slow
/// responses never distort the arrival process); closed-loop traces
/// keep `concurrency` requests outstanding. Returns the aggregated
/// [`LoadReport`].
pub fn drive(handle: &CoordinatorHandle, trace: &Trace, opts: &DriveOptions) -> LoadReport {
    let mut report = LoadReport::new(trace.events.len(), opts.slo_ttft_s);
    // bracket the run with time-series samples (and add one per
    // completion) so `GET /metrics/history` has edges to rate over even
    // when the run is shorter than the background sampler's period
    handle.metrics.sample_history();
    let t0 = Instant::now();
    if let Some(cl) = trace.closed_loop {
        // closed loop: `concurrency` outstanding; ANY completion (not
        // just the oldest) releases the next submission, matching the
        // virtual driver's semantics — otherwise one long request at
        // the window head would stall refills while other slots drain
        let mut events = trace.events.iter();
        let mut window: Vec<std::sync::mpsc::Receiver<GenResponse>> = Vec::new();
        for ev in events.by_ref().take(cl.concurrency.max(1)) {
            window.push(handle.submit(gen_request(ev.prompt_tokens, ev.max_new_tokens)));
        }
        while !window.is_empty() {
            let mut progressed = false;
            let mut i = 0;
            while i < window.len() {
                match window[i].try_recv() {
                    Ok(resp) => {
                        record_response(&mut report, &resp);
                        handle.metrics.sample_history();
                    }
                    Err(std::sync::mpsc::TryRecvError::Empty) => {
                        i += 1;
                        continue;
                    }
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        report.failed += 1;
                    }
                }
                window.swap_remove(i);
                progressed = true;
                if let Some(ev) = events.next() {
                    if cl.think_s > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(cl.think_s));
                    }
                    window.push(handle.submit(gen_request(ev.prompt_tokens, ev.max_new_tokens)));
                }
            }
            if !progressed {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
    } else {
        let mut events = trace.events.clone();
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::scope(|scope| {
            let clock_handle = handle.clone();
            scope.spawn(move || {
                for ev in events {
                    let target = std::time::Duration::from_secs_f64(ev.at_s.max(0.0));
                    let elapsed = t0.elapsed();
                    if target > elapsed {
                        std::thread::sleep(target - elapsed);
                    }
                    let r = clock_handle.submit(gen_request(ev.prompt_tokens, ev.max_new_tokens));
                    if tx.send(r).is_err() {
                        break;
                    }
                }
                drop(tx);
            });
            // collect on this thread while the clock thread submits
            for pending in rx {
                match pending.recv() {
                    Ok(resp) => {
                        record_response(&mut report, &resp);
                        handle.metrics.sample_history();
                    }
                    Err(_) => report.failed += 1,
                }
            }
        });
    }
    handle.metrics.sample_history();
    report.makespan_s = t0.elapsed().as_secs_f64();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{Arrival, LenDist, TraceSpec};

    fn trace(arrival: Arrival, n: usize) -> Trace {
        TraceSpec {
            arrival,
            prompt_len: LenDist::Uniform { lo: 8, hi: 200 },
            output_len: LenDist::Fixed(8),
            requests: n,
            seed: 42,
        }
        .generate()
    }

    #[test]
    fn sim_completes_everything_and_measures_queueing() {
        let mut svc = FixedService { prefill_s: 0.02, decode_s: 0.01 };
        let t = trace(Arrival::Poisson { rate: 20.0 }, 200);
        let r = simulate(&t, &mut svc, &SimOptions::default());
        assert_eq!(r.completed, 200);
        assert_eq!(r.failed, 0);
        assert_eq!(r.ttft.count(), 200);
        assert_eq!(r.queue_wait.count(), 200);
        assert!(r.makespan_s >= t.span_s());
        assert!(r.ttft.percentile(50.0).is_finite());
        assert!(r.e2e.percentile(95.0) >= r.ttft.percentile(50.0));
        assert!((0.0..=1.0).contains(&r.goodput()));
        // decode dominates: 8 tokens at 10ms steps ≥ 70ms e2e floor
        assert!(r.e2e.percentile(50.0) > 0.07, "{}", r.e2e.percentile(50.0));
    }

    #[test]
    fn sim_goodput_degrades_with_load() {
        let opts = SimOptions { slo_ttft_s: 0.1, ..SimOptions::default() };
        let g = |rate: f64| {
            let mut svc = FixedService { prefill_s: 0.03, decode_s: 0.015 };
            simulate(&trace(Arrival::Poisson { rate }, 300), &mut svc, &opts).goodput()
        };
        let light = g(1.0);
        let heavy = g(200.0);
        assert!(light > 0.9, "light load goodput {light}");
        assert!(heavy < 0.5, "overload goodput {heavy}");
    }

    #[test]
    fn sim_closed_loop_bounds_concurrency() {
        let mut svc = FixedService { prefill_s: 0.02, decode_s: 0.01 };
        let t = trace(Arrival::Closed { concurrency: 4, think_s: 0.0 }, 64);
        let r = simulate(&t, &mut svc, &SimOptions::default());
        assert_eq!(r.completed, 64);
        // closed loop self-paces: queue waits stay near zero
        assert!(r.queue_wait.percentile(95.0) < 0.2);
    }

    #[test]
    fn sim_faster_service_is_never_worse() {
        let t = trace(Arrival::Bursty { rate: 30.0, cv: 3.0 }, 250);
        let opts = SimOptions::default();
        let mut fast = FixedService { prefill_s: 0.01, decode_s: 0.005 };
        let mut slow = FixedService { prefill_s: 0.03, decode_s: 0.012 };
        let rf = simulate(&t, &mut fast, &opts);
        let rs = simulate(&t, &mut slow, &opts);
        assert!(rf.goodput() >= rs.goodput());
        // small slack: batch-formation timing can differ between the runs
        assert!(rf.ttft.percentile(95.0) <= rs.ttft.percentile(95.0) + 5e-3);
        assert!(rf.makespan_s <= rs.makespan_s + 1e-9);
    }

    fn trace_with(arrival: Arrival, n: usize, lo: usize, hi: usize) -> Trace {
        TraceSpec {
            arrival,
            prompt_len: LenDist::Uniform { lo, hi },
            output_len: LenDist::Fixed(8),
            requests: n,
            seed: 7,
        }
        .generate()
    }

    /// Prefill cost linear in padded tokens, decode in group width — the
    /// shape that makes bucket padding (and its removal) visible.
    struct TokenLinear;
    impl ServiceModel for TokenLinear {
        fn prefill_s(&mut self, batch: usize, seq: usize) -> f64 {
            1e-4 * (batch * seq) as f64
        }
        fn decode_s(&mut self, batch: usize) -> f64 {
            2e-4 * batch as f64
        }
    }

    #[test]
    fn sim_continuous_equals_bucketed_without_long_prompts() {
        // chunk = 128 under the default buckets/budget; with every
        // prompt at or below it the continuous loop never engages the
        // chunk lane and the budget never binds, so the two modes run
        // the exact same virtual-time steps
        let t = trace_with(Arrival::Poisson { rate: 30.0 }, 200, 8, 120);
        let mut svc_b = FixedService { prefill_s: 0.02, decode_s: 0.01 };
        let mut svc_c = svc_b;
        let bucketed = simulate(&t, &mut svc_b, &SimOptions::default());
        let opts = SimOptions { mode: BatchMode::Continuous, ..SimOptions::default() };
        let cont = simulate(&t, &mut svc_c, &opts);
        assert_eq!(cont.completed, bucketed.completed);
        assert_eq!(cont.makespan_s, bucketed.makespan_s);
        assert_eq!(cont.goodput(), bucketed.goodput());
        assert_eq!(cont.ttft.percentile(99.0), bucketed.ttft.percentile(99.0));
        assert_eq!(cont.queue_wait.percentile(95.0), bucketed.queue_wait.percentile(95.0));
    }

    #[test]
    fn sim_continuous_beats_bucketed_on_long_prompt_mixes() {
        // prompts span 8..240: roughly half exceed the 128-token chunk,
        // so bucketed drags every mixed cohort into the padded (8, 256)
        // shape while continuous prefills shorts in small buckets and
        // slices longs on the chunk lane
        let t = trace_with(Arrival::Poisson { rate: 25.0 }, 220, 8, 240);
        let mut svc_b = TokenLinear;
        let mut svc_c = TokenLinear;
        let opts_b = SimOptions { slo_ttft_s: 0.1, ..SimOptions::default() };
        let opts_c = SimOptions { mode: BatchMode::Continuous, ..opts_b.clone() };
        let bucketed = simulate(&t, &mut svc_b, &opts_b);
        let cont = simulate(&t, &mut svc_c, &opts_c);
        assert_eq!(bucketed.completed, 220);
        assert_eq!(cont.completed, 220);
        // strictly less padded prefill work: continuous must not lose
        // throughput, and median TTFT improves outright
        assert!(
            cont.qps() >= bucketed.qps() * 0.99,
            "continuous qps {} vs bucketed {}",
            cont.qps(),
            bucketed.qps()
        );
        assert!(
            cont.goodput() + 1e-9 >= bucketed.goodput(),
            "continuous goodput {} vs bucketed {}",
            cont.goodput(),
            bucketed.goodput()
        );
        assert!(
            cont.ttft.percentile(50.0) < bucketed.ttft.percentile(50.0),
            "continuous ttft p50 {} vs bucketed {}",
            cont.ttft.percentile(50.0),
            bucketed.ttft.percentile(50.0)
        );
    }

    #[test]
    fn sim_continuous_chunked_prompts_complete_with_finite_ttft() {
        // every prompt needs the chunk lane (all > 128); closed loop
        // keeps four outstanding so lane + decode interleave constantly
        let t = trace_with(Arrival::Closed { concurrency: 4, think_s: 0.0 }, 48, 150, 250);
        let mut svc = TokenLinear;
        let opts = SimOptions { mode: BatchMode::Continuous, ..SimOptions::default() };
        let r = simulate(&t, &mut svc, &opts);
        assert_eq!(r.completed, 48);
        assert_eq!(r.failed, 0);
        assert_eq!(r.ttft.count(), 48);
        assert_eq!(r.queue_wait.count(), 48);
        assert!(r.ttft.percentile(99.0).is_finite());
    }

    #[test]
    fn synth_prompt_is_byte_sized() {
        assert_eq!(synth_prompt(17).len(), 17);
        assert_eq!(synth_prompt(0).len(), 1);
        assert!(synth_prompt(100).is_ascii());
    }
}
