//! Arrival-process + length-distribution trace generation, and a JSONL
//! replay format.
//!
//! A trace is the workload's ground truth: *when* requests arrive and
//! *how big* they are. Generation composes an [`Arrival`] process
//! (Poisson, bursty/Gamma, closed-loop) with prompt/output
//! [`LenDist`]s (fixed, uniform, heavy-tailed "ShareGPT-like"
//! lognormal), all drawn from the deterministic [`crate::util::rng::Rng`]
//! — the same `(TraceSpec, seed)` always produces the bit-identical
//! trace (pinned by `tests/property_workload.rs`), so capacity
//! bisection compares policies on *exactly* the same request sequence.
//!
//! Replay: one JSON object per line,
//! `{"at_s":0.125,"prompt_tokens":48,"max_new_tokens":16}`, written by
//! [`Trace::to_jsonl`] and read by [`Trace::parse_jsonl`] (the format
//! `tpcc load --trace/--save-trace` speaks). Closed-loop is a
//! generator mode, not a replay format: its arrival times depend on
//! completions, so its JSONL round-trips as an open-loop trace.

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// One scheduled request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// arrival offset from trace start (seconds; 0 for closed-loop)
    pub at_s: f64,
    /// prompt length in tokens (byte-level: bytes)
    pub prompt_tokens: usize,
    pub max_new_tokens: usize,
}

/// Closed-loop parameters: `concurrency` outstanding requests, each
/// completion triggering the next submission after `think_s`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClosedLoop {
    pub concurrency: usize,
    pub think_s: f64,
}

/// A generated or replayed request trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Some(_) when the driver should run closed-loop instead of
    /// honouring `at_s`
    pub closed_loop: Option<ClosedLoop>,
}

/// Inter-arrival process.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// exponential inter-arrivals at `rate` requests/s
    Poisson { rate: f64 },
    /// Gamma inter-arrivals with mean `1/rate` and coefficient of
    /// variation `cv` (> 1 = burstier than Poisson; shape k = 1/cv²)
    Bursty { rate: f64, cv: f64 },
    /// closed loop: `concurrency` in flight, `think_s` between a
    /// completion and the next submission
    Closed { concurrency: usize, think_s: f64 },
}

impl Arrival {
    /// Parse the CLI spec: `poisson:RATE`, `bursty:RATE[:CV]`,
    /// `closed:CONCURRENCY[:THINK_S]`.
    ///
    /// ```
    /// use tpcc::workload::trace::Arrival;
    /// assert_eq!(Arrival::parse("poisson:4").unwrap(), Arrival::Poisson { rate: 4.0 });
    /// assert_eq!(Arrival::parse("bursty:8").unwrap(), Arrival::Bursty { rate: 8.0, cv: 3.0 });
    /// assert_eq!(
    ///     Arrival::parse("closed:16:0.5").unwrap(),
    ///     Arrival::Closed { concurrency: 16, think_s: 0.5 }
    /// );
    /// assert!(Arrival::parse("poisson:0").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<Arrival> {
        let mut parts = s.split(':');
        let kind = parts.next().unwrap_or("");
        let p1 = parts.next();
        let p2 = parts.next();
        anyhow::ensure!(parts.next().is_none(), "too many fields in arrival spec {s:?}");
        let f = |v: Option<&str>, what: &str| -> anyhow::Result<f64> {
            v.ok_or_else(|| anyhow::anyhow!("arrival spec {s:?} missing {what}"))?
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad {what} in arrival spec {s:?}"))
        };
        match kind {
            "poisson" => {
                let rate = f(p1, "rate")?;
                anyhow::ensure!(rate > 0.0, "poisson rate must be > 0");
                Ok(Arrival::Poisson { rate })
            }
            "bursty" => {
                let rate = f(p1, "rate")?;
                let cv = match p2 {
                    Some(_) => f(p2, "cv")?,
                    None => 3.0,
                };
                anyhow::ensure!(rate > 0.0 && cv > 0.0, "bursty rate and cv must be > 0");
                Ok(Arrival::Bursty { rate, cv })
            }
            "closed" => {
                let concurrency = f(p1, "concurrency")? as usize;
                let think_s = match p2 {
                    Some(_) => f(p2, "think_s")?,
                    None => 0.0,
                };
                anyhow::ensure!(concurrency > 0, "closed-loop concurrency must be > 0");
                anyhow::ensure!(think_s >= 0.0, "think_s must be >= 0");
                Ok(Arrival::Closed { concurrency, think_s })
            }
            _ => anyhow::bail!("unknown arrival process {s:?} (want poisson:R | bursty:R[:CV] | closed:N[:THINK])"),
        }
    }

    /// Compact display label (report headers).
    pub fn label(&self) -> String {
        match self {
            Arrival::Poisson { rate } => format!("poisson:{rate}"),
            Arrival::Bursty { rate, cv } => format!("bursty:{rate}:cv{cv}"),
            Arrival::Closed { concurrency, think_s } => {
                format!("closed:{concurrency}:think{think_s}")
            }
        }
    }
}

/// Token-length distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LenDist {
    Fixed(usize),
    /// uniform over [lo, hi] inclusive
    Uniform { lo: usize, hi: usize },
    /// heavy-tailed "ShareGPT-like": `median · exp(sigma · N(0,1))`,
    /// rounded and clamped to [1, cap]
    LogNormal { median: f64, sigma: f64, cap: usize },
}

impl LenDist {
    /// Parse the CLI spec: a bare number (fixed), `fixed:N`,
    /// `uniform:LO:HI`, `lognormal:MEDIAN:SIGMA[:CAP]`, or the
    /// `sharegpt` alias (lognormal median 48, σ 1.0, cap 224).
    ///
    /// ```
    /// use tpcc::workload::trace::LenDist;
    /// assert_eq!(LenDist::parse("64").unwrap(), LenDist::Fixed(64));
    /// assert_eq!(LenDist::parse("uniform:8:32").unwrap(), LenDist::Uniform { lo: 8, hi: 32 });
    /// assert!(matches!(LenDist::parse("sharegpt").unwrap(), LenDist::LogNormal { .. }));
    /// assert!(LenDist::parse("uniform:9:3").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<LenDist> {
        if let Ok(n) = s.parse::<usize>() {
            anyhow::ensure!(n > 0, "fixed length must be > 0");
            return Ok(LenDist::Fixed(n));
        }
        if s == "sharegpt" {
            return Ok(LenDist::LogNormal { median: 48.0, sigma: 1.0, cap: 224 });
        }
        let parts: Vec<&str> = s.split(':').collect();
        let usize_at = |i: usize| -> anyhow::Result<usize> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("length spec {s:?} missing field {i}"))?
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad field {i} in length spec {s:?}"))
        };
        let f64_at = |i: usize| -> anyhow::Result<f64> {
            parts
                .get(i)
                .ok_or_else(|| anyhow::anyhow!("length spec {s:?} missing field {i}"))?
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad field {i} in length spec {s:?}"))
        };
        match parts[0] {
            "fixed" => {
                let n = usize_at(1)?;
                anyhow::ensure!(n > 0, "fixed length must be > 0");
                Ok(LenDist::Fixed(n))
            }
            "uniform" => {
                let (lo, hi) = (usize_at(1)?, usize_at(2)?);
                anyhow::ensure!(lo > 0 && lo <= hi, "uniform wants 0 < lo <= hi");
                Ok(LenDist::Uniform { lo, hi })
            }
            "lognormal" => {
                let median = f64_at(1)?;
                let sigma = f64_at(2)?;
                let cap = if parts.len() > 3 { usize_at(3)? } else { 4 * median.ceil() as usize };
                anyhow::ensure!(median > 0.0 && sigma >= 0.0 && cap > 0, "bad lognormal params");
                Ok(LenDist::LogNormal { median, sigma, cap })
            }
            _ => anyhow::bail!(
                "unknown length distribution {s:?} (want N | fixed:N | uniform:LO:HI | lognormal:MED:SIGMA[:CAP] | sharegpt)"
            ),
        }
    }

    /// Draw one length (always >= 1).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Fixed(n) => n.max(1),
            LenDist::Uniform { lo, hi } => lo + rng.below(hi - lo + 1),
            LenDist::LogNormal { median, sigma, cap } => {
                let v = median * (sigma * rng.normal() as f64).exp();
                (v.round() as usize).clamp(1, cap.max(1))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            LenDist::Fixed(n) => format!("fixed:{n}"),
            LenDist::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
            LenDist::LogNormal { median, sigma, cap } => {
                format!("lognormal:{median}:{sigma}:{cap}")
            }
        }
    }
}

/// Everything needed to (re)generate a trace deterministically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    pub arrival: Arrival,
    pub prompt_len: LenDist,
    pub output_len: LenDist,
    pub requests: usize,
    pub seed: u64,
}

impl TraceSpec {
    /// Generate the trace. Same spec + seed → bit-identical events.
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.seed);
        let mut events = Vec::with_capacity(self.requests);
        let mut t = 0.0f64;
        for _ in 0..self.requests {
            let at_s = match self.arrival {
                Arrival::Poisson { rate } => {
                    t += rng.exponential(rate);
                    t
                }
                Arrival::Bursty { rate, cv } => {
                    // Gamma(k, θ) with k = 1/cv², θ = 1/(rate·k):
                    // mean 1/rate, squared-CV cv²
                    let k = 1.0 / (cv * cv);
                    t += gamma(&mut rng, k) / (rate * k);
                    t
                }
                Arrival::Closed { .. } => 0.0,
            };
            events.push(TraceEvent {
                at_s,
                prompt_tokens: self.prompt_len.sample(&mut rng),
                max_new_tokens: self.output_len.sample(&mut rng),
            });
        }
        let closed_loop = match self.arrival {
            Arrival::Closed { concurrency, think_s } => {
                Some(ClosedLoop { concurrency, think_s })
            }
            _ => None,
        };
        Trace { events, closed_loop }
    }
}

/// Gamma(shape, 1) via Marsaglia–Tsang, boosted for shape < 1.
fn gamma(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        // Gamma(k) = Gamma(k+1) · U^(1/k)
        return gamma(rng, shape + 1.0) * rng.f64().max(1e-12).powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal() as f64;
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.f64().max(1e-300);
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v3;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
            return d * v3;
        }
    }
}

impl Trace {
    /// Serialize as JSONL (one event object per line).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in &self.events {
            out.push_str(
                &json::obj(vec![
                    ("at_s", json::num(ev.at_s)),
                    ("prompt_tokens", json::num(ev.prompt_tokens as f64)),
                    ("max_new_tokens", json::num(ev.max_new_tokens as f64)),
                ])
                .to_string(),
            );
            out.push('\n');
        }
        out
    }

    /// Parse the JSONL replay format (blank lines ignored). Events are
    /// sorted by arrival time; the result is an open-loop trace.
    pub fn parse_jsonl(s: &str) -> anyhow::Result<Trace> {
        let mut events = Vec::new();
        for (lineno, line) in s.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let doc = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
            let at_s = doc
                .get("at_s")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("trace line {}: missing at_s", lineno + 1))?;
            anyhow::ensure!(
                at_s.is_finite() && at_s >= 0.0,
                "trace line {}: at_s must be finite and >= 0",
                lineno + 1
            );
            // lengths are required: silently defaulting a missing or
            // mistyped field would turn a foreign trace into a
            // degenerate 1-token workload with no error
            let len_field = |key: &str| -> anyhow::Result<usize> {
                let n = doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                    anyhow::anyhow!("trace line {}: missing numeric {key}", lineno + 1)
                })?;
                anyhow::ensure!(n >= 1, "trace line {}: {key} must be >= 1", lineno + 1);
                Ok(n)
            };
            events.push(TraceEvent {
                at_s,
                prompt_tokens: len_field("prompt_tokens")?,
                max_new_tokens: len_field("max_new_tokens")?,
            });
        }
        anyhow::ensure!(!events.is_empty(), "trace file holds no events");
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        Ok(Trace { events, closed_loop: None })
    }

    /// Largest arrival offset (0 for closed-loop traces).
    pub fn span_s(&self) -> f64 {
        self.events.last().map(|e| e.at_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(arrival: Arrival) -> TraceSpec {
        TraceSpec {
            arrival,
            prompt_len: LenDist::Uniform { lo: 8, hi: 64 },
            output_len: LenDist::Fixed(16),
            requests: 400,
            seed: 7,
        }
    }

    #[test]
    fn poisson_mean_interarrival() {
        let t = spec(Arrival::Poisson { rate: 5.0 }).generate();
        assert_eq!(t.events.len(), 400);
        let mean_gap = t.span_s() / 400.0;
        assert!((mean_gap - 0.2).abs() < 0.04, "mean gap {mean_gap}");
        // arrivals are nondecreasing
        for w in t.events.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        let cv_of = |t: &Trace| {
            let gaps: Vec<f64> = t
                .events
                .windows(2)
                .map(|w| w[1].at_s - w[0].at_s)
                .collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            var.sqrt() / m
        };
        let p = spec(Arrival::Poisson { rate: 5.0 }).generate();
        let b = spec(Arrival::Bursty { rate: 5.0, cv: 4.0 }).generate();
        assert!(cv_of(&b) > 1.3 * cv_of(&p), "bursty cv {} vs poisson {}", cv_of(&b), cv_of(&p));
        // bursty keeps roughly the requested mean rate
        let mean_gap = b.span_s() / 400.0;
        assert!((mean_gap - 0.2).abs() < 0.1, "bursty mean gap {mean_gap}");
    }

    #[test]
    fn closed_loop_marks_trace() {
        let t = spec(Arrival::Closed { concurrency: 8, think_s: 0.1 }).generate();
        assert_eq!(t.closed_loop, Some(ClosedLoop { concurrency: 8, think_s: 0.1 }));
        assert!(t.events.iter().all(|e| e.at_s == 0.0));
    }

    #[test]
    fn lognormal_clamps_and_spreads() {
        let d = LenDist::LogNormal { median: 32.0, sigma: 1.0, cap: 128 };
        let mut rng = Rng::new(3);
        let samples: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        assert!(samples.iter().all(|&s| (1..=128).contains(&s)));
        assert!(samples.iter().any(|&s| s == 128), "cap never hit");
        assert!(samples.iter().any(|&s| s < 16), "no small samples");
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        assert!((20.0..=44.0).contains(&median), "median {median}");
    }

    #[test]
    fn parse_specs() {
        assert!(Arrival::parse("bursty:2:0").is_err());
        assert!(Arrival::parse("closed:0").is_err());
        assert!(Arrival::parse("uniform:1").is_err());
        assert!(LenDist::parse("fixed:0").is_err());
        assert!(LenDist::parse("lognormal:32").is_err());
        assert_eq!(
            LenDist::parse("lognormal:32:0.5").unwrap(),
            LenDist::LogNormal { median: 32.0, sigma: 0.5, cap: 128 }
        );
    }
}
