//! Byte-level tokenizer (vocab 256) matching the build-time training
//! (`python/compile/train.py` trains on raw UTF-8 bytes).

/// Byte-level: every u8 is a token id. Infallible, reversible for valid
/// UTF-8 inputs; decoding is lossy for invalid sequences.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "The parish church of Oakhaven, rebuilt in 1450.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo – ö";
        let ids = t.encode(s);
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
        assert_eq!(t.decode(&ids), s);
    }

    #[test]
    fn clamps_out_of_range() {
        let t = ByteTokenizer;
        // 300 clamps to byte 255 (invalid UTF-8 alone -> replacement
        // char under lossy decoding); -5 clamps to NUL.
        assert_eq!(t.decode(&[72, 300, -5, 105]), "H\u{fffd}\u{0}i");
    }
}
