//! Policy spec parsing, serialisation and the resolved per-site table.
//!
//! Grammar of the compact CLI spec (clauses separated by `;`, later
//! clauses override earlier ones — last match wins):
//!
//! ```text
//! policy   := clause (';' clause)*
//! clause   := selector '=' scheme
//! selector := 'default' | 'all' | '*' | atom ('.' atom)*
//! atom     := 'attn' | 'mlp' | 'prefill' | 'decode' | 'layers[' ranges ']'
//! ranges   := range (',' range)*      range := INT | INT '-' INT
//! scheme   := any compressor spec ('none', 'fp4_e2m1_b32_e8m0',
//!             'int4_channelwise', 'topk3', ...)
//! ```
//!
//! `default=` sets the base scheme for unmatched sites (position
//! independent); `all=`/`*=` is an ordinary match-everything *rule*, so
//! placed last it overrides every earlier clause like any other rule.
//!
//! `uniform:<scheme>` and a bare compressor spec are shorthands for a
//! policy with no rules (every site gets `<scheme>` — the seed path).

use crate::util::json::{self, Json};

use super::{Phase, Site, SiteKind};

/// Validate a compressor spec string without binding it to a tensor
/// shape (`none` is the engine's uncompressed path; everything else
/// must parse as a [`crate::mxfmt::Compressor`] spec).
pub fn validate_spec(spec: &str) -> anyhow::Result<()> {
    if spec == "none" {
        return Ok(());
    }
    // the channel count only affects scale granularity, not validity
    crate::mxfmt::compressor_from_spec_ch(spec, 64).map(|_| ())
}

/// A predicate over [`Site`]s: unset dimensions match everything.
///
/// ```
/// use tpcc::policy::{Phase, Selector, Site, SiteKind};
/// let sel = Selector::parse("layers[0-1,7].mlp").unwrap();
/// let hit = Site { layer: 7, kind: SiteKind::MlpOut, phase: Phase::Decode };
/// let miss = Site { layer: 7, kind: SiteKind::AttnOut, phase: Phase::Decode };
/// assert!(sel.matches(hit) && !sel.matches(miss));
/// ```
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Selector {
    /// match only this collective kind (attn / mlp)
    pub kind: Option<SiteKind>,
    /// match only this serving phase (prefill / decode)
    pub phase: Option<Phase>,
    /// match only these layers (inclusive ranges)
    pub layers: Option<Vec<(usize, usize)>>,
}

impl Selector {
    /// Parse a `.`-joined atom list (see the module grammar).
    pub fn parse(s: &str) -> anyhow::Result<Selector> {
        let mut sel = Selector::default();
        for atom in s.split('.') {
            match atom {
                "attn" => set_once(&mut sel.kind, SiteKind::AttnOut, atom)?,
                "mlp" => set_once(&mut sel.kind, SiteKind::MlpOut, atom)?,
                "prefill" => set_once(&mut sel.phase, Phase::Prefill, atom)?,
                "decode" => set_once(&mut sel.phase, Phase::Decode, atom)?,
                a if a.starts_with("layers[") && a.ends_with(']') => {
                    let body = &a["layers[".len()..a.len() - 1];
                    let mut ranges = Vec::new();
                    for part in body.split(',') {
                        let part = part.trim();
                        anyhow::ensure!(!part.is_empty(), "empty layer range in {s:?}");
                        let (lo, hi) = match part.split_once('-') {
                            Some((a, b)) => (a.trim().parse()?, b.trim().parse()?),
                            None => {
                                let v: usize = part.parse()?;
                                (v, v)
                            }
                        };
                        anyhow::ensure!(lo <= hi, "inverted layer range {part:?} in {s:?}");
                        ranges.push((lo, hi));
                    }
                    anyhow::ensure!(
                        sel.layers.replace(ranges).is_none(),
                        "duplicate layers[..] atom in {s:?}"
                    );
                }
                _ => anyhow::bail!(
                    "unknown selector atom {atom:?} (want attn|mlp|prefill|decode|layers[..])"
                ),
            }
        }
        Ok(sel)
    }

    /// Does this selector match `site`?
    pub fn matches(&self, site: Site) -> bool {
        if self.kind.is_some_and(|k| k != site.kind) {
            return false;
        }
        if self.phase.is_some_and(|p| p != site.phase) {
            return false;
        }
        if let Some(ranges) = &self.layers {
            return ranges.iter().any(|&(lo, hi)| lo <= site.layer && site.layer <= hi);
        }
        true
    }

    /// Canonical spec-string form (inverse of [`Selector::parse`]).
    pub fn to_spec_string(&self) -> String {
        let mut atoms = Vec::new();
        if let Some(ranges) = &self.layers {
            let body: Vec<String> = ranges
                .iter()
                .map(|&(lo, hi)| if lo == hi { lo.to_string() } else { format!("{lo}-{hi}") })
                .collect();
            atoms.push(format!("layers[{}]", body.join(",")));
        }
        if let Some(k) = self.kind {
            atoms.push(k.name().to_string());
        }
        if let Some(p) = self.phase {
            atoms.push(p.name().to_string());
        }
        if atoms.is_empty() {
            "all".to_string()
        } else {
            atoms.join(".")
        }
    }
}

fn set_once<T>(slot: &mut Option<T>, value: T, atom: &str) -> anyhow::Result<()> {
    anyhow::ensure!(slot.replace(value).is_none(), "conflicting selector atom {atom:?}");
    Ok(())
}

/// A rule-based per-site compression policy: an ordered list of
/// `(selector, scheme)` rules over a default scheme. Resolution is
/// last-match-wins; sites no rule matches get the default.
///
/// ```
/// use tpcc::policy::{CompressionPolicy, Phase, Site, SiteKind};
/// let p = CompressionPolicy::parse("mlp=fp4_e2m1_b32_e8m0;layers[0]=none").unwrap();
/// let t = p.table(2);
/// let mlp1 = Site { layer: 1, kind: SiteKind::MlpOut, phase: Phase::Prefill };
/// let mlp0 = Site { layer: 0, kind: SiteKind::MlpOut, phase: Phase::Prefill };
/// let attn1 = Site { layer: 1, kind: SiteKind::AttnOut, phase: Phase::Prefill };
/// assert_eq!(t.spec(mlp1), "fp4_e2m1_b32_e8m0");
/// assert_eq!(t.spec(mlp0), "none"); // layers[0] rule came later: it wins
/// assert_eq!(t.spec(attn1), "none"); // default
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CompressionPolicy {
    /// scheme for sites no rule matches
    pub default_spec: String,
    /// ordered rules; the last matching rule wins
    pub rules: Vec<(Selector, String)>,
}

impl CompressionPolicy {
    /// The seed-equivalent policy: every site gets `spec`.
    pub fn uniform(spec: &str) -> CompressionPolicy {
        CompressionPolicy { default_spec: spec.to_string(), rules: Vec::new() }
    }

    /// Parse a policy spec with `"none"` as the base default.
    /// See [`CompressionPolicy::parse_with_default`].
    pub fn parse(s: &str) -> anyhow::Result<CompressionPolicy> {
        Self::parse_with_default(s, "none")
    }

    /// Parse a policy spec string. `base_default` seeds the default
    /// scheme (the engine passes its `--compress` spec, so a partial
    /// policy like `attn=none` leaves the remaining sites on the
    /// engine-wide scheme); an explicit `default=` clause overrides it,
    /// while `all=`/`*=` adds a match-everything *rule* (position
    /// dependent, like any other clause).
    ///
    /// Accepted forms: `uniform:<scheme>`, a bare compressor spec, or
    /// the `;`-separated clause grammar (module docs).
    pub fn parse_with_default(s: &str, base_default: &str) -> anyhow::Result<CompressionPolicy> {
        let s = s.trim();
        if let Some(spec) = s.strip_prefix("uniform:") {
            validate_spec(spec)?;
            return Ok(Self::uniform(spec));
        }
        if !s.contains('=') {
            anyhow::ensure!(!s.is_empty(), "empty policy spec");
            validate_spec(s)
                .map_err(|e| anyhow::anyhow!("policy spec {s:?} is not a compressor spec: {e}"))?;
            return Ok(Self::uniform(s));
        }
        let mut default_spec = base_default.to_string();
        let mut rules = Vec::new();
        for clause in s.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            let (sel, scheme) = clause
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("policy clause {clause:?} missing '='"))?;
            let (sel, scheme) = (sel.trim(), scheme.trim());
            validate_spec(scheme)?;
            match sel {
                "default" => default_spec = scheme.to_string(),
                // a match-everything rule: position in the clause list
                // matters (last match wins), unlike `default=`
                "all" | "*" => rules.push((Selector::default(), scheme.to_string())),
                _ => rules.push((Selector::parse(sel)?, scheme.to_string())),
            }
        }
        Ok(CompressionPolicy { default_spec, rules })
    }

    /// Resolve one site (last matching rule wins, else the default).
    pub fn resolve(&self, site: Site) -> &str {
        self.rules
            .iter()
            .rev()
            .find(|(sel, _)| sel.matches(site))
            .map(|(_, spec)| spec.as_str())
            .unwrap_or(&self.default_spec)
    }

    /// Fully resolve the policy for an `n_layers` model.
    pub fn table(&self, n_layers: usize) -> PolicyTable {
        let specs = Site::all(n_layers).into_iter().map(|s| self.resolve(s).to_string()).collect();
        PolicyTable { name: self.to_spec_string(), n_layers, specs }
    }

    /// Canonical compact spec string (parses back to an equivalent
    /// policy).
    pub fn to_spec_string(&self) -> String {
        if self.rules.is_empty() {
            return format!("uniform:{}", self.default_spec);
        }
        let mut out = vec![format!("default={}", self.default_spec)];
        for (sel, spec) in &self.rules {
            out.push(format!("{}={}", sel.to_spec_string(), spec));
        }
        out.join(";")
    }
}

/// A fully resolved per-site scheme assignment — what the engine binds.
/// Built from a [`CompressionPolicy`], or directly by the `paper` /
/// `auto` searches.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyTable {
    /// provenance label (`uniform:<spec>`, `paper`, `auto`, or the
    /// canonical rule string)
    pub name: String,
    /// layer count of the model this table resolves
    pub n_layers: usize,
    /// per-site spec, indexed by [`Site::index`]
    specs: Vec<String>,
}

impl PolicyTable {
    /// Every site on one scheme (the seed-equivalent table).
    pub fn uniform(n_layers: usize, spec: &str) -> PolicyTable {
        PolicyTable {
            name: format!("uniform:{spec}"),
            n_layers,
            specs: vec![spec.to_string(); Site::count(n_layers)],
        }
    }

    /// Build from an explicit per-site assignment (callers: the
    /// `paper`/`auto` searches). `specs` must have one entry per
    /// [`Site::index`] of an `n_layers` model.
    pub fn from_specs(name: &str, n_layers: usize, specs: Vec<String>) -> anyhow::Result<PolicyTable> {
        anyhow::ensure!(
            specs.len() == Site::count(n_layers),
            "policy table wants {} specs, got {}",
            Site::count(n_layers),
            specs.len()
        );
        Ok(PolicyTable { name: name.to_string(), n_layers, specs })
    }

    /// The scheme bound at `site`.
    pub fn spec(&self, site: Site) -> &str {
        &self.specs[site.index()]
    }

    /// Reassign one site.
    pub fn set(&mut self, site: Site, spec: &str) {
        self.specs[site.index()] = spec.to_string();
    }

    /// Sorted, deduplicated list of schemes the table uses.
    pub fn distinct(&self) -> Vec<String> {
        let mut d = self.specs.clone();
        d.sort();
        d.dedup();
        d
    }

    /// `Some(spec)` when every site is on the same scheme.
    pub fn is_uniform(&self) -> Option<&str> {
        let first = self.specs.first()?;
        self.specs.iter().all(|s| s == first).then_some(first.as_str())
    }

    /// Scheme histogram: `(spec, site count)` sorted by count, then
    /// name (deterministic) — the table summaries in `tpcc table6`.
    pub fn histogram(&self) -> Vec<(String, usize)> {
        let mut h: Vec<(String, usize)> = Vec::new();
        for spec in self.distinct() {
            let n = self.specs.iter().filter(|s| **s == spec).count();
            h.push((spec, n));
        }
        h.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        h
    }

    /// One-line description for telemetry and table rows.
    pub fn summary(&self) -> String {
        match self.is_uniform() {
            Some(spec) => format!("uniform:{spec}"),
            None => {
                let parts: Vec<String> = self
                    .histogram()
                    .into_iter()
                    .map(|(spec, n)| format!("{spec}:{n}"))
                    .collect();
                format!("{}{{{}}}", self.name, parts.join(","))
            }
        }
    }

    /// JSON serialisation served by the coordinator's `GET /policy`.
    pub fn to_json(&self) -> Json {
        let mut sites = std::collections::BTreeMap::new();
        for site in Site::all(self.n_layers) {
            sites.insert(site.label(), json::s(self.spec(site)));
        }
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("n_layers", json::num(self.n_layers as f64)),
            ("distinct", json::arr(self.distinct().iter().map(|s| json::s(s)).collect())),
            ("sites", Json::Obj(sites)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site(layer: usize, kind: SiteKind, phase: Phase) -> Site {
        Site { layer, kind, phase }
    }

    #[test]
    fn parse_issue_example() {
        // the spec shape from the issue, with real scheme names
        let p = CompressionPolicy::parse(
            "mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0,3]=none;decode=none",
        )
        .unwrap();
        let t = p.table(4);
        assert_eq!(t.spec(site(1, SiteKind::MlpOut, Phase::Prefill)), "fp4_e2m1_b32_e8m0");
        assert_eq!(t.spec(site(1, SiteKind::AttnOut, Phase::Prefill)), "none");
        // first/last layer exempt, decode exempt
        assert_eq!(t.spec(site(0, SiteKind::MlpOut, Phase::Prefill)), "none");
        assert_eq!(t.spec(site(3, SiteKind::MlpOut, Phase::Prefill)), "none");
        assert_eq!(t.spec(site(1, SiteKind::MlpOut, Phase::Decode)), "none");
    }

    #[test]
    fn last_match_wins_and_default_applies() {
        let p = CompressionPolicy::parse(
            "default=fp5_e2m2_b32_e8m0;mlp=fp4_e2m1_b32_e8m0;mlp.decode=none",
        )
        .unwrap();
        let t = p.table(2);
        assert_eq!(t.spec(site(0, SiteKind::MlpOut, Phase::Prefill)), "fp4_e2m1_b32_e8m0");
        assert_eq!(t.spec(site(0, SiteKind::MlpOut, Phase::Decode)), "none");
        assert_eq!(t.spec(site(0, SiteKind::AttnOut, Phase::Decode)), "fp5_e2m2_b32_e8m0");
    }

    #[test]
    fn all_clause_is_a_last_match_wins_rule() {
        // `all=` placed last overrides every earlier rule ...
        let p = CompressionPolicy::parse("mlp=fp4_e2m1_b32_e8m0;all=none").unwrap();
        let t = p.table(2);
        assert_eq!(t.is_uniform(), Some("none"));
        // ... and placed first it is overridden by later rules
        let p = CompressionPolicy::parse("*=none;mlp=fp4_e2m1_b32_e8m0").unwrap();
        let t = p.table(2);
        assert_eq!(t.spec(site(0, SiteKind::MlpOut, Phase::Prefill)), "fp4_e2m1_b32_e8m0");
        assert_eq!(t.spec(site(0, SiteKind::AttnOut, Phase::Prefill)), "none");
        // a manually built empty selector serialises to `all=` and
        // round-trips as the same match-everything rule
        let manual = CompressionPolicy {
            default_spec: "none".into(),
            rules: vec![
                (Selector { kind: Some(SiteKind::MlpOut), ..Default::default() }, "fp16".into()),
                (Selector::default(), "none".into()),
            ],
        };
        let re = CompressionPolicy::parse(&manual.to_spec_string()).unwrap();
        assert_eq!(manual.table(3), re.table(3));
        assert_eq!(re.table(3).is_uniform(), Some("none"));
    }

    #[test]
    fn uniform_forms() {
        for s in ["uniform:fp4_e2m1_b32_e8m0", "fp4_e2m1_b32_e8m0"] {
            let p = CompressionPolicy::parse(s).unwrap();
            let t = p.table(3);
            assert_eq!(t.is_uniform(), Some("fp4_e2m1_b32_e8m0"));
        }
        assert_eq!(
            CompressionPolicy::parse("uniform:none").unwrap().table(2).is_uniform(),
            Some("none")
        );
    }

    #[test]
    fn parse_with_engine_default() {
        let p = CompressionPolicy::parse_with_default("attn=none", "fp4_e2m1_b32_e8m0").unwrap();
        let t = p.table(2);
        assert_eq!(t.spec(site(0, SiteKind::AttnOut, Phase::Prefill)), "none");
        assert_eq!(t.spec(site(0, SiteKind::MlpOut, Phase::Prefill)), "fp4_e2m1_b32_e8m0");
    }

    #[test]
    fn serialize_roundtrip() {
        for s in [
            "uniform:none",
            "uniform:fp4_e2m1_b32_e8m0",
            "mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0-1,3]=none;decode=none",
            "default=fp5_e2m2_b16_e8m0;layers[2].mlp.prefill=int4_channelwise",
        ] {
            let p = CompressionPolicy::parse(s).unwrap();
            let p2 = CompressionPolicy::parse(&p.to_spec_string()).unwrap();
            assert_eq!(p.to_spec_string(), p2.to_spec_string());
            for n_layers in [1usize, 4, 9] {
                assert_eq!(p.table(n_layers), p2.table(n_layers));
            }
        }
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(CompressionPolicy::parse("").is_err());
        assert!(CompressionPolicy::parse("bogus_scheme").is_err());
        assert!(CompressionPolicy::parse("mlp=bogus_scheme").is_err());
        assert!(CompressionPolicy::parse("sideways=none").is_err());
        assert!(Selector::parse("layers[3-1]").is_err());
        assert!(Selector::parse("attn.mlp").is_err());
        assert!(Selector::parse("layers[]").is_err());
    }

    #[test]
    fn histogram_and_summary() {
        let p = CompressionPolicy::parse("mlp=fp4_e2m1_b32_e8m0").unwrap();
        let t = p.table(2);
        let h = t.histogram();
        assert_eq!(h.len(), 2);
        assert_eq!(h[0].1 + h[1].1, Site::count(2));
        assert!(t.summary().contains("fp4_e2m1_b32_e8m0"));
        assert!(t.is_uniform().is_none());
    }

    #[test]
    fn json_shape() {
        let t = PolicyTable::uniform(2, "none");
        let j = t.to_json();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("uniform:none"));
        assert_eq!(j.get("n_layers").and_then(|v| v.as_i64()), Some(2));
        let sites = j.get("sites").unwrap().as_obj().unwrap();
        assert_eq!(sites.len(), Site::count(2));
        assert_eq!(sites.get("l0.attn.prefill").and_then(|v| v.as_str()), Some("none"));
    }
}
