//! Per-site calibration data and the per-scheme reconstruction error
//! measured on it.
//!
//! The `paper` and `auto` policies need an error signal per site: how
//! much does quantizing *this* site's partials with *this* scheme
//! perturb the reduced activation? Two sources feed it:
//!
//! * **Synthetic** ([`Calibration::synthetic`]) — deterministic
//!   activation-shaped samples (normal with lognormal magnitude spread,
//!   the distribution the MX schemes target) whose spread varies by
//!   site: MLP outputs are heavier-tailed than attention outputs, and
//!   the spread grows with depth, mirroring the residual-stream growth
//!   real transformers exhibit. No artifacts needed.
//! * **Captured** ([`Calibration::from_samples`]) — real pre-quantization
//!   partials recorded by `TpEngine::capture_calibration` during a
//!   calibration forward pass (prefill + one decode step).
//!
//! The error metric is what the collective actually does: every rank's
//! sample is fake-quantized (`requant_add`) into an accumulator and the
//! result compared against the exact sum — relative RMS error.

use crate::mxfmt::{compressor_from_spec_ch, Compressor};
use crate::util::rng::Rng;

use super::{Phase, Site, SiteKind};

/// Target per-site sample length (values). Samples are rounded to a
/// multiple of `d_model` when it fits (keeps channel-wise schemes
/// meaningful) and are always a multiple of 32 (the largest MX block).
/// Sized so a full 80-layer site grid scores in seconds even in debug
/// builds (the Table 6 tests run it).
const TARGET_SAMPLE_VALUES: usize = 512;

/// Per-site, per-rank activation samples used to score schemes.
pub struct Calibration {
    pub n_layers: usize,
    pub d_model: usize,
    /// TP world size (ranks per site sample)
    pub world: usize,
    /// `[site index][rank][value]` pre-quantization partials
    samples: Vec<Vec<Vec<f32>>>,
}

impl Calibration {
    /// Sample length used for a hidden size of `d_model` (multiple of
    /// `d_model` when `d_model <= TARGET`, else a block-aligned cut).
    pub fn sample_len(d_model: usize) -> usize {
        let len = if d_model == 0 || d_model > TARGET_SAMPLE_VALUES {
            TARGET_SAMPLE_VALUES
        } else {
            d_model * (TARGET_SAMPLE_VALUES / d_model).max(1)
        };
        // clamp to a multiple of the largest MX block
        (len / 32).max(1) * 32
    }

    /// Deterministic activation-shaped calibration set (no artifacts
    /// required). `seed` pins the sample; equal seeds give bit-equal
    /// calibrations.
    pub fn synthetic(n_layers: usize, d_model: usize, world: usize, seed: u64) -> Calibration {
        let len = Self::sample_len(d_model);
        let world = world.max(1);
        let mut samples = Vec::with_capacity(Site::count(n_layers));
        for site in Site::all(n_layers) {
            // heavier tails on MLP outputs, growing with depth: the
            // sites the paper leaves uncompressed are the ones whose
            // outliers make low-bit blocks expensive
            let base = match site.kind {
                SiteKind::AttnOut => 1.4f32,
                SiteKind::MlpOut => 2.2f32,
            };
            let depth = 1.0 + 0.8 * site.layer as f32 / n_layers.max(1) as f32;
            let spread = base * depth;
            let mut per_rank = Vec::with_capacity(world);
            for rank in 0..world {
                let mut rng = Rng::new(
                    seed ^ (site.index() as u64).wrapping_mul(0x9E37_79B9)
                        ^ (rank as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9),
                );
                let mut v = vec![0.0f32; len];
                rng.fill_activations(&mut v, spread);
                per_rank.push(v);
            }
            samples.push(per_rank);
        }
        Calibration { n_layers, d_model, world, samples }
    }

    /// Build from captured per-site samples (`[site][rank][value]`,
    /// indexed by [`Site::index`]). Decode sites the capture pass never
    /// reached fall back to their prefill twin's sample.
    pub fn from_samples(
        n_layers: usize,
        d_model: usize,
        mut samples: Vec<Vec<Vec<f32>>>,
    ) -> anyhow::Result<Calibration> {
        anyhow::ensure!(
            samples.len() == Site::count(n_layers),
            "capture has {} site slots, want {}",
            samples.len(),
            Site::count(n_layers)
        );
        for site in Site::all(n_layers) {
            if samples[site.index()].is_empty() && site.phase == Phase::Decode {
                let twin = Site { phase: Phase::Prefill, ..site };
                samples[site.index()] = samples[twin.index()].clone();
            }
            anyhow::ensure!(
                !samples[site.index()].is_empty(),
                "calibration pass never reached site {}",
                site.label()
            );
        }
        let world = samples[0].len();
        Ok(Calibration { n_layers, d_model, world, samples })
    }

    /// The per-rank samples captured for `site`.
    pub fn sample(&self, site: Site) -> &[Vec<f32>] {
        &self.samples[site.index()]
    }

    /// Relative RMS error of the compressed reduce at `site`:
    /// `||Q-reduce - exact-reduce|| / ||exact-reduce||`. `None` (the
    /// uncompressed path) is exact by definition.
    pub fn site_error(&self, site: Site, comp: Option<&dyn Compressor>) -> f64 {
        let Some(c) = comp else { return 0.0 };
        let ranks = &self.samples[site.index()];
        let len = ranks[0].len();
        let mut exact = vec![0.0f32; len];
        for r in ranks {
            for (e, v) in exact.iter_mut().zip(r) {
                *e += v;
            }
        }
        let mut acc = vec![0.0f32; len];
        let mut scratch = Vec::new();
        for r in ranks {
            c.requant_add(r, &mut acc, &mut scratch);
        }
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..len {
            num += ((acc[i] - exact[i]) as f64).powi(2);
            den += (exact[i] as f64).powi(2);
        }
        if den <= 0.0 {
            return 0.0;
        }
        (num / den).sqrt()
    }

    /// [`Calibration::site_error`] for a spec string (builds the
    /// compressor with this calibration's channel count).
    pub fn scheme_error(&self, site: Site, spec: &str) -> anyhow::Result<f64> {
        if spec == "none" {
            return Ok(0.0);
        }
        let c = compressor_from_spec_ch(spec, self.d_model)?;
        Ok(self.site_error(site, Some(c.as_ref())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::{MxCodec, MxScheme, NoCompress};

    #[test]
    fn sample_len_is_aligned() {
        for d in [0usize, 64, 192, 256, 1024, 4096, 8192] {
            let len = Calibration::sample_len(d);
            assert_eq!(len % 32, 0, "d={d}");
            assert!(len >= 32 && len <= 2 * TARGET_SAMPLE_VALUES, "d={d} len={len}");
            if d > 0 && d <= TARGET_SAMPLE_VALUES {
                assert_eq!(len % d, 0, "d={d} len={len}");
            }
        }
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = Calibration::synthetic(2, 192, 2, 7);
        let b = Calibration::synthetic(2, 192, 2, 7);
        for site in Site::all(2) {
            assert_eq!(a.sample(site), b.sample(site));
        }
    }

    #[test]
    fn errors_sane() {
        let calib = Calibration::synthetic(2, 192, 2, 3);
        let mx = MxCodec::new(MxScheme::parse("fp4_e2m1_b32_e8m0").unwrap());
        for site in Site::all(2) {
            assert_eq!(calib.site_error(site, None), 0.0);
            let e = calib.site_error(site, Some(&mx));
            assert!(e.is_finite() && e >= 0.0, "{}: {e}", site.label());
            // NoCompress is lossless: error must be exactly zero
            assert_eq!(calib.site_error(site, Some(&NoCompress)), 0.0);
            assert_eq!(calib.scheme_error(site, "none").unwrap(), 0.0);
        }
        assert!(calib.scheme_error(Site::all(2)[0], "bogus").is_err());
    }

    #[test]
    fn decode_fallback_in_from_samples() {
        let n_layers = 1;
        let mut samples = vec![Vec::new(); Site::count(n_layers)];
        for site in Site::all(n_layers) {
            if site.phase == Phase::Prefill {
                samples[site.index()] = vec![vec![1.0f32; 64]; 2];
            }
        }
        let c = Calibration::from_samples(n_layers, 64, samples).unwrap();
        for site in Site::all(n_layers) {
            assert_eq!(c.sample(site).len(), 2);
        }
        // all-empty slot errors out
        let empty = vec![Vec::new(); Site::count(1)];
        assert!(Calibration::from_samples(1, 64, empty).is_err());
    }
}
