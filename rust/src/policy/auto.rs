//! Built-in policy searches: `paper` (the §5.1 selection rule applied
//! per-site) and `auto` (greedy sensitivity search under an error
//! budget).
//!
//! Both consume the same inputs: a [`Calibration`] (per-site error per
//! candidate scheme) and a [`SearchScenario`] (per-site virtual time
//! per candidate scheme, scored by the collective auto-planner
//! [`crate::collective::plan::score`] via [`crate::collective::plan::choose`]
//! — the identical model the engine charges at execution, so search
//! savings are realized savings).
//!
//! The `auto` search carries a hard never-worse guarantee: given a
//! baseline table (e.g. `uniform:fp4_e2m1_b32_e8m0`) whose modeled
//! error fits the budget, the returned table is never slower than the
//! baseline in total virtual time *or* in TTFT-phase virtual time, and
//! never exceeds the budget — if the greedy allocation ends up worse it
//! falls back to the baseline outright.

use crate::collective::plan::{self, AlgoChoice};
use crate::collective::Topology;
use crate::interconnect::HwProfile;
use crate::mxfmt::{compressor_from_spec_ch, Compressor};

use super::{Calibration, Phase, PolicyTable, Site};

/// Candidate schemes the built-in searches consider: the uncompressed
/// path plus the paper's Table-1 MX grid (§5.1 searches MX only).
///
/// Channel-wise INT is deliberately absent: its error is defined by
/// scales shared across *all rows* of a `d_model`-channel tensor, which
/// the length-capped calibration samples cannot represent for large
/// models (a short sample degrades it to near-per-value scaling and
/// would score it as spuriously error-free). Policies can still bind
/// `int4_channelwise` explicitly via rule specs — the engine then uses
/// the true channel count; only the built-in searches skip it.
pub const CANDIDATES: &[&str] = &[
    "none",
    "fp3_e1m1_b8_e8m0",
    "fp3_e1m1_b16_e8m0",
    "fp3_e1m1_b32_e8m0",
    "fp4_e2m1_b8_e8m0",
    "fp4_e2m1_b16_e8m0",
    "fp4_e2m1_b32_e8m0",
    "fp5_e2m2_b8_e8m0",
    "fp5_e2m2_b16_e8m0",
    "fp5_e2m2_b32_e8m0",
];

/// Per-site error threshold (%) of the `paper` policy — the §5.1 "<3%
/// PPL increase" bar, applied to the per-site calibration error.
pub const PAPER_ERR_BUDGET_PCT: f64 = 3.0;

/// Default mean-error budget (%) of the `auto` policy.
pub const DEFAULT_AUTO_BUDGET_PCT: f64 = 3.0;

/// The deployment the search prices collectives against: message sizes
/// per phase plus the topology/codec-rate inputs the planner scores
/// with.
#[derive(Debug, Clone)]
pub struct SearchScenario {
    /// TP world size
    pub world: usize,
    pub topo: Topology,
    /// profile codec throughput (values/s), see
    /// [`HwProfile::quant_values_per_s`]
    pub quant_values_per_s: f64,
    /// per-rank partial values of one prefill collective
    pub prefill_values: usize,
    /// per-rank partial values of one decode collective
    pub decode_values: usize,
}

impl SearchScenario {
    /// Scenario for `prefill_tokens` (batch × seq) prefills and
    /// `decode_batch`-wide decode steps of a `d_model` model on
    /// `profile` at TP `world`.
    pub fn new(
        profile: &'static HwProfile,
        world: usize,
        prefill_tokens: usize,
        decode_batch: usize,
        d_model: usize,
    ) -> SearchScenario {
        SearchScenario {
            world,
            topo: Topology::from_profile(profile, world),
            quant_values_per_s: profile.quant_values_per_s,
            prefill_values: prefill_tokens.max(1) * d_model,
            decode_values: decode_batch.max(1) * d_model,
        }
    }

    /// Message size (per-rank values) of one collective in `phase`.
    pub fn values(&self, phase: Phase) -> usize {
        match phase {
            Phase::Prefill => self.prefill_values,
            Phase::Decode => self.decode_values,
        }
    }
}

/// Precomputed per-candidate costs: calibration error per site, and
/// planner-scored virtual time + wire bytes per phase (time and wire
/// depend only on the phase's message size, not the layer).
pub struct SiteCosts {
    /// candidate spec strings, `costs.err[site][cand]` order
    pub candidates: Vec<String>,
    /// sites in [`Site::index`] order
    pub sites: Vec<Site>,
    /// relative RMS calibration error per `[site][candidate]`
    pub err: Vec<Vec<f64>>,
    /// planner-estimated virtual seconds per collective, per
    /// `[phase.ord-like: 0 = prefill, 1 = decode][candidate]`
    time: [Vec<f64>; 2],
    /// accounted wire bytes per collective (received per worker), same
    /// indexing as `time`
    wire: [Vec<u64>; 2],
    /// effective wire bits per value per candidate (16.0 for `none`)
    pub eff_bits: Vec<f64>,
}

/// The aggregate score of a fully resolved table under a
/// [`SiteCosts`] model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TableScore {
    /// Σ over all sites of the planner-estimated collective time (one
    /// prefill pass + one decode step)
    pub time_total_s: f64,
    /// Σ over prefill sites only — the TTFT communication component
    pub ttft_comm_s: f64,
    /// mean per-site relative RMS calibration error (fraction)
    pub mean_err: f64,
    /// accounted wire bytes of one full prefill pass
    pub prefill_wire_bytes: u64,
}

impl TableScore {
    /// Mean error as a percentage (the budget unit).
    pub fn mean_err_pct(&self) -> f64 {
        self.mean_err * 100.0
    }
}

fn phase_slot(phase: Phase) -> usize {
    match phase {
        Phase::Prefill => 0,
        Phase::Decode => 1,
    }
}

impl SiteCosts {
    /// Score every candidate at every site: errors from `calib`,
    /// times/wire from the collective planner on `scen`.
    pub fn build(
        calib: &Calibration,
        scen: &SearchScenario,
        candidates: &[&str],
    ) -> anyhow::Result<SiteCosts> {
        anyhow::ensure!(!candidates.is_empty(), "no candidate schemes");
        let comps: Vec<Option<Box<dyn Compressor>>> = candidates
            .iter()
            .map(|spec| {
                if *spec == "none" {
                    Ok(None)
                } else {
                    compressor_from_spec_ch(spec, calib.d_model).map(Some)
                }
            })
            .collect::<anyhow::Result<_>>()?;

        let mut time = [Vec::new(), Vec::new()];
        let mut wire = [Vec::new(), Vec::new()];
        for phase in Phase::ALL {
            let values = scen.values(phase);
            let slot = phase_slot(phase);
            for comp in &comps {
                let p = plan::choose(
                    values,
                    scen.world,
                    comp.as_deref(),
                    &scen.topo,
                    scen.quant_values_per_s,
                    AlgoChoice::Auto,
                );
                time[slot].push(p.est_total_s);
                let shard = match comp {
                    Some(c) => c.wire_bytes(values),
                    None => values * 2, // fp16 baseline, as the engine accounts it
                };
                wire[slot].push((shard * scen.world.saturating_sub(1)) as u64);
            }
        }

        let sites = Site::all(calib.n_layers);
        let mut err = Vec::with_capacity(sites.len());
        for &site in &sites {
            let mut row = Vec::with_capacity(comps.len());
            for comp in &comps {
                row.push(calib.site_error(site, comp.as_deref()));
            }
            err.push(row);
        }
        let len = Calibration::sample_len(calib.d_model);
        let eff_bits = comps
            .iter()
            .map(|c| c.as_ref().map_or(16.0, |c| c.effective_bits(len)))
            .collect();
        Ok(SiteCosts {
            candidates: candidates.iter().map(|s| s.to_string()).collect(),
            sites,
            err,
            time,
            wire,
            eff_bits,
        })
    }

    /// Planner-estimated virtual seconds of one collective at `site`
    /// under candidate `cand`.
    pub fn time(&self, site: Site, cand: usize) -> f64 {
        self.time[phase_slot(site.phase)][cand]
    }

    /// Accounted wire bytes of one collective at `site` under `cand`.
    pub fn wire(&self, site: Site, cand: usize) -> u64 {
        self.wire[phase_slot(site.phase)][cand]
    }

    /// Index of `spec` in the candidate list.
    pub fn cand_index(&self, spec: &str) -> Option<usize> {
        self.candidates.iter().position(|c| c == spec)
    }

    /// Score a resolved table. Errors if the table uses a scheme
    /// outside this cost model's candidate list.
    pub fn eval_table(&self, table: &PolicyTable) -> anyhow::Result<TableScore> {
        let mut score =
            TableScore { time_total_s: 0.0, ttft_comm_s: 0.0, mean_err: 0.0, prefill_wire_bytes: 0 };
        for &site in &self.sites {
            let spec = table.spec(site);
            let cand = self
                .cand_index(spec)
                .ok_or_else(|| anyhow::anyhow!("scheme {spec:?} not in the candidate list"))?;
            let t = self.time(site, cand);
            score.time_total_s += t;
            score.mean_err += self.err[site.index()][cand];
            if site.phase == Phase::Prefill {
                score.ttft_comm_s += t;
                score.prefill_wire_bytes += self.wire(site, cand);
            }
        }
        score.mean_err /= self.sites.len().max(1) as f64;
        Ok(score)
    }

    fn assignment_table(&self, name: &str, n_layers: usize, assign: &[usize]) -> PolicyTable {
        let mut specs = vec![String::new(); Site::count(n_layers)];
        for (i, &site) in self.sites.iter().enumerate() {
            specs[site.index()] = self.candidates[assign[i]].clone();
        }
        PolicyTable::from_specs(name, n_layers, specs).expect("assignment covers all sites")
    }
}

/// The paper's §5.1 selection rule applied per-site: among the MX
/// candidates whose calibration error clears `threshold_pct`, pick the
/// fewest effective bits (ties: lower error). The uncompressed path is
/// always a candidate (error 0, 16 bits), so sites where every scheme
/// degrades too much stay uncompressed — the "selected activations"
/// behaviour.
pub fn paper_policy(calib: &Calibration, threshold_pct: f64) -> anyhow::Result<PolicyTable> {
    // errors only — price-of-time does not enter the paper rule, so a
    // dummy single-node scenario is fine for cost construction
    let profile = HwProfile::by_name("cpu").expect("cpu profile");
    let scen = SearchScenario::new(profile, calib.world.max(2), 128, 8, calib.d_model.max(32));
    let costs = SiteCosts::build(calib, &scen, CANDIDATES)?;

    let mut assign = Vec::with_capacity(costs.sites.len());
    for (si, _site) in costs.sites.iter().enumerate() {
        let mut best: Option<usize> = None;
        for (ci, _spec) in costs.candidates.iter().enumerate() {
            let err_pct = costs.err[si][ci] * 100.0;
            if err_pct < threshold_pct {
                let better = match best {
                    None => true,
                    Some(b) => {
                        costs.eff_bits[ci] < costs.eff_bits[b]
                            || (costs.eff_bits[ci] == costs.eff_bits[b]
                                && costs.err[si][ci] < costs.err[si][b])
                    }
                };
                if better {
                    best = Some(ci);
                }
            }
        }
        // nothing clears the bar: fall back to the lowest-error
        // candidate ("none" is first and has error 0, so it wins ties)
        let chosen = best.unwrap_or_else(|| {
            let mut b = 0usize;
            for ci in 1..costs.candidates.len() {
                if costs.err[si][ci] < costs.err[si][b] {
                    b = ci;
                }
            }
            b
        });
        assign.push(chosen);
    }
    Ok(costs.assignment_table("paper", calib.n_layers, &assign))
}

/// Result of [`auto_search`].
pub struct AutoOutcome {
    /// the chosen per-site assignment
    pub table: PolicyTable,
    /// [`SiteCosts::eval_table`] of that assignment
    pub score: TableScore,
    /// true when the greedy allocation lost to the baseline and the
    /// baseline table was returned instead (the never-worse guarantee)
    pub fell_back: bool,
}

/// Greedy sensitivity search: starting from the all-uncompressed
/// assignment, repeatedly apply the (site, scheme) upgrade with the
/// best virtual-time saving per unit of added calibration error, while
/// the mean per-site error stays within `budget_pct`.
///
/// When `baseline` is given (and is scoreable under `costs` with error
/// within budget), the result is guaranteed never slower than it — in
/// total virtual time and in TTFT-phase time — by falling back to the
/// baseline if the greedy allocation is worse on either axis.
pub fn auto_search(
    costs: &SiteCosts,
    n_layers: usize,
    budget_pct: f64,
    baseline: Option<&PolicyTable>,
    name: &str,
) -> anyhow::Result<AutoOutcome> {
    let none = costs
        .cand_index("none")
        .ok_or_else(|| anyhow::anyhow!("auto search needs 'none' among the candidates"))?;
    let n_sites = costs.sites.len();
    anyhow::ensure!(n_sites > 0, "no sites to search");
    let budget = budget_pct / 100.0;

    let mut assign = vec![none; n_sites];
    let mut err_sum: f64 = 0.0;
    loop {
        // best (Δtime / Δerror) move within budget; deterministic:
        // strict improvement required, first-best wins
        let mut best: Option<(usize, usize, f64)> = None; // (site, cand, ratio)
        for si in 0..n_sites {
            let cur = assign[si];
            let t_cur = costs.time(costs.sites[si], cur);
            let e_cur = costs.err[si][cur];
            for ci in 0..costs.candidates.len() {
                if ci == cur {
                    continue;
                }
                let dt = t_cur - costs.time(costs.sites[si], ci);
                if dt <= 0.0 {
                    continue;
                }
                let de = costs.err[si][ci] - e_cur;
                if (err_sum + de) / n_sites as f64 > budget {
                    continue;
                }
                let ratio = dt / de.max(1e-18);
                if best.is_none_or(|(_, _, r)| ratio > r) {
                    best = Some((si, ci, ratio));
                }
            }
        }
        let Some((si, ci, _)) = best else { break };
        err_sum += costs.err[si][ci] - costs.err[si][assign[si]];
        assign[si] = ci;
    }

    let table = costs.assignment_table(name, n_layers, &assign);
    let score = costs.eval_table(&table)?;

    if let Some(base) = baseline {
        if let Ok(base_score) = costs.eval_table(base) {
            let base_fits = base_score.mean_err_pct() <= budget_pct + 1e-12;
            let worse = score.time_total_s > base_score.time_total_s + 1e-15
                || score.ttft_comm_s > base_score.ttft_comm_s + 1e-15;
            if base_fits && worse {
                let mut table = base.clone();
                table.name = format!("{name}(={})", base.name);
                return Ok(AutoOutcome { table, score: base_score, fell_back: true });
            }
        }
    }
    Ok(AutoOutcome { table, score, fell_back: false })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_layers: usize) -> (Calibration, SiteCosts) {
        let calib = Calibration::synthetic(n_layers, 192, 2, 11);
        let profile = HwProfile::by_name("l4").unwrap();
        let scen = SearchScenario::new(profile, 2, 8 * 128, 8, 192);
        let costs = SiteCosts::build(&calib, &scen, CANDIDATES).unwrap();
        (calib, costs)
    }

    #[test]
    fn costs_shapes_and_monotone_wire() {
        let (_, costs) = setup(2);
        assert_eq!(costs.sites.len(), Site::count(2));
        assert_eq!(costs.err.len(), costs.sites.len());
        let none = costs.cand_index("none").unwrap();
        for &site in &costs.sites {
            // compressed candidates put fewer bytes on the wire than fp16
            for ci in 0..costs.candidates.len() {
                if ci != none {
                    assert!(costs.wire(site, ci) < costs.wire(site, none));
                }
            }
            assert!(costs.time(site, none) > 0.0);
        }
    }

    #[test]
    fn eval_uniform_none_is_exact() {
        let (_, costs) = setup(2);
        let t = PolicyTable::uniform(2, "none");
        let s = costs.eval_table(&t).unwrap();
        assert_eq!(s.mean_err, 0.0);
        assert!(s.time_total_s > 0.0 && s.ttft_comm_s > 0.0);
        assert!(s.ttft_comm_s < s.time_total_s);
        // unknown scheme is an error
        let t = PolicyTable::uniform(2, "topk3");
        assert!(costs.eval_table(&t).is_err());
    }

    #[test]
    fn paper_threshold_extremes() {
        let calib = Calibration::synthetic(3, 192, 2, 5);
        // nothing clears a 0% bar except the exact path
        let t = paper_policy(&calib, 0.0).unwrap();
        for site in Site::all(3) {
            assert_eq!(t.spec(site), "none", "{}", site.label());
        }
        // an infinite bar admits everything: fewest effective bits wins
        let t = paper_policy(&calib, f64::INFINITY).unwrap();
        for site in Site::all(3) {
            assert_eq!(t.spec(site), "fp3_e1m1_b32_e8m0", "{}", site.label());
        }
    }

    #[test]
    fn auto_respects_budget_and_baseline() {
        let (_, costs) = setup(2);
        let uniform = PolicyTable::uniform(2, "fp4_e2m1_b32_e8m0");
        let u = costs.eval_table(&uniform).unwrap();
        let out =
            auto_search(&costs, 2, u.mean_err_pct(), Some(&uniform), "auto").unwrap();
        assert!(out.score.mean_err_pct() <= u.mean_err_pct() + 1e-9);
        assert!(out.score.time_total_s <= u.time_total_s + 1e-12);
        assert!(out.score.ttft_comm_s <= u.ttft_comm_s + 1e-12);
        // consistency: the reported score is the table's score
        let re = costs.eval_table(&out.table).unwrap();
        assert!((re.time_total_s - out.score.time_total_s).abs() < 1e-12);
    }

    #[test]
    fn auto_zero_budget_stays_within_it() {
        let (_, costs) = setup(1);
        let out = auto_search(&costs, 1, 0.0, None, "auto").unwrap();
        assert!(out.score.mean_err_pct() <= 1e-12);
        // and never slower than all-none (its own starting point)
        let none = costs.eval_table(&PolicyTable::uniform(1, "none")).unwrap();
        assert!(out.score.time_total_s <= none.time_total_s + 1e-12);
    }

    #[test]
    fn auto_missing_none_errors() {
        let calib = Calibration::synthetic(1, 192, 2, 1);
        let profile = HwProfile::by_name("l4").unwrap();
        let scen = SearchScenario::new(profile, 2, 128, 8, 192);
        let costs = SiteCosts::build(&calib, &scen, &["fp4_e2m1_b32_e8m0"]).unwrap();
        assert!(auto_search(&costs, 1, 3.0, None, "auto").is_err());
    }
}
