//! Online compression-error sentinel: does the calibration-time error
//! budget still hold under live traffic?
//!
//! The `paper`/`auto` policies pick per-site schemes from errors
//! measured on a *calibration* sample ([`super::Calibration`]). Live
//! activations drift — longer prompts, different domains, deeper decode
//! positions — and a site whose observed error exceeds its budget is
//! silently degrading quality. The sentinel streams the same metric the
//! calibrator uses (relative RMS of the fake-quantized reduce vs the
//! exact reduce, [`observed_error`]) on a sampled subset of live
//! collectives: every [`DEFAULT_SAMPLE_EVERY`]-th forward pass measures
//! every compressed site it touches on a bounded prefix of the real
//! partials, so the steady-state cost is a few microseconds per sampled
//! forward.
//!
//! A site *trips* after [`DEFAULT_TRIP_AFTER`] consecutive over-budget
//! samples (one outlier prompt must not flip policy). Tripped sites are
//! reported as drift counters on `/metrics`, as a `policy_drift`
//! section on `GET /policy`, and through
//! `TpEngine::apply_drift_fallback`, which rebinds them to `none` —
//! the never-worse scheme (bit-exact, zero error) — and marks them
//! `fell_back` so they are not re-tripped.

use crate::mxfmt::Compressor;
use crate::util::json::{self, Json};

use super::{PolicyTable, Site};

/// Measure (and pay for) the error on every 16th forward pass.
pub const DEFAULT_SAMPLE_EVERY: u64 = 16;

/// Consecutive over-budget samples before a site trips.
pub const DEFAULT_TRIP_AFTER: u64 = 3;

/// Cap on values measured per sample (same target the calibrator uses).
const TARGET_SAMPLE_VALUES: usize = 512;

/// Relative RMS error (a fraction, not percent) of fake-quantizing each
/// rank's partial with `comp` and summing, vs the exact sum — the live
/// twin of [`super::Calibration::site_error`], computed on a bounded
/// prefix of the partials. `align` (the model's `d_model`) keeps the
/// prefix a whole number of channel rows so channel-wise schemes see
/// well-formed input; prefixes shorter than one row use the full
/// available length.
pub fn observed_error(partials: &[&[f32]], comp: &dyn Compressor, align: usize) -> f64 {
    if partials.is_empty() {
        return 0.0;
    }
    let len = partials.iter().map(|p| p.len()).min().unwrap_or(0);
    if len == 0 {
        return 0.0;
    }
    let align = align.max(1);
    let take = if len <= TARGET_SAMPLE_VALUES.max(align) {
        len
    } else {
        let rows = (TARGET_SAMPLE_VALUES.max(align) / align).max(1);
        (rows * align).min(len)
    };
    let mut exact = vec![0.0f32; take];
    for p in partials {
        for (e, v) in exact.iter_mut().zip(&p[..take]) {
            *e += v;
        }
    }
    let mut acc = vec![0.0f32; take];
    let mut scratch = Vec::new();
    for p in partials {
        comp.requant_add(&p[..take], &mut acc, &mut scratch);
    }
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..take {
        num += ((acc[i] - exact[i]) as f64).powi(2);
        den += (exact[i] as f64).powi(2);
    }
    if den <= 0.0 {
        return 0.0;
    }
    (num / den).sqrt()
}

/// Streaming drift state for one site.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SiteDrift {
    pub samples: u64,
    pub err_sum_pct: f64,
    pub err_max_pct: f64,
    pub over_budget: u64,
    pub consecutive_over: u64,
    /// Sustained over-budget drift detected.
    pub tripped: bool,
    /// The policy engine already rebound this site to its never-worse
    /// scheme; it is excluded from further tripping.
    pub fell_back: bool,
}

impl SiteDrift {
    pub fn err_mean_pct(&self) -> f64 {
        if self.samples == 0 {
            f64::NAN
        } else {
            self.err_sum_pct / self.samples as f64
        }
    }
}

/// The online error sentinel bound to one engine policy binding.
pub struct Sentinel {
    budget_pct: f64,
    sample_every: u64,
    trip_after: u64,
    forwards: u64,
    sampling: bool,
    sites: Vec<SiteDrift>,
    version: u64,
}

impl Sentinel {
    pub fn new(n_sites: usize, budget_pct: f64) -> Sentinel {
        Sentinel::with_tuning(n_sites, budget_pct, DEFAULT_SAMPLE_EVERY, DEFAULT_TRIP_AFTER)
    }

    pub fn with_tuning(
        n_sites: usize,
        budget_pct: f64,
        sample_every: u64,
        trip_after: u64,
    ) -> Sentinel {
        Sentinel {
            budget_pct,
            sample_every: sample_every.max(1),
            trip_after: trip_after.max(1),
            forwards: 0,
            sampling: false,
            sites: vec![SiteDrift::default(); n_sites],
            version: 0,
        }
    }

    pub fn budget_pct(&self) -> f64 {
        self.budget_pct
    }

    /// Called once per forward pass; returns whether this pass measures
    /// observed error at its sites. The first pass always samples so a
    /// short run still produces sentinel data.
    pub fn begin_forward(&mut self) -> bool {
        self.sampling = self.forwards % self.sample_every == 0;
        self.forwards += 1;
        self.sampling
    }

    /// Whether the forward pass opened by the last
    /// [`begin_forward`](Self::begin_forward) is a sampling pass.
    pub fn sampling_now(&self) -> bool {
        self.sampling
    }

    /// Fold one observed-error measurement (percent) for a site.
    pub fn observe(&mut self, site_index: usize, err_pct: f64) {
        let Some(s) = self.sites.get_mut(site_index) else { return };
        if !err_pct.is_finite() {
            return;
        }
        s.samples += 1;
        s.err_sum_pct += err_pct;
        s.err_max_pct = s.err_max_pct.max(err_pct);
        if err_pct > self.budget_pct {
            s.over_budget += 1;
            s.consecutive_over += 1;
            if s.consecutive_over >= self.trip_after && !s.tripped && !s.fell_back {
                s.tripped = true;
            }
        } else {
            s.consecutive_over = 0;
        }
        self.version += 1;
    }

    /// Site indices currently tripped and not yet fallen back — what
    /// `apply_drift_fallback` acts on.
    pub fn tripped(&self) -> Vec<usize> {
        self.sites
            .iter()
            .enumerate()
            .filter(|(_, s)| s.tripped && !s.fell_back)
            .map(|(i, _)| i)
            .collect()
    }

    /// Record that the policy engine rebound `site_index` to its
    /// never-worse scheme.
    pub fn mark_fallback(&mut self, site_index: usize) {
        if let Some(s) = self.sites.get_mut(site_index) {
            s.tripped = false;
            s.fell_back = true;
            s.consecutive_over = 0;
            self.version += 1;
        }
    }

    pub fn site(&self, site_index: usize) -> Option<&SiteDrift> {
        self.sites.get(site_index)
    }

    /// Bumped on every state change — the coordinator refreshes the
    /// cached `/policy` body only when this moves.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Drift counters mirrored onto `/metrics`.
    pub fn metrics(&self) -> Vec<(&'static str, f64)> {
        let samples: u64 = self.sites.iter().map(|s| s.samples).sum();
        let over: u64 = self.sites.iter().map(|s| s.over_budget).sum();
        let tripped = self.sites.iter().filter(|s| s.tripped).count();
        let fell_back = self.sites.iter().filter(|s| s.fell_back).count();
        let max_err = self.sites.iter().map(|s| s.err_max_pct).fold(0.0f64, f64::max);
        vec![
            ("drift_budget_pct", self.budget_pct),
            ("drift_samples_total", samples as f64),
            ("drift_over_budget_total", over as f64),
            ("drift_sites_tripped", tripped as f64),
            ("drift_sites_fell_back", fell_back as f64),
            ("drift_max_err_pct", max_err),
        ]
    }

    /// The `policy_drift` section of `GET /policy`. Only sites with at
    /// least one sample get a row.
    pub fn to_json(&self, n_layers: usize) -> Json {
        let all = Site::all(n_layers);
        let label = |i: usize| {
            all.get(i).map(|s| s.label()).unwrap_or_else(|| format!("site{i}"))
        };
        let mut rows = Vec::new();
        let mut tripped = Vec::new();
        let mut fell_back = Vec::new();
        for (i, s) in self.sites.iter().enumerate() {
            if s.tripped {
                tripped.push(json::s(&label(i)));
            }
            if s.fell_back {
                fell_back.push(json::s(&label(i)));
            }
            if s.samples == 0 {
                continue;
            }
            rows.push(json::obj(vec![
                ("site", json::s(&label(i))),
                ("samples", json::num(s.samples as f64)),
                ("err_mean_pct", json::num_or_null(s.err_mean_pct())),
                ("err_max_pct", json::num(s.err_max_pct)),
                ("over_budget", json::num(s.over_budget as f64)),
                ("tripped", Json::Bool(s.tripped)),
                ("fell_back", Json::Bool(s.fell_back)),
            ]));
        }
        json::obj(vec![
            ("budget_pct", json::num(self.budget_pct)),
            ("sample_every", json::num(self.sample_every as f64)),
            ("trip_after", json::num(self.trip_after as f64)),
            ("forwards", json::num(self.forwards as f64)),
            ("tripped", Json::Arr(tripped)),
            ("fell_back", Json::Arr(fell_back)),
            ("sites", Json::Arr(rows)),
        ])
    }
}

/// The fallback transform `apply_drift_fallback` binds: every tripped
/// site moves to `none`, the never-worse scheme (bit-exact wire, zero
/// observed error, never slower than itself under drift). Pure —
/// testable without artifacts.
pub fn fallback_table(table: &PolicyTable, tripped: &[usize]) -> PolicyTable {
    let mut out = table.clone();
    for site in Site::all(table.n_layers) {
        if tripped.contains(&site.index()) {
            out.set(site, "none");
        }
    }
    if !tripped.is_empty() && !out.name.ends_with("+drift-fallback") {
        out.name = format!("{}+drift-fallback", out.name);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxfmt::{compressor_from_spec_ch, NoCompress};
    use crate::policy::Calibration;

    #[test]
    fn observed_error_matches_calibrator_semantics() {
        // the sentinel's live metric on the calibrator's own samples
        // must agree with site_error: same math, same prefix policy
        let calib = Calibration::synthetic(2, 192, 2, 3);
        let comp = compressor_from_spec_ch("fp4_e2m1_b32_e8m0", 192).unwrap();
        for site in Site::all(2) {
            let refs: Vec<&[f32]> = calib.sample(site).iter().map(|v| v.as_slice()).collect();
            let live = observed_error(&refs, comp.as_ref(), 192);
            let cal = calib.site_error(site, Some(comp.as_ref()));
            assert!(
                (live - cal).abs() < 1e-12,
                "{}: live {live} vs calib {cal}",
                site.label()
            );
        }
    }

    #[test]
    fn lossless_scheme_observes_zero_error() {
        let parts: Vec<Vec<f32>> = vec![vec![0.5f32; 64], vec![-0.25f32; 64]];
        let refs: Vec<&[f32]> = parts.iter().map(|v| v.as_slice()).collect();
        assert_eq!(observed_error(&refs, &NoCompress, 64), 0.0);
        // degenerate inputs never panic
        assert_eq!(observed_error(&[], &NoCompress, 64), 0.0);
        let empty: &[f32] = &[];
        assert_eq!(observed_error(&[empty], &NoCompress, 64), 0.0);
    }

    #[test]
    fn prefix_is_bounded_and_row_aligned() {
        // a huge partial must be cut to ~TARGET values on a d_model grid
        let parts: Vec<Vec<f32>> = vec![vec![1.0f32; 192 * 64]; 2];
        let refs: Vec<&[f32]> = parts.iter().map(|v| v.as_slice()).collect();
        // NoCompress => 0 regardless; this is a no-panic/shape test
        assert_eq!(observed_error(&refs, &NoCompress, 192), 0.0);
    }

    #[test]
    fn sentinel_trips_on_sustained_over_budget_drift() {
        let mut s = Sentinel::with_tuning(4, 3.0, 4, 3);
        // pass cadence: first forward samples, then every 4th
        assert!(s.begin_forward());
        assert!(!s.begin_forward());
        assert!(!s.begin_forward());
        assert!(!s.begin_forward());
        assert!(s.begin_forward());
        // one outlier does not trip
        s.observe(1, 9.0);
        assert!(s.tripped().is_empty());
        s.observe(1, 1.0); // back under budget resets the streak
        s.observe(1, 9.0);
        s.observe(1, 9.0);
        assert!(s.tripped().is_empty());
        s.observe(1, 9.0); // third consecutive
        assert_eq!(s.tripped(), vec![1]);
        // counters reflect the history
        let m: std::collections::BTreeMap<_, _> = s.metrics().into_iter().collect();
        assert_eq!(m["drift_sites_tripped"], 1.0);
        assert_eq!(m["drift_over_budget_total"], 4.0);
        assert_eq!(m["drift_samples_total"], 6.0);
        assert_eq!(m["drift_max_err_pct"], 9.0);
        // fallback clears the trip and pins the site
        let v0 = s.version();
        s.mark_fallback(1);
        assert!(s.version() > v0);
        assert!(s.tripped().is_empty());
        assert!(s.site(1).unwrap().fell_back);
        // a fallen-back site never re-trips
        for _ in 0..10 {
            s.observe(1, 9.0);
        }
        assert!(s.tripped().is_empty());
    }

    #[test]
    fn under_budget_stream_never_trips() {
        let mut s = Sentinel::new(8, 3.0);
        for _ in 0..100 {
            s.observe(3, 1.5);
        }
        assert!(s.tripped().is_empty());
        assert_eq!(s.site(3).unwrap().over_budget, 0);
        assert!((s.site(3).unwrap().err_mean_pct() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn drift_json_names_sites() {
        let mut s = Sentinel::with_tuning(8, 3.0, 1, 1);
        s.observe(0, 5.0); // l0.attn.prefill trips immediately (trip_after=1)
        s.observe(5, 1.0);
        let j = s.to_json(2);
        let body = j.to_string();
        let parsed = crate::util::json::Json::parse(&body).unwrap();
        assert_eq!(parsed.get("budget_pct").unwrap().as_f64(), Some(3.0));
        let tripped = parsed.get("tripped").unwrap().as_arr().unwrap();
        assert_eq!(tripped.len(), 1);
        assert_eq!(tripped[0].as_str(), Some("l0.attn.prefill"));
        assert_eq!(parsed.get("sites").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn fallback_table_rebinds_tripped_sites_to_none() {
        let table = PolicyTable::uniform(2, "fp4_e2m1_b32_e8m0");
        let tripped = vec![1usize, 6];
        let out = fallback_table(&table, &tripped);
        for site in Site::all(2) {
            let want = if tripped.contains(&site.index()) { "none" } else { "fp4_e2m1_b32_e8m0" };
            assert_eq!(out.spec(site), want, "{}", site.label());
        }
        assert!(out.name.ends_with("+drift-fallback"));
        // no trips => identity
        let same = fallback_table(&table, &[]);
        assert_eq!(same, table);
    }
}
