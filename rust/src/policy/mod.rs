//! Per-site compression policy engine ("selected activations").
//!
//! The paper's headline TTFT wins come from compressing *selected*
//! activations, not every tensor: §5.1 searches a scheme per model, and
//! the follow-up literature (Dong et al., Lamprecht et al.) shows the
//! quality–latency frontier lives in per-layer / per-site selectivity.
//! This module generalises the engine's single global [`crate::mxfmt::Compressor`]
//! to a mapping from each collective **site** — layer index ×
//! {attention-out, mlp-out} × phase {prefill, decode} — to a compressor
//! spec:
//!
//! * [`Site`] / [`SiteKind`] / [`Phase`] — the coordinates of one
//!   row-parallel collective in the forward pass.
//! * [`CompressionPolicy`] — rule-based policy with a compact CLI spec
//!   string (`mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0-1]=none`) and a
//!   JSON serialisation for the server; resolves to a [`PolicyTable`].
//! * [`PolicyTable`] — the fully resolved per-site assignment the
//!   engine binds (one spec string per site).
//! * [`Calibration`] — per-site activation samples (synthetic, or
//!   captured from a calibration forward pass) and the per-scheme
//!   reconstruction error measured on them.
//! * [`SiteCosts`] / [`auto_search`] / [`paper_policy`] — the built-in
//!   `paper` (§5.1 selection rule applied per-site) and `auto` (greedy
//!   sensitivity search under an error budget) policies.
//! * [`Sentinel`] / [`observed_error`] — the online drift sentinel:
//!   streams the calibrator's error metric over sampled live
//!   collectives and trips sites whose observed error sustains above
//!   the calibrated budget (`policy_drift` on `GET /policy`).
//!
//! Seed equivalence: `uniform:<spec>` resolves every site to `<spec>`,
//! which the engine binds to exactly the same compressor object and
//! collective plan the seed's global path used — bit-identical output,
//! pinned by `tests/property_policy.rs`.

pub mod auto;
pub mod calibration;
pub mod drift;
pub mod spec;

pub use auto::{
    auto_search, paper_policy, AutoOutcome, SearchScenario, SiteCosts, TableScore, CANDIDATES,
    DEFAULT_AUTO_BUDGET_PCT, PAPER_ERR_BUDGET_PCT,
};
pub use calibration::Calibration;
pub use drift::{fallback_table, observed_error, Sentinel, SiteDrift};
pub use spec::{CompressionPolicy, PolicyTable, Selector};

/// Which row-parallel collective inside a transformer layer a site
/// refers to (each layer performs one after attention and one after
/// the MLP).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SiteKind {
    /// the collective after the attention block's row-parallel `wo`
    AttnOut,
    /// the collective after the MLP's row-parallel `w_down`
    MlpOut,
}

impl SiteKind {
    /// Both kinds, in site-index order.
    pub const ALL: [SiteKind; 2] = [SiteKind::AttnOut, SiteKind::MlpOut];

    /// Spec-string atom (`attn` / `mlp`).
    pub fn name(&self) -> &'static str {
        match self {
            SiteKind::AttnOut => "attn",
            SiteKind::MlpOut => "mlp",
        }
    }

    fn ord(&self) -> usize {
        match self {
            SiteKind::AttnOut => 0,
            SiteKind::MlpOut => 1,
        }
    }
}

/// Which serving phase the collective runs in. Decode messages are two
/// to three orders of magnitude smaller than prefill messages, so the
/// profitable scheme differs per phase (often: compress prefill, leave
/// α-bound decode traffic uncompressed).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    /// Both phases, in site-index order.
    pub const ALL: [Phase; 2] = [Phase::Prefill, Phase::Decode];

    /// Spec-string atom (`prefill` / `decode`).
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    fn ord(&self) -> usize {
        match self {
            Phase::Prefill => 0,
            Phase::Decode => 1,
        }
    }
}

/// One collective site: the (layer, kind, phase) coordinate every
/// policy maps to a compressor spec.
///
/// ```
/// use tpcc::policy::{Phase, Site, SiteKind};
/// let s = Site { layer: 3, kind: SiteKind::MlpOut, phase: Phase::Decode };
/// assert_eq!(s.label(), "l3.mlp.decode");
/// assert_eq!(Site::all(2).len(), Site::count(2));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Site {
    pub layer: usize,
    pub kind: SiteKind,
    pub phase: Phase,
}

impl Site {
    /// Dense index into per-site tables: sites of one layer are
    /// adjacent, ordered (attn, mlp) × (prefill, decode).
    pub fn index(&self) -> usize {
        (self.layer * 2 + self.kind.ord()) * 2 + self.phase.ord()
    }

    /// Number of sites an `n_layers` model has (4 per layer).
    pub fn count(n_layers: usize) -> usize {
        n_layers * 4
    }

    /// Every site of an `n_layers` model, in [`Site::index`] order.
    pub fn all(n_layers: usize) -> Vec<Site> {
        let mut out = Vec::with_capacity(Self::count(n_layers));
        for layer in 0..n_layers {
            for kind in SiteKind::ALL {
                for phase in Phase::ALL {
                    out.push(Site { layer, kind, phase });
                }
            }
        }
        out
    }

    /// Human-readable label (`l<layer>.<kind>.<phase>`), used by the
    /// JSON serialisation and telemetry.
    pub fn label(&self) -> String {
        format!("l{}.{}.{}", self.layer, self.kind.name(), self.phase.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_index_is_dense_and_ordered() {
        for n_layers in [1usize, 2, 5, 32] {
            let all = Site::all(n_layers);
            assert_eq!(all.len(), Site::count(n_layers));
            for (i, s) in all.iter().enumerate() {
                assert_eq!(s.index(), i, "{}", s.label());
            }
        }
    }

    #[test]
    fn labels_are_unique() {
        let all = Site::all(3);
        let mut labels: Vec<String> = all.iter().map(Site::label).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), Site::count(3));
    }
}
