//! tpcc — Tensor-Parallel Communication Compression serving stack.
//!
//! Full-system reproduction of *"Communication Compression for Tensor
//! Parallel LLM Inference"* (Hansen-Palmus et al., 2024): a rust serving
//! coordinator that executes AOT-compiled XLA stage programs (JAX +
//! Pallas, lowered at build time) across a tensor-parallel worker group,
//! compressing the row-parallel all-gather traffic with OCP Microscaling
//! (MX) block quantization.
//!
//! Layer map (DESIGN.md):
//! * [`runtime`]    — PJRT CPU client, manifest-driven artifact loading.
//! * [`tp`]         — TP engine: a rank-thread runtime (one worker per
//!                    rank, each owning its own PJRT client and shard)
//!                    with a sequential reference path behind
//!                    `--rank-threads off`; threads the collective plan
//!                    + per-algo telemetry.
//! * [`fabric`]     — shared-memory collective fabric: poisonable
//!                    barrier + rendezvous shard-exchange slots the
//!                    rank workers meet at between stages.
//! * [`collective`] — topology-aware collective engine: algorithm menu
//!                    (flat ring, recursive doubling, two-shot,
//!                    hierarchical) behind one trait, two-level
//!                    [`collective::Topology`], pipelined chunking with
//!                    encode/link overlap, and an auto-planner scoring
//!                    {algorithm × chunking} per message shape.
//! * [`policy`]     — per-site compression policy engine: maps each
//!                    collective site (layer × {attn-out, mlp-out} ×
//!                    {prefill, decode}) to a compressor spec; built-in
//!                    `uniform` / `paper` / `auto` policies plus a
//!                    compact CLI spec grammar and JSON for the server;
//!                    online drift sentinel ([`policy::Sentinel`])
//!                    comparing observed quantization error against the
//!                    calibration budget, with a never-worse fallback.
//! * [`mxfmt`]      — MX codec (bit-exact vs the Pallas kernels) + the
//!                    Bian et al. baselines (channel-wise INT, TopK).
//! * [`interconnect`] — α/β link simulator with single- and multi-node
//!                    hardware profiles (PCIe/NVLink intra, Ethernet/IB
//!                    inter).
//! * [`coordinator`]  — continuous batcher, KV-cache pool, sessions.
//! * [`obs`]        — structured tracing: per-thread span rings threaded
//!                    from request admission down to the codec passes,
//!                    Chrome-trace/Perfetto export (`tpcc trace`,
//!                    `GET /trace`), per-phase gauges on `/metrics`;
//!                    per-request flight recorder ([`obs::flight`],
//!                    `GET /debug/requests`, `tpcc explain`); leveled
//!                    structured event log ([`obs::log`], `GET /logs`,
//!                    stderr sink behind `--log-level`); declarative
//!                    alert-rule engine over the metrics time-series
//!                    ([`obs::alert`], `GET /alerts`, `tpcc_alert_firing`
//!                    gauges); terminal operator dashboard
//!                    ([`obs::top`], `tpcc top [--once]`).
//! * [`metrics`]    — counters/gauges/histograms plus a bounded
//!                    time-series ring ([`metrics::MetricsHistory`]):
//!                    gap-aware windowed QPS / tokens-per-s / wire /
//!                    preemption / shed rates and TTFT-SLO burn rate
//!                    over 1m/5m/30m windows (`GET /metrics/history`),
//!                    per-(route, status) HTTP counters, build info +
//!                    uptime, Prometheus text exposition
//!                    (`GET /metrics?format=prom`).
//! * [`server`]     — minimal HTTP/1.1 front end (per-algorithm
//!                    collective counters on `/metrics`; every answered
//!                    connection counted and access-logged).
//! * [`eval`]       — perplexity harness (Tables 1/2/5).
//! * [`model`]      — model configs, weight loading, analytic perf model.
//! * [`workload`]   — serving-under-load engine: trace generation
//!                    (Poisson/bursty/closed-loop × length
//!                    distributions), wall-clock and virtual-time load
//!                    drivers, streaming latency histograms, and the
//!                    SLO-capacity search behind Table 7.
//! * [`tables`]     — generators for every paper table (benches wrap these).

pub mod bench;
pub mod collective;
pub mod coordinator;
pub mod eval;
pub mod fabric;
pub mod interconnect;
pub mod metrics;
pub mod model;
pub mod mxfmt;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod server;
pub mod tables;
pub mod tokenizer;
pub mod tp;
pub mod util;
pub mod workload;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Repo-root-relative artifact dir, overridable via `TPCC_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("TPCC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
