//! PJRT runtime: loads AOT artifacts (HLO text) listed in
//! `artifacts/manifest.json`, compiles them on the CPU PJRT client on
//! first use, and executes them from the serving hot path.
//!
//! Python never runs here — this module plus the artifact files are the
//! entire inference engine (three-layer contract, DESIGN.md).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

pub use manifest::{ArtifactInfo, Manifest};

/// Lazily-compiled executable cache over one PJRT client.
///
/// Not `Send`: the `xla` crate's client is `Rc`-based, so the engine owns
/// a single `Runtime` on its dedicated thread (the coordinator talks to
/// it via channels).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    root: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub compile_count: RefCell<usize>,
    pub compile_secs: RefCell<f64>,
}

impl Runtime {
    /// `root` is the artifacts directory (contains manifest.json, hlo/).
    pub fn load(root: &std::path::Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(&root.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Runtime {
            client,
            manifest,
            root: root.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            compile_count: RefCell::new(0),
            compile_secs: RefCell::new(0.0),
        })
    }

    /// The artifacts directory this runtime was loaded from. The
    /// rank-thread runtime uses this to construct each worker's own
    /// `Runtime` (the PJRT client is not `Send`, so every thread builds
    /// its own from the same root).
    pub fn root(&self) -> &std::path::Path {
        &self.root
    }

    /// Fetch (compiling if needed) the executable for a manifest entry.
    pub fn executable(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let info = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))?;
        let path = self.root.join(&info.path);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("bad path"))?,
        )
        .map_err(anyhow_xla)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(anyhow_xla)?;
        *self.compile_count.borrow_mut() += 1;
        *self.compile_secs.borrow_mut() += t0.elapsed().as_secs_f64();
        let rc = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute an artifact; returns the flattened output literals
    /// (stage programs are lowered with `return_tuple=True`).
    pub fn execute(&self, name: &str, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let out = exe.execute::<xla::Literal>(args).map_err(anyhow_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        lit.to_tuple().map_err(anyhow_xla)
    }

    /// Like [`execute`], but borrowing the argument literals (hot path —
    /// avoids deep-copying weight literals on every stage call).
    pub fn execute_refs(
        &self,
        name: &str,
        args: &[&xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let out = exe.execute::<&xla::Literal>(args).map_err(anyhow_xla)?;
        let lit = out[0][0].to_literal_sync().map_err(anyhow_xla)?;
        lit.to_tuple().map_err(anyhow_xla)
    }

    pub fn warm(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

// ----------------------------------------------------------------------
// literal helpers
// ----------------------------------------------------------------------

/// f32 literal with shape.
pub fn lit_f32(dims: &[usize], data: &[f32]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(anyhow_xla)
}

/// u8 literal with shape.
pub fn lit_u8(dims: &[usize], data: &[u8]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::U8, dims, data)
        .map_err(anyhow_xla)
}

/// i32 literal with shape.
pub fn lit_i32(dims: &[usize], data: &[i32]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(anyhow_xla)
}

/// i32 scalar literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_f32(l: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(anyhow_xla)
}

pub fn to_vec_u8(l: &xla::Literal) -> anyhow::Result<Vec<u8>> {
    l.to_vec::<u8>().map_err(anyhow_xla)
}

/// Copy a literal's f32 payload into an existing buffer (no alloc).
pub fn copy_f32_into(l: &xla::Literal, dst: &mut [f32]) -> anyhow::Result<()> {
    l.copy_raw_to::<f32>(dst).map_err(anyhow_xla)
}
