//! `artifacts/manifest.json` — the contract between the python AOT
//! exporter and the rust runtime.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: String,
    pub kind: String,
    pub model: String,
    pub tp: usize,
    pub batch: usize,
    pub seq: usize,
    pub scheme: Option<String>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactInfo>,
    by_name: HashMap<String, usize>,
    pub raw: Json,
    pub seq_buckets: Vec<usize>,
    pub batch_buckets: Vec<usize>,
    pub tp_degrees: Vec<usize>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let raw = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(raw)
    }

    pub fn from_json(raw: Json) -> anyhow::Result<Manifest> {
        let mut artifacts = Vec::new();
        let list = raw
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        for a in list {
            let io = |key: &str| -> Vec<IoSpec> {
                a.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .map(|s| IoSpec {
                                shape: s
                                    .get("shape")
                                    .and_then(|v| v.as_arr())
                                    .map(|d| d.iter().filter_map(|x| x.as_usize()).collect())
                                    .unwrap_or_default(),
                                dtype: s
                                    .get("dtype")
                                    .and_then(|v| v.as_str())
                                    .unwrap_or("")
                                    .to_string(),
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            artifacts.push(ArtifactInfo {
                name: a.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                path: a.get("path").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                kind: a.get("kind").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                model: a.get("model").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                tp: a.get("tp").and_then(|v| v.as_usize()).unwrap_or(0),
                batch: a.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
                seq: a.get("seq").and_then(|v| v.as_usize()).unwrap_or(0),
                scheme: a.get("scheme").and_then(|v| v.as_str()).map(str::to_string),
                inputs: io("inputs"),
                outputs: io("outputs"),
            });
        }
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        let usizes = |key: &str| -> Vec<usize> {
            raw.get(key)
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default()
        };
        Ok(Manifest {
            seq_buckets: usizes("seq_buckets"),
            batch_buckets: usizes("batch_buckets"),
            tp_degrees: usizes("tp_degrees"),
            artifacts,
            by_name,
            raw,
        })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// Stage lookup by coordinates.
    pub fn stage(
        &self,
        model: &str,
        kind: &str,
        tp: usize,
        batch: usize,
        seq: usize,
        scheme: Option<&str>,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| {
            a.model == model
                && a.kind == kind
                && a.batch == batch
                && a.seq == seq
                && (a.tp == tp || a.tp == 0 && (kind == "embed" || kind == "final" || kind == "quantize"))
                && a.scheme.as_deref() == scheme
        })
    }

    /// Smallest exported seq bucket >= len for (model, kind, tp).
    pub fn seq_bucket_for(
        &self,
        model: &str,
        kind: &str,
        tp: usize,
        batch: usize,
        len: usize,
    ) -> Option<usize> {
        let mut buckets: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == kind && a.batch == batch && (a.tp == tp || a.tp == 0))
            .map(|a| a.seq)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        buckets.into_iter().find(|&b| b >= len)
    }

    /// Batch buckets available (sorted) for a stage family.
    pub fn batch_bucket_for(&self, model: &str, kind: &str, tp: usize, n: usize) -> Option<usize> {
        let mut buckets: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == kind && (a.tp == tp || a.tp == 0))
            .map(|a| a.batch)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        buckets.into_iter().find(|&b| b >= n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let doc = r#"{
          "artifacts": [
            {"name": "nano/embed_b1_s16", "path": "hlo/nano/embed_b1_s16.hlo.txt",
             "kind": "embed", "model": "nano", "batch": 1, "seq": 16,
             "inputs": [{"shape": [1, 16], "dtype": "int32"}],
             "outputs": [{"shape": [1, 16, 128], "dtype": "float32"}]},
            {"name": "nano/attn_tp2_b1_s16", "path": "x", "kind": "attn",
             "model": "nano", "tp": 2, "batch": 1, "seq": 16,
             "inputs": [], "outputs": []},
            {"name": "nano/attn_tp2_b1_s64", "path": "x", "kind": "attn",
             "model": "nano", "tp": 2, "batch": 1, "seq": 64,
             "inputs": [], "outputs": []}
          ],
          "seq_buckets": [1, 16, 64], "batch_buckets": [1, 8], "tp_degrees": [1, 2]
        }"#;
        Manifest::from_json(Json::parse(doc).unwrap()).unwrap()
    }

    #[test]
    fn lookup_by_name_and_stage() {
        let m = sample();
        assert!(m.by_name("nano/embed_b1_s16").is_some());
        let a = m.stage("nano", "attn", 2, 1, 64, None).unwrap();
        assert_eq!(a.name, "nano/attn_tp2_b1_s64");
        assert!(m.stage("nano", "attn", 4, 1, 64, None).is_none());
    }

    #[test]
    fn bucket_selection() {
        let m = sample();
        assert_eq!(m.seq_bucket_for("nano", "attn", 2, 1, 10), Some(16));
        assert_eq!(m.seq_bucket_for("nano", "attn", 2, 1, 17), Some(64));
        assert_eq!(m.seq_bucket_for("nano", "attn", 2, 1, 65), None);
        // embed has tp=0 (degree-independent)
        assert_eq!(m.seq_bucket_for("nano", "embed", 2, 1, 5), Some(16));
    }

    #[test]
    fn io_specs_parsed() {
        let m = sample();
        let e = m.by_name("nano/embed_b1_s16").unwrap();
        assert_eq!(e.inputs[0].shape, vec![1, 16]);
        assert_eq!(e.outputs[0].shape, vec![1, 16, 128]);
        assert_eq!(e.outputs[0].dtype, "float32");
    }
}
