//! tpcc CLI — leader entrypoint for the serving stack and the paper's
//! experiment drivers.
//!
//! Commands:
//!   serve   --model micro --tp 2 --compress fp4_e2m1_b32_e8m0 --addr 127.0.0.1:8080
//!           [--log-level debug|info|warn|error] [--log-json]
//!   top     --addr 127.0.0.1:8080 [--once] [--interval S]
//!           (terminal dashboard over /metrics, /alerts, /logs)
//!   gen     --model micro --tp 2 --prompt "..." [--max-tokens 48]
//!   eval    --model small --tp 2 --compress <spec> [--split test] [--tokens 4096]
//!   table1|table2|table3|table4|table5   (regenerate a paper table)
//!   table6  (selective-compression ablation: uniform vs paper vs auto)
//!   table7  (serving under load: capacity at a TTFT SLO per policy)
//!   load    --model micro --tp 2 --arrival poisson:4 --requests 32 [--policy ...]
//!           [--explain]  (append the flight-recorder attribution table)
//!   explain --addr 127.0.0.1:8080   (p50-vs-tail attribution from a
//!           running server's GET /debug/requests; without --addr,
//!           drives an inline load first — same flags as `load`)
//!   bench   (rank-runtime perf snapshot; --json BENCH_rankpar.json)
//!   bench --codec   (codec roofline; --json BENCH_codec.json)
//!   golden --emit   (regenerate rust/tests/golden_codec.json)
//!   trace   --model micro --tp 2 [--requests 4] [--out trace.json]
//!           (run requests with the span recorder on, export
//!            Chrome-trace JSON for Perfetto / chrome://tracing)
//!   info    (artifact + model inventory)
//!
//! `--policy` selects per-site compression (see `rust/src/policy/`):
//! `uniform:<scheme>`, `paper`, `auto[:budget_pct]`, or a rule string
//! such as `"mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0]=none;decode=none"`.
//!
//! `--rank-threads off|auto|N` selects the execution core: worker
//! threads per TP rank (the default, `auto`) or the sequential
//! reference path (`off`). `RANK_THREADS` sets the session default.

use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest, Sampling};
use tpcc::model::weights::Weights;
use tpcc::obs::log::{cli as log_cli, Level};
use tpcc::util::json;
use tpcc::runtime::Runtime;
use tpcc::server::Server;
use tpcc::tables::{common, table1, table2, table3, table4, table5, table6, table7};
use tpcc::tp::{EngineOptions, RankThreads, TpEngine};
use tpcc::util::cli::Args;
use tpcc::workload::{self, Arrival, DriveOptions, LenDist, LoadShape, SloSpec, Trace, TraceSpec};

fn main() {
    if let Err(e) = run() {
        log_cli(Level::Error, "command failed", vec![("err", json::s(&format!("{e:#}")))]);
        std::process::exit(1);
    }
}

/// Resolve `--rank-threads` (falling back to the `RANK_THREADS` env
/// default baked into [`EngineOptions::new`]).
fn rank_threads_arg(args: &Args) -> anyhow::Result<RankThreads> {
    match args.get("rank-threads") {
        Some(v) => RankThreads::parse(v),
        None => Ok(RankThreads::from_env()),
    }
}

/// Continuous-batching knobs shared by `serve` and `load`:
/// `--decode-batch`, `--max-batch-tokens` (per-step admission token
/// budget), `--kv-block` (paged-KV block size in tokens) and
/// `--kv-pool` (total KV blocks per rank shard; small pools force
/// preemption — useful for stress runs).
fn batcher_opts(args: &Args) -> anyhow::Result<CoordinatorOptions> {
    let base = CoordinatorOptions::default();
    let kv_pool_blocks = match args.get("kv-pool") {
        Some(v) => Some(v.parse::<usize>().map_err(|_| {
            anyhow::anyhow!("--kv-pool: expected a block count, got {v:?}")
        })?),
        None => None,
    };
    Ok(CoordinatorOptions {
        decode_batch: args.get_usize("decode-batch", base.decode_batch),
        max_batch_tokens: args.get_usize("max-batch-tokens", base.max_batch_tokens),
        kv_block: args.get_usize("kv-block", base.kv_block),
        kv_pool_blocks,
        ..base
    })
}

fn build_engine(args: &Args) -> anyhow::Result<TpEngine> {
    let model = args.get_or("model", "micro").to_string();
    let tp = args.get_usize("tp", 2);
    let compress = args.get_or("compress", "none").to_string();
    let policy = args.get_or("policy", "").to_string();
    let profile = args.get_or("profile", "cpu").to_string();
    let algo = args.get_or("algo", "auto").to_string();
    let rank_threads = rank_threads_arg(args)?;
    let root = common::artifacts_root()?;
    let rt = Runtime::load(&root)?;
    let weights = Weights::load(&root.join("weights").join(&model))?;
    let opts = EngineOptions::new(&model, tp)
        .with_compress(&compress)
        .with_policy(&policy)
        .with_profile(&profile)
        .with_algo(&algo)
        .with_rank_threads(rank_threads);
    TpEngine::new(rt, &weights, opts)
}

/// Print the flight-recorder attribution table (`tpcc explain`).
fn print_explain(records: &[tpcc::obs::flight::RequestRecord]) {
    match tpcc::obs::flight::attribution(records) {
        Some(a) => print!("{}", tpcc::obs::flight::render_attribution(&a)),
        None => println!("explain: need at least two completed requests to attribute"),
    }
}

/// The `load` command body (also `explain` without `--addr`): drive a
/// trace through a fresh coordinator, print the load report, and — when
/// `explain` — the flight-recorder attribution table.
fn run_load(args: &Args, explain: bool) -> anyhow::Result<()> {
    // trace: replayed from --trace FILE, or generated from
    // --arrival/--prompt-len/--output-len/--requests/--seed
    let trace = match args.get("trace") {
        Some(path) => Trace::parse_jsonl(&std::fs::read_to_string(path)?)?,
        None => {
            let spec = TraceSpec {
                arrival: Arrival::parse(args.get_or("arrival", "poisson:4"))?,
                prompt_len: LenDist::parse(args.get_or("prompt-len", "sharegpt"))?,
                output_len: LenDist::parse(args.get_or("output-len", "lognormal:16:0.7:64"))?,
                requests: args.get_usize("requests", 32),
                seed: args.get_usize("seed", 42) as u64,
            };
            spec.generate()
        }
    };
    if let Some(path) = args.get("save-trace") {
        std::fs::write(path, trace.to_jsonl())?;
        println!("trace saved to {path} ({} events)", trace.events.len());
    }
    let slo_ttft_s = args.get_f64("slo-ttft", 0.25);
    let args2 = args.clone();
    let copts = CoordinatorOptions {
        drift_fallback: args.has("drift-fallback"),
        ..batcher_opts(args)?
    };
    let (handle, join) = spawn(move || build_engine(&args2), copts)?;
    handle.metrics.set_ttft_slo(slo_ttft_s);
    println!(
        "tpcc load: {} requests, {} events span {:.1}s",
        trace.events.len(),
        if trace.closed_loop.is_some() { "closed-loop" } else { "open-loop" },
        trace.span_s()
    );
    let report = workload::drive(&handle, &trace, &DriveOptions { slo_ttft_s });
    report.publish(&handle.metrics);
    report.print("load");
    // --metrics-out FILE: dump the full metric registry (the same JSON
    // GET /metrics serves) so scripts can assert on counters like
    // preemptions_total without standing up the HTTP server
    if let Some(path) = args.get("metrics-out") {
        let mut body = handle.metrics.to_json().to_string();
        body.push('\n');
        std::fs::write(path, body)?;
        println!("metrics written to {path}");
    }
    if explain {
        let records: Vec<_> =
            handle.flight.records().iter().map(|r| (**r).clone()).collect();
        print_explain(&records);
    }
    handle.shutdown();
    drop(handle);
    join.join().unwrap()?;
    Ok(())
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => {
            let addr = args.get_or("addr", "127.0.0.1:8080").to_string();
            let model = args.get_or("model", "micro").to_string();
            let tp = args.get_usize("tp", 2);
            let compress = args.get_or("compress", "none").to_string();
            let policy = args.get_or("policy", "").to_string();
            let profile = args.get_or("profile", "cpu").to_string();
            let algo = args.get_or("algo", "auto").to_string();
            let rank_threads = rank_threads_arg(&args)?;
            let copts = CoordinatorOptions {
                sampling: if args.has("greedy") {
                    Sampling::Greedy
                } else {
                    Sampling::Temperature { t: 0.8, top_k: 40 }
                },
                // span recorder on by default so GET /trace has data;
                // --no-trace turns it off (sub-5% overhead, but zero is
                // zero)
                trace: !args.has("no-trace"),
                // --drift-fallback: auto-rebind sites the error
                // sentinel trips to the never-worse `none` scheme
                drift_fallback: args.has("drift-fallback"),
                ..batcher_opts(&args)?
            };
            let (handle, _join) = spawn(
                move || {
                    let root = common::artifacts_root()?;
                    let rt = Runtime::load(&root)?;
                    let weights = Weights::load(&root.join("weights").join(&model))?;
                    TpEngine::new(
                        rt,
                        &weights,
                        EngineOptions::new(&model, tp)
                            .with_compress(&compress)
                            .with_policy(&policy)
                            .with_profile(&profile)
                            .with_algo(&algo)
                            .with_rank_threads(rank_threads),
                    )
                },
                copts,
            )?;
            // goodput on /metrics is measured against this TTFT SLO
            handle.metrics.set_ttft_slo(args.get_f64("slo-ttft", 0.25));
            // stderr log sink: warn-and-above by default so shed/drift/
            // alert events reach the terminal without access-log noise;
            // --log-level opens it up, --log-json emits JSON lines
            let stderr_level = match args.get("log-level") {
                Some(v) => Some(tpcc::obs::log::Level::parse(v).ok_or_else(|| {
                    anyhow::anyhow!("--log-level: expected debug|info|warn|error, got {v:?}")
                })?),
                None => Some(tpcc::obs::log::Level::Warn),
            };
            handle.log.set_stderr(stderr_level, args.has("log-json"));
            let server = Server::bind(&addr, handle)?;
            println!(
                "tpcc serving on http://{addr}  (POST /generate [\"stream\":true for NDJSON], \
                 GET /metrics[?format=prom], GET /metrics/history, GET /debug/requests, \
                 GET /policy, GET /trace, GET /logs, GET /alerts)"
            );
            server.serve_forever()
        }
        "top" => {
            // operator dashboard against a running server; --once is
            // the non-interactive single-frame mode CI exercises
            let addr = args.get_or("addr", "127.0.0.1:8080").to_string();
            tpcc::obs::top::run(&addr, args.has("once"), args.get_f64("interval", 2.0))
        }
        "load" => run_load(&args, args.has("explain")),
        "explain" => {
            // p50-vs-tail attribution table from a flight-recorder
            // dump: a running server's (`--addr HOST:PORT` hits its
            // GET /debug/requests), or an inline load driven right
            // here (same flags as `load`)
            if let Some(addr) = args.get("addr") {
                let (status, body) = tpcc::server::http_get(addr, "/debug/requests")?;
                anyhow::ensure!(status == 200, "GET /debug/requests -> HTTP {status}");
                let parsed = tpcc::util::json::Json::parse(&body)?;
                let records = tpcc::obs::flight::records_from_json(&parsed);
                println!(
                    "tpcc explain: {} flight records from http://{addr}/debug/requests",
                    records.len()
                );
                print_explain(&records);
                Ok(())
            } else {
                run_load(&args, true)
            }
        }
        "gen" => {
            let prompt = args.get_or("prompt", "The parish church of ").to_string();
            let max_tokens = args.get_usize("max-tokens", 48);
            let args2 = args.clone();
            let (handle, t) = spawn(
                move || build_engine(&args2),
                CoordinatorOptions::default(),
            )?;
            let resp = handle.generate(GenRequest {
                prompt: prompt.clone(),
                max_new_tokens: max_tokens,
                greedy: true,
                stop_token: -1,
            })?;
            println!("prompt : {prompt}");
            println!("output : {}", resp.text);
            println!(
                "ttft {:.3}s  e2e {:.3}s  tpot {:.1}ms  virtual-prefill {:.4}s",
                resp.ttft_s,
                resp.e2e_s,
                resp.tpot_s * 1e3,
                resp.virtual_prefill_s
            );
            handle.shutdown();
            drop(handle);
            t.join().unwrap()?;
            Ok(())
        }
        "eval" => {
            let split = args.get_or("split", "test");
            let tokens = args.get_usize("tokens", 4096);
            let mut eng = build_engine(&args)?;
            let text = common::corpus(split)?;
            let r = tpcc::eval::perplexity(
                &mut eng,
                &text,
                tpcc::eval::EvalOptions { max_tokens: tokens, ..Default::default() },
            )?;
            println!(
                "model={} tp={} compress={} split={split}: ppl {:.4} over {} tokens ({:.1}s)",
                eng.cfg.name,
                eng.opts.tp,
                eng.compressor_name(),
                r.ppl(),
                r.tokens,
                r.wall_s
            );
            Ok(())
        }
        "table1" => {
            let t = table1::run(common::eval_tokens(4096))?;
            table1::print(&t);
            Ok(())
        }
        "table2" => {
            let rows = table2::run(common::eval_tokens(4096))?;
            table2::print(&rows);
            Ok(())
        }
        "table3" => {
            let rows = table3::run_analytic();
            table3::print(&rows, "analytic, paper-scale");
            let ablation = table3::run_algo_ablation();
            table3::print_algo_ablation(&ablation);
            let live = table3::run_live("l4", 2, 8, 128, args.get_usize("reps", 5), true)?;
            table3::print(&[live], "live micro model on CPU PJRT");
            Ok(())
        }
        "table4" => {
            let t = table4::run(common::eval_tokens(4096))?;
            table4::print(&t);
            Ok(())
        }
        "table5" => {
            let rows = table5::run(common::eval_tokens(2048))?;
            table5::print(&rows);
            Ok(())
        }
        "table6" => {
            let rows = table6::run_analytic()?;
            table6::print(&rows);
            // live section (micro model, real PPL deltas) when artifacts
            // are available; the analytic section needs none
            if common::artifacts_root().is_ok() {
                let live = table6::run_live(common::eval_tokens(2048))?;
                table6::print_live(&live);
            }
            Ok(())
        }
        "table7" => {
            let base = table7::Table7Config::default();
            let cfg = table7::Table7Config {
                slo: SloSpec {
                    ttft_s: args.get_f64("slo-ttft", base.slo.ttft_s),
                    min_goodput: args.get_f64("goodput", base.slo.min_goodput),
                },
                shape: LoadShape {
                    requests: args.get_usize("requests", base.shape.requests),
                    ..base.shape
                },
                iters: args.get_usize("iters", base.iters),
            };
            let rows = table7::run(&cfg)?;
            table7::print(&rows, &cfg);
            Ok(())
        }
        "bench" => {
            // --codec: codec roofline snapshot (fast vs reference
            // GB/s per scheme x block against the memcpy ceiling);
            // --json writes the tracked BENCH_codec.json file. Needs
            // no artifacts — the codec is self-contained.
            if args.has("codec") {
                let budget = args.get_f64("budget", 0.1);
                let rows = tpcc::bench::codec::run(budget);
                tpcc::bench::codec::print(&rows);
                if let Some(path) = args.get("json") {
                    let mut body = tpcc::bench::codec::to_json(&rows).to_string();
                    body.push('\n');
                    std::fs::write(path, body)?;
                    println!("snapshot written to {path}");
                }
                return Ok(());
            }
            // rank-runtime perf snapshot: sequential vs parallel
            // wall-clock TTFT per live config; --json writes the
            // tracked BENCH_rankpar.json trajectory file. The parallel
            // leg defaults to `auto` regardless of RANK_THREADS — the
            // bench exists to compare against the sequential baseline.
            let reps = args.get_usize("reps", 5);
            let rank_threads = match args.get("rank-threads") {
                Some(v) => RankThreads::parse(v)?,
                None => RankThreads::Auto,
            };
            let rows = tpcc::bench::rankpar::run(reps, rank_threads)?;
            tpcc::bench::rankpar::print(&rows);
            if let Some(path) = args.get("json") {
                let mut body = tpcc::bench::rankpar::to_json(&rows, reps).to_string();
                body.push('\n');
                std::fs::write(path, body)?;
                println!("snapshot written to {path}");
            }
            Ok(())
        }
        "trace" => {
            // capture a span timeline: run a few requests through the
            // coordinator with the recorder enabled, then export the
            // merged spans as Chrome-trace JSON (load the file in
            // Perfetto or chrome://tracing; tid = rank, pid = request /
            // forward step)
            let requests = args.get_usize("requests", 4);
            let max_tokens = args.get_usize("max-tokens", 8);
            let prompt = args.get_or("prompt", "The parish church of ").to_string();
            let args2 = args.clone();
            let (handle, join) = spawn(
                move || build_engine(&args2),
                CoordinatorOptions {
                    decode_batch: args.get_usize("decode-batch", 8),
                    trace: true,
                    ..Default::default()
                },
            )?;
            let pending: Vec<_> = (0..requests)
                .map(|i| {
                    handle.submit(GenRequest {
                        prompt: format!("{prompt}{i}"),
                        max_new_tokens: max_tokens,
                        greedy: true,
                        stop_token: -1,
                    })
                })
                .collect();
            for rx in pending {
                let _ = rx.recv();
            }
            let dump = handle.tracer.drain();
            log_cli(
                Level::Info,
                "trace captured",
                vec![
                    ("spans", json::num(dump.spans.len() as f64)),
                    ("dropped", json::num(dump.dropped as f64)),
                    ("requests", json::num(requests as f64)),
                ],
            );
            let mut body = dump.to_chrome_json().to_string();
            body.push('\n');
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &body)?;
                    log_cli(
                        Level::Info,
                        "chrome-trace JSON written",
                        vec![("path", json::s(path))],
                    );
                }
                None => print!("{body}"),
            }
            handle.shutdown();
            drop(handle);
            join.join().unwrap()?;
            Ok(())
        }
        "golden" => {
            // regenerate the committed codec golden vectors
            // (rust/tests/golden_codec.json). The emitter asserts the
            // fast codec's wire bit-identical to the reference on
            // every scheme before writing anything, so a drifted file
            // can never be committed by accident.
            anyhow::ensure!(
                args.has("emit"),
                "golden: pass --emit to regenerate (writes to stdout, or --out PATH)"
            );
            let body = tpcc::mxfmt::golden::emit();
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &body)?;
                    log_cli(
                        Level::Info,
                        "golden vectors written",
                        vec![
                            ("path", json::s(path)),
                            ("n", json::num(tpcc::mxfmt::golden::GOLDEN_N as f64)),
                        ],
                    );
                }
                None => print!("{body}"),
            }
            Ok(())
        }
        "info" => {
            let root = common::artifacts_root()?;
            let rt = Runtime::load(&root)?;
            println!("tpcc {} — artifacts at {}", tpcc::version(), root.display());
            println!("artifacts: {}", rt.manifest.artifacts.len());
            println!("seq buckets: {:?}", rt.manifest.seq_buckets);
            println!("batch buckets: {:?}", rt.manifest.batch_buckets);
            println!("tp degrees: {:?}", rt.manifest.tp_degrees);
            if let Some(models) = rt.manifest.raw.get("models").and_then(|m| m.as_obj()) {
                for (name, m) in models {
                    println!(
                        "model {name}: d={} L={} H={} params={}",
                        m.get("d_model").and_then(|v| v.as_i64()).unwrap_or(0),
                        m.get("n_layers").and_then(|v| v.as_i64()).unwrap_or(0),
                        m.get("n_heads").and_then(|v| v.as_i64()).unwrap_or(0),
                        m.get("params").and_then(|v| v.as_i64()).unwrap_or(0),
                    );
                }
            }
            Ok(())
        }
        _ => {
            println!(
                "tpcc {} — TP communication-compression serving stack\n\
                 commands: serve | top | gen | eval | load | explain | bench | golden | trace | table1..table7 | info\n\
                 common flags: --model nano|micro|small --tp N --compress SPEC\n\
                               --policy uniform:SPEC|paper|auto[:BUDGET%]|RULES\n\
                               --profile l4|a100|2x4l4|2x4a100|cpu\n\
                               --algo auto|ring|recursive_doubling|two_shot|hierarchical\n\
                               --rank-threads off|auto|N (per-rank worker threads; off = sequential)\n\
                 bench flags:  --reps N --json BENCH_rankpar.json\n\
                               --codec [--budget S] --json BENCH_codec.json (codec roofline)\n\
                 golden flags: --emit [--out rust/tests/golden_codec.json]\n\
                 trace flags:  --requests N --max-tokens N --out trace.json (default: stdout)\n\
                 policy rules: \"mlp=fp4_e2m1_b32_e8m0;attn=none;layers[0-1]=none;decode=none\"\n\
                 load flags:   --arrival poisson:R|bursty:R[:CV]|closed:N[:THINK]\n\
                               --prompt-len sharegpt|N|uniform:LO:HI|lognormal:MED:SIG[:CAP]\n\
                               --output-len ... --requests N --seed S --slo-ttft S\n\
                               --trace FILE | --save-trace FILE | --explain | --metrics-out FILE\n\
                 explain flags: --addr HOST:PORT (read a live server) | load flags\n\
                 batch flags (serve|load): --decode-batch N --max-batch-tokens N (admission budget)\n\
                               --kv-block TOKENS --kv-pool BLOCKS (small pool forces preemption)\n\
                 serve flags:  --drift-fallback (sentinel rebinds drifting sites to none)\n\
                               --log-level debug|info|warn|error --log-json (stderr event sink)\n\
                 top flags:    --addr HOST:PORT --once (single frame, no TTY) --interval S",
                tpcc::version()
            );
            Ok(())
        }
    }
}
