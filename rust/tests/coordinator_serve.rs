//! Serving-stack integration: coordinator + continuous batcher + HTTP
//! front end over the real nano engine and AOT artifacts.

use tpcc::coordinator::{spawn, CoordinatorOptions, GenRequest};
use tpcc::model::weights::Weights;
use tpcc::runtime::Runtime;
use tpcc::server::{http_get, http_post, Server};
use tpcc::tp::{EngineOptions, TpEngine};

fn have_artifacts() -> bool {
    tpcc::artifacts_dir().join("manifest.json").exists()
}

fn spawn_nano(
    compress: &'static str,
) -> (tpcc::coordinator::CoordinatorHandle, std::thread::JoinHandle<anyhow::Result<()>>) {
    spawn(
        move || {
            let root = tpcc::artifacts_dir();
            let rt = Runtime::load(&root)?;
            let weights = Weights::load(&root.join("weights/nano"))?;
            TpEngine::new(rt, &weights, EngineOptions::new("nano", 2).with_compress(compress))
        },
        CoordinatorOptions::default(),
    )
    .unwrap()
}

#[test]
fn single_request_roundtrip() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn_nano("none");
    let resp = handle
        .generate(GenRequest {
            prompt: "The river ".into(),
            max_new_tokens: 8,
            greedy: true,
            stop_token: -1,
        })
        .unwrap();
    assert_eq!(resp.new_tokens, 8);
    assert!(resp.ttft_s > 0.0 && resp.e2e_s >= resp.ttft_s);
    assert!(!resp.text.is_empty());
    assert_eq!(handle.metrics.requests_completed.get(), 1);
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}

#[test]
fn concurrent_requests_batch_together() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn_nano("fp4_e2m1_b32_e8m0");
    // submit 6 requests at once: the batcher should prefill them together
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            handle.submit(GenRequest {
                prompt: format!("In {} the parish of ", 1800 + i),
                max_new_tokens: 12,
                greedy: true,
                stop_token: -1,
            })
        })
        .collect();
    let mut texts = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.new_tokens, 12);
        texts.push(resp.text);
    }
    assert_eq!(handle.metrics.requests_completed.get(), 6);
    // compression accounting flowed through the collective
    assert!(handle.metrics.comm_bytes_saved.get() > 0);
    // batching actually happened: far fewer engine batches than
    // sequential execution would need (6 prefills + 6*12 decodes)
    let batches = handle.metrics.batches_executed.get();
    assert!(batches < 40, "batches={batches} suggests no batching");
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}

#[test]
fn decode_matches_between_compressed_and_not_roughly() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    // greedy generations from the same prompt should agree for the
    // first few tokens at FP5 fidelity (sanity that compression is not
    // destroying the model inside the serving path)
    let (h1, j1) = spawn_nano("none");
    let (h2, j2) = spawn_nano("fp5_e2m2_b8_e8m0");
    let req = GenRequest {
        prompt: " = Eastvale = ".into(),
        max_new_tokens: 6,
        greedy: true,
        stop_token: -1,
    };
    let a = h1.generate(req.clone()).unwrap();
    let b = h2.generate(req).unwrap();
    let common_prefix = a
        .text
        .bytes()
        .zip(b.text.bytes())
        .take_while(|(x, y)| x == y)
        .count();
    assert!(
        common_prefix >= 3,
        "compressed generation diverged immediately: {:?} vs {:?}",
        a.text,
        b.text
    );
    for (h, j) in [(h1, j1), (h2, j2)] {
        h.shutdown();
        drop(h);
        j.join().unwrap().unwrap();
    }
}

#[test]
fn http_server_generate_and_metrics() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn_nano("none");
    let server = Server::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(3).unwrap());

    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));

    let (code, body) = http_post(
        &addr,
        "/generate",
        r#"{"prompt": "The weekly market ", "max_tokens": 5, "greedy": true}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let doc = tpcc::util::json::Json::parse(&body).unwrap();
    assert_eq!(doc.get("new_tokens").unwrap().as_i64(), Some(5));
    assert!(doc.get("ttft_s").unwrap().as_f64().unwrap() > 0.0);

    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let m = tpcc::util::json::Json::parse(&body).unwrap();
    assert_eq!(m.get("requests_completed").unwrap().as_i64(), Some(1));
    // the collective engine publishes per-algorithm counters
    let algo_calls: f64 = ["ring", "recursive_doubling", "two_shot", "hierarchical"]
        .iter()
        .filter_map(|a| m.get(&format!("collective_calls_{a}")))
        .filter_map(|v| v.as_f64())
        .sum();
    assert!(algo_calls > 0.0, "no per-algorithm collective counters in /metrics: {body}");

    srv.join().unwrap();
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}

/// Streaming `/generate` against the real engine: token lines must be
/// on the wire while the engine is still decoding, not replayed after
/// completion, and the final line carries the full response summary.
#[test]
fn streaming_generate_emits_tokens_before_completion() {
    use std::time::{Duration, Instant};

    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn_nano("none");
    let server = Server::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(1).unwrap());

    let mut stamps: Vec<Instant> = Vec::new();
    let (code, chunks) = tpcc::server::http_post_stream(
        &addr,
        "/generate",
        r#"{"prompt": "The abbey of ", "max_tokens": 24, "greedy": true, "stream": true}"#,
        |_| stamps.push(Instant::now()),
    )
    .unwrap();
    assert_eq!(code, 200);
    assert_eq!(chunks.len(), 25, "24 token lines + 1 done line: {chunks:?}");
    let first = tpcc::util::json::Json::parse(chunks[0].trim()).unwrap();
    assert_eq!(first.get("index").unwrap().as_i64(), Some(0));
    assert!(first.get("done").is_none());
    let last = tpcc::util::json::Json::parse(chunks.last().unwrap().trim()).unwrap();
    assert_eq!(last.get("done").unwrap().as_bool(), Some(true));
    assert_eq!(last.get("new_tokens").unwrap().as_i64(), Some(24));
    let ttft = last.get("ttft_s").unwrap().as_f64().unwrap();
    let e2e = last.get("e2e_s").unwrap().as_f64().unwrap();
    assert!(ttft > 0.0 && ttft < e2e, "ttft {ttft} vs e2e {e2e}");
    // the whole point of streaming: the first token led the done line by
    // real decode time, not by the microseconds of draining a buffer
    let lead = stamps.last().unwrap().duration_since(stamps[0]);
    assert!(lead >= Duration::from_millis(2), "stream arrived all at once (lead {lead:?})");
    // the streaming path still feeds per-request accounting
    assert_eq!(handle.metrics.requests_completed.get(), 1);

    srv.join().unwrap();
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}

#[test]
fn http_server_rejects_malformed_requests_with_400_and_404() {
    use std::io::{Read as _, Write as _};

    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let (handle, join) = spawn_nano("none");
    let server = Server::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.serve_n(4).unwrap());

    // invalid JSON body -> 400, connection answered rather than dropped
    let (code, body) = http_post(&addr, "/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("error"));

    // JSON without a prompt -> 400
    let (code, body) = http_post(&addr, "/generate", r#"{"max_tokens": 4}"#).unwrap();
    assert_eq!(code, 400, "{body}");

    // unknown path -> 404
    let (code, body) = http_get(&addr, "/nope").unwrap();
    assert_eq!(code, 404, "{body}");

    // garbage that is not HTTP at all -> 400, not a dropped connection
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.write_all(b"\r\n").unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 400"), "got {raw:?}");

    srv.join().unwrap();
    handle.shutdown();
    drop(handle);
    join.join().unwrap().unwrap();
}
